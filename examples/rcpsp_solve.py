"""End-to-end driver for the paper's experiment: solve an RCPSP suite
with the TURBO-style batched engine (one `Solver` session — compilation
is paid once and amortized over the whole suite), cross-check against
the sequential baseline, and ground-verify every solution (paper Table 1
workflow).

  PYTHONPATH=src python examples/rcpsp_solve.py [--n 10] [--count 5]
  PYTHONPATH=src python examples/rcpsp_solve.py --file path/to/file.rcp
"""

import argparse

from repro import solver
from repro.core import baseline
from repro.core.backend import available_backends
from repro.core.models import rcpsp


def solve_one(sess, inst, timeout):
    m, h = rcpsp.build_model(inst)
    cm = m.compile()
    par = sess.solve(cm)
    seq = baseline.SequentialSolver(cm, sess.config.search_options()) \
        .solve(timeout_s=timeout)
    line = (f"{inst.name:24s} turbo-jax: {par.status:8s} mk={par.objective} "
            f"nodes={par.n_nodes:6d} {par.wall_s:6.1f}s | "
            f"seq: {seq.status:8s} mk={seq.objective} "
            f"nodes={seq.n_nodes:6d} {seq.wall_s:6.1f}s")
    if par.solution is not None:
        s_idx = [v.idx for v in h["s"]]
        ok, mk = rcpsp.check_solution(inst, par.solution[s_idx])
        line += f" | ground-check {'OK' if ok and mk == par.objective else 'FAIL'}"
    if par.objective is not None and seq.objective is not None:
        assert par.status != "OPTIMAL" or seq.status != "OPTIMAL" or \
            par.objective == seq.objective, "solvers disagree!"
    print(line)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8, help="tasks per instance")
    ap.add_argument("--count", type=int, default=4)
    ap.add_argument("--resources", type=int, default=4)
    ap.add_argument("--lanes", type=int, default=16)
    ap.add_argument("--subs", type=int, default=64)
    ap.add_argument("--timeout", type=float, default=60)
    ap.add_argument("--file", default=None,
                    help="Patterson .rcp or PSPLIB .sm file")
    ap.add_argument("--backend", default="gather",
                    choices=available_backends(),
                    help="propagation backend (core/backend.py)")
    args = ap.parse_args()

    sess = solver.Solver(solver.SolveConfig.preset(
        "prove", n_lanes=args.lanes, eps_target=args.subs,
        timeout_s=args.timeout, backend=args.backend))
    if args.file:
        inst = (rcpsp.parse_psplib_sm(args.file)
                if args.file.endswith(".sm")
                else rcpsp.parse_patterson(args.file))
        solve_one(sess, inst, args.timeout)
        return
    for seed in range(args.count):
        inst = rcpsp.generate(args.n, n_resources=args.resources, seed=seed)
        solve_one(sess, inst, args.timeout)
    stats = sess.session_stats()
    print(f"session: {stats['solves']} solves, {stats['n_compiles']} "
          f"compiles ({stats['compile_s']:.1f}s), "
          f"{stats['runner_hits']} cache hits")


if __name__ == "__main__":
    main()
