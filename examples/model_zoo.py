"""Model zoo driver: solve every workload (DESIGN.md §10) through the
EPS-decomposed engine and ground-check the solutions.

  PYTHONPATH=src python examples/model_zoo.py                 # all models
  PYTHONPATH=src python examples/model_zoo.py --model nqueens \
      --backend pallas --eps-target 32
"""

import argparse
import time

from repro.core import engine
from repro.core import models as zoo
from repro.core import search as S
from repro.core.backend import available_backends


def solve_one(name, args):
    mod = zoo.ZOO[name]
    inst = (zoo.bench_instance(name, seed=args.seed) if args.bench
            else zoo.small_instance(name, seed=args.seed))
    m, h = mod.build_model(inst)
    cm = m.compile()
    opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=512,
                           backend=args.backend)
    t0 = time.time()
    res = engine.solve(cm, n_lanes=args.lanes, eps_target=args.eps_target,
                       opts=opts, timeout_s=args.timeout)
    line = (f"{inst.name:24s} {res.status:8s} obj={res.objective} "
            f"nodes={res.n_nodes:6d} ({res.nodes_per_sec:7.0f}/s) "
            f"supersteps={res.n_supersteps:5d} {time.time() - t0:5.1f}s")
    checked = zoo.ground_check(mod, inst, h, res)
    if checked is not None:
        line += f" | ground-check {'OK' if checked else 'FAIL'}"
    print(line)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all",
                    choices=["all"] + sorted(zoo.ZOO))
    ap.add_argument("--backend", default="gather",
                    choices=available_backends())
    ap.add_argument("--lanes", type=int, default=16)
    ap.add_argument("--eps-target", type=int, default=64,
                    help="EPS pool size (DESIGN.md §9); 1 = single root")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=60)
    ap.add_argument("--bench", action="store_true",
                    help="larger benchmark-tier instances")
    args = ap.parse_args()

    names = sorted(zoo.ZOO) if args.model == "all" else [args.model]
    for name in names:
        solve_one(name, args)


if __name__ == "__main__":
    main()
