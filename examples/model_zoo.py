"""Model zoo driver: solve every workload (DESIGN.md §10) through one
`Solver` session and ground-check the solutions.

  PYTHONPATH=src python examples/model_zoo.py                 # all models
  PYTHONPATH=src python examples/model_zoo.py --model nqueens \
      --backend pallas --eps-target 32
  PYTHONPATH=src python examples/model_zoo.py --model knapsack --many 4
"""

import argparse
import time

from repro import solver
from repro.core import models as zoo
from repro.core.backend import available_backends


def solve_one(sess, name, args):
    mod = zoo.ZOO[name]
    inst = (zoo.bench_instance(name, seed=args.seed) if args.bench
            else zoo.small_instance(name, seed=args.seed))
    m, h = mod.build_model(inst)
    cm = m.compile()
    t0 = time.time()
    res = sess.solve(cm)
    line = (f"{inst.name:24s} {res.status:8s} obj={res.objective} "
            f"nodes={res.n_nodes:6d} ({res.nodes_per_sec:7.0f}/s) "
            f"supersteps={res.n_supersteps:5d} {time.time() - t0:5.1f}s")
    checked = zoo.ground_check(mod, inst, h, res)
    if checked is not None:
        line += f" | ground-check {'OK' if checked else 'FAIL'}"
    print(line)


def solve_many_demo(sess, name, count, args):
    """The throughput path: `count` same-shape instances of one model in
    a single batched device dispatch (DESIGN.md §11)."""
    mod = zoo.ZOO[name]
    insts = [(zoo.bench_instance(name, seed=args.seed + k) if args.bench
              else zoo.small_instance(name, seed=args.seed + k))
             for k in range(count)]
    built = [mod.build_model(i) for i in insts]
    cms = [m.compile() for m, _ in built]
    t0 = time.time()
    results = sess.solve_many(cms)
    wall = time.time() - t0
    for inst, (m, h), res in zip(insts, built, results):
        checked = zoo.ground_check(mod, inst, h, res)
        print(f"{inst.name:24s} {res.status:8s} obj={res.objective} "
              f"| ground-check {'OK' if checked else checked}")
    print(f"solve_many: {count} instances in {wall:.1f}s "
          f"({count / max(wall, 1e-9):.1f} instances/s, one dispatch)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all",
                    choices=["all"] + sorted(zoo.ZOO))
    ap.add_argument("--backend", default="gather",
                    choices=available_backends())
    ap.add_argument("--lanes", type=int, default=16)
    ap.add_argument("--eps-target", type=int, default=64,
                    help="EPS pool size (DESIGN.md §9); 1 = single root")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=60)
    ap.add_argument("--bench", action="store_true",
                    help="larger benchmark-tier instances")
    ap.add_argument("--many", type=int, default=None, metavar="N",
                    help="solve N same-shape instances of --model in one "
                         "batched dispatch (solve_many; needs --model)")
    args = ap.parse_args()

    sess = solver.Solver(solver.SolveConfig.preset(
        "prove", n_lanes=args.lanes, eps_target=args.eps_target,
        timeout_s=args.timeout, backend=args.backend, max_depth=512))
    if args.many:
        if args.model == "all":
            ap.error("--many needs a specific --model (same-shape batch)")
        solve_many_demo(sess, args.model, args.many, args)
        return
    names = sorted(zoo.ZOO) if args.model == "all" else [args.model]
    for name in names:
        solve_one(sess, name, args)


if __name__ == "__main__":
    main()
