"""The paper's solver as the framework's planning engine: partition
llama3-8b layers into pipeline stages under a memory cap, then schedule
microbatch rounds as an RCPSP (DESIGN.md §3).

  PYTHONPATH=src python examples/planner_demo.py
"""

import numpy as np

from repro import configs
from repro.distributed import planner
from repro.nn import model as MD


def main():
    cfg = configs.get("llama3-8b")
    # per-layer cost proxy = params (uniform here); pretend layer 0 and
    # the last layer are heavier (embedding/unembedding co-located)
    L = 8                                # plan at 8-superlayer granularity
    costs = [10] * L
    costs[0] += 6                        # embed
    costs[-1] += 9                       # unembed + loss
    mems = [4] * L
    mems[0] += 2
    mems[-1] += 3

    stages, T = planner.plan_partition(costs, mems, n_stages=4, mem_cap=12,
                                       timeout_s=120)
    print(f"layer→stage: {stages}   bottleneck cost: {T}")
    for k in range(4):
        members = [i for i, s in enumerate(stages) if s == k]
        print(f"  stage {k}: layers {members} "
              f"cost={sum(costs[i] for i in members)} "
              f"mem={sum(mems[i] for i in members)}")

    stage_costs = [sum(costs[i] for i, s in enumerate(stages) if s == k)
                   for k in range(4)]
    starts, mk, res = planner.schedule_microbatches(stage_costs, 4,
                                                    timeout_s=120)
    eff = planner.pipeline_efficiency(stage_costs, mk, 4)
    print(f"\nmicrobatch schedule ({res.status}): makespan={mk} "
          f"efficiency={eff:.2%}")
    horizon = mk
    for mb, row in enumerate(starts):
        lane = [" "] * horizon
        for st, t in enumerate(row):
            for u in range(stage_costs[st]):
                lane[t + u] = str(st)
        print(f"  mb{mb}: {''.join(lane)}")


if __name__ == "__main__":
    main()
