"""Quickstart: model a problem in PCCP, solve it, check the paper's
determinism guarantee.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro import solver
from repro.core.model import Model
from repro.core.backend import available_backends, get_backend
from repro.core.fixpoint import fixpoint, sequential_fixpoint


def main():
    # -- a tiny scheduling model (paper §PCCP, in miniature) --------------
    m = Model("quickstart")
    # three jobs with durations 3, 4, 2 on one machine (disjunctive),
    # minimize the makespan
    d = [3, 4, 2]
    s = [m.int_var(0, 20, f"s{i}") for i in range(3)]
    mk = m.int_var(0, 30, "makespan")
    for i in range(3):
        m.add(s[i] + d[i] <= mk)
        for j in range(i + 1, 3):
            # i before j OR j before i (reified disjunction)
            bij = m.reify(s[i] + d[i] <= s[j], f"b{i}{j}")
            bji = m.reify(s[j] + d[j] <= s[i], f"b{j}{i}")
            m.add(bij + bji >= 1)
    m.minimize(mk)
    m.branch_on(s + [mk])
    cm = m.compile()

    # -- parallel == sequential fixpoint (Prop. 3) -------------------------
    lb_p, ub_p, it, _ = fixpoint(cm, cm.lb0, cm.ub0, stop_on_fail=False)
    lb_s, ub_s = sequential_fixpoint(cm, cm.lb0, cm.ub0)
    same = bool(jnp.all(lb_p == jnp.asarray(lb_s))
                & jnp.all(ub_p == jnp.asarray(ub_s)))
    print(f"parallel sweep fixpoint in {it} sweeps; "
          f"== sequential chaotic iteration: {same}")

    # -- every propagation backend computes the same fixpoint --------------
    lbs = jnp.tile(cm.lb0[None], (4, 1))
    ubs = jnp.tile(cm.ub0[None], (4, 1))
    stores = {name: get_backend(name).fixpoint_batch(cm, lbs, ubs)[:2]
              for name in available_backends()}
    ref = stores["gather"]
    agree = all(bool(jnp.all(l == ref[0]) & jnp.all(u == ref[1]))
                for l, u in stores.values())
    print(f"backends {available_backends()} agree on the batched "
          f"fixpoint: {agree}")

    # -- solve through the session API (DESIGN.md §11): a SolveConfig
    #    consolidates lanes / EPS / backend / strategy (eps_target=32
    #    decomposes the root into ~32 subproblems that seed and replenish
    #    the 8 lanes, DESIGN.md §9; backend="pallas" would swap in the
    #    VMEM kernel), and the Solver session caches the compiled runner
    #    so a second same-shape solve skips jit entirely ------------------
    sess = solver.Solver(solver.SolveConfig(n_lanes=8, eps_target=32,
                                            backend="gather"))
    res = sess.solve(cm)
    print(f"status={res.status} makespan={res.objective} "
          f"nodes={res.n_nodes} ({res.nodes_per_sec:.0f} nodes/s)")
    starts = [int(res.solution[v.idx]) for v in s]
    print("starts:", starts)
    assert res.objective == sum(d)       # one machine => serial schedule

    # -- warm path: same shapes, no recompilation -------------------------
    res2 = sess.solve(cm)
    stats = sess.session_stats()
    print(f"warm solve: {res2.wall_s*1e3:.0f}ms (cold {res.wall_s:.1f}s), "
          f"{stats['n_compiles']} compile for {stats['solves']} solves")
    assert res2.objective == res.objective
    assert stats["n_compiles"] == 1


if __name__ == "__main__":
    main()
