"""End-to-end training driver: ~100M-param llama-family model, a few
hundred steps on the deterministic synthetic pipeline, with
checkpoint/resume.  (Scaled-down seq/batch so a few hundred steps fit a
CPU container; on real hardware pass --seq 4096 --global-batch 256.)

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 40 --preset 25m
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLM
from repro.ft.fault_tolerance import TrainSupervisor
from repro.nn import model as MD
from repro.nn.layers import init_params
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import train_step

PRESETS = {
    # ~104M params: llama3 family, reduced dims
    "100m": dict(n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
                 d_ff=2560, vocab=32768, head_dim=64),
    # ~25M: fast CI-scale variant
    "25m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=3,
                d_ff=1536, vocab=16384, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    base = configs.get("llama3-8b")
    cfg = dataclasses.replace(base, name=f"llama-{args.preset}",
                              **PRESETS[args.preset])
    data = SyntheticLM(cfg, args.seq, args.global_batch, seed=0)
    params = init_params(MD.param_specs(cfg), jax.random.PRNGKey(0))
    n = sum(int(np.prod(v.shape)) for v in params.values())
    print(f"model {cfg.name}: {n/1e6:.1f}M params, seq={args.seq}, "
          f"global_batch={args.global_batch}, steps={args.steps}")

    opt = init_opt_state(params)
    ocfg = OptConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                     total_steps=args.steps, schedule="cosine")
    jstep = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, ocfg,
                                               remat=True, chunks=(256, 256)))
    t0 = time.time()
    hist = []

    def step_fn(params, opt_state, step):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, m = jstep(params, opt_state, batch)
        loss = float(m["loss"])
        hist.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = (step + 1) * args.seq * args.global_batch / \
                (time.time() - t0)
            print(f"step {step:4d}  loss {loss:7.4f}  "
                  f"lr {float(m['lr']):.2e}  {tok_s:,.0f} tok/s")
        return params, opt_state, m

    sup = TrainSupervisor(Checkpointer(args.ckpt_dir),
                          ckpt_every=max(args.steps // 4, 10))
    sup.run(params, opt, step_fn, args.steps)
    print(f"done in {time.time()-t0:.0f}s; "
          f"loss {hist[0]:.4f} -> {min(hist[-10:]):.4f} "
          f"(uniform = {np.log(cfg.vocab):.3f})")


if __name__ == "__main__":
    main()
