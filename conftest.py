"""Repo-root conftest: puts the repo root on sys.path so tests can import
the `benchmarks` package (`PYTHONPATH=src pytest tests/` covers `repro`).

Deliberately does NOT set the multi-device XLA flag in this process —
smoke tests and benches must see 1 device; multi-device tests go through
the `fake_devices` fixture below, which runs their payload in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the flag only takes effect before jax initializes).
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "tests"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (full-mesh dry-runs etc.); deselect with "
        "-m 'not slow'")
    config.addinivalue_line(
        "markers",
        "large: scale-tier test (solves large_instance models end-to-end; "
        "minutes, not seconds); skipped unless REPRO_RUN_LARGE=1 so "
        "tier-1 stays fast")
    # the engine.solve shim's DeprecationWarning is an *error* suite-wide:
    # internal callers must use Solver sessions (tests/util.solve_session);
    # the shim tests in tests/test_api.py opt back in via catch_warnings
    config.addinivalue_line(
        "filterwarnings", "error:engine.solve is deprecated")


def pytest_collection_modifyitems(config, items):
    if os.environ.get("REPRO_RUN_LARGE"):
        return
    skip_large = pytest.mark.skip(
        reason="scale-tier test; set REPRO_RUN_LARGE=1 to run")
    for item in items:
        if "large" in item.keywords:
            item.add_marker(skip_large)


@pytest.fixture(scope="session")
def fake_devices():
    """Runner for multi-device CPU tests: ``fake_devices(code)`` executes
    ``code`` in a subprocess that sees 8 fake host devices and returns
    its stdout.  Skips the test cleanly when this JAX build ignores the
    forced-host-device-count flag (tests/util.py probes once per
    session)."""
    import util

    if not util.can_fake_devices(8):
        pytest.skip("jax build cannot fake host devices "
                    "(--xla_force_host_platform_device_count ignored)")
    return util.run_fake_devices
