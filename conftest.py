"""Repo-root conftest: puts the repo root on sys.path so tests can import
the `benchmarks` package (`PYTHONPATH=src pytest tests/` covers `repro`).

Deliberately does NOT set the 512-device XLA flag — smoke tests and
benches must see 1 device; dry-run tests spawn subprocesses with their
own flags (see tests/test_dryrun.py).
"""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (full-mesh dry-runs etc.); deselect with "
        "-m 'not slow'")
