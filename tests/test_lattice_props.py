"""Property-based lattice/fixpoint tests (DESIGN.md §2, §14).

The algebraic laws the whole engine rests on — stores form a lattice,
sweeps are monotone (extensive) maps on it, and the fixpoint is the
least fixed point, hence idempotent — checked on randomized inputs.

Runs in two modes: under `hypothesis` when the environment has it
(requirements-test.txt lists it), and always under a seeded-numpy
fallback driving the same property functions, so the laws are exercised
on CI images without the package too.
"""

import numpy as np
import pytest

from repro.core import fixpoint as fp
from repro.core.lattice import np_iz_join
from util import random_model, random_substores

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

SEEDS = [0, 1, 2, 3, 4]


def _stores(seed: int, n: int = 8):
    rng = np.random.default_rng(seed)
    cm = random_model(rng).compile()
    lbs, ubs = random_substores(rng, cm, n)
    return cm, lbs, ubs


# ---------------------------------------------------------------------------
# property functions (shared by both drivers)
# ---------------------------------------------------------------------------


def check_join_commutative(lb_a, ub_a, lb_b, ub_b):
    l1, u1 = np_iz_join(lb_a, ub_a, lb_b, ub_b)
    l2, u2 = np_iz_join(lb_b, ub_b, lb_a, ub_a)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_array_equal(u1, u2)


def check_join_associative(lb_a, ub_a, lb_b, ub_b, lb_c, ub_c):
    left = np_iz_join(*np_iz_join(lb_a, ub_a, lb_b, ub_b), lb_c, ub_c)
    right = np_iz_join(lb_a, ub_a, *np_iz_join(lb_b, ub_b, lb_c, ub_c))
    np.testing.assert_array_equal(left[0], right[0])
    np.testing.assert_array_equal(left[1], right[1])


def check_join_idempotent_extensive(lb_a, ub_a, lb_b, ub_b):
    l, u = np_iz_join(lb_a, ub_a, lb_a, ub_a)
    np.testing.assert_array_equal(l, lb_a)
    np.testing.assert_array_equal(u, ub_a)
    # the join refines both arguments: a ⊑ a⊔b (lb grows, ub shrinks)
    l, u = np_iz_join(lb_a, ub_a, lb_b, ub_b)
    assert (l >= lb_a).all() and (l >= lb_b).all()
    assert (u <= ub_a).all() and (u <= ub_b).all()


def check_sweep_monotone(cm, lbs, ubs):
    """One sweep only *tightens*: lb' >= lb, ub' <= ub pointwise."""
    for lb, ub in zip(lbs, ubs):
        nlb, nub = fp.sweep(cm, lb, ub)
        assert (np.asarray(nlb) >= lb).all()
        assert (np.asarray(nub) <= ub).all()


def check_fixpoint_idempotent(cm, lbs, ubs):
    """fixpoint(fixpoint(s)) == fixpoint(s): the engine lands on a fixed
    point, so running propagation again changes nothing."""
    for lb, ub in zip(lbs, ubs):
        l1, u1, _, converged = fp.fixpoint(cm, lb, ub)
        assert bool(converged)
        l2, u2, iters2, _ = fp.fixpoint(cm, np.asarray(l1), np.asarray(u1))
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))


def check_fixpoint_under_join(cm, lbs, ubs):
    """Propagation commutes with information order: the fixpoint of a
    joined store refines the join of the fixpoints (monotonicity of the
    abstract transfer functions, paper Thm. 2).

    Only stated for consistent results: once a store *fails*, the engine
    stops sweeping (failure is definitive, DESIGN.md §2), so a failed
    fixpoint legitimately reports looser bounds on the other variables.
    """
    checked = 0
    for i in range(len(lbs) - 1):
        la, ua, lb_, ub_ = lbs[i], ubs[i], lbs[i + 1], ubs[i + 1]
        fl_a, fu_a, _, _ = fp.fixpoint(cm, la, ua)
        jl, ju = np_iz_join(la, ua, lb_, ub_)
        fjl, fju, _, _ = fp.fixpoint(cm, jl, ju)
        if (np.asarray(fjl) > np.asarray(fju)).any() or \
                (np.asarray(fl_a) > np.asarray(fu_a)).any():
            continue
        # fix(a⊔b) ⊒ fix(a)⊔b ⊒ fix(a) on the lb side (dually on ub)
        assert (np.asarray(fjl) >= np.asarray(fl_a)).all()
        assert (np.asarray(fju) <= np.asarray(fu_a)).all()
        checked += 1
    return checked


# ---------------------------------------------------------------------------
# seeded-numpy driver (always runs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_join_laws_seeded(seed):
    cm, lbs, ubs = _stores(seed, n=6)
    for i in range(len(lbs) - 2):
        check_join_commutative(lbs[i], ubs[i], lbs[i + 1], ubs[i + 1])
        check_join_associative(lbs[i], ubs[i], lbs[i + 1], ubs[i + 1],
                               lbs[i + 2], ubs[i + 2])
        check_join_idempotent_extensive(lbs[i], ubs[i],
                                        lbs[i + 1], ubs[i + 1])


@pytest.mark.parametrize("seed", SEEDS)
def test_sweep_monotone_seeded(seed):
    cm, lbs, ubs = _stores(seed)
    check_sweep_monotone(cm, lbs, ubs)


@pytest.mark.parametrize("seed", SEEDS[:3])
def test_fixpoint_idempotent_seeded(seed):
    cm, lbs, ubs = _stores(seed, n=4)
    check_fixpoint_idempotent(cm, lbs, ubs)


@pytest.mark.parametrize("seed", SEEDS[:3])
def test_fixpoint_monotone_under_join_seeded(seed):
    cm, lbs, ubs = _stores(seed, n=4)
    check_fixpoint_under_join(cm, lbs, ubs)


# ---------------------------------------------------------------------------
# hypothesis driver (richer shrinking search; skipped when not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    bounds = st.integers(min_value=-20, max_value=20)

    @st.composite
    def store_pairs(draw, n_vars=6):
        lb = np.array([draw(bounds) for _ in range(n_vars)])
        ub = np.array([draw(bounds) for _ in range(n_vars)])
        return lb, ub

    @settings(deadline=None, max_examples=40)
    @given(store_pairs(), store_pairs(), store_pairs())
    def test_join_laws_hypothesis(a, b, c):
        check_join_commutative(a[0], a[1], b[0], b[1])
        check_join_associative(a[0], a[1], b[0], b[1], c[0], c[1])
        check_join_idempotent_extensive(a[0], a[1], b[0], b[1])

    @settings(deadline=None, max_examples=10)
    @given(st.integers(min_value=0, max_value=2 ** 16))
    def test_sweep_and_fixpoint_hypothesis(seed):
        cm, lbs, ubs = _stores(seed, n=3)
        check_sweep_monotone(cm, lbs, ubs)
        check_fixpoint_idempotent(cm, lbs, ubs)
else:
    @pytest.mark.skip(reason="hypothesis not installed; seeded fallback "
                             "drivers above cover the same properties")
    def test_join_laws_hypothesis():
        pass
