"""End-to-end solver behaviour: engine vs sequential baseline vs brute
force, RCPSP ground checks, EPS completeness, B&B optimality."""

import itertools

import numpy as np
import pytest

from repro.core.model import Model
from repro.core import engine, baseline, eps, search as S
from util import solve_session
from repro.core.models import rcpsp


def brute_force_min(m: Model, cm, obj_idx):
    """Enumerate all assignments of the branch vars (tiny domains only)."""
    lb0, ub0 = np.asarray(cm.lb0), np.asarray(cm.ub0)
    seq = baseline.SequentialSolver(cm)
    best = None
    doms = [range(int(lb0[v]), int(ub0[v]) + 1)
            for v in np.asarray(cm.branch_vars)]
    for combo in itertools.product(*doms):
        lb, ub = lb0.copy(), ub0.copy()
        for v, val in zip(np.asarray(cm.branch_vars), combo):
            lb[v] = ub[v] = val
        if seq.propagate(lb, ub) and (lb == ub).all():
            o = int(lb[obj_idx])
            best = o if best is None else min(best, o)
    return best


def small_opt_model():
    m = Model("m")
    x = m.int_var(0, 4, "x")
    y = m.int_var(0, 4, "y")
    z = m.int_var(0, 9, "z")
    m.add(x + y >= 5)
    m.add(x <= z)
    m.add(y <= z)
    b = m.reify(x <= 1)
    m.add(2 * x + 3 * y <= 11)
    m.minimize(z)
    m.branch_on([x, y, z])
    return m


def test_engine_matches_brute_force():
    m = small_opt_model()
    cm = m.compile()
    bf = brute_force_min(m, cm, cm.obj_var)
    res = solve_session(cm, n_lanes=4, n_subproblems=8)
    assert res.status == engine.OPTIMAL
    assert res.objective == bf


def test_engine_matches_baseline_statuses():
    for seed in range(4):
        inst = rcpsp.generate(5, n_resources=2, seed=seed, edge_prob=0.3)
        m, _ = rcpsp.build_model(inst)
        cm = m.compile()
        opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=256)
        seq = baseline.SequentialSolver(cm, opts).solve(timeout_s=120)
        par = solve_session(cm, n_lanes=4, n_subproblems=8, opts=opts,
                           timeout_s=300)
        assert seq.status == par.status == engine.OPTIMAL
        assert seq.objective == par.objective


def test_solution_passes_ground_checker():
    inst = rcpsp.generate(6, n_resources=3, seed=9, edge_prob=0.25)
    m, h = rcpsp.build_model(inst)
    cm = m.compile()
    res = solve_session(cm, n_lanes=8, n_subproblems=16,
                       opts=S.SearchOptions(var_strategy=S.MIN_LB,
                                            max_depth=256))
    assert res.status == engine.OPTIMAL
    s_idx = [v.idx for v in h["s"]]
    ok, mk = rcpsp.check_solution(inst, res.solution[s_idx])
    assert ok and mk == res.objective


def test_unsat_detected():
    m = Model()
    a = m.int_var(0, 3, "a")
    b = m.int_var(0, 3, "b")
    m.add(a + b >= 9)
    res = solve_session(m.compile(), n_lanes=2)
    assert res.status == engine.UNSAT and res.complete


def test_result_invariant_to_lane_count():
    """Paper's determinism claim at system level: decomposition and lane
    counts change the schedule, never the answer."""
    inst = rcpsp.generate(5, n_resources=2, seed=2, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    objs = set()
    for lanes, subs in [(1, 1), (2, 4), (8, 32)]:
        res = solve_session(cm, n_lanes=lanes, n_subproblems=subs,
                           opts=S.SearchOptions(max_depth=256))
        assert res.status == engine.OPTIMAL
        objs.add(res.objective)
    assert len(objs) == 1


def test_eps_partition_is_complete():
    """Union of EPS subproblem boxes must cover every root solution."""
    inst = rcpsp.generate(4, n_resources=2, seed=5, edge_prob=0.3)
    m, h = rcpsp.build_model(inst)
    cm = m.compile()
    subs_lb, subs_ub = eps.decompose(cm, 8)
    # optimal solution found without EPS must fall in exactly >=1 box
    res = solve_session(cm, n_lanes=1, subs=(np.asarray(cm.lb0)[None],
                                            np.asarray(cm.ub0)[None]))
    sol = res.solution
    hits = 0
    for i in range(subs_lb.shape[0]):
        if (subs_lb[i] <= sol).all() and (sol <= subs_ub[i]).all():
            hits += 1
    assert hits >= 1


def test_bnb_prunes_but_keeps_optimum():
    m = small_opt_model()
    cm = m.compile()
    # huge lane count => massive parallel redundancy, same answer
    res = solve_session(cm, n_lanes=16, n_subproblems=64)
    assert res.status == engine.OPTIMAL
    assert res.objective == brute_force_min(m, cm, cm.obj_var)


def test_satisfaction_stop_on_first():
    m = Model()
    x = m.int_var(0, 50, "x")
    y = m.int_var(0, 50, "y")
    m.add((x + y).eq(40))
    m.add(x >= 10)
    opts = S.SearchOptions(stop_on_first=True)
    res = solve_session(m.compile(), n_lanes=4, opts=opts)
    assert res.status == engine.SAT
    assert res.solution[x.idx] + res.solution[y.idx] == 40


def test_multi_device_engine_matches_single():
    """The shard_map engine on a fake 4-device mesh returns the same
    objective as the single-device engine (bound sharing via pmin)."""
    import jax
    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices (run under dryrun XLA flags)")
    from repro.compat import make_mesh
    mesh = make_mesh((4,), ("workers",))
    inst = rcpsp.generate(5, n_resources=2, seed=1, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    r1 = solve_session(cm, n_lanes=4, n_subproblems=16)
    r2 = solve_session(cm, n_lanes=2, n_subproblems=16, mesh=mesh,
                      lane_axes=("workers",))
    assert r1.status == r2.status == engine.OPTIMAL
    assert r1.objective == r2.objective


def test_dispatch_pool_shared_queue():
    """Shared-queue dispatcher: unique assignment, exhaustion marks done."""
    import jax.numpy as jnp
    from repro.core import search as S
    from repro.core.models import rcpsp

    inst = rcpsp.generate(4, n_resources=2, seed=0)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    opts = S.SearchOptions()
    st = S.init_lanes(cm, 4, opts)
    # 3 subproblems, 4 fresh lanes: three get 0,1,2; the fourth is done
    st2, head = S.dispatch_pool(st, jnp.asarray(0, jnp.int32), 3)
    got = sorted(int(x) for x in st2.next_sub if int(x) < 3)
    assert got == [0, 1, 2]
    assert int(st2.done.sum()) == 1
    assert int(head) == 3
    # nothing further to hand out
    st3, head2 = S.dispatch_pool(st2._replace(
        fresh=jnp.ones(4, bool),
        next_sub=jnp.full((4,), S.UNASSIGNED, jnp.int32)), head, 3)
    assert bool(st3.done.all())


def test_solution_requires_fixpoint_convergence():
    """With a 1-sweep cap, fully-fixed-but-unpropagated stores must not
    be recorded as solutions (the §Perf H1 soundness guard)."""
    from repro.core import search as S
    m = Model()
    x = m.int_var(0, 3, "x")
    y = m.int_var(0, 3, "y")
    m.add((x + y).eq(3))
    m.add(x <= 1)
    opts = S.SearchOptions(max_fixpoint_iters=1, max_depth=64)
    res = solve_session(m.compile(), n_lanes=2, n_subproblems=4, opts=opts)
    assert res.status == engine.SAT
    sol = res.solution
    assert sol[x.idx] + sol[y.idx] == 3 and sol[x.idx] <= 1
