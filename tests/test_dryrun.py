"""Dry-run machinery integration tests (subprocess: 512 fake devices).

The full 80-cell matrix runs via ``python -m repro.launch.dryrun --all``
(results in dryrun_report.json); here we verify the machinery end-to-end
on the cheapest cells so regressions are caught by pytest.
"""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_lm_cell_compiles_both_meshes():
    out = _run(r"""
import os, json
from repro.launch.dryrun import run_cell
for mp in (False, True):
    rec = run_cell("qwen2.5-3b", "decode_32k", multi_pod=mp)
    assert rec["status"] == "OK", rec
    assert rec["per_device"]["argument_bytes"] > 0
    assert rec["per_device"]["temp_bytes"] < 16e9   # fits v5e HBM
print("CELLS_OK")
""")
    assert "CELLS_OK" in out


@pytest.mark.slow
def test_skip_rules_applied():
    out = _run(r"""
from repro.launch.dryrun import run_cell
rec = run_cell("llama3-8b", "long_500k")
assert rec["status"] == "SKIP" and "full-attention" in rec["reason"]
rec2 = run_cell("mamba2-1.3b", "long_500k")
assert rec2["status"] == "OK"
print("SKIPS_OK")
""")
    assert "SKIPS_OK" in out


@pytest.mark.slow
def test_solver_dryrun_cli():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-m", "repro.launch.solve",
                        "--dryrun", "--n", "6"], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=1200)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SOLVER dry-run OK" in r.stdout


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = f32[8,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[4,16]{1,0} all-gather(%y), dimensions={0}
  %nope = f32[2,2]{1,0} add(%a, %b)
  ROOT %t = (f32[1]{0}) tuple(%c)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 8 * 1024 * 4
    assert out["all-gather"] == 4 * 16 * 2
    assert out["count"] == 2


def test_reduced_cfg_structurally_sound():
    from benchmarks.roofline import reduced_cfg, unit_counts
    from repro import configs
    from repro.nn.model import decoder_groups, param_specs
    for arch in configs.ARCH_IDS:
        full, (ka, kb) = unit_counts(arch)
        for k in (ka, kb):
            cfg = reduced_cfg(arch, k)
            param_specs(cfg)                      # must build
            if cfg.encdec is None:
                groups = decoder_groups(cfg)
                pat = len(cfg.rglru.pattern) if cfg.rglru else 1
                tot = sum(c * (pat if kind == "period" else 1)
                          for kind, c, _ in groups)
                assert tot == cfg.n_layers, (arch, k, groups)
