"""Shared test helpers: random PCCP models + store perturbations."""

from __future__ import annotations

import numpy as np

from repro.core.model import Model


def random_model(rng: np.random.Generator, n_vars: int = 6,
                 n_props: int = 8, dom: int = 12, max_terms: int = 3,
                 p_reified: float = 0.4) -> Model:
    """A random mix of plain and reified linear inequalities.

    RHS is sampled around the constraint's feasible range so entailment,
    disentailment and genuine pruning all occur.
    """
    m = Model("rand")
    xs = [m.int_var(0, int(rng.integers(1, dom)), f"x{i}")
          for i in range(n_vars)]
    for _ in range(n_props):
        k = int(rng.integers(1, min(max_terms, n_vars) + 1))
        idx = rng.choice(n_vars, size=k, replace=False)
        coefs = rng.integers(-4, 5, size=k)
        coefs[coefs == 0] = 1
        expr = sum(int(c) * xs[i] for c, i in zip(coefs, idx))
        lo = sum(min(int(c) * 0, int(c) * m.ub0[xs[i].idx])
                 for c, i in zip(coefs, idx))
        hi = sum(max(int(c) * 0, int(c) * m.ub0[xs[i].idx])
                 for c, i in zip(coefs, idx))
        rhs = int(rng.integers(lo - 2, hi + 3))
        lin = expr <= rhs
        if rng.random() < p_reified:
            b = m.reify(lin)
            if rng.random() < 0.5:
                m.add(b >= 1 if rng.random() < 0.5 else b <= 0)
        else:
            m.add(lin)
    m.branch_on(xs)
    return m


def random_substores(rng: np.random.Generator, cm, n: int):
    """n random consistent-or-not stores obtained by random tells."""
    lb0, ub0 = np.asarray(cm.lb0), np.asarray(cm.ub0)
    V = cm.n_vars
    lbs = np.tile(lb0, (n, 1))
    ubs = np.tile(ub0, (n, 1))
    for i in range(n):
        for _ in range(int(rng.integers(0, 8))):
            v = int(rng.integers(1, V))
            if lb0[v] >= ub0[v]:
                continue
            cut = int(rng.integers(lb0[v], ub0[v] + 1))
            if rng.random() < 0.5:
                lbs[i, v] = max(lbs[i, v], cut)
            else:
                ubs[i, v] = min(ubs[i, v], cut)
    return lbs, ubs
