"""Shared test helpers: random PCCP models, store perturbations, and the
multi-device CPU harness (subprocesses with XLA-faked host devices) that
the distributed-EPS tests run on."""

from __future__ import annotations

import functools
import os
import subprocess
import sys

import numpy as np

from repro.core.model import Model

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def solve_session(cm, *, n_lanes=64, n_subproblems=None, eps_target=None,
                  opts=None, timeout_s=None, max_supersteps=None, chunk=256,
                  mesh=None, lane_axes=(), subs=None):
    """`engine.solve`-shaped convenience over the session API: maps the
    legacy kwargs onto a `SolveConfig` and solves through the shared
    default session (compile caching across the whole test run) —
    without tripping the shim's DeprecationWarning.  Tests asserting on
    the deprecation itself call `engine.solve` directly
    (tests/test_api.py)."""
    from repro import solver
    from repro.core import search as S

    o = opts or S.SearchOptions()
    cfg = solver.SolveConfig(
        n_lanes=n_lanes,
        eps_target=(eps_target if eps_target is not None else n_subproblems),
        chunk=chunk, timeout_s=timeout_s, max_supersteps=max_supersteps,
        backend=o.backend, backend_opts=o.backend_opts,
        var_strategy=o.var_strategy, val_strategy=o.val_strategy,
        max_depth=o.max_depth, max_fixpoint_iters=o.max_fixpoint_iters,
        stop_on_first=o.stop_on_first, mesh=mesh,
        lane_axes=tuple(lane_axes))
    return solver.solve(cm, subs=subs, config=cfg)


def run_fake_devices(code: str, n_devices: int = 8,
                     timeout: int = 1200) -> str:
    """Run ``code`` in a fresh interpreter that sees ``n_devices`` fake
    CPU devices (``XLA_FLAGS=--xla_force_host_platform_device_count``,
    which only takes effect before jax initializes — hence the
    subprocess).  Returns stdout; asserts a zero exit with the child's
    stderr tail in the failure message."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=timeout)
    assert r.returncode == 0, (
        f"fake-device subprocess failed (rc={r.returncode}):\n"
        f"{r.stderr[-3000:]}")
    return r.stdout


@functools.lru_cache(maxsize=None)
def can_fake_devices(n_devices: int = 8) -> bool:
    """True when this JAX build honors the forced host device count —
    probed once per test session in a throwaway subprocess so tests can
    skip cleanly on builds where the flag is a no-op."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    try:
        r = subprocess.run(
            [sys.executable, "-c",
             "import jax; print(jax.device_count())"],
            capture_output=True, text=True, env=env, cwd=ROOT,
            timeout=600)
    except (OSError, subprocess.TimeoutExpired):
        return False
    return r.returncode == 0 and r.stdout.strip() == str(n_devices)


def random_model(rng: np.random.Generator, n_vars: int = 6,
                 n_props: int = 8, dom: int = 12, max_terms: int = 3,
                 p_reified: float = 0.4) -> Model:
    """A random mix of plain and reified linear inequalities.

    RHS is sampled around the constraint's feasible range so entailment,
    disentailment and genuine pruning all occur.
    """
    m = Model("rand")
    xs = [m.int_var(0, int(rng.integers(1, dom)), f"x{i}")
          for i in range(n_vars)]
    for _ in range(n_props):
        k = int(rng.integers(1, min(max_terms, n_vars) + 1))
        idx = rng.choice(n_vars, size=k, replace=False)
        coefs = rng.integers(-4, 5, size=k)
        coefs[coefs == 0] = 1
        expr = sum(int(c) * xs[i] for c, i in zip(coefs, idx))
        lo = sum(min(int(c) * 0, int(c) * m.ub0[xs[i].idx])
                 for c, i in zip(coefs, idx))
        hi = sum(max(int(c) * 0, int(c) * m.ub0[xs[i].idx])
                 for c, i in zip(coefs, idx))
        rhs = int(rng.integers(lo - 2, hi + 3))
        lin = expr <= rhs
        if rng.random() < p_reified:
            b = m.reify(lin)
            if rng.random() < 0.5:
                m.add(b >= 1 if rng.random() < 0.5 else b <= 0)
        else:
            m.add(lin)
    m.branch_on(xs)
    return m


def random_substores(rng: np.random.Generator, cm, n: int):
    """n random consistent-or-not stores obtained by random tells."""
    lb0, ub0 = np.asarray(cm.lb0), np.asarray(cm.ub0)
    V = cm.n_vars
    lbs = np.tile(lb0, (n, 1))
    ubs = np.tile(ub0, (n, 1))
    for i in range(n):
        for _ in range(int(rng.integers(0, 8))):
            v = int(rng.integers(1, V))
            if lb0[v] >= ub0[v]:
                continue
            cut = int(rng.integers(lb0[v], ub0[v] + 1))
            if rng.random() < 0.5:
                lbs[i, v] = max(lbs[i, v], cut)
            else:
                ubs[i, v] = min(ubs[i, v], cut)
    return lbs, ubs
