"""Resident search megakernel tests (DESIGN.md §13, ISSUE 6).

Three layers of coverage for `kernels/fixpoint_kernel.search_pallas`
and its `pallas_resident` backend:

* **bit-parity** — K fused supersteps inside the megakernel must equal
  K unfused `search.lanes_step` iterations field-for-field (stores,
  decision path, status flags, stats, best bound, pool cursor), for
  K ∈ {1, 4, 16} and for the §Perf-H1 capped-fixpoint soundness guard
  (an unconverged superstep defers branching *inside the kernel* too);
* **solver parity** — `pallas_resident` with K=16 proves the same
  optimum as `gather` through the full session API on zoo instances;
* **VMEM budget** — `vmem_budget`/`fit_lane_tile` raise clear errors /
  auto-shrink with a warning instead of handing Mosaic an
  un-allocatable kernel, and the auto-shrunk multi-tile kernel (strided
  pool shards — a different dispatch trajectory) stays sound+complete.

Everything runs in Pallas interpret mode (no TPU in CI).
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro import solver
from repro.core import eps, models as zoo, search as S
from repro.kernels import fixpoint_kernel as FK


def _setup(n_lanes=8, eps_target=8, max_depth=64, **opt_kw):
    inst = zoo.small_instance("rcpsp", seed=0)
    cm = zoo.ZOO["rcpsp"].build_model(inst)[0].compile()
    opts = S.SearchOptions(max_depth=max_depth, **opt_kw)
    subs_lb, subs_ub = eps.decompose(cm, eps_target, opts)
    subs_lb = jnp.asarray(subs_lb)
    subs_ub = jnp.asarray(subs_ub)
    st = S.init_lanes(cm, n_lanes, opts)
    gbest = jnp.asarray(jnp.iinfo(cm.jdtype).max // 4, cm.jdtype)
    return cm, opts, subs_lb, subs_ub, st, gbest


def _gdone(st, stop_on_first):
    g = bool(np.asarray(st.done).all())
    if stop_on_first:
        g |= bool(np.asarray(st.has_sol).any())
    return g


def _unfused(cm, opts, subs_lb, subs_ub, st, gbest, supersteps):
    """The host reference: K guarded `lanes_step` iterations — exactly
    the unfused `_run_chunk` semantics the kernel's per-superstep
    `lax.cond(gdone, identity, run)` must reproduce."""
    pool_head = jnp.zeros((), jnp.int32)
    it = 0
    for _ in range(supersteps):
        if _gdone(st, opts.stop_on_first):
            break
        st, pool_head = S.lanes_step(cm, subs_lb, subs_ub, opts, st,
                                     gbest, pool_head)
        gbest = jnp.minimum(gbest, S.lanes_best(st, cm.jdtype))
        it += 1
    return st, gbest, it, int(pool_head)


def _assert_state_equal(a: S.LaneState, b: S.LaneState):
    for f in S.LaneState._fields:
        av, bv = getattr(a, f), getattr(b, f)
        if av is None or bv is None:       # inactive bitset stores
            assert av is None and bv is None, f"LaneState.{f} presence"
            continue
        ref, got = np.asarray(av), np.asarray(bv)
        assert ref.dtype == got.dtype or f in FK._BOOL_FIELDS
        np.testing.assert_array_equal(
            ref.astype(np.int64), got.astype(np.int64),
            err_msg=f"LaneState.{f} diverged")


@pytest.mark.parametrize("supersteps", [1, 4, 16])
def test_fused_bit_parity(supersteps):
    cm, opts, subs_lb, subs_ub, st0, gbest0 = _setup()
    ref_st, ref_gbest, ref_it, ref_head = _unfused(
        cm, opts, subs_lb, subs_ub, st0, gbest0, supersteps)
    st, gbest, it, head, stopped = FK.search_pallas(
        cm, subs_lb, subs_ub, st0, gbest0, jnp.asarray(0, jnp.int32),
        jnp.zeros((1,), jnp.int32), supersteps=supersteps, lane_tile=0,
        interpret=True)
    _assert_state_equal(ref_st, st)
    assert int(gbest) == int(ref_gbest)
    assert int(it) == ref_it
    assert int(head[0]) == ref_head
    assert bool(stopped) == _gdone(ref_st, opts.stop_on_first)


def test_fused_bit_parity_capped_fixpoint():
    """§Perf H1 soundness guard inside the kernel: with
    max_fixpoint_iters=1 most supersteps end unconverged, so
    `lane_commit_tile` must defer branching (keep sweeping, no node
    expansion) — fused and unfused must still agree bit-for-bit, and
    the capped search must still reach the true optimum."""
    cm, opts, subs_lb, subs_ub, st0, gbest0 = _setup(max_fixpoint_iters=1)
    ref_st, ref_gbest, ref_it, ref_head = _unfused(
        cm, opts, subs_lb, subs_ub, st0, gbest0, 16)
    st, gbest, it, head, _ = FK.search_pallas(
        cm, subs_lb, subs_ub, st0, gbest0, jnp.asarray(0, jnp.int32),
        jnp.zeros((1,), jnp.int32), supersteps=16, lane_tile=0,
        max_fixpoint_iters=1, interpret=True)
    _assert_state_equal(ref_st, st)
    assert int(gbest) == int(ref_gbest)
    assert int(it) == ref_it
    # the guard really fired: mid-flight (before the search exhausts and
    # totals converge to the same tree) a capped run has expanded fewer
    # nodes than an uncapped one, because unconverged supersteps defer
    # branching.  Exercise it THROUGH the kernel at supersteps=4.
    capped4, *_ = FK.search_pallas(
        cm, subs_lb, subs_ub, st0, gbest0, jnp.asarray(0, jnp.int32),
        jnp.zeros((1,), jnp.int32), supersteps=4, lane_tile=0,
        max_fixpoint_iters=1, interpret=True)
    full4, *_ = _unfused(cm, S.SearchOptions(max_depth=64),
                         subs_lb, subs_ub, st0, gbest0, 4)
    assert (int(np.asarray(capped4.n_nodes).sum())
            < int(np.asarray(full4.n_nodes).sum()))


def test_stop_on_first_freezes_mid_launch():
    """`stop_on_first` can trip in the middle of a K-launch; the kernel
    must freeze (identity supersteps) from that point, matching the
    host loop's early break — `it` counts only the live supersteps."""
    cm, opts, subs_lb, subs_ub, st0, gbest0 = _setup(stop_on_first=True)
    ref_st, ref_gbest, ref_it, ref_head = _unfused(
        cm, opts, subs_lb, subs_ub, st0, gbest0, 16)
    st, gbest, it, head, stopped = FK.search_pallas(
        cm, subs_lb, subs_ub, st0, gbest0, jnp.asarray(0, jnp.int32),
        jnp.zeros((1,), jnp.int32), supersteps=16, lane_tile=0,
        stop_on_first=True, interpret=True)
    assert ref_it < 16, "instance too easy to exercise mid-launch stop"
    _assert_state_equal(ref_st, st)
    assert int(it) == ref_it
    assert bool(stopped)


@pytest.mark.parametrize("model", ["rcpsp", "nqueens", "jobshop"])
def test_zoo_proven_optimum_parity(model):
    """K=16 resident solve proves the same optimum as gather through the
    session API (the ISSUE-6 acceptance bar, bit-identical objectives)."""
    inst = zoo.small_instance(model, seed=0)
    cm = zoo.ZOO[model].build_model(inst)[0].compile()
    kw = dict(n_lanes=8, eps_target=8, timeout_s=600, max_depth=512)
    ref = solver.Solver(solver.SolveConfig.preset(
        "prove", backend="gather", **kw)).solve(cm)
    res = solver.Solver(solver.SolveConfig.preset(
        "prove", backend="pallas_resident", supersteps_per_launch=16,
        **kw)).solve(cm)
    assert ref.status == solver.OPTIMAL
    assert res.status == ref.status
    assert res.objective == ref.objective


# -------------------------------------------------------------------------
# VMEM budget + auto-shrink
# -------------------------------------------------------------------------

def _cm():
    inst = zoo.small_instance("rcpsp", seed=0)
    return zoo.ZOO["rcpsp"].build_model(inst)[0].compile()


def test_vmem_budget_shape():
    cm = _cm()
    b1 = FK.vmem_budget(cm, 1)
    b8 = FK.vmem_budget(cm, 8)
    assert set(b1) == {"tables", "stores", "state", "scratch", "total"}
    assert b1["state"] == 0                      # non-resident: no state
    assert b8["tables"] == b1["tables"]          # broadcast, tile-invariant
    assert b8["stores"] == 8 * b1["stores"]
    assert b8["total"] > b1["total"]
    r8 = FK.vmem_budget(cm, 8, resident=True, max_depth=64, pool_size=8)
    assert r8["state"] > 0
    assert r8["total"] > b8["total"]
    # smoke-tier models must actually fit the default budget
    assert r8["total"] <= FK.VMEM_LIMIT_BYTES


def test_fit_lane_tile_clamps_and_shrinks():
    cm = _cm()
    assert FK.fit_lane_tile(cm, 64, 8) == 8      # clamped to n_lanes
    assert FK.fit_lane_tile(cm, 8, 8) == 8       # fits: unchanged
    # a limit between budget(4) and budget(8) forces exactly one halving
    lim = (FK.vmem_budget(cm, 4)["total"]
           + FK.vmem_budget(cm, 8)["total"]) // 2
    with pytest.warns(UserWarning, match="shrinking to 4"):
        assert FK.fit_lane_tile(cm, 8, 8, limit_bytes=lim) == 4


def test_fit_lane_tile_clear_error_when_nothing_fits():
    cm = _cm()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(ValueError, match="does not fit VMEM"):
            FK.fit_lane_tile(cm, 8, 8, limit_bytes=1024)


def test_auto_shrink_resident_still_sound(monkeypatch):
    """Force the resident kernel to auto-shrink to 2 grid cells (strided
    pool shards — a different dispatch trajectory than the one-cell
    parity mode) and check the solve is still sound and complete: same
    proven optimum as gather."""
    cm = _cm()
    kw = dict(n_lanes=8, eps_target=8, timeout_s=600, max_depth=512)
    ref = solver.Solver(solver.SolveConfig.preset(
        "prove", backend="gather", **kw)).solve(cm)
    # the limit must straddle budget(tile=4)..budget(tile=8) for the
    # ACTUAL pool the session will decompose, so one halving happens
    pool = eps.decompose(cm, 8, S.SearchOptions(max_depth=512))[0].shape[0]
    lim = (FK.vmem_budget(cm, 4, resident=True, max_depth=512,
                          pool_size=pool)["total"]
           + FK.vmem_budget(cm, 8, resident=True, max_depth=512,
                            pool_size=pool)["total"]) // 2
    monkeypatch.setattr(FK, "VMEM_LIMIT_BYTES", int(lim))
    with pytest.warns(UserWarning, match="search_pallas: lane_tile=8"):
        res = solver.Solver(solver.SolveConfig.preset(
            "prove", backend="pallas_resident", supersteps_per_launch=8,
            **kw)).solve(cm)
    assert res.status == ref.status == solver.OPTIMAL
    assert res.objective == ref.objective


def test_config_rejects_supersteps_on_other_backends():
    with pytest.raises(ValueError, match="pallas_resident"):
        solver.SolveConfig.preset("prove", backend="gather",
                                  supersteps_per_launch=4)
