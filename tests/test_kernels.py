"""Per-kernel validation: Pallas fixpoint kernel vs the pure-jnp oracle.

Sweeps model shapes (vars/props/terms), store batches, lane tiles and
dtypes; asserts the comparison spec of kernels/ops.py — equal failed
masks, exact store equality on non-failed lanes (integer lattice ⇒
assert_array_equal is the allclose).
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; never hard-error
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops
from repro.kernels.fixpoint_kernel import fixpoint_pallas
from repro.kernels.ref import fixpoint_ref
from util import random_model, random_substores


def _check(cm, lbs, ubs, lane_tile):
    lbs, ubs = jnp.asarray(lbs), jnp.asarray(ubs)
    gl, gu = ops.batched_fixpoint(cm, lbs, ubs, impl="gather")
    rl, ru = ops.batched_fixpoint(cm, lbs, ubs, impl="scatter")
    pl_, pu, sweeps, conv = fixpoint_pallas(cm, lbs, ubs,
                                            lane_tile=lane_tile)
    assert bool(np.asarray(conv).all())   # uncapped run must converge
    for (al, au) in [(rl, ru), (pl_, pu)]:
        fg = np.asarray((gl > gu).any(axis=1))
        fa = np.asarray((al > au).any(axis=1))
        np.testing.assert_array_equal(fg, fa)
        ok = ~fg
        np.testing.assert_array_equal(np.asarray(gl)[ok], np.asarray(al)[ok])
        np.testing.assert_array_equal(np.asarray(gu)[ok], np.asarray(au)[ok])
    # a tile does >=1 sweep unless every lane arrived already failed
    if not np.asarray((lbs > ubs).any(axis=1)).all():
        assert int(np.asarray(sweeps).max()) >= 1


@given(seed=st.integers(0, 10_000),
       n_vars=st.integers(2, 10),
       n_props=st.integers(1, 16),
       lanes=st.integers(1, 9),
       lane_tile=st.sampled_from([1, 2, 4, 8]))
@settings(deadline=None, max_examples=15)
def test_pallas_matches_oracle_random(seed, n_vars, n_props, lanes, lane_tile):
    rng = np.random.default_rng(seed)
    cm = random_model(rng, n_vars=n_vars, n_props=n_props).compile()
    lbs, ubs = random_substores(rng, cm, lanes)
    _check(cm, lbs, ubs, lane_tile)


@pytest.mark.parametrize("pad_terms,pad_occ", [(8, 8), (16, 8), (8, 32)])
def test_pallas_padding_sweep(pad_terms, pad_occ):
    """Padding variations change K/D but never results."""
    rng = np.random.default_rng(7)
    m = random_model(rng, n_vars=8, n_props=12)
    cm = m.compile(pad_terms_to=pad_terms, pad_occ_to=pad_occ)
    lbs, ubs = random_substores(rng, cm, 6)
    _check(cm, lbs, ubs, lane_tile=2)


def test_pallas_on_rcpsp():
    """Realistic model: the paper's RCPSP decomposition."""
    from repro.core.models import rcpsp
    inst = rcpsp.generate(6, n_resources=2, seed=11, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    rng = np.random.default_rng(3)
    lbs, ubs = random_substores(rng, cm, 10)
    _check(cm, lbs, ubs, lane_tile=4)


def test_pallas_all_failed_tile():
    """A tile whose lanes all fail must exit (live-lane early stop)."""
    from repro.core.model import Model
    m = Model()
    x = m.int_var(0, 5, "x")
    m.add(x >= 3)
    m.add(x <= 1)
    cm = m.compile()
    lbs = jnp.tile(cm.lb0[None], (4, 1))
    ubs = jnp.tile(cm.ub0[None], (4, 1))
    nl, nu, sweeps, _ = fixpoint_pallas(cm, lbs, ubs, lane_tile=4)
    assert bool(jnp.all(jnp.any(nl > nu, axis=1)))
    assert int(np.asarray(sweeps).max()) < 100


def test_ref_is_fixpoint():
    """Oracle output is a fixpoint of the scatter sweep."""
    from repro.core.fixpoint import sweep_scatter
    rng = np.random.default_rng(13)
    cm = random_model(rng, n_vars=6, n_props=10).compile()
    lbs, ubs = random_substores(rng, cm, 5)
    nl, nu = fixpoint_ref(cm, jnp.asarray(lbs), jnp.asarray(ubs))
    for i in range(5):
        if bool(jnp.any(nl[i] > nu[i])):
            continue
        sl, su = sweep_scatter(cm, nl[i], nu[i])
        np.testing.assert_array_equal(np.asarray(sl), np.asarray(nl[i]))
        np.testing.assert_array_equal(np.asarray(su), np.asarray(nu[i]))
