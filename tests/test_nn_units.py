"""Unit tests for NN substrate internals: MoE dispatch combinatorics,
blocked-attention masking vs a dense oracle, rope properties, causal
conv streaming, SSD chunk invariance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; never hard-error
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.nn import attention as A  # noqa: E402
from repro.nn import moe as M
from repro.nn.layers import apply_rope, rms_norm
from repro.nn.ssm import _causal_conv, ssd_chunked


# ---------------------------------------------------------------- MoE --

def test_dispatch_indices_exact():
    idx = jnp.array([[0, 1], [1, 0], [1, 1]])        # T=3, k=2, E=2
    slot_token, keep, rank = M.dispatch_indices(idx, n_experts=2,
                                                capacity=4)
    st_ = np.asarray(slot_token).reshape(2, 4)
    # expert 0 receives tokens 0 and 1 (in token order)
    assert st_[0, 0] == 0 and st_[0, 1] == 1
    # expert 1 receives tokens 0, 1, 2, 2
    assert list(st_[1, :4]) == [0, 1, 2, 2]
    assert bool(keep.all())


def test_dispatch_capacity_drops_in_order():
    idx = jnp.zeros((5, 1), jnp.int32)               # all to expert 0
    slot_token, keep, rank = M.dispatch_indices(idx, n_experts=2,
                                                capacity=3)
    assert int(keep.sum()) == 3                      # first 3 kept
    assert bool(keep[:3].all()) and not bool(keep[3:].any())


@given(seed=st.integers(0, 1000))
@settings(deadline=None, max_examples=10)
def test_dispatch_roundtrip_property(seed):
    """Every kept (token, slot) lands in a unique slot of its expert."""
    rng = np.random.default_rng(seed)
    T, K, E = 12, 2, 4
    idx = jnp.asarray(rng.integers(0, E, size=(T, K)))
    C = 6
    slot_token, keep, rank = M.dispatch_indices(idx, E, C)
    st_ = np.asarray(slot_token)
    used = set()
    for t in range(T):
        for k in range(K):
            if bool(keep[t, k]):
                slot = int(idx[t, k]) * C + int(rank[t, k])
                assert st_[slot] == t
                assert slot not in used
                used.add(slot)


def test_moe_ffn_matches_dense_single_expert():
    """E=1, top-1, ample capacity == plain SwiGLU with that expert."""
    from repro.nn.layers import swiglu
    from repro.configs.base import ArchConfig, MoEConfig
    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                     moe=MoEConfig(n_experts=1, top_k=1, d_expert=32,
                                   capacity_factor=4.0))
    key = jax.random.PRNGKey(0)
    p = {
        "m/router": jax.random.normal(key, (16, 1)),
        "m/w_gate": jax.random.normal(key, (1, 16, 32)) * 0.1,
        "m/w_up": jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32)) * 0.1,
        "m/w_down": jax.random.normal(jax.random.PRNGKey(2), (1, 32, 16)) * 0.1,
    }
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 16), jnp.bfloat16)
    y, aux = M.moe_ffn(p, "m", x, cfg)
    ref = swiglu(x.reshape(-1, 16), p["m/w_gate"][0], p["m/w_up"][0],
                 p["m/w_down"][0]).reshape(2, 8, 16)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)
    assert float(aux["moe_dropped"]) == 0.0


# ---------------------------------------------------------- attention --

def _dense_attention(q, k, v, mask):
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(q.shape[-1])
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("Sq,Sk,qc,kc,causal,window",
                         [(8, 8, 4, 4, True, 0),
                          (8, 8, 3, 5, True, 0),     # ragged chunks
                          (8, 8, 8, 8, False, 0),
                          (16, 16, 4, 4, True, 6),   # sliding window
                          (1, 12, 1, 4, True, 0)])   # decode-like
def test_blocked_attention_vs_dense(Sq, Sk, qc, kc, causal, window):
    key = jax.random.PRNGKey(0)
    B, H, hd = 2, 2, 8
    q = jax.random.normal(key, (B, Sq, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sk, H, hd),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sk, H, hd),
                          jnp.bfloat16)
    q_pos = jnp.arange(Sk - Sq, Sk)                 # suffix queries
    kv_pos = jnp.arange(Sk)
    out = A.blocked_attention(q, k, v, q_pos, kv_pos, causal=causal,
                              window=window, q_chunk=qc, kv_chunk=kc)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    ref = _dense_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_gqa_broadcast_matches_repeat():
    """KV-head broadcast == explicitly repeated KV heads."""
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 8, 4, 2, 8
    q = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd),
                          jnp.bfloat16)
    pos = jnp.arange(S)
    a = A.blocked_attention(q, k, v, pos, pos, q_chunk=4, kv_chunk=4)
    kr = jnp.repeat(k, H // KV, axis=2)
    vr = jnp.repeat(v, H // KV, axis=2)
    b = A.blocked_attention(q, kr, vr, pos, pos, q_chunk=4, kv_chunk=4)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-2)


# ---------------------------------------------------------------- rope --

def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 2, 16))
    pos = jnp.arange(6)
    y = apply_rope(x, pos, theta=10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y, np.float32),
                                              axis=-1), rtol=2e-2)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 16))
    dots = []
    for p0 in (0, 5, 11):
        qr = apply_rope(jnp.tile(q, (1, 1, 1)), jnp.array([p0]), 1e4)
        kr = apply_rope(jnp.tile(k, (1, 1, 1)), jnp.array([p0 + 3]), 1e4)
        dots.append(float(jnp.sum(qr.astype(jnp.float32)
                                  * kr.astype(jnp.float32))))
    # bf16 output quantization bounds the spread (exact in f32)
    assert max(dots) - min(dots) < 5e-2


# ------------------------------------------------------------- conv/ssd --

def test_causal_conv_streaming_matches_batch():
    key = jax.random.PRNGKey(0)
    B, S, C, W = 2, 10, 4, 4
    x = jax.random.normal(key, (B, S, C), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (W, C)) * 0.3
    b = jnp.zeros((C,))
    full, _ = _causal_conv(x, w, b)
    tail = jnp.zeros((B, W - 1, C), jnp.bfloat16)
    outs = []
    for t in range(S):
        o, tail = _causal_conv(x[:, t:t + 1], w, b, tail)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(stream, np.float32), atol=2e-2)


def test_ssd_chunk_size_invariance():
    """SSD output must not depend on the chunk size (math identity)."""
    key = jax.random.PRNGKey(0)
    B, S, H, hd, G, N = 1, 16, 2, 4, 1, 8
    xh = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (B, S, H)))
    Am = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (B, S, G, N),
                           jnp.bfloat16) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(4), (B, S, G, N),
                           jnp.bfloat16) * 0.5
    y4, h4 = ssd_chunked(xh, dt, Am, Bm, Cm, chunk=4)
    y16, h16 = ssd_chunked(xh, dt, Am, Bm, Cm, chunk=16)
    y5, h5 = ssd_chunked(xh, dt, Am, Bm, Cm, chunk=5)   # ragged
    np.testing.assert_allclose(np.asarray(y4, np.float32),
                               np.asarray(y16, np.float32), atol=3e-2)
    np.testing.assert_allclose(np.asarray(y4, np.float32),
                               np.asarray(y5, np.float32), atol=3e-2)
    np.testing.assert_allclose(np.asarray(h4), np.asarray(h16), atol=3e-2)


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8), jnp.bfloat16)
    g = jnp.ones((8,))
    a = rms_norm(x, g, 1e-6)
    b = rms_norm(x * 100.0, g, 1e-6)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-2)
