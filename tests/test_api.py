"""Session-oriented solver API (repro.solver / core/api.py, DESIGN.md
§11): SolveConfig validation + presets, compile-cached Solver sessions
(warm solves compile nothing), solve_many batched-dispatch parity vs
sequential solves, solve_iter anytime streaming, and the single status
derivation (derive_result)."""

import warnings

import numpy as np
import pytest

from repro import solver
from repro.core import engine
from repro.core import models as zoo
from repro.core import search as S
from repro.core.backend import available_backends
from repro.core.models import knapsack, rcpsp

SMALL = dict(n_lanes=4, eps_target=8)


def _compile_zoo(name, seeds):
    mod = zoo.ZOO[name]
    cms, handles, insts = [], [], []
    for s in seeds:
        inst = zoo.small_instance(name, seed=s)
        m, h = mod.build_model(inst)
        cms.append(m.compile())
        handles.append(h)
        insts.append(inst)
    return cms, handles, insts


# -------------------------------------------------------------------------
# SolveConfig: validation + presets
# -------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(n_lanes=0), dict(n_lanes=-3), dict(chunk=0), dict(max_depth=0),
    dict(eps_target=0), dict(max_supersteps=0), dict(max_fixpoint_iters=0),
    dict(timeout_s=0.0), dict(timeout_s=-1.0),
    dict(backend="cuda"),
    dict(var_strategy="random"), dict(val_strategy="max"),
    dict(backend_opts=(("lane_tile", 4, 9),)),
    dict(lane_axes=("workers",)),        # lane_axes without a mesh
])
def test_config_validation_errors(kw):
    with pytest.raises(ValueError):
        solver.SolveConfig(**kw)


def test_config_mesh_needs_lane_axes():
    import jax
    mesh = jax.make_mesh((1,), ("w",))
    with pytest.raises(ValueError):
        solver.SolveConfig(mesh=mesh)                 # no lane_axes
    with pytest.raises(ValueError):
        solver.SolveConfig(mesh=mesh, lane_axes=("bogus",))
    cfg = solver.SolveConfig(mesh=mesh, lane_axes=("w",))
    assert cfg.lane_axes == ("w",)


def test_config_normalizes_backend_opts_dict():
    cfg = solver.SolveConfig(backend="pallas",
                             backend_opts={"lane_tile": 4})
    assert cfg.backend_opts == (("lane_tile", 4),)
    # equal to the tuple spelling => same cache key
    cfg2 = solver.SolveConfig(backend="pallas",
                              backend_opts=(("lane_tile", 4),))
    assert cfg == cfg2 and hash(cfg) == hash(cfg2)


def test_presets():
    prove = solver.SolveConfig.preset("prove")
    first = solver.SolveConfig.preset("first_solution")
    fast = solver.SolveConfig.preset("fast")
    assert prove.var_strategy == S.MIN_LB and not prove.stop_on_first
    assert first.stop_on_first
    assert fast.max_fixpoint_iters == 4
    # overrides apply on top of the recipe
    cfg = solver.SolveConfig.preset("fast", n_lanes=128, backend="scatter")
    assert cfg.n_lanes == 128 and cfg.backend == "scatter" \
        and cfg.max_fixpoint_iters == 4
    with pytest.raises(ValueError):
        solver.SolveConfig.preset("does-not-exist")
    # the provenance tag never splits the cache key
    assert solver.SolveConfig.preset("prove") == solver.SolveConfig(
        var_strategy=S.MIN_LB, max_depth=1024)


def test_config_compile_key_ignores_budgets():
    a = solver.SolveConfig(timeout_s=None, max_supersteps=None)
    b = solver.SolveConfig(timeout_s=10.0, max_supersteps=50, eps_target=3)
    assert a.compile_key() == b.compile_key()


# -------------------------------------------------------------------------
# Solver session: compile cache
# -------------------------------------------------------------------------

def test_session_warm_solve_compiles_nothing():
    """The cache-hit acceptance bar: the second same-shape solve builds
    no runner and compiles no executable (asserted on the session
    counters), and is measurably faster than the cold first."""
    cms, _, _ = _compile_zoo("knapsack", range(2))
    sess = solver.Solver(solver.SolveConfig.preset("prove", **SMALL))
    r0 = sess.solve(cms[0])
    assert sess.stats["last_solve_cold"]
    cold = sess.session_stats()
    assert cold["runner_builds"] == 1 and cold["n_compiles"] == 1
    cold_wall = r0.wall_s

    r1 = sess.solve(cms[1])       # different instance, same shapes
    assert not sess.stats["last_solve_cold"]
    warm = sess.session_stats()
    assert warm["runner_builds"] == 1, "second solve rebuilt the runner"
    assert warm["n_compiles"] == 1, "second solve recompiled"
    assert warm["runner_hits"] == 1
    assert r0.status == r1.status == solver.OPTIMAL
    # compile dominates the cold solve on these smoke instances; the
    # warm solve skipping it must be visibly faster
    assert r1.wall_s < cold_wall

    # per-call config overrides that only touch host budgets still hit
    sess.solve(cms[0], timeout_s=60.0)
    assert sess.session_stats()["n_compiles"] == 1


def test_first_solution_preset_never_claims_optimal():
    """stop_on_first on an optimization model stops at the first
    incumbent: the result must be SAT/incomplete, never a (false)
    OPTIMAL proof — the early-out is not exhaustion."""
    inst = knapsack.generate(n=8, seed=1)
    m, h = knapsack.build_model(inst)
    cm = m.compile()
    sess = solver.Solver(solver.SolveConfig.preset(
        "first_solution", var_strategy=S.INPUT_ORDER, **SMALL))
    res = sess.solve(cm)
    assert res.solution is not None
    assert res.status == solver.SAT
    assert not res.complete
    # the first incumbent of this instance is NOT the optimum — the old
    # gdone-as-proof logic reported OPTIMAL here
    proof = solver.Solver(solver.SolveConfig.preset("prove", **SMALL)) \
        .solve(cm)
    assert proof.status == solver.OPTIMAL
    assert res.objective > proof.objective


def test_clear_cache_recompiles():
    cms, _, _ = _compile_zoo("knapsack", range(1))
    sess = solver.Solver(solver.SolveConfig.preset("prove", **SMALL))
    sess.solve(cms[0])
    sess.clear_cache()
    sess.solve(cms[0])
    assert sess.session_stats()["runner_builds"] == 2


def test_session_distinct_config_distinct_runner():
    cms, _, _ = _compile_zoo("knapsack", range(1))
    sess = solver.Solver(solver.SolveConfig.preset("prove", **SMALL))
    sess.solve(cms[0])
    sess.solve(cms[0], backend="scatter")
    assert sess.session_stats()["runner_builds"] == 2


# -------------------------------------------------------------------------
# solve_many: batched dispatch parity
# -------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["knapsack", "nqueens", "jobshop"])
def test_solve_many_matches_sequential(name):
    """N same-shape instances through ONE batched dispatch return the
    same statuses/objectives as N sequential session solves."""
    cms, handles, insts = _compile_zoo(name, range(3))
    sess = solver.Solver(solver.SolveConfig.preset("prove", **SMALL,
                                                   max_depth=256))
    many = sess.solve_many(cms)
    seq = [sess.solve(cm) for cm in cms]
    mod = zoo.ZOO[name]
    for inst, h, a, b in zip(insts, handles, many, seq):
        assert a.status == b.status == solver.OPTIMAL
        assert a.objective == b.objective
        assert zoo.ground_check(mod, inst, h, a)


@pytest.mark.parametrize("backend", sorted(available_backends()))
def test_solve_many_parity_all_backends(backend):
    """The acceptance bar: solve_many(n=4) == 4 sequential solves on
    every registered propagation backend (knapsack, seeded)."""
    cms, _, _ = _compile_zoo("knapsack", range(4))
    sess = solver.Solver(solver.SolveConfig.preset(
        "prove", **SMALL, backend=backend))
    many = sess.solve_many(cms)
    seq = [sess.solve(cm) for cm in cms]
    assert [(r.status, r.objective) for r in many] == \
        [(r.status, r.objective) for r in seq]
    assert all(r.status == solver.OPTIMAL for r in many)


def test_solve_many_rejects_shape_mismatch():
    k, _, _ = _compile_zoo("knapsack", range(1))
    q, _, _ = _compile_zoo("nqueens", range(1))
    with pytest.raises(ValueError, match="same-shape"):
        solver.Solver().solve_many([k[0], q[0]])


def test_solve_many_empty():
    assert solver.Solver().solve_many([]) == []


# -------------------------------------------------------------------------
# solve_iter: anytime incumbent stream
# -------------------------------------------------------------------------

def test_solve_iter_monotone_bound_trace():
    """Progress events on seeded RCPSP: the incumbent bound is monotone
    non-increasing, the final event carries the OPTIMAL result, and the
    improvements trace is strictly decreasing down to the optimum."""
    inst = rcpsp.generate(6, n_resources=2, seed=3, edge_prob=0.25)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    sess = solver.Solver(solver.SolveConfig.preset(
        "prove", n_lanes=8, eps_target=16, chunk=4, max_depth=256))
    events = list(sess.solve_iter(cm))
    assert len(events) >= 2, "chunk=4 must yield multiple progress events"
    assert all(not e.final for e in events[:-1]) and events[-1].final

    bounds = [e.best_objective for e in events
              if e.best_objective is not None]
    assert bounds, "no incumbent ever reported"
    assert all(a >= b for a, b in zip(bounds, bounds[1:])), bounds

    res = events[-1].result
    assert res is not None and res.status == solver.OPTIMAL
    imps = res.improvements
    assert imps and imps[-1].objective == res.objective
    assert all(a.objective > b.objective for a, b in zip(imps, imps[1:]))
    assert all(a.superstep <= b.superstep for a, b in zip(imps, imps[1:]))
    # the trace is also on the blocking path
    res2 = sess.solve(cm)
    assert [i.objective for i in res2.improvements] == \
        [i.objective for i in imps]


def test_solve_iter_max_supersteps_anytime():
    """A superstep budget turns into an anytime answer: SAT with the
    best incumbent found so far, not a blocking failure.  Decomposed
    lowering: the native §12 propagators finish this instance inside the
    budget, which would make the early-out unreachable."""
    inst = rcpsp.generate(6, n_resources=2, seed=3, edge_prob=0.25)
    m, _ = rcpsp.build_model(inst, decompose=True)
    cm = m.compile()
    sess = solver.Solver(solver.SolveConfig.preset(
        "prove", n_lanes=4, eps_target=8, chunk=4, max_depth=256,
        max_supersteps=24))
    res = sess.solve(cm)
    assert res.n_supersteps <= 24 + 4       # chunk granularity
    if res.solution is not None:
        assert res.status == solver.SAT     # incumbent, not a proof
        assert not res.complete


# -------------------------------------------------------------------------
# derive_result: the one status derivation (satellite of this PR)
# -------------------------------------------------------------------------

def _sat_cm():
    from repro.core.model import Model
    m = Model("sat")
    x = m.int_var(0, 3, "x")
    y = m.int_var(0, 3, "y")
    m.add(x + y >= 2)                        # satisfaction: no objective
    return m.compile()


def test_derive_result_sat_picks_solution_lane():
    """SAT-mode incumbent pick: the solution must come from a lane with
    has_sol=True, never from argmin of the all-big objective tie (which
    would return lane 0's zeroed best_sol row)."""
    cm = _sat_cm()
    big = np.iinfo(np.int32).max // 4
    L, V = 3, cm.n_vars
    best_obj = np.full((L,), big, np.int32)
    has_sol = np.array([False, False, True])
    best_sol = np.zeros((L, V), np.int32)
    best_sol[2] = np.arange(V)              # only lane 2 holds a solution
    res = engine.derive_result(
        cm, best_obj, has_sol, best_sol, incomplete=np.zeros(L, bool),
        done=True, n_nodes=5, n_fails=1, n_sols=1, n_sweeps=9,
        n_supersteps=4, wall_s=0.1)
    assert res.status == solver.SAT
    assert res.objective is None
    assert (res.solution == best_sol[2]).all()
    assert res.complete


def test_derive_result_statuses():
    cm = _sat_cm()
    L, V = 2, cm.n_vars
    none = dict(best_obj=np.zeros(L, np.int32),
                has_sol=np.zeros(L, bool),
                best_sol=np.zeros((L, V), np.int32),
                incomplete=np.zeros(L, bool),
                n_nodes=0, n_fails=0, n_sols=0, n_sweeps=0,
                n_supersteps=0, wall_s=0.0)
    assert engine.derive_result(cm, done=True, **none).status == \
        solver.UNSAT
    assert engine.derive_result(cm, done=False, **none).status == \
        solver.UNKNOWN
    # depth-limit incompleteness forbids UNSAT even when done
    none["incomplete"] = np.array([True, False])
    r = engine.derive_result(cm, done=True, **none)
    assert r.status == solver.UNKNOWN and not r.complete


def test_derive_result_optimization_statuses():
    inst = knapsack.generate(n=4, seed=0)
    m, _ = knapsack.build_model(inst)
    cm = m.compile()
    L, V = 3, cm.n_vars
    best_obj = np.array([50, -7, 10], np.int32)
    has_sol = np.array([True, True, True])
    best_sol = np.tile(np.arange(V, dtype=np.int32), (L, 1))
    best_sol[1] += 100
    kw = dict(best_obj=best_obj, has_sol=has_sol, best_sol=best_sol,
              incomplete=np.zeros(L, bool), n_nodes=1, n_fails=0,
              n_sols=3, n_sweeps=1, n_supersteps=1, wall_s=0.0)
    r = engine.derive_result(cm, done=True, **kw)
    assert r.status == solver.OPTIMAL and r.objective == -7
    assert (r.solution == best_sol[1]).all()
    r = engine.derive_result(cm, done=False, **kw)
    assert r.status == solver.SAT and r.objective == -7   # incumbent


# -------------------------------------------------------------------------
# engine.solve shim
# -------------------------------------------------------------------------

def test_engine_shim_deprecated_but_equivalent():
    cms, _, _ = _compile_zoo("knapsack", range(1))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = engine.solve(cms[0], n_lanes=4, n_subproblems=8)
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)
            and "engine.solve is deprecated" in str(w.message)]
    # exactly once per call — the shim is the ONLY warner on this path
    # (internal callers all go through Solver sessions now, so the suite
    # stays warning-clean outside this test)
    assert len(deps) == 1, [str(w.message) for w in caught]
    new = solver.Solver(solver.SolveConfig(**SMALL)).solve(cms[0])
    assert legacy.status == new.status == solver.OPTIMAL
    assert legacy.objective == new.objective


def test_engine_shim_maps_search_options():
    cms, _, _ = _compile_zoo("knapsack", range(1))
    opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=128,
                           backend="scatter", stop_on_first=False)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = engine.solve(cms[0], n_lanes=4, n_subproblems=8, opts=opts)
    assert res.status == solver.OPTIMAL


# -------------------------------------------------------------------------
# pool padding (eps.pad_pool)
# -------------------------------------------------------------------------

def test_pad_pool_failed_stores_are_inert():
    """Padded pool == unpadded pool results (pads are born failed)."""
    from repro.core import eps
    inst = rcpsp.generate(5, n_resources=2, seed=1, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    subs = eps.decompose(cm, 6)
    padded = eps.pad_pool(*subs, 16)
    assert padded[0].shape[0] == 16
    assert (padded[0][subs[0].shape[0]:, 0] >
            padded[1][subs[0].shape[0]:, 0]).all()     # failed stores
    sess = solver.Solver(solver.SolveConfig.preset(
        "prove", n_lanes=4, max_depth=256, pad_pool=False))
    a = sess.solve(cm, subs=subs)
    b = sess.solve(cm, subs=padded)
    assert a.status == b.status == solver.OPTIMAL
    assert a.objective == b.objective
