"""EPS decomposition properties and the eps_target solver speedup
(DESIGN.md §9): partition property, UNSAT roots, and same-optimum /
fewer-supersteps vs single-root search."""

import itertools

import numpy as np

from repro.core import baseline, engine, eps, search as S
from util import solve_session
from repro.core.model import Model
from repro.core.models import rcpsp


def _boxes_disjoint(lb_a, ub_a, lb_b, ub_b) -> bool:
    return bool(((lb_a > ub_b) | (lb_b > ub_a)).any())


def test_partition_boxes_pairwise_disjoint_and_consistent():
    """Pool boxes are complementary (left x ≤ m / right x ≥ m+1): any two
    are disjoint on at least one variable, and no failed child survives."""
    inst = rcpsp.generate(5, n_resources=2, seed=7, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    subs_lb, subs_ub = eps.decompose(cm, 12)
    Sn = subs_lb.shape[0]
    assert Sn >= 1
    for i in range(Sn):
        assert (subs_lb[i] <= subs_ub[i]).all()          # failed dropped
        assert (np.asarray(cm.lb0) <= subs_lb[i]).all()  # inside root box
        assert (subs_ub[i] <= np.asarray(cm.ub0)).all()
    for i in range(Sn):
        for j in range(i + 1, Sn):
            assert _boxes_disjoint(subs_lb[i], subs_ub[i],
                                   subs_lb[j], subs_ub[j]), (i, j)


def test_partition_covers_every_solution():
    """Completeness (eps.py docstring): every solution of the root lies in
    exactly one box — brute-forced on a tiny model."""
    m = Model("cover")
    x = m.int_var(0, 3, "x")
    y = m.int_var(0, 3, "y")
    z = m.int_var(0, 6, "z")
    m.add(x + y <= 4)
    m.add((x + y).eq(z * 1))
    m.branch_on([x, y, z])
    cm = m.compile()
    subs_lb, subs_ub = eps.decompose(cm, 6)
    seq = baseline.SequentialSolver(cm)
    lb0, ub0 = np.asarray(cm.lb0), np.asarray(cm.ub0)
    n_solutions = 0
    for xv, yv in itertools.product(range(4), range(4)):
        lb, ub = lb0.copy(), ub0.copy()
        lb[x.idx] = ub[x.idx] = xv
        lb[y.idx] = ub[y.idx] = yv
        if not (seq.propagate(lb, ub) and (lb == ub).all()):
            continue
        n_solutions += 1
        hits = sum(1 for i in range(subs_lb.shape[0])
                   if (subs_lb[i] <= lb).all() and (lb <= subs_ub[i]).all())
        assert hits == 1, (xv, yv, hits)
    assert n_solutions > 0


def test_unsat_root_returns_failed_sub():
    """S >= 1 even for unsatisfiable roots: one explicitly failed store so
    downstream shapes never go empty."""
    m = Model("unsat")
    a = m.int_var(0, 3, "a")
    b = m.int_var(0, 3, "b")
    m.add(a + b >= 9)
    cm = m.compile()
    subs_lb, subs_ub = eps.decompose(cm, 8)
    assert subs_lb.shape[0] >= 1
    assert all((subs_lb[i] > subs_ub[i]).any()
               for i in range(subs_lb.shape[0]))


def test_decompose_hits_target_region():
    """On a wide satisfiable root the pool reaches ~target subproblems."""
    inst = rcpsp.generate(6, n_resources=2, seed=3, edge_prob=0.25)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    for target in (4, 16):
        subs_lb, _ = eps.decompose(cm, target)
        assert subs_lb.shape[0] >= target


def test_eps_target_same_optimum_fewer_supersteps():
    """The acceptance bar: solve(eps_target=n_lanes) matches single-root
    search on seeded RCPSP and takes strictly fewer supersteps.  Uses the
    decomposed lowering: the native §12 propagators solve this instance
    in so few supersteps that the EPS-vs-single-root gap (what this test
    measures) vanishes into the chunk granularity."""
    inst = rcpsp.generate(5, n_resources=2, seed=1, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst, decompose=True)
    cm = m.compile()
    opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=256)
    single = solve_session(cm, n_lanes=8, eps_target=1, opts=opts)
    multi = solve_session(cm, n_lanes=8, eps_target=8, opts=opts)
    assert single.status == multi.status == engine.OPTIMAL
    assert single.objective == multi.objective
    assert multi.n_supersteps < single.n_supersteps


def test_eps_target_matches_default_decomposition():
    """solve(eps_target=8) and the default pool agree on the optimum."""
    inst = rcpsp.generate(5, n_resources=2, seed=0, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=256)
    r_eps = solve_session(cm, n_lanes=8, eps_target=8, opts=opts)
    r_def = solve_session(cm, n_lanes=8, opts=opts)
    assert r_eps.status == r_def.status == engine.OPTIMAL
    assert r_eps.objective == r_def.objective
