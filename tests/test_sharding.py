"""Sharding-rule unit tests (AbstractMesh — no devices needed)."""

import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import configs
from repro.distributed import sharding as SH
from repro.nn import model as MD

# AbstractMesh takes a tuple of (axis_name, size) pairs in this JAX version
MESH1 = AbstractMesh((("data", 16), ("model", 16)))
MESH2 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_spec_divisibility_drops_axis():
    rules = SH.rules_for("train")
    # 130 not divisible by 16 -> replicated
    assert SH.spec_for((130,), ("embed",), rules, MESH1) == P()
    assert SH.spec_for((128,), ("embed",), rules, MESH1) == P("data")


def test_spec_multi_axis_batch():
    rules = SH.rules_for("train")
    s = SH.spec_for((256, 4096), ("batch", None), rules, MESH2)
    assert s == P(("pod", "data"))
    # batch=1 (long_500k): replicate
    s = SH.spec_for((1, 1), ("batch", None), rules, MESH2)
    assert s == P()


def test_no_axis_reuse_within_tensor():
    rules = {"a": ("model",), "b": ("model",)}
    s = SH.spec_for((32, 32), ("a", "b"), rules, MESH1)
    # second dim can't reuse "model"
    assert s == P("model")


def test_param_shardings_cover_all_archs():
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        specs = MD.param_specs(cfg)
        for mesh in (MESH1, MESH2):
            for mode in ("train", "serve"):
                sh = SH.shardings_for_specs(specs, SH.rules_for(mode), mesh)
                for path, s in sh.items():
                    spec = s.spec
                    shape = specs[path].shape
                    # every sharded dim divides
                    for dim, entry in zip(shape, tuple(spec)):
                        if entry is None:
                            continue
                        axes = entry if isinstance(entry, tuple) else (entry,)
                        size = int(np.prod([mesh.shape[a] for a in axes]))
                        assert dim % size == 0, (arch, path, spec)


def test_train_embed_fully_sharded():
    cfg = configs.get("llama3-8b")
    specs = MD.param_specs(cfg)
    sh = SH.shardings_for_specs(specs, SH.rules_for("train"), MESH1)
    assert sh["embed/tok"].spec == P("model", "data")


def test_serve_params_not_zero3():
    """Serve mode avoids per-layer gathers: embed dim replicated.
    (wq is stacked [L, d, H*hd] — layers axis replicated too.)"""
    cfg = configs.get("llama3-8b")
    specs = MD.param_specs(cfg)
    sh = SH.shardings_for_specs(specs, SH.rules_for("serve"), MESH1)
    assert sh["blocks/attn/wq"].spec == P(None, None, "model")
    tr = SH.shardings_for_specs(specs, SH.rules_for("train"), MESH1)
    assert tr["blocks/attn/wq"].spec == P(None, "data", "model")


def test_cache_shardings_mla_latent():
    """Stacked MLA latent caches must shard batch + latent (the 253GB
    replication bug this rule system exists to prevent)."""
    cfg = configs.get("deepseek-v2-236b")
    caches = jax.eval_shape(lambda: MD.init_cache(cfg, 128, 32768))
    sh = SH.cache_shardings(cfg, caches, MESH1)
    spec = sh["blocks"].c_kv.spec
    assert "data" in str(spec) and "model" in str(spec)


def test_cache_shardings_all_archs_valid():
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        B = 8 if arch != "deepseek-v2-236b" else 128
        caches = jax.eval_shape(lambda: MD.init_cache(cfg, 128, 4096))
        for mesh in (MESH1, MESH2):
            sh = SH.cache_shardings(cfg, caches, mesh)
            flat_c = jax.tree_util.tree_leaves(caches)
            flat_s = jax.tree_util.tree_leaves(
                sh, is_leaf=lambda x: hasattr(x, "spec"))
            for c, s in zip(flat_c, flat_s):
                for dim, entry in zip(c.shape, tuple(s.spec)):
                    if entry is None:
                        continue
                    axes = entry if isinstance(entry, tuple) else (entry,)
                    size = int(np.prod([mesh.shape[a] for a in axes]))
                    assert dim % size == 0, (arch, c.shape, s.spec)
