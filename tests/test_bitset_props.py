"""Property tests for the bitset finite-domain lattice (DESIGN.md §17).

The word-level primitives `core/bitset.py` builds Compact-Table and
middle-out branching on — SWAR popcount/ctz/clz, the join/meet lattice
contract, and the `from_bounds`/`to_bounds` interval bridges (a Galois
connection with the bounds lattice) — checked on randomized words and
domains.  Follows the two-driver pattern of tests/test_lattice_props.py:
seeded-numpy always, `hypothesis` on top when installed.  Every law is
checked simultaneously on the jnp primitives and their np_ host mirrors
(the sequential baseline must see the *same* lattice).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitset as B

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

SEEDS = [0, 1, 2, 3, 4]


def _words(seed: int, shape=(64,)):
    rng = np.random.default_rng(seed)
    w = rng.integers(0, 2 ** 32, size=shape, dtype=np.uint64)
    # mix in the adversarial corner words
    corners = np.array([0, 1, 0x80000000, 0xFFFFFFFF, 0xAAAAAAAA,
                        0x55555555, 0x7FFFFFFF, 0xFFFE0001],
                       dtype=np.uint64)
    w.flat[:corners.size] = corners[:min(corners.size, w.size)]
    return w.astype(np.uint32)


def _doms(seed: int, n_vars=16, n_words=2):
    rng = np.random.default_rng(seed)
    dom = rng.integers(0, 2 ** 32, size=(n_vars, n_words),
                       dtype=np.uint64).astype(np.uint32)
    dom[0] = 0                                    # one empty domain
    dom[1] = B.FULL                               # one full domain
    mask = rng.random((n_vars, n_words)) < 0.3    # some sparse ones
    dom[2:] &= np.where(mask[2:], np.uint32(0x01010101), B.FULL)
    return dom


# ---------------------------------------------------------------------------
# property functions (shared by both drivers)
# ---------------------------------------------------------------------------


def check_swar_vs_reference(w):
    """popcount/ctz/clz against int.bit_count-style python references,
    jnp and np mirrors in lockstep."""
    ref_pop = np.array([bin(int(x)).count("1") for x in w], np.uint32)
    ref_ctz = np.array(
        [32 if x == 0 else (int(x) & -int(x)).bit_length() - 1 for x in w],
        np.uint32)
    ref_clz = np.array([32 - int(x).bit_length() for x in w], np.uint32)
    np.testing.assert_array_equal(np.asarray(B.popcount(jnp.asarray(w))),
                                  ref_pop)
    np.testing.assert_array_equal(np.asarray(B.np_popcount(w)), ref_pop)
    np.testing.assert_array_equal(np.asarray(B.ctz(jnp.asarray(w))), ref_ctz)
    np.testing.assert_array_equal(np.asarray(B.clz(jnp.asarray(w))), ref_clz)


def check_join_semilattice(a, b, c):
    """⊔ = AND is ACI, ⊓ = OR is its dual; absorption ties them."""
    ja, jb, jc = (jnp.asarray(x) for x in (a, b, c))
    np.testing.assert_array_equal(np.asarray(B.join(ja, jb)),
                                  np.asarray(B.join(jb, ja)))
    np.testing.assert_array_equal(
        np.asarray(B.join(B.join(ja, jb), jc)),
        np.asarray(B.join(ja, B.join(jb, jc))))
    np.testing.assert_array_equal(np.asarray(B.join(ja, ja)), a)
    np.testing.assert_array_equal(
        np.asarray(B.join(ja, B.meet(ja, jb))), a)      # absorption
    np.testing.assert_array_equal(
        np.asarray(B.meet(ja, B.join(ja, jb))), a)
    # join refines both arguments in information order (a ≤ a⊔b)
    j = B.join(ja, jb)
    assert bool(np.asarray(B.leq(ja, j)).all())
    assert bool(np.asarray(B.leq(jb, j)).all())


def check_count_and_empty(dom):
    ref = np.array([sum(bin(int(w)).count("1") for w in row)
                    for row in dom], np.uint32)
    np.testing.assert_array_equal(np.asarray(B.count(jnp.asarray(dom))), ref)
    np.testing.assert_array_equal(np.asarray(B.np_count(dom)), ref)
    np.testing.assert_array_equal(np.asarray(B.is_empty(jnp.asarray(dom))),
                                  ref == 0)
    np.testing.assert_array_equal(np.asarray(B.np_is_empty(dom)), ref == 0)


def check_bounds_roundtrip(lb, ub, off, n_words):
    """from_bounds/to_bounds form a Galois connection with the interval
    lattice: to_bounds(from_bounds(l, u)) == (l, u) exactly for
    non-empty in-range intervals, and an empty interval packs to the
    all-zero (failed) domain whose hull crosses itself."""
    dom = np.asarray(B.from_bounds(jnp.asarray(lb), jnp.asarray(ub),
                                   jnp.asarray(off), n_words))
    np.testing.assert_array_equal(
        dom, B.np_from_bounds(lb, ub, off, n_words))
    lo, hi = B.to_bounds(jnp.asarray(dom), jnp.asarray(off))
    nlo, nhi = B.np_to_bounds(dom, off)
    np.testing.assert_array_equal(np.asarray(lo), nlo)
    np.testing.assert_array_equal(np.asarray(hi), nhi)
    nonempty = lb <= ub
    np.testing.assert_array_equal(nlo[nonempty], lb[nonempty])
    np.testing.assert_array_equal(nhi[nonempty], ub[nonempty])
    assert (dom[~nonempty] == 0).all()
    assert (nlo[~nonempty] > nhi[~nonempty]).all()
    # membership agrees with the interval on every in-range value
    for v in range(int(off.min()), int(off.min()) + 32 * n_words):
        val = np.full(lb.shape, v)
        want = (lb <= v) & (v <= ub) & (v - off >= 0) & \
               (v - off < 32 * n_words)
        got = np.asarray(B.has_value(jnp.asarray(dom), jnp.asarray(val),
                                     jnp.asarray(off)))
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(B.np_has_value(dom, val, off), want)


def check_hull_vs_enumeration(dom, off):
    """min/max_value equal the enumerated extremes of the set bits."""
    lo, hi = B.to_bounds(jnp.asarray(dom), jnp.asarray(off))
    lo, hi = np.asarray(lo), np.asarray(hi)
    W = dom.shape[-1]
    for v in range(dom.shape[0]):
        bits = [32 * w + k for w in range(W) for k in range(32)
                if (int(dom[v, w]) >> k) & 1]
        if bits:
            assert lo[v] == off[v] + min(bits)
            assert hi[v] == off[v] + max(bits)
        else:
            assert lo[v] == off[v] + 32 * W and hi[v] == off[v] - 1


def check_clear_value(dom, off):
    """np_clear_value removes exactly one membership and is the x ≠ v
    branching tell: monotone (information only grows)."""
    rng = np.random.default_rng(int(dom[2:].sum()) % (2 ** 31))
    vals = off + rng.integers(-4, 32 * dom.shape[-1] + 4, size=dom.shape[0])
    out = B.np_clear_value(dom, vals, off)
    assert not B.np_has_value(out, vals, off).any()
    # only the targeted bit may differ
    diff = dom ^ out
    assert (B.np_popcount(diff).sum(axis=-1) <= 1).all()
    in_range = (vals - off >= 0) & (vals - off < 32 * dom.shape[-1])
    had = B.np_has_value(dom, vals, off)
    np.testing.assert_array_equal(B.np_popcount(diff).sum(axis=-1) == 1,
                                  had & in_range)


def check_low_mask():
    ns = jnp.arange(-3, 36)
    got = np.asarray(B.low_mask(ns))
    want = np.array([(1 << min(max(int(n), 0), 32)) - 1 for n in ns],
                    dtype=np.uint64).astype(np.uint32)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# seeded-numpy driver (always runs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_swar_primitives_seeded(seed):
    check_swar_vs_reference(_words(seed))


def test_low_mask_edges():
    check_low_mask()


@pytest.mark.parametrize("seed", SEEDS)
def test_join_semilattice_seeded(seed):
    a, b, c = _words(seed), _words(seed + 100), _words(seed + 200)
    check_join_semilattice(a, b, c)


@pytest.mark.parametrize("seed", SEEDS)
def test_count_and_empty_seeded(seed):
    check_count_and_empty(_doms(seed))


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("n_words", [1, 2, 3])
def test_bounds_roundtrip_seeded(seed, n_words):
    rng = np.random.default_rng(seed)
    n = 24
    off = rng.integers(-50, 50, size=n)
    lb = off + rng.integers(-2, 32 * n_words + 2, size=n)
    ub = lb + rng.integers(-3, 32 * n_words, size=n)
    lb = np.clip(lb, off, off + 32 * n_words - 1)
    ub = np.clip(ub, off - 1, off + 32 * n_words - 1)
    check_bounds_roundtrip(lb, ub, off, n_words)


@pytest.mark.parametrize("seed", SEEDS)
def test_hull_and_clear_seeded(seed):
    dom = _doms(seed, n_vars=12, n_words=2)
    off = np.random.default_rng(seed + 7).integers(-30, 30, size=12)
    check_hull_vs_enumeration(dom, off)
    check_clear_value(dom, off)


def test_from_bounds_track_pins_full():
    lb = np.array([3, 3])
    ub = np.array([5, 5])
    off = np.array([0, 0])
    track = np.array([1, 0])
    dom = np.asarray(B.from_bounds(jnp.asarray(lb), jnp.asarray(ub),
                                   jnp.asarray(off), 2,
                                   track=jnp.asarray(track)))
    assert dom[0, 0] == 0b111000 and dom[0, 1] == 0
    assert (dom[1] == B.FULL).all()
    np.testing.assert_array_equal(
        dom, B.np_from_bounds(lb, ub, off, 2, track=track))


# ---------------------------------------------------------------------------
# hypothesis driver (richer shrinking search; skipped when not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    word = st.integers(min_value=0, max_value=2 ** 32 - 1)

    @st.composite
    def word_arrays(draw, n=8):
        return np.array([draw(word) for _ in range(n)],
                        dtype=np.uint64).astype(np.uint32)

    @settings(deadline=None, max_examples=40)
    @given(word_arrays(), word_arrays(), word_arrays())
    def test_bitset_laws_hypothesis(a, b, c):
        check_swar_vs_reference(a)
        check_join_semilattice(a, b, c)

    @settings(deadline=None, max_examples=20)
    @given(st.integers(min_value=0, max_value=2 ** 16),
           st.integers(min_value=1, max_value=3))
    def test_bounds_roundtrip_hypothesis(seed, n_words):
        rng = np.random.default_rng(seed)
        off = rng.integers(-50, 50, size=8)
        lb = off + rng.integers(-2, 32 * n_words + 2, size=8)
        ub = lb + rng.integers(-3, 32 * n_words, size=8)
        lb = np.clip(lb, off, off + 32 * n_words - 1)
        ub = np.clip(ub, off - 1, off + 32 * n_words - 1)
        check_bounds_roundtrip(lb, ub, off, n_words)
else:
    @pytest.mark.skip(reason="hypothesis not installed; seeded fallback "
                             "drivers above cover the same properties")
    def test_bitset_laws_hypothesis():
        pass
