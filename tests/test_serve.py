"""Solver-as-a-service (repro.serve + api.LaneBatch, DESIGN.md §15):
continuous-batching scheduler over mixed-shape request streams.

Covers: mixed-shape concurrent admission (separate buckets, no
warm-bucket recompile, bit-identical results vs sequential
`Solver.solve` on every propagation backend), mid-flight warm joins at
chunk boundaries, deadline expiry/eviction honesty (an evicted search
never claims OPTIMAL/UNSAT), pool-padding inertness under continuous
admission (no phantom subproblems from spliced/retired slots), seeded
open-loop trace reproducibility, metrics math, and the threaded
`SolverService` surface.
"""

import time

import numpy as np
import pytest

from repro import solver
from repro.core import eps
from repro.core import models as zoo
from repro.serve import (MetricsRecorder, RequestQueue, SolveRequest,
                         SolverScheduler, SolverService)
from repro.serve import loadgen

SMALL = dict(n_lanes=4, eps_target=8, chunk=8, max_depth=128)
CFG = solver.SolveConfig.preset("prove", **SMALL)


def _cm(name, seed):
    m, _ = zoo.ZOO[name].build_model(zoo.small_instance(name, seed=seed))
    return m.compile()


@pytest.fixture(scope="module")
def sess():
    """One warm session shared by all gather-backend tests (the compile
    cache is keyed by shape x config, so buckets compile once per
    module, not once per test)."""
    return solver.Solver(CFG)


def _sequential(sess_or_cfg, cms):
    s = (sess_or_cfg if isinstance(sess_or_cfg, solver.Solver)
         else solver.Solver(sess_or_cfg))
    return [s.solve(cm) for cm in cms]


# -------------------------------------------------------------------------
# mixed-shape concurrent admission (satellite: bucketing + parity)
# -------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("gather", "scatter", "pallas"))
def test_mixed_shapes_bucket_separately_and_match_sequential(backend):
    cfg = CFG.replace(backend=backend)
    cms = [_cm("knapsack", 0), _cm("jobshop", 0),
           _cm("knapsack", 1), _cm("jobshop", 1)]
    sched = SolverScheduler(cfg, max_batch=2)
    handles = [sched.submit(SolveRequest(cm=cms[i], request_id=f"q{i}"))
               for i in range(4)]
    sched.run_until_drained(max_wall_s=600.0)

    buckets = sched.buckets()
    assert len(buckets) == 2, f"expected 2 shape buckets, got {buckets}"
    for label, b in buckets.items():
        assert b["n_requests"] == 2
        # the warm request joined the bucket WITHOUT a recompile
        assert b["n_compiles"] == 1, (label, b)

    ref = _sequential(cfg, cms)
    for h, r in zip(handles, ref):
        res = h.result()
        assert res.complete
        assert (res.status, res.objective) == (r.status, r.objective)
        if r.solution is None:
            assert res.solution is None
        else:
            assert np.array_equal(res.solution, r.solution)


def test_per_request_config_gets_its_own_bucket(sess):
    cm = _cm("knapsack", 0)
    sched = SolverScheduler(CFG, max_batch=2, session=sess)
    h1 = sched.submit(SolveRequest(cm=cm, request_id="a"))
    h2 = sched.submit(SolveRequest(cm=cm, request_id="b",
                                   config=CFG.replace(n_lanes=2)))
    sched.run_until_drained(max_wall_s=600.0)
    assert len(sched.buckets()) == 2      # compile_key differs => new bucket
    assert h1.result().status == h2.result().status == solver.OPTIMAL
    assert h1.result().objective == h2.result().objective


# -------------------------------------------------------------------------
# LaneBatch: mid-flight joins + honest early retirement
# -------------------------------------------------------------------------

def test_lane_batch_midflight_join_no_recompile(sess):
    """A second request joins the compiled batch at a chunk boundary
    while the first is still searching; both results stay bit-identical
    to sequential solves and nothing recompiles."""
    cfg = CFG.replace(chunk=1)          # finest boundary: 1 superstep
    cms = [_cm("knapsack", 0), _cm("knapsack", 1)]
    batch = sess.lane_batch(cms[0], width=2, config=cfg)
    opts = cfg.search_options()

    def subs(cm):
        return eps.decompose(cm, cfg.resolved_eps_target(), opts)

    batch.splice(0, cms[0], *subs(cms[0]), request_id="first")
    snap = batch.step()
    assert not bool(snap.gdone[0]), "instance too easy for a 1-superstep " \
                                    "chunk; pick a harder one"
    compiles_before_join = batch.runner.n_compiles
    batch.splice(1, cms[1], *subs(cms[1]), request_id="late")
    while not bool(batch.snapshot().gdone.all()):
        snap = batch.step()
    assert batch.runner.n_compiles == compiles_before_join

    t0 = time.time()
    got = [batch.retire(i, wall_s=time.time() - t0) for i in (0, 1)]
    ref = _sequential(cfg, cms)
    for res, r in zip(got, ref):
        assert res.complete
        assert (res.status, res.objective) == (r.status, r.objective)
        assert np.array_equal(res.solution, r.solution)
    assert batch.occupancy == 0 and batch.n_retired == 2


def test_lane_batch_early_retire_never_claims_complete(sess):
    """Deadline-eviction honesty: retiring a slot before its search is
    exhausted derives from the LIVE state (before the freeze), so the
    result can be SAT/UNKNOWN but never a completed OPTIMAL/UNSAT."""
    cfg = CFG.replace(chunk=1)
    cm = _cm("knapsack", 0)
    batch = sess.lane_batch(cm, width=2, config=cfg)
    lb, ub = eps.decompose(cm, cfg.resolved_eps_target(),
                           cfg.search_options())
    batch.splice(0, cm, lb, ub, request_id="evict-me")
    snap = batch.step()
    assert not bool(snap.gdone[0])
    res = batch.retire(0, wall_s=0.01)          # evict mid-search
    assert not res.complete
    assert res.status in (solver.SAT, solver.UNKNOWN)
    if res.status == solver.SAT:
        assert res.solution is not None
    # the slot is reusable and a fresh solve on it is still correct
    batch.splice(0, cm, lb, ub, request_id="again")
    while not bool(batch.snapshot().gdone[0]):
        batch.step()
    res2 = batch.retire(0, wall_s=0.1)
    ref = _sequential(cfg, [cm])[0]
    assert res2.complete
    assert (res2.status, res2.objective) == (ref.status, ref.objective)


def test_lane_batch_slot_misuse_raises(sess):
    cm = _cm("knapsack", 0)
    batch = sess.lane_batch(cm, width=2)
    lb, ub = eps.decompose(cm, CFG.resolved_eps_target(),
                           CFG.search_options())
    with pytest.raises(ValueError, match="idle"):
        batch.retire(0, wall_s=0.0)
    batch.splice(0, cm, lb, ub)
    with pytest.raises(ValueError, match="occupied"):
        batch.splice(0, cm, lb, ub)
    with pytest.raises(ValueError, match="signature"):
        batch.splice(1, _cm("jobshop", 0), lb, ub)


# -------------------------------------------------------------------------
# deadlines
# -------------------------------------------------------------------------

def test_deadline_expired_while_queued_is_unknown(sess):
    """A request whose deadline elapses before it reaches a slot is
    answered UNKNOWN/incomplete without ever occupying a slot."""
    sched = SolverScheduler(CFG, max_batch=2, session=sess)
    h = sched.submit(SolveRequest(cm=_cm("knapsack", 0),
                                  request_id="late", deadline_s=1e-4))
    time.sleep(0.01)                       # let the deadline pass queued
    sched.run_until_drained(max_wall_s=60.0)
    res = h.result()
    assert res.status == solver.UNKNOWN and not res.complete
    assert res.solution is None and res.n_nodes == 0
    rec = sched.recorder.requests["late"]
    assert rec.deadline_missed and rec.t_admit is None


def test_scheduler_deadline_eviction_is_honest(sess):
    """An admitted request evicted at its deadline retires incomplete
    with its best anytime answer — never a claimed proof."""
    cfg = CFG.replace(chunk=1)             # many quanta per solve
    sched = SolverScheduler(cfg, max_batch=1, session=sess)
    h = sched.submit(SolveRequest(cm=_cm("knapsack", 0),
                                  request_id="tight", deadline_s=0.02))
    sched.run_until_drained(max_wall_s=120.0)
    res = h.result()
    if res.complete:                       # solver won the race: fine
        assert res.status in (solver.OPTIMAL, solver.UNSAT)
    else:
        assert res.status in (solver.SAT, solver.UNKNOWN)
        assert sched.recorder.requests["tight"].deadline_missed


def test_request_validation():
    with pytest.raises(ValueError, match="deadline"):
        SolveRequest(cm=None, deadline_s=0.0)
    with pytest.raises(ValueError, match="deadline"):
        SolveRequest(cm=None, deadline_s=-1.0)
    a, b = SolveRequest(cm=None), SolveRequest(cm=None)
    assert a.request_id != b.request_id    # auto ids stay distinct


# -------------------------------------------------------------------------
# pool padding stays inert under continuous admission (regression)
# -------------------------------------------------------------------------

def test_spliced_pool_padding_is_inert(sess):
    """pow2-padded pools on spliced slots + all-failed pools on idle and
    retired slots must add ZERO phantom subproblems: statuses,
    objectives, solutions and solution COUNTS are identical to unpadded
    sequential solves."""
    cms = [_cm("knapsack", s) for s in range(3)]
    ref = _sequential(CFG.replace(pad_pool=False), cms)

    sched = SolverScheduler(CFG, max_batch=2, session=sess)  # pads to bucket
    handles = [sched.submit(SolveRequest(cm=c, request_id=f"p{i}"))
               for i, c in enumerate(cms)]
    sched.run_until_drained(max_wall_s=600.0)
    # 3 requests through 2 slots => at least one slot was retired and
    # re-spliced with the padded pool of a different instance
    (bucket,) = sched.buckets().values()
    assert bucket["n_spliced"] == 3 and bucket["n_retired"] == 3
    for h, r in zip(handles, ref):
        res = h.result()
        assert (res.status, res.objective) == (r.status, r.objective)
        assert res.n_sols == r.n_sols, "padding contributed phantom sols"
        assert np.array_equal(res.solution, r.solution)


def test_fit_pool_and_failed_pool():
    lb = np.zeros((3, 4), np.int32)
    ub = np.ones((3, 4), np.int32)
    flb, fub = eps.fit_pool(lb, ub, 8)
    assert flb.shape == fub.shape == (8, 4)
    assert np.array_equal(flb[:3], lb) and np.array_equal(fub[:3], ub)
    assert (flb[3:, 0] > fub[3:, 0]).all()          # pads explicitly failed
    with pytest.raises(ValueError, match="fit"):
        eps.fit_pool(lb, ub, 2)
    il, iu = eps.failed_pool(lb[0], ub[0], 5)
    assert il.shape == iu.shape == (5, 4)
    assert (il[:, 0] > iu[:, 0]).all()              # every row failed


# -------------------------------------------------------------------------
# open-loop load generation
# -------------------------------------------------------------------------

def test_poisson_trace_is_reproducible_and_mixed():
    t1 = loadgen.poisson_trace(40, 100.0, seed=7)
    t2 = loadgen.poisson_trace(40, 100.0, seed=7)
    assert t1 == t2                                  # frozen dataclasses
    assert t1 != loadgen.poisson_trace(40, 100.0, seed=8)
    assert len({a.model for a in t1}) >= 2           # >= 2 shape buckets
    assert {a.deadline_s for a in t1} == set(loadgen.DEFAULT_DEADLINES)
    times = [a.t_arrival for a in t1]
    assert times == sorted(times) and times[0] > 0.0
    with pytest.raises(ValueError):
        loadgen.poisson_trace(0, 100.0)
    with pytest.raises(ValueError):
        loadgen.poisson_trace(5, 0.0)


def test_open_loop_smoke_matches_sequential(sess):
    """Small end-to-end open-loop run: every completed request
    bit-identical to the sequential reference, batching observed."""
    trace = loadgen.poisson_trace(6, 200.0, seed=3)
    sched = SolverScheduler(CFG, max_batch=2, session=sess)
    handles = loadgen.run_open_loop(sched, trace, max_wall_s=600.0)
    ref = loadgen.sequential_reference(trace, CFG)
    for _, h in handles:
        res = h.result()
        assert res.complete
        assert (res.status, res.objective) == ref[h.request.request_id]
    s = sched.recorder.summary()
    assert s["n_done"] == 6 and s["n_deadline_missed"] == 0
    assert all(b["n_compiles"] <= 1 for b in sched.buckets().values())


# -------------------------------------------------------------------------
# metrics
# -------------------------------------------------------------------------

def test_metrics_summary_math():
    class R:                                 # minimal SolveResult stand-in
        def __init__(self, status, obj, complete):
            self.status, self.objective = status, obj
            self.complete, self.n_supersteps = complete, 5

    m = MetricsRecorder()
    m.record_submit("a", 100.0)
    m.record_admit("a", "b0", 101.0)
    m.record_first_incumbent("a", 102.0)
    m.record_first_incumbent("a", 109.0)     # dedup: first one wins
    m.record_done("a", R("OPTIMAL", 7, True), 103.0)
    m.record_submit("b", 100.5)
    m.record_admit("b", "b0", 100.5)
    m.record_done("b", R("SAT", None, False), 104.5, deadline_missed=True)
    m.sample_queue_depth(2)
    m.sample_occupancy("b0", 2, 4)
    s = m.summary()
    assert s["n_requests"] == s["n_done"] == 2
    assert s["n_deadline_missed"] == 1
    assert s["statuses"] == {"OPTIMAL": 1, "SAT": 1}
    assert s["ttfi_s"]["p50"] == 2.0         # 102 - 100, dedup held
    assert s["latency_s"]["max"] == 4.0      # b: 104.5 - 100.5
    assert s["tto_s"]["n"] == 1 and s["tto_s"]["p50"] == 3.0
    assert s["queue_wait_s"]["max"] == 1.0
    assert s["batch_occupancy"]["p50"] == 0.5
    assert s["span_s"] == 4.5                # 100.0 .. 104.5
    assert s["instances_per_sec"] == round(2 / 4.5, 2)


def test_request_queue_thread_safety_smoke():
    q = RequestQueue()
    assert len(q) == 0 and q.drain() == []
    q.push(1)
    q.push(2)
    assert len(q) == 2
    assert q.drain() == [1, 2] and len(q) == 0


# -------------------------------------------------------------------------
# the Progress timing contract (shared with the superstep bench)
# -------------------------------------------------------------------------

def test_progress_t_host_is_the_single_timing_source(sess):
    """`Progress.t_host` is the absolute host clock at emission and
    `wall_s` the elapsed-since-solve-start clock; their difference is
    the solve-start epoch, constant across the stream — the one timing
    source the serving metrics and the superstep bench both consume."""
    cm = _cm("knapsack", 0)
    t_before = time.time()
    events = list(sess.solve_iter(cm))
    t_after = time.time()
    assert events and events[-1].final
    hosts = [ev.t_host for ev in events]
    assert hosts == sorted(hosts)
    assert all(t_before <= h <= t_after for h in hosts)
    starts = [ev.t_host - ev.wall_s for ev in events]
    assert max(starts) - min(starts) < 1e-6
    assert events[-1].wall_s == events[-1].result.wall_s


# -------------------------------------------------------------------------
# threaded service surface
# -------------------------------------------------------------------------

def test_solver_service_threaded_submit_and_stream(sess):
    cms = [_cm("knapsack", s) for s in range(3)]
    ref = _sequential(sess, cms)
    with SolverService(CFG, max_batch=2, session=sess) as svc:
        handles = [svc.submit(c, request_id=f"t{i}")
                   for i, c in enumerate(cms)]
        events = list(handles[0].events(timeout=600.0))
        assert events and events[-1].final
        assert events[-1].result is not None
        for h, r in zip(handles, ref):
            res = h.result(timeout=600.0)
            assert (res.status, res.objective) == (r.status, r.objective)
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(cms[0])
