"""Compressed gradient all-reduce: numerics + traffic claim (subprocess
with 8 fake devices)."""

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_int8_psum_mean_accuracy_and_int8_wire():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.compat import make_mesh, shard_map
from repro.distributed.collectives import int8_psum_mean, psum_mean

mesh = make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32)) * 0.01

f = jax.jit(shard_map(partial(int8_psum_mean, axis_name="pod"),
                      mesh=mesh,
                      in_specs=jax.sharding.PartitionSpec("pod"),
                      out_specs=jax.sharding.PartitionSpec("pod")))
g = jax.jit(shard_map(partial(psum_mean, axis_name="pod"),
                      mesh=mesh,
                      in_specs=jax.sharding.PartitionSpec("pod"),
                      out_specs=jax.sharding.PartitionSpec("pod")))
approx = np.asarray(f(x))
exact = np.asarray(g(x))
# error bound: quantization step = max|x|/127; after averaging unchanged
step = float(jnp.max(jnp.abs(x))) / 127
err = np.abs(approx - exact).max()
assert err <= step, (err, step)
# the wire payload is int8 (s8 all-reduce in the HLO)
txt = f.lower(x).compile().as_text()
assert "s32" in txt and ("s8[" in txt or "convert" in txt)
assert err > 0  # it IS lossy (sanity that compression really happened)
print("INT8_OK", err, step)
""")
    assert "INT8_OK" in out


def test_pod_sync_grads_tree():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.distributed.collectives import pod_sync_grads

mesh = make_mesh((2, 4), ("pod", "data"))
grads = {"a/w": jnp.ones((4, 4)) * 2.0, "b/w": -jnp.ones((3,))}
out = pod_sync_grads(grads, mesh, axis="pod", compress=True)
for k in grads:
    np.testing.assert_allclose(np.asarray(out[k]), np.asarray(grads[k]),
                               atol=0.05)
# no 'pod' axis in mesh -> no-op
mesh2 = make_mesh((8,), ("data",))
out2 = pod_sync_grads(grads, mesh2, axis="pod")
assert out2 is grads
print("POD_SYNC_OK")
""")
    assert "POD_SYNC_OK" in out
