"""Typed propagator table (DESIGN.md §12): native AllDifferent /
Cumulative propagators vs their ReifLinLe decompositions.

Three layers of guarantees:

* **unit semantics** — Hall-interval pruning / pigeonhole failure for
  `alldiff_candidates_tile`, compulsory-part filtering / overload failure
  for `cumulative_candidates_tile`;
* **parity oracles** — on seeded zoo instances the native lowering and
  the ``decompose=True`` lowering (the pre-§12 blowup) prove the same
  optima, and the sequential event-driven solver (`core/baseline.py`,
  which runs its own numpy transcription of the kind tiles) agrees;
* **backend bit-parity + size regression** — the kind-dispatched fixpoint
  is bit-identical across gather/scatter/pallas on mixed-bank stores, and
  the native tables stay ≥2× smaller than the decompositions on
  n-queens/jobshop (the ISSUE-4 acceptance bar).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import solver
from repro.core import baseline
from repro.core import models as zoo
from repro.core.backend import get_backend
from repro.core.fixpoint import fixpoint
from repro.core.model import Model
from repro.core.models import coloring, jobshop, nqueens, rcpsp

SMALL = dict(n_lanes=8, eps_target=16, timeout_s=300.0, max_depth=256)


# --------------------------------------------------------------------------
# unit semantics of the kind tiles (via single-store fixpoint)
# --------------------------------------------------------------------------

def test_alldiff_bounds_consistency_prunes():
    """x=0 fixed forces y=1 then z=2 (Hall intervals [0,0], [0,1])."""
    m = Model("ad-chain")
    x = m.int_var(0, 0, "x")
    y = m.int_var(0, 1, "y")
    z = m.int_var(0, 2, "z")
    m.alldifferent([x, y, z])
    cm = m.compile()
    lb, ub, _, conv = fixpoint(cm, cm.lb0, cm.ub0)
    lb, ub = np.asarray(lb), np.asarray(ub)
    assert bool(conv)
    assert (lb[1:] == [0, 1, 2]).all() and (ub[1:] == [0, 1, 2]).all()


def test_alldiff_pigeonhole_fails():
    """3 vars over 2 values: |{k : dom ⊆ [0,1]}| = 3 > 2 ⇒ fail."""
    m = Model("ad-pigeon")
    vs = [m.int_var(0, 1, f"v{i}") for i in range(3)]
    m.alldifferent(vs)
    cm = m.compile()
    lb, ub, _, _ = fixpoint(cm, cm.lb0, cm.ub0)
    assert bool((np.asarray(lb) > np.asarray(ub)).any())


def test_alldiff_offsets_shift_the_clash():
    """With offsets (0, 1), x=0 and y=0 do NOT clash (0 ≠ 1) but x=1,
    y=0 would (1 = 0+1): bounds must reflect the shifted view."""
    m = Model("ad-offs")
    x = m.int_var(1, 1, "x")
    y = m.int_var(0, 1, "y")
    m.alldifferent([x, y], offsets=[0, 1])
    cm = m.compile()
    lb, ub, _, _ = fixpoint(cm, cm.lb0, cm.ub0)
    lb, ub = np.asarray(lb), np.asarray(ub)
    assert lb[y.idx] == ub[y.idx] == 1   # y+1 must avoid x=1 ⇒ y=1 (→2)


def test_cumulative_compulsory_part_pushes_lb():
    """s0 fixed at 0 (dur 2) occupies [0,2); cap 1 pushes s1 to ≥ 2."""
    m = Model("cu-push")
    s0 = m.int_var(0, 0, "s0")
    s1 = m.int_var(0, 3, "s1")
    m.cumulative([s0, s1], [2, 2], [1, 1], 1)
    cm = m.compile()
    lb, ub, _, _ = fixpoint(cm, cm.lb0, cm.ub0)
    assert int(np.asarray(lb)[s1.idx]) == 2


def test_cumulative_overload_fails():
    """Two unit tasks pinned to t=0 with demands 1+1 > cap 1 ⇒ fail."""
    m = Model("cu-over")
    a = m.int_var(0, 0, "a")
    b = m.int_var(0, 0, "b")
    m.cumulative([a, b], [1, 1], [1, 1], 1)
    cm = m.compile()
    lb, ub, _, _ = fixpoint(cm, cm.lb0, cm.ub0)
    assert bool((np.asarray(lb) > np.asarray(ub)).any())


def test_cumulative_rejects_negative_start_domains():
    """The time-table grid is [0, horizon): a negative feasible start
    would be silently pruned, so compile must refuse instead."""
    m = Model("cu-neg")
    s = m.int_var(-3, -1, "s")
    m.cumulative([s], [1], [1], 1)
    with pytest.raises(ValueError, match="negative domain"):
        m.compile()


def test_cumulative_zero_duration_and_demand_inert():
    """Zero-duration / zero-demand tasks never constrain anything."""
    m = Model("cu-inert")
    a = m.int_var(0, 0, "a")
    b = m.int_var(0, 5, "b")
    m.cumulative([a, b], [0, 3], [7, 0], 1)
    cm = m.compile()
    lb, ub, _, conv = fixpoint(cm, cm.lb0, cm.ub0)
    assert bool(conv)
    np.testing.assert_array_equal(np.asarray(lb), np.asarray(cm.lb0))
    np.testing.assert_array_equal(np.asarray(ub), np.asarray(cm.ub0))


# --------------------------------------------------------------------------
# native vs decomposed parity oracles (+ sequential event-driven solver)
# --------------------------------------------------------------------------

def _zoo_pair(name, seed):
    mod = zoo.ZOO[name]
    inst = zoo.small_instance(name, seed=seed)
    mn, hn = mod.build_model(inst)
    md, _ = mod.build_model(inst, decompose=True)
    return mod, inst, hn, mn.compile(), md.compile()


@pytest.mark.parametrize("name", ["nqueens", "coloring", "jobshop", "rcpsp"])
@pytest.mark.parametrize("seed", [0, 1])
def test_native_matches_decomposed_optimum(name, seed):
    """Same proven optimum from the native table and the pre-§12
    decomposition, and the ground checker accepts the native solution."""
    mod, inst, hn, cmn, cmd = _zoo_pair(name, seed)
    sess = solver.Solver(solver.SolveConfig.preset("prove", **SMALL))
    rn = sess.solve(cmn)
    rd = sess.solve(cmd)
    assert rn.status == rd.status == solver.OPTIMAL
    assert rn.objective == rd.objective
    assert zoo.ground_check(mod, inst, hn, rn) is True


@pytest.mark.parametrize("name", ["nqueens", "jobshop", "rcpsp"])
def test_sequential_solver_handles_native_kinds(name):
    """The event-driven CPU baseline (its own numpy kind transcriptions)
    proves the same optimum on the native lowering."""
    mod, inst, hn, cmn, _ = _zoo_pair(name, seed=2)
    cfg = solver.SolveConfig.preset("prove", **SMALL)
    rs = baseline.SequentialSolver(cmn, cfg.search_options()).solve(
        timeout_s=120)
    rp = solver.Solver(cfg).solve(cmn)
    assert rs.status == rp.status == solver.OPTIMAL
    assert rs.objective == rp.objective


def test_unsat_parity_native_vs_decomposed():
    """An over-constrained instance is UNSAT under both lowerings."""
    m = Model("unsat-native")
    vs = [m.int_var(0, 2, f"v{i}") for i in range(4)]
    m.alldifferent(vs)          # 4 vars, 3 values
    m.minimize(vs[0])
    m.branch_on(vs)
    md = Model("unsat-decomp")
    ws = [md.int_var(0, 2, f"w{i}") for i in range(4)]
    md.alldifferent(ws, decompose=True)
    md.minimize(ws[0])
    md.branch_on(ws)
    sess = solver.Solver(solver.SolveConfig.preset("prove", **SMALL))
    rn, rd = sess.solve(m.compile()), sess.solve(md.compile())
    assert rn.status == rd.status == solver.UNSAT


# --------------------------------------------------------------------------
# 3-way backend bit-parity of the kind-dispatched fixpoint
# --------------------------------------------------------------------------

def _mixed_model():
    """One model exercising every bank: linear rows + 2 alldiffs
    (one with offsets) + a cumulative."""
    m = Model("mixed")
    q = [m.int_var(0, 4, f"q{i}") for i in range(5)]
    mk = m.int_var(0, 12, "mk")
    m.alldifferent(q)
    m.alldifferent(q, offsets=list(range(5)))
    m.cumulative(q, [2, 1, 2, 1, 2], [1, 2, 1, 1, 2], 3)
    for qi in q:
        m.add(qi + 1 <= mk)
    m.minimize(mk)
    m.branch_on(q)
    return m.compile()


def test_backend_bit_parity_mixed_banks():
    """gather / scatter / pallas reach identical fixpoints (equal failed
    masks, bit-identical non-failed stores) on stores that exercise all
    three banks, including failing ones."""
    cm = _mixed_model()
    rng = np.random.default_rng(12)
    V = cm.n_vars
    L = 6
    lb0, ub0 = np.asarray(cm.lb0), np.asarray(cm.ub0)
    lbs = np.tile(lb0, (L, 1))
    ubs = np.tile(ub0, (L, 1))
    for i in range(1, L):       # random consistent-or-not tightenings
        for _ in range(3):
            v = int(rng.integers(1, V))
            lbs[i, v] = rng.integers(lb0[v], ub0[v] + 1)
            ubs[i, v] = rng.integers(lbs[i, v] - 1, ub0[v] + 1)
    lbs, ubs = jnp.asarray(lbs), jnp.asarray(ubs)
    ref_l, ref_u, _, ref_c = get_backend("gather").fixpoint_batch(
        cm, lbs, ubs)
    ref_l, ref_u = np.asarray(ref_l), np.asarray(ref_u)
    failed = (ref_l > ref_u).any(axis=1)
    assert bool(np.asarray(ref_c).all())
    for name in ("scatter", "pallas"):
        be = get_backend(name, **(dict(lane_tile=4) if name == "pallas"
                                  else {}))
        al, au, _, conv = be.fixpoint_batch(cm, lbs, ubs)
        al, au = np.asarray(al), np.asarray(au)
        np.testing.assert_array_equal(failed, (al > au).any(axis=1),
                                      err_msg=f"failed mask: {name}")
        ok = ~failed
        np.testing.assert_array_equal(ref_l[ok], al[ok], err_msg=name)
        np.testing.assert_array_equal(ref_u[ok], au[ok], err_msg=name)
        assert bool(np.asarray(conv).all()), name


@pytest.mark.parametrize("backend", ["gather", "scatter", "pallas"])
def test_backend_identical_objectives_native(backend):
    """End-to-end: every backend proves the same optimum on the native
    zoo lowerings (the ISSUE-4 acceptance criterion)."""
    sess = solver.Solver(solver.SolveConfig.preset(
        "prove", backend=backend, **SMALL))
    for name in ("nqueens", "jobshop", "rcpsp"):
        mod, inst, hn, cmn, _ = _zoo_pair(name, seed=0)
        res = sess.solve(cmn)
        ref = solver.Solver(solver.SolveConfig.preset(
            "prove", **SMALL)).solve(cmn)
        assert res.status == ref.status == solver.OPTIMAL, name
        assert res.objective == ref.objective, name


# --------------------------------------------------------------------------
# propagator-count regression guard
# --------------------------------------------------------------------------

def test_native_tables_smaller():
    """Native P < decomposed P on every switched model, and ≥2× smaller
    on n-queens / jobshop (the ISSUE-4 bar); fewer variables too (no
    fresh reification booleans)."""
    for name, min_ratio in (("nqueens", 2.0), ("jobshop", 2.0),
                            ("coloring", 1.0), ("rcpsp", 1.0)):
        mod = zoo.ZOO[name]
        inst = zoo.bench_instance(name, seed=0)
        cmn = mod.build_model(inst)[0].compile()
        cmd = mod.build_model(inst, decompose=True)[0].compile()
        assert cmn.total_props < cmd.total_props, name
        assert cmd.total_props >= min_ratio * cmn.total_props, (
            name, cmn.total_props, cmd.total_props)
        assert cmn.n_vars <= cmd.n_vars, name


def test_counts_visible_on_compiled_model():
    """`total_props` decomposes into the per-kind statics."""
    cm = _mixed_model()
    assert cm.total_props == cm.n_props + cm.n_alldiff + cm.n_cumulative
    assert cm.n_alldiff == 2 and cm.n_cumulative == 1
