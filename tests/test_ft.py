"""Fault tolerance: atomic checkpoints, bit-exact kill-and-resume,
heartbeat failure detection, elastic re-mesh, lane rebalance."""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLM
from repro.ft.fault_tolerance import (FailureInjector, Heartbeat,
                                      TrainSupervisor, rebalance_lanes,
                                      scaled_batch)
from repro.nn import model as MD
from repro.nn.layers import init_params
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import train_step


def _tiny_setup(tmp, ckpt_every=5):
    cfg = configs.get_smoke("qwen2.5-3b")
    data = SyntheticLM(cfg, seq_len=16, global_batch=4, seed=0)
    key = jax.random.PRNGKey(0)
    params = init_params(MD.param_specs(cfg), key)
    opt = init_opt_state(params)
    ocfg = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20,
                     schedule="cosine")
    jstep = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, ocfg,
                                               remat=False, chunks=(8, 8)))

    def step_fn(params, opt_state, step):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        return jstep(params, opt_state, batch)

    return params, opt, step_fn


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp)
        params = {"a/b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
        opt = init_opt_state(params)
        ck.save(7, params, opt)
        step, p2, o2 = ck.restore()
        assert step == 7
        np.testing.assert_array_equal(p2["a/b"], np.asarray(params["a/b"]))
        assert o2["step"] == 0


def test_checkpoint_retention_and_latest():
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp, keep=2)
        params = {"w": jnp.ones(3)}
        opt = init_opt_state(params)
        for s in (1, 2, 3, 4):
            ck.save(s, params, opt)
        assert ck.steps() == [3, 4]
        assert ck.latest_step() == 4


def test_kill_and_resume_bit_exact():
    """A run killed at step 10 and resumed must end bit-identical to an
    uninterrupted run (deterministic data + optimizer)."""
    with tempfile.TemporaryDirectory() as tmp:
        params, opt, step_fn = _tiny_setup(tmp)

        # uninterrupted reference
        p_ref, o_ref = params, opt
        for s in range(14):
            p_ref, o_ref, _ = step_fn(p_ref, o_ref, s)

        # interrupted: supervisor checkpoints every 5; run to 10, "crash"
        ck = Checkpointer(os.path.join(tmp, "ck"))
        sup = TrainSupervisor(ck, ckpt_every=5)
        sup.run(params, opt, step_fn, n_steps=10)
        # resume a fresh supervisor (simulates restarted process)
        ck2 = Checkpointer(os.path.join(tmp, "ck"))
        sup2 = TrainSupervisor(ck2, ckpt_every=5)
        p_res, o_res, _ = sup2.run(params, opt, step_fn, n_steps=14)

        for k in p_ref:
            np.testing.assert_array_equal(np.asarray(p_ref[k]),
                                          np.asarray(p_res[k]), err_msg=k)


def test_heartbeat_failure_detection():
    t = {"now": 0.0}
    hb = Heartbeat(["h0", "h1", "h2"], timeout_s=10, clock=lambda: t["now"])
    inj = FailureInjector({3: ["h1"]})
    for step in range(6):
        t["now"] += 5.0
        inj.advance(step, hb)
    assert hb.dead_hosts() == ["h1"]


def test_supervisor_invokes_failure_path():
    with tempfile.TemporaryDirectory() as tmp:
        params, opt, step_fn = _tiny_setup(tmp)
        t = {"now": 0.0}
        hb = Heartbeat(["h0", "h1"], timeout_s=1, clock=lambda: t["now"])

        def clockstep(p, o, s):
            t["now"] += 2.0
            return step_fn(p, o, s)

        sup = TrainSupervisor(Checkpointer(tmp), ckpt_every=100,
                              heartbeat=hb, injector=FailureInjector(
                                  {4: ["h1"]}))
        seen = {}

        def on_failure(dead, step, log):
            seen["dead"] = dead
            seen["step"] = step
            return None

        sup.run(params, opt, clockstep, n_steps=20, on_failure=on_failure)
        assert seen["dead"] == ["h1"] and seen["step"] >= 4


def test_scaled_batch():
    assert scaled_batch(256, 16) == 16
    assert scaled_batch(256, 15) == 17


def test_rebalance_lanes():
    # lane 0 exhausted, lane 1 has 4 subproblems queued
    next_sub = np.array([20, 1], dtype=np.int64)     # n_lanes=2, n_subs=9
    done = np.array([True, False])
    ns, dn, moved = rebalance_lanes(next_sub, done, n_subs=9, n_lanes=2)
    assert moved == 1
    assert not dn[0]                  # revived
    assert ns[0] in (7,)              # stole the donor's last queued sub


def test_elastic_remesh_subprocess():
    """Re-shard a params tree from an 8-device mesh to a 4-device mesh in
    a subprocess with fake devices; values must be preserved."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.ft.fault_tolerance import elastic_remesh
from repro.distributed import sharding as SH
from repro.nn import model as MD
from repro import configs

cfg = configs.get_smoke("llama3-8b")
specs = MD.param_specs(cfg)
rules = SH.rules_for("train")

def mk(n):
    from repro.compat import make_mesh
    return make_mesh((n,), ("data",))

mesh8, mesh4 = mk(8), mk(4)
from repro.nn.layers import init_params
params = init_params(specs, jax.random.PRNGKey(0))
sh8 = SH.shardings_for_specs(specs, rules, mesh8)
params8 = jax.tree.map(jax.device_put, params, sh8)
params4 = elastic_remesh(params8,
                         mesh4,
                         lambda m: SH.shardings_for_specs(specs, rules, m))
for k in params:
    np.testing.assert_array_equal(np.asarray(params[k]),
                                  np.asarray(params4[k]))
    assert len(params4[k].sharding.mesh.devices.flatten()) == 4
print("ELASTIC_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
