"""Per-architecture smoke tests (deliverable f): reduced config of the
same family, one forward + one train step on CPU, output shapes + no
NaNs; decode-vs-teacher-forcing consistency for the cache machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.nn import model as MD
from repro.nn.layers import init_params
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import train_step

B, S = 2, 16
CHUNKS = (8, 8)


def make_batch(cfg, key, with_labels=True):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok}
    if with_labels:
        batch["labels"] = jnp.roll(tok, -1, axis=1)
    if cfg.frontend == "vit_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(MD.param_specs(cfg), key)
    batch = make_batch(cfg, key, with_labels=False)
    logits, aux = MD.forward_train(params, cfg, batch, remat=False,
                                   chunks=CHUNKS)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_one_train_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(MD.param_specs(cfg), key)
    opt = init_opt_state(params)
    batch = make_batch(cfg, key)
    ocfg = OptConfig(warmup_steps=1, total_steps=10)
    p2, opt2, metrics = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg, ocfg, remat=True,
                                   chunks=CHUNKS))(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(bool(jnp.any(p2[k] != params[k])) for k in params)
    assert moved
    # no NaNs anywhere
    for k, v in p2.items():
        assert bool(jnp.all(jnp.isfinite(v))), k


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(MD.param_specs(cfg), key)
    batch = make_batch(cfg, key, with_labels=False)
    tok = batch["tokens"]
    full, _ = MD.forward_train(params, cfg, batch, remat=False,
                               chunks=CHUNKS)
    pb = dict(batch)
    pb["tokens"] = tok[:, :S - 1]
    lg_pre, caches = MD.forward_prefill(params, cfg, pb, smax=32,
                                        chunks=CHUNKS)
    lg_dec, _ = MD.forward_decode(params, cfg, tok[:, S - 1:S], caches,
                                  chunks=(1, 8))
    scale = max(float(jnp.max(jnp.abs(full))), 1.0)
    assert float(jnp.max(jnp.abs(lg_pre - full[:, S - 2]))) < 0.08 * scale
    assert float(jnp.max(jnp.abs(lg_dec - full[:, S - 1]))) < 0.08 * scale


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-1.3b",
                                  "recurrentgemma-2b",
                                  "seamless-m4t-large-v2"])
def test_unrolled_matches_scanned(arch):
    """unroll_scans() (roofline accounting mode) is numerically identical
    to the production scan path."""
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(3)
    params = init_params(MD.param_specs(cfg), key)
    batch = make_batch(cfg, key, with_labels=False)
    a, _ = MD.forward_train(params, cfg, batch, remat=False, chunks=CHUNKS)
    with MD.unroll_scans():
        b, _ = MD.forward_train(params, cfg, batch, remat=False,
                                chunks=CHUNKS)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-2)


def test_full_configs_match_assignment():
    """Exact numbers from the assignment table."""
    t = {a: configs.get(a) for a in configs.ARCH_IDS}
    c = t["deepseek-v2-236b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (60, 5120, 128,
                                                           102400)
    assert c.moe.n_experts == 160 and c.moe.top_k == 6 and c.moe.n_shared == 2
    assert c.mla.kv_lora == 512
    c = t["dbrx-132b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (40, 6144,
                                                                48, 8)
    assert c.moe.n_experts == 16 and c.moe.top_k == 4
    c = t["pixtral-12b"]
    assert (c.n_layers, c.d_model, c.vocab) == (40, 5120, 131072)
    c = t["qwen3-4b"]
    assert c.qk_norm and (c.n_layers, c.d_ff) == (36, 9728)
    c = t["minicpm-2b"]
    assert (c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (2304, 36, 36,
                                                            5760)
    c = t["qwen2.5-3b"]
    assert c.qkv_bias and (c.d_model, c.n_kv_heads) == (2048, 2)
    c = t["llama3-8b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (32, 4096, 14336,
                                                        128256)
    c = t["recurrentgemma-2b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (26, 2560,
                                                                10, 1)
    assert c.rglru.window == 2048
    c = t["seamless-m4t-large-v2"]
    assert c.encdec.enc_layers == 24 and c.encdec.dec_layers == 24
    assert (c.d_model, c.vocab) == (1024, 256206)
    c = t["mamba2-1.3b"]
    assert (c.n_layers, c.d_model, c.vocab) == (48, 2048, 50280)
    assert c.ssm.state == 128


def test_param_counts_plausible():
    """Full-config parameter counts are in the advertised ballpark."""
    expect = {"deepseek-v2-236b": (200e9, 260e9),
              "dbrx-132b": (120e9, 140e9),
              "pixtral-12b": (11e9, 14e9),
              "qwen3-4b": (3e9, 5e9),
              "minicpm-2b": (2e9, 3.3e9),
              "qwen2.5-3b": (2.7e9, 3.7e9),
              "llama3-8b": (7e9, 9e9),
              "recurrentgemma-2b": (2e9, 3.5e9),
              "seamless-m4t-large-v2": (1.2e9, 2.8e9),
              "mamba2-1.3b": (1e9, 1.6e9)}
    for a, (lo, hi) in expect.items():
        n = configs.get(a).n_params()
        assert lo <= n <= hi, (a, n)
