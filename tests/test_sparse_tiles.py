"""Sparse occurrence banks and segmented kind tiles (DESIGN.md §16).

The contract under test: the CSR-packed sparse AllDifferent/Cumulative
tiles are *bit-identical* to the dense O(N³)/O(C·T·H) tiles on every
backend and at per-sweep granularity; the compile-time crossover picks
the layout from the static shape signature alone (and the signature
distinguishes the layouts, so cached runners never mix them); the dense
guard refuses un-allocatable tiles with a byte estimate; and EPS pool
padding stays inert under the sparse layout.

The `large`-marked tests solve the scale tier end-to-end (nqueens-256
to proven optimum on gather and pallas) — minutes, not seconds, so they
run only under ``REPRO_RUN_LARGE=1``.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api, eps, search as S
from repro.core.backend import get_backend
from repro.core.compile import (DENSE_TILE_MAX_BYTES,
                                alldiff_dense_tile_bytes,
                                alldiff_sparse_tile_bytes)
from repro.core.fixpoint import fixpoint_batch, sweep_batch, \
    sweep_scatter_batch
from repro.core.model import Model
from repro.core.models import ZOO, ground_check, large_instance, nqueens, \
    rcpsp
from util import random_substores, solve_session

ALL = ("gather", "scatter", "pallas")


def _pallas_kw(name, lanes):
    return dict(lane_tile=min(4, lanes)) if name == "pallas" else {}


def _compile_pair(m):
    """(dense, sparse) compilations of one model — same arrays, forced
    layouts."""
    return m.compile(bank_layout="dense"), m.compile(bank_layout="sparse")


def _models():
    """Models with real AllDifferent / Cumulative banks, mid-sized enough
    that the sparse segment logic sees multi-row segments."""
    out = []
    m, _ = nqueens.build_model(nqueens.generate(9, seed=0))
    out.append(("nqueens-9", m))
    m, _ = rcpsp.build_model(rcpsp.generate(7, n_resources=2, seed=3,
                                            edge_prob=0.3))
    out.append(("rcpsp-7", m))
    return out


# ---------------------------------------------------------------------------
# bit parity: sparse vs dense, per sweep and at the fixpoint, all backends
# ---------------------------------------------------------------------------

def test_sparse_dense_per_sweep_parity():
    """Every individual sweep of the sparse tiles is bit-identical to the
    dense tiles (not just the fixpoint): sweep k of sparse == sweep k of
    dense for k = 1..5, gather and scatter forms."""
    for name, m in _models():
        dn, sp = _compile_pair(m)
        assert dn.ad_layout == dn.cu_layout == "dense"
        assert "sparse" in (sp.ad_layout, sp.cu_layout)
        rng = np.random.default_rng(11)
        lbs, ubs = random_substores(rng, dn, 5)
        dl = sl = jnp.asarray(lbs)
        du = su = jnp.asarray(ubs)
        for k in range(5):
            dl, du = sweep_batch(dn, dl, du)
            sl, su = sweep_batch(sp, sl, su)
            np.testing.assert_array_equal(
                np.asarray(dl), np.asarray(sl),
                err_msg=f"{name} sweep {k} lb")
            np.testing.assert_array_equal(
                np.asarray(du), np.asarray(su),
                err_msg=f"{name} sweep {k} ub")
        # scatter form too
        dl, du = sweep_scatter_batch(dn, jnp.asarray(lbs), jnp.asarray(ubs))
        sl, su = sweep_scatter_batch(sp, jnp.asarray(lbs), jnp.asarray(ubs))
        np.testing.assert_array_equal(np.asarray(dl), np.asarray(sl),
                                      err_msg=f"{name} scatter lb")
        np.testing.assert_array_equal(np.asarray(du), np.asarray(su),
                                      err_msg=f"{name} scatter ub")


def test_sparse_dense_fixpoint_parity_all_backends():
    """Dense and sparse compilations reach bit-identical fixpoints on
    every backend (the cross-layout analogue of the backend parity
    gate), on the non-failed stores, with identical failed masks."""
    for name, m in _models():
        dn, sp = _compile_pair(m)
        rng = np.random.default_rng(23)
        lbs, ubs = random_substores(rng, dn, 6)
        lbs, ubs = jnp.asarray(lbs), jnp.asarray(ubs)
        L = int(lbs.shape[0])
        ref_l, ref_u, _, _ = get_backend("gather").fixpoint_batch(
            dn, lbs, ubs)
        ref_l, ref_u = np.asarray(ref_l), np.asarray(ref_u)
        failed = (ref_l > ref_u).any(axis=1)
        ok = ~failed
        for be in ALL:
            al, au, _, _ = get_backend(be, **_pallas_kw(be, L)) \
                .fixpoint_batch(sp, lbs, ubs)
            al, au = np.asarray(al), np.asarray(au)
            np.testing.assert_array_equal(
                failed, (al > au).any(axis=1),
                err_msg=f"{name}/{be} sparse failed-mask")
            np.testing.assert_array_equal(ref_l[ok], al[ok],
                                          err_msg=f"{name}/{be} lb")
            np.testing.assert_array_equal(ref_u[ok], au[ok],
                                          err_msg=f"{name}/{be} ub")


def test_sparse_dense_capped_pallas_parity():
    """Bounded chaotic iteration stays deterministic across layouts on
    the kernel path: max_iters=k pallas sweeps agree with the dense
    gather reference sweeps for k = 1, 2."""
    m, _ = nqueens.build_model(nqueens.generate(8, seed=1))
    dn, sp = _compile_pair(m)
    rng = np.random.default_rng(5)
    lbs, ubs = random_substores(rng, dn, 4)
    lbs, ubs = jnp.asarray(lbs), jnp.asarray(ubs)
    for k in (1, 2):
        gl, gu, _, _ = get_backend("gather").fixpoint_batch(
            dn, lbs, ubs, max_iters=k)
        pl, pu, _, _ = get_backend("pallas", lane_tile=4).fixpoint_batch(
            sp, lbs, ubs, max_iters=k)
        np.testing.assert_array_equal(np.asarray(gl), np.asarray(pl))
        np.testing.assert_array_equal(np.asarray(gu), np.asarray(pu))


def test_sparse_dense_solve_parity():
    """End-to-end: identical status/objective dense vs sparse through
    the full search engine."""
    for name, m in _models():
        dn, sp = _compile_pair(m)
        rd = solve_session(dn, n_lanes=8, eps_target=16, timeout_s=60.0)
        rs = solve_session(sp, n_lanes=8, eps_target=16, timeout_s=60.0)
        assert (rd.status, rd.objective) == (rs.status, rs.objective), name


# ---------------------------------------------------------------------------
# crossover dispatch, cache keys, and the dense guard
# ---------------------------------------------------------------------------

def test_auto_crossover_picks_layouts():
    """Small banks stay dense; banks whose dense tile exceeds
    DENSE_TILE_MAX_BYTES go sparse — decided at compile time from the
    static shapes alone."""
    small = nqueens.build_model(nqueens.generate(8, seed=0))[0].compile()
    assert small.ad_layout == "dense"
    it = small.jdtype.itemsize
    assert alldiff_dense_tile_bytes(small.n_alldiff, small.ad_width,
                                    it) <= DENSE_TILE_MAX_BYTES

    big = nqueens.build_model(nqueens.generate(64, seed=0))[0].compile()
    assert big.ad_layout == "sparse"
    it = big.jdtype.itemsize
    assert alldiff_dense_tile_bytes(big.n_alldiff, big.ad_width,
                                    it) > DENSE_TILE_MAX_BYTES
    assert alldiff_sparse_tile_bytes(big.ad_packed, it) \
        < alldiff_dense_tile_bytes(big.n_alldiff, big.ad_width, it)


def test_forced_layout_overrides():
    m, _ = nqueens.build_model(nqueens.generate(8, seed=0))
    assert m.compile(bank_layout="dense").ad_layout == "dense"
    assert m.compile(bank_layout="sparse").ad_layout == "sparse"
    assert m.compile(bank_layout="auto").ad_layout == "dense"
    with pytest.raises(ValueError, match="bank_layout"):
        m.compile(bank_layout="csr")


def test_layout_in_shape_signature():
    """Dense and sparse compilations of the same model must never share
    a cached runner: their shape signatures differ."""
    m, _ = nqueens.build_model(nqueens.generate(8, seed=0))
    dn, sp = _compile_pair(m)
    assert api.shape_signature(dn) != api.shape_signature(sp)
    # and re-compiling the same layout is signature-stable
    assert api.shape_signature(dn) == \
        api.shape_signature(m.compile(bank_layout="dense"))


def test_dense_guard_raises_with_byte_estimate():
    """Forcing dense on a scale-tier bank refuses to compile, naming the
    tile size and the sparse escape hatch."""
    m, _ = nqueens.build_model(nqueens.generate(256, seed=0))
    with pytest.raises(ValueError) as ei:
        m.compile(bank_layout="dense")
    msg = str(ei.value)
    assert "bytes" in msg and "sparse" in msg


def test_negative_capacity_rejected():
    m = Model("badcap")
    xs = [m.int_var(0, 5, f"s{i}") for i in range(3)]
    m.cumulative(xs, [2, 2, 2], [1, 1, 1], -1)
    m.branch_on(xs)
    with pytest.raises(ValueError, match="capacity"):
        m.compile()


# ---------------------------------------------------------------------------
# pool-size bucketing and EPS padding under the sparse layout
# ---------------------------------------------------------------------------

def test_bucket_pow2_then_1024_multiples():
    assert api._bucket(1) == 1
    assert api._bucket(3) == 4
    assert api._bucket(1000) == 1024
    assert api._bucket(1024) == 1024
    # the §16 cap: beyond 1024 the bucket grows by 1024-multiples, not
    # doublings — a 2500-sub pool allocates 3072 rows, not 4096
    assert api._bucket(1025) == 2048
    assert api._bucket(2500) == 3072
    assert api._bucket(4100) == 5120          # pow2 would have been 8192
    for n in (1, 7, 900, 1025, 1200, 5000):
        b = api._bucket(n)
        assert b >= n
        assert api._bucket(b) == b            # idempotent on bucket sizes


def test_pad_pool_inert_under_sparse_layout():
    """Padded (explicitly failed) pool rows stay frozen through sparse
    kind tiles: zero sweeps, bounds untouched, still failed — so bucket
    padding can never perturb statuses/objectives (eps.pad_pool's
    contract)."""
    m, _ = nqueens.build_model(nqueens.generate(8, seed=0))
    sp = m.compile(bank_layout="sparse")
    subs_lb, subs_ub = eps.decompose(sp, 3)
    n_real = subs_lb.shape[0]
    pl, pu = eps.pad_pool(subs_lb, subs_ub, n_real + 5)
    pad = np.zeros(pl.shape[0], bool)
    pad[n_real:] = True
    assert (pl[pad, 0] > pu[pad, 0]).all()     # padded rows arrive failed
    for be in ALL:
        al, au, sweeps, _ = get_backend(be, **_pallas_kw(be, pl.shape[0])) \
            .fixpoint_batch(sp, jnp.asarray(pl), jnp.asarray(pu))
        np.testing.assert_array_equal(np.asarray(al)[pad], pl[pad],
                                      err_msg=f"{be}: padded lb moved")
        np.testing.assert_array_equal(np.asarray(au)[pad], pu[pad],
                                      err_msg=f"{be}: padded ub moved")
        assert int(np.asarray(sweeps)[pad].max(initial=0)) == 0, be


# ---------------------------------------------------------------------------
# the scale tier end-to-end (REPRO_RUN_LARGE=1)
# ---------------------------------------------------------------------------

@pytest.mark.large
@pytest.mark.parametrize("backend", ("gather", "pallas"))
def test_rcpsp_96_proven_optimum(backend):
    """rcpsp-96 (98 vars, sparse Cumulative banks) solves to PROVEN
    optimum end-to-end on the sparse path — the scale-tier proof that
    actually completes on a single CPU core (seconds on gather)."""
    inst = large_instance("rcpsp", seed=0)
    m, handles = ZOO["rcpsp"].build_model(inst)
    cm = m.compile()
    assert cm.cu_layout == "sparse"
    opts = S.SearchOptions(backend=backend,
                           backend_opts=(dict(lane_tile=1)
                                         if backend == "pallas" else ()))
    res = solve_session(cm, n_lanes=8, eps_target=16, opts=opts,
                        timeout_s=1800.0)
    from repro import solver
    assert res.status == solver.OPTIMAL
    assert ground_check(ZOO["rcpsp"], inst, handles, res) is True


@pytest.mark.large
@pytest.mark.parametrize("backend", ("gather", "pallas"))
def test_nqueens_256_proven_optimum(backend):
    """nqueens-256 compiles onto the sparse AllDifferent layout (dense
    would need a ~805 MB tile and refuses to compile) and solves to
    PROVEN optimum.

    Honesty note: the *propagation* at this size is fully verified in
    the always-on tests above (bit parity with dense, all backends);
    completing this end-to-end proof needs accelerator-scale lane
    counts — on this container's single CPU core the search phase
    times out for reasons that predate the sparse tiles (plain
    backtracking already stalls on DENSE nqueens-32), which is exactly
    the paper's motivation for GPU-scale parallel search."""
    inst = large_instance("nqueens", seed=0)
    m, handles = ZOO["nqueens"].build_model(inst)
    cm = m.compile()
    assert cm.ad_layout == "sparse"
    opts = S.SearchOptions(var_strategy=S.MIN_DOM,
                           val_strategy=S.VAL_SPLIT, backend=backend,
                           backend_opts=(dict(lane_tile=1)
                                         if backend == "pallas" else ()))
    res = solve_session(cm, n_lanes=64, eps_target=256, opts=opts,
                        max_supersteps=200000, timeout_s=3600.0)
    from repro import solver
    assert res.status == solver.OPTIMAL
    assert ground_check(ZOO["nqueens"], inst, handles, res) is True
