"""Pallas flash-attention vs dense oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def dense_ref(q, k, v, causal):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    kr = jnp.repeat(k, H // KV, axis=2)
    vr = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))


@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk,causal", [
    (2, 64, 4, 4, 16, 16, 16, True),
    (1, 64, 4, 2, 32, 32, 16, True),     # GQA
    (2, 64, 2, 1, 16, 16, 32, True),     # MQA
    (1, 64, 2, 2, 16, 64, 64, False),    # single block, bidirectional
    (1, 48, 2, 2, 16, 16, 16, True),     # ragged-pad path
])
def test_flash_matches_dense(B, S, H, KV, hd, bq, bk, causal):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd),
                          jnp.bfloat16)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    ref = dense_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_flash_matches_blocked_xla():
    """Cross-check the two attention implementations against each other."""
    from repro.nn.attention import blocked_attention
    key = jax.random.PRNGKey(3)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, hd),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, KV, hd),
                          jnp.bfloat16)
    pos = jnp.arange(S)
    a = flash_attention(q, k, v, causal=True, bq=16, bk=16)
    b = blocked_attention(q, k, v, pos, pos, causal=True, q_chunk=16,
                          kv_chunk=16)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=3e-2)
