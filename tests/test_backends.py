"""Backend-parity tests for the propagation-backend layer (core/backend.py).

The three registered backends — gather (XLA sweep), scatter (join oracle)
and pallas (VMEM kernel, interpret-mode on CPU) — must reach identical
least fixed points on lane-batched [L, V] stores, per the comparison spec
of kernels/ops.py: equal failed-lane masks, bit-identical stores on every
non-failed lane (integer lattice ⇒ exact equality, no tolerance).

Seeded-random instances keep these property-shaped without requiring
`hypothesis` (which the offline container lacks); the loops below are the
batched-path extension of the gather/scatter oracle tests in
test_semantics.py / test_kernels.py.
"""

import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine, search as S
from repro.core.backend import (PropagationBackend, available_backends,
                                get_backend, register_backend)
from repro.core.fixpoint import fixpoint, fixpoint_batch
from repro.core.models import rcpsp
from util import random_model, random_substores, solve_session

ALL = ("gather", "scatter", "pallas")


def _pallas_kw(name, lanes):
    return dict(lane_tile=min(4, lanes)) if name == "pallas" else {}


def _assert_parity(cm, lbs, ubs, max_iters=None):
    lbs, ubs = jnp.asarray(lbs), jnp.asarray(ubs)
    L = int(lbs.shape[0])
    ref_l, ref_u, _, ref_conv = get_backend("gather").fixpoint_batch(
        cm, lbs, ubs, max_iters=max_iters)
    ref_l, ref_u = np.asarray(ref_l), np.asarray(ref_u)
    failed = (ref_l > ref_u).any(axis=1)
    ok = ~failed
    for name in ("scatter", "pallas"):
        al, au, _, conv = get_backend(name, **_pallas_kw(name, L)) \
            .fixpoint_batch(cm, lbs, ubs, max_iters=max_iters)
        al, au = np.asarray(al), np.asarray(au)
        np.testing.assert_array_equal(failed, (al > au).any(axis=1),
                                      err_msg=f"failed-mask mismatch: {name}")
        np.testing.assert_array_equal(ref_l[ok], al[ok], err_msg=name)
        np.testing.assert_array_equal(ref_u[ok], au[ok], err_msg=name)
        if max_iters is None:
            # uncapped: every backend must report a genuine fixed point
            assert bool(np.asarray(ref_conv).all())
            assert bool(np.asarray(conv).all()), name
    return failed


def test_backend_parity_random_rcpsp_batched():
    """Seeded random RCPSP instances: all backends agree on batched
    fixpoints (the acceptance-criterion property test)."""
    saw_failed = saw_ok = False
    for seed in range(4):
        inst = rcpsp.generate(4 + seed, n_resources=2, seed=seed,
                              edge_prob=0.3)
        m, _ = rcpsp.build_model(inst)
        cm = m.compile()
        rng = np.random.default_rng(100 + seed)
        lbs, ubs = random_substores(rng, cm, 6)
        failed = _assert_parity(cm, lbs, ubs)
        saw_failed |= bool(failed.any())
        saw_ok |= bool((~failed).any())
    assert saw_ok          # the property must have exercised live lanes


def test_backend_parity_random_models_batched():
    """Random mixed plain/reified models, including failing stores."""
    for seed in range(6):
        rng = np.random.default_rng(seed)
        cm = random_model(rng, n_vars=2 + seed, n_props=3 + 2 * seed) \
            .compile()
        lbs, ubs = random_substores(rng, cm, 5)
        _assert_parity(cm, lbs, ubs)


def test_backend_parity_capped_iters():
    """With a sweep cap the XLA backends stay bit-identical (bounded
    chaotic iteration is deterministic); converged flags must then be
    honest: unconverged lanes may exist."""
    inst = rcpsp.generate(6, n_resources=2, seed=7, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    rng = np.random.default_rng(7)
    lbs, ubs = random_substores(rng, cm, 4)
    lbs, ubs = jnp.asarray(lbs), jnp.asarray(ubs)
    gl, gu, gs, gc = get_backend("gather").fixpoint_batch(cm, lbs, ubs,
                                                          max_iters=1)
    sl, su, ss, sc = get_backend("scatter").fixpoint_batch(cm, lbs, ubs,
                                                           max_iters=1)
    np.testing.assert_array_equal(np.asarray(gl), np.asarray(sl))
    np.testing.assert_array_equal(np.asarray(gu), np.asarray(su))
    assert int(np.asarray(gs).max()) <= 1
    # honesty of the convergence flags (search's §Perf H1 guard depends on
    # it): lanes stopped by the cap must NOT claim a fixed point — and the
    # root stores here genuinely need >1 sweep, so some lane is unconverged
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(sc))
    assert not bool(np.asarray(gc).all())
    # sanity that the cap was the reason: uncapped, all lanes converge
    _, _, _, full_c = get_backend("gather").fixpoint_batch(cm, lbs, ubs)
    assert bool(np.asarray(full_c).all())


def test_batched_matches_vmapped_single_store():
    """fixpoint_batch is bit-identical to vmap(fixpoint) — stores, sweep
    counts and convergence flags (the hoisting is a pure refactor)."""
    rng = np.random.default_rng(42)
    cm = random_model(rng, n_vars=7, n_props=11).compile()
    lbs, ubs = random_substores(rng, cm, 8)
    lbs, ubs = jnp.asarray(lbs), jnp.asarray(ubs)
    vl, vu, vi, vc = jax.vmap(lambda l, u: fixpoint(cm, l, u))(lbs, ubs)
    bl, bu, bi, bc = fixpoint_batch(cm, lbs, ubs)
    np.testing.assert_array_equal(np.asarray(vl), np.asarray(bl))
    np.testing.assert_array_equal(np.asarray(vu), np.asarray(bu))
    np.testing.assert_array_equal(np.asarray(vi), np.asarray(bi))
    np.testing.assert_array_equal(np.asarray(vc), np.asarray(bc))


def test_single_store_entry_point():
    """The protocol's single-store fixpoint agrees with the batch of 1."""
    rng = np.random.default_rng(5)
    cm = random_model(rng, n_vars=5, n_props=8).compile()
    lbs, ubs = random_substores(rng, cm, 1)
    for name in ALL:
        be = get_backend(name, **_pallas_kw(name, 1))
        sl, su, _, _ = be.fixpoint(cm, jnp.asarray(lbs[0]),
                                   jnp.asarray(ubs[0]))
        bl, bu, _, _ = be.fixpoint_batch(cm, jnp.asarray(lbs),
                                         jnp.asarray(ubs))
        np.testing.assert_array_equal(np.asarray(sl), np.asarray(bl)[0])
        np.testing.assert_array_equal(np.asarray(su), np.asarray(bu)[0])


def test_registry_roundtrip_and_unknown():
    assert set(ALL) <= set(available_backends())
    for name in ALL:
        be = get_backend(name)
        assert isinstance(be, PropagationBackend)
        assert be.name == name
    with pytest.raises(ValueError, match="unknown propagation backend"):
        get_backend("cuda")
    # registration is open: downstream tuned kernels can claim a name
    class _Probe(type(get_backend("gather"))):
        name = "probe"
    register_backend("probe", _Probe)
    try:
        assert get_backend("probe").name == "probe"
    finally:
        from repro.core import backend as B
        del B._REGISTRY["probe"]


def test_engine_solves_with_every_backend():
    """solve_session(..., opts=SearchOptions(backend=...)) end-to-end on
    CPU for all three backends, identical optimum and node counts (the
    superstep is deterministic regardless of propagation strategy)."""
    inst = rcpsp.generate(5, n_resources=2, seed=3, edge_prob=0.3)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    results = {}
    for name in ALL:
        opts = S.SearchOptions(
            var_strategy=S.MIN_LB, max_depth=128, backend=name,
            backend_opts=((("lane_tile", 4),) if name == "pallas" else ()))
        results[name] = solve_session(cm, n_lanes=4, n_subproblems=8,
                                     opts=opts, timeout_s=600, chunk=64)
    ref = results["gather"]
    assert ref.status == engine.OPTIMAL
    for name, res in results.items():
        assert res.status == engine.OPTIMAL, name
        assert res.objective == ref.objective, name
        assert res.n_nodes == ref.n_nodes, name


def test_search_propagation_is_batched():
    """Structural guard for the acceptance criterion: the search module
    has no per-lane fixpoint call left — propagation enters only through
    the backend layer's batched entry point."""
    import ast
    import inspect
    from repro.core import search
    tree = ast.parse(inspect.getsource(search))
    calls = [n.func.attr if isinstance(n.func, ast.Attribute) else
             getattr(n.func, "id", None)
             for n in ast.walk(tree) if isinstance(n, ast.Call)]
    assert "fixpoint" not in calls          # single-store form is gone
    assert "fixpoint_batch" in calls        # batched backend call is there
