"""Compact-Table extensional propagation (DESIGN.md §17).

Four layers of guarantees for the bitset subsystem + CT propagator kind:

* **unit semantics** — support filtering on hand-checked chains
  (including hole pruning no bounds propagator can see), wipeout
  failure, `Model.table` validation (arity, out-of-domain tuples, the
  empty table);
* **per-sweep bit-parity** — `sweep_batch` (gather) and
  `sweep_scatter_batch` produce bit-identical `(lb, ub, dom)` after
  EVERY sweep, and the fused resident megakernel reproduces K unfused
  `lanes_step` supersteps field-for-field (dom included) on a table
  model under middle-out branching;
* **parity oracles** — native CT vs the ``decompose=True`` reified
  disjunction, the sequential baseline (its own numpy transcription),
  and all four backends prove the same status/objective on the new zoo
  models, ground-checked;
* **statics** — `shape_signature` separates table layouts; the VMEM
  budget grows by the CT scratch + bitset stores.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import solver
from repro.core import api, baseline, eps, models as zoo, search as S
from repro.core import bitset as B
from repro.core import fixpoint as F
from repro.core.backend import get_backend
from repro.core.model import Model
from repro.kernels import fixpoint_kernel as FK

SMALL = dict(n_lanes=8, eps_target=16, timeout_s=300.0, max_depth=256)


def _chain_model():
    """x,y,z ∈ (0,4), table(x,y) ∘ table(y,z), x ≥ 1 — the hand-checked
    fixpoint is x∈[1,3], y∈[2,4], z∈[1,2]."""
    m = Model("ct-chain")
    x = m.int_var(0, 4, "x")
    y = m.int_var(0, 4, "y")
    z = m.int_var(0, 4, "z")
    m.table([x, y], [(0, 1), (1, 2), (3, 4), (4, 0)])
    m.table([y, z], [(1, 3), (2, 2), (4, 1)])
    m.add(x >= 1)
    m.minimize(x)
    m.branch_on([x, y, z])
    return m.compile(), (x, y, z)


def _mixed_ct_model(decompose=False):
    """Tables + a linear objective coupling — every bank in one model."""
    m = Model("ct-mixed")
    xs = [m.int_var(0, 5, f"x{i}") for i in range(4)]
    m.table(xs, [(0, 1, 2, 3), (1, 2, 3, 4), (2, 3, 4, 5),
                 (5, 4, 3, 2), (0, 2, 4, 1)], decompose=decompose)
    m.table([xs[0], xs[3]], [(0, 3), (2, 3), (5, 2), (1, 4)],
            decompose=decompose)
    obj = m.int_var(0, 30, "obj")
    for c in (xs[0] * 3 + xs[1]).eq(obj):
        m.add(c)
    m.minimize(obj)
    m.branch_on(xs)
    return m.compile()


# --------------------------------------------------------------------------
# unit semantics
# --------------------------------------------------------------------------

def test_ct_chain_filters_to_hand_checked_hull():
    cm, (x, y, z) = _chain_model()
    lb0, ub0 = jnp.asarray(cm.lb0)[None], jnp.asarray(cm.ub0)[None]
    dom0 = B.from_bounds(lb0, ub0, jnp.asarray(cm.dom_off), cm.n_words,
                         track=jnp.asarray(cm.dom_track))
    nlb, nub, dom, _, conv = F.fixpoint_batch(cm, lb0, ub0, dom0)
    assert bool(conv[0])
    idx = [x.idx, y.idx, z.idx]
    np.testing.assert_array_equal(np.asarray(nlb)[0, idx], [1, 2, 1])
    np.testing.assert_array_equal(np.asarray(nub)[0, idx], [3, 4, 2])


def test_ct_prunes_holes_bounds_cannot_see():
    """dom carries holes across constraints: with x restricted to the
    supported {1, 3} (a hull no bounds propagator can shrink), the
    second table sees the hole at x=2 and drops y=5."""
    m = Model("ct-holes")
    x = m.int_var(0, 4, "x")
    y = m.int_var(0, 9, "y")
    m.table([x], [(1,), (3,)])
    m.table([x, y], [(1, 0), (2, 5), (3, 7)])
    m.minimize(y)
    m.branch_on([x, y])
    cm = m.compile()
    dom0 = B.from_bounds(jnp.asarray(cm.lb0)[None],
                         jnp.asarray(cm.ub0)[None],
                         jnp.asarray(cm.dom_off), cm.n_words,
                         track=jnp.asarray(cm.dom_track))
    nlb, nub, dom, _, conv = F.fixpoint_batch(
        cm, jnp.asarray(cm.lb0)[None], jnp.asarray(cm.ub0)[None], dom0)
    assert bool(conv[0])
    # bounds alone would keep x∈[1,3] hence y up to 7 *with* y=5 alive;
    # the bitset knows x=2 is gone, so y ∈ {0, 7}
    assert not bool(np.asarray(
        B.has_value(dom[:, y.idx], jnp.asarray([5]),
                    jnp.asarray(cm.dom_off)[y.idx][None]))[0])
    assert int(np.asarray(nlb)[0, y.idx]) == 0
    assert int(np.asarray(nub)[0, y.idx]) == 7


def test_ct_wipeout_fails():
    m = Model("ct-wipe")
    x = m.int_var(0, 3, "x")
    y = m.int_var(0, 3, "y")
    m.table([x, y], [(0, 1), (1, 2)])
    m.table([x, y], [(2, 3), (3, 0)])
    m.branch_on([x, y])
    cm = m.compile()
    lb, ub, _, _ = F.fixpoint(cm, cm.lb0, cm.ub0)
    assert bool((np.asarray(lb) > np.asarray(ub)).any())


def test_table_validation():
    m = Model("ct-bad")
    x = m.int_var(0, 3, "x")
    y = m.int_var(0, 3, "y")
    with pytest.raises(ValueError, match="arity"):
        m.table([x, y], [(1, 2, 3)])
    # out-of-domain tuples are dropped; an empty table is trivially false
    m2 = Model("ct-empty")
    a = m2.int_var(0, 3, "a")
    b = m2.int_var(0, 3, "b")
    m2.table([a, b], [(9, 9), (-1, 2)])
    m2.branch_on([a, b])
    cm = m2.compile()
    res = solver.Solver(solver.SolveConfig.preset("prove", **SMALL)) \
        .solve(cm)
    assert res.status == solver.UNSAT


# --------------------------------------------------------------------------
# per-sweep bit-parity
# --------------------------------------------------------------------------

def test_gather_scatter_bit_identical_per_sweep():
    """Every individual sweep — not just the fixpoint — produces the
    same (lb, ub, dom) words from the gather and scatter strategies."""
    cm = _mixed_ct_model()
    rng = np.random.default_rng(3)
    V, L = cm.n_vars, 6
    lbs = np.tile(np.asarray(cm.lb0), (L, 1))
    ubs = np.tile(np.asarray(cm.ub0), (L, 1))
    for i in range(1, L):
        for _ in range(2):
            v = int(rng.integers(0, 4))
            lbs[i, v] = rng.integers(lbs[i, v], ubs[i, v] + 1)
    gl = sl = jnp.asarray(lbs)
    gu = su = jnp.asarray(ubs)
    gd = sd = B.from_bounds(gl, gu, jnp.asarray(cm.dom_off), cm.n_words,
                            track=jnp.asarray(cm.dom_track))
    for sweep in range(6):
        gl, gu, gd = F.sweep_batch(cm, gl, gu, gd)
        sl, su, sd = F.sweep_scatter_batch(cm, sl, su, sd)
        np.testing.assert_array_equal(np.asarray(gl), np.asarray(sl),
                                      err_msg=f"lb sweep {sweep}")
        np.testing.assert_array_equal(np.asarray(gu), np.asarray(su),
                                      err_msg=f"ub sweep {sweep}")
        np.testing.assert_array_equal(np.asarray(gd), np.asarray(sd),
                                      err_msg=f"dom sweep {sweep}")


def test_backend_fixpoint_parity_with_dom():
    """gather / scatter / pallas land on bit-identical (lb, ub, dom)
    fixpoints on table stores (equal failed masks)."""
    cm = _mixed_ct_model()
    L = 5
    lbs = np.tile(np.asarray(cm.lb0), (L, 1))
    ubs = np.tile(np.asarray(cm.ub0), (L, 1))
    lbs[1, 0] = 3                      # forces table filtering
    ubs[2, 1] = 2
    lbs[3, 0] = 5
    ubs[3, 3] = 1                      # infeasible with the second table
    lbs, ubs = jnp.asarray(lbs), jnp.asarray(ubs)
    dom = B.from_bounds(lbs, ubs, jnp.asarray(cm.dom_off), cm.n_words,
                        track=jnp.asarray(cm.dom_track))
    rl, ru, rd, _, rc = get_backend("gather").fixpoint_batch(
        cm, lbs, ubs, dom=dom)
    rl, ru = np.asarray(rl), np.asarray(ru)
    failed = (rl > ru).any(axis=1)
    assert failed[3] and not failed[0]
    assert bool(np.asarray(rc).all())
    for name in ("scatter", "pallas"):
        be = get_backend(name, **(dict(lane_tile=4) if name == "pallas"
                                  else {}))
        al, au, ad, _, conv = be.fixpoint_batch(cm, lbs, ubs, dom=dom)
        al, au = np.asarray(al), np.asarray(au)
        np.testing.assert_array_equal(failed, (al > au).any(axis=1),
                                      err_msg=f"failed mask: {name}")
        ok = ~failed
        np.testing.assert_array_equal(rl[ok], al[ok], err_msg=name)
        np.testing.assert_array_equal(ru[ok], au[ok], err_msg=name)
        np.testing.assert_array_equal(np.asarray(rd)[ok],
                                      np.asarray(ad)[ok], err_msg=name)
        assert bool(np.asarray(conv).all()), name


@pytest.mark.parametrize("supersteps", [4, 16])
def test_resident_fused_bit_parity_with_dom(supersteps):
    """K fused supersteps in the megakernel equal K unfused `lanes_step`
    iterations field-for-field — including the bitset stores — on a
    table model under middle-out branching (the §17 resident path)."""
    inst = zoo.small_instance("crossword", seed=0)
    cm = zoo.ZOO["crossword"].build_model(inst)[0].compile()
    opts = S.SearchOptions(max_depth=64, val_strategy=S.VAL_MIDDLE_OUT)
    subs_lb, subs_ub = eps.decompose(cm, 8, opts)
    subs_lb, subs_ub = jnp.asarray(subs_lb), jnp.asarray(subs_ub)
    st0 = S.init_lanes(cm, 8, opts)
    assert st0.dom is not None         # table model: bitset store active
    gbest = jnp.asarray(jnp.iinfo(cm.jdtype).max // 4, cm.jdtype)
    ref_st, ref_gbest = st0, gbest
    pool_head = jnp.zeros((), jnp.int32)
    it = 0
    for _ in range(supersteps):
        if bool(np.asarray(ref_st.done).all()):
            break
        ref_st, pool_head = S.lanes_step(cm, subs_lb, subs_ub, opts,
                                         ref_st, ref_gbest, pool_head)
        ref_gbest = jnp.minimum(ref_gbest, S.lanes_best(ref_st, cm.jdtype))
        it += 1
    st, gbest2, it2, head, _ = FK.search_pallas(
        cm, subs_lb, subs_ub, st0, gbest, jnp.asarray(0, jnp.int32),
        jnp.zeros((1,), jnp.int32), supersteps=supersteps, lane_tile=0,
        val_strategy=S.VAL_MIDDLE_OUT, interpret=True)
    for f in S.LaneState._fields:
        av, bv = getattr(ref_st, f), getattr(st, f)
        assert (av is None) == (bv is None), f
        if av is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(av).astype(np.int64),
            np.asarray(bv).astype(np.int64),
            err_msg=f"LaneState.{f} diverged")
    assert int(gbest2) == int(ref_gbest)
    assert int(it2) == it
    assert int(head[0]) == int(pool_head)


# --------------------------------------------------------------------------
# parity oracles on the zoo models
# --------------------------------------------------------------------------

def _zoo_pair(name, seed):
    mod = zoo.ZOO[name]
    inst = zoo.small_instance(name, seed=seed)
    mn, hn = mod.build_model(inst)
    md, _ = mod.build_model(inst, decompose=True)
    return mod, inst, hn, mn.compile(), md.compile()


@pytest.mark.parametrize("name", ["crossword", "configuration"])
@pytest.mark.parametrize("seed", [0, 1])
def test_native_ct_matches_decomposed_optimum(name, seed):
    """Native CT and the reified-disjunction oracle prove the same
    optimum, and the ground checker accepts the native solution."""
    mod, inst, hn, cmn, cmd = _zoo_pair(name, seed)
    sess = solver.Solver(solver.SolveConfig.preset("prove", **SMALL))
    rn, rd = sess.solve(cmn), sess.solve(cmd)
    assert rn.status == rd.status == solver.OPTIMAL
    assert rn.objective == rd.objective
    assert zoo.ground_check(mod, inst, hn, rn) is True


@pytest.mark.parametrize("backend", ["scatter", "pallas", "pallas_resident"])
@pytest.mark.parametrize("name", ["crossword", "configuration"])
def test_all_backends_same_objective_on_tables(backend, name):
    """Every backend proves the gather optimum on the CT zoo models —
    the §17 acceptance bar."""
    mod, inst, hn, cmn, _ = _zoo_pair(name, seed=0)
    ref = solver.Solver(solver.SolveConfig.preset(
        "prove", **SMALL)).solve(cmn)
    res = solver.Solver(solver.SolveConfig.preset(
        "prove", backend=backend, **SMALL)).solve(cmn)
    assert ref.status == res.status == solver.OPTIMAL, name
    assert ref.objective == res.objective, name
    assert zoo.ground_check(mod, inst, hn, res) is True


@pytest.mark.parametrize("name", ["crossword", "configuration"])
@pytest.mark.parametrize("val_strategy",
                         [S.VAL_MIN, S.VAL_SPLIT, S.VAL_MIDDLE_OUT])
def test_sequential_baseline_agrees(name, val_strategy):
    """The event-driven CPU baseline (numpy CT transcription + bitset
    DFS stack) proves the same optimum under every value strategy."""
    mod, inst, hn, cmn, _ = _zoo_pair(name, seed=1)
    cfg = solver.SolveConfig.preset("prove", val_strategy=val_strategy,
                                    **SMALL)
    rs = baseline.SequentialSolver(cmn, cfg.search_options()).solve(
        timeout_s=120)
    rp = solver.Solver(cfg).solve(cmn)
    assert rs.status == rp.status == solver.OPTIMAL
    assert rs.objective == rp.objective


def test_middle_out_on_boundless_model_matches_split():
    """middle_out works on table-free models too (dom synthesized just
    for branching) and proves the same optimum as split."""
    inst = zoo.small_instance("nqueens", seed=0)
    cm = zoo.ZOO["nqueens"].build_model(inst)[0].compile()
    r_split = solver.Solver(solver.SolveConfig.preset(
        "prove", val_strategy=S.VAL_SPLIT, **SMALL)).solve(cm)
    r_mid = solver.Solver(solver.SolveConfig.preset(
        "prove", val_strategy=S.VAL_MIDDLE_OUT, **SMALL)).solve(cm)
    assert r_split.status == r_mid.status == solver.OPTIMAL
    assert r_split.objective == r_mid.objective


def test_middle_out_selects_nearest_live_value():
    """Unit: on dom {0, 4} of x ∈ (0,4) the mid is 2 and the nearest
    live value below wins the tie rule → branch value 0."""
    cm, (x, y, z) = _chain_model()
    L = 1
    lb = jnp.asarray(np.tile(np.asarray(cm.lb0), (L, 1)))
    ub = jnp.asarray(np.tile(np.asarray(cm.ub0), (L, 1)))
    dom = B.from_bounds(lb, ub, jnp.asarray(cm.dom_off), cm.n_words,
                        track=jnp.asarray(cm.dom_track))
    # carve x's domain down to {0, 4}
    dom = dom.at[0, x.idx, 0].set(np.uint32(0b10001))
    dec_var, dec_val = S.select_branch_tile(
        lb, ub, jnp.asarray(cm.branch_vars), var_strategy=S.MIN_DOM,
        val_strategy=S.VAL_MIDDLE_OUT, dom=dom,
        dom_off=jnp.asarray(cm.dom_off))[:2]
    assert int(dec_var[0]) == x.idx
    assert int(dec_val[0]) == 0


# --------------------------------------------------------------------------
# statics: shape_signature, VMEM budget
# --------------------------------------------------------------------------

def test_shape_signature_separates_table_layouts():
    """Same V and bounds, different table banks ⇒ different signatures
    (the satellite-2 fix: a warm session must not reuse a runner whose
    CT statics differ)."""
    def base(tuples):
        m = Model("sig")
        xs = [m.int_var(0, 5, f"x{i}") for i in range(4)]
        m.add(xs[0] + xs[1] <= 9)
        if tuples:
            m.table(xs, tuples)
        m.minimize(xs[0])
        m.branch_on(xs)
        return m.compile()

    no_table = base([])
    small_t = base([(0, 1, 2, 3)] + [(1, 2, 3, 4)])
    many_t = base([(i % 6, (i + 1) % 6, (i + 2) % 6, (i + 3) % 6)
                   for i in range(40)])    # > 32 tuples: wider ct_words
    sigs = {api.shape_signature(cm) for cm in (no_table, small_t, many_t)}
    assert len(sigs) == 3
    assert small_t.ct_words == 1 and many_t.ct_words == 2


def test_vmem_budget_includes_ct_scratch_and_dom_stores():
    cm = _mixed_ct_model()
    b1, b8 = FK.vmem_budget(cm, 1), FK.vmem_budget(cm, 8)
    assert set(b1) == {"tables", "stores", "state", "scratch", "total"}
    assert b8["tables"] == b1["tables"]      # banks are lane-invariant
    assert b8["stores"] == 8 * b1["stores"]  # dom words scale with lanes
    assert b1["scratch"] > 0                 # CT unpacked members live here
    # the bitset store really is accounted: stores > plain 2·V·4 per lane
    assert b1["stores"] > 2 * cm.n_vars * 4
    assert FK.fit_lane_tile(cm, 8, 8) == 8
