"""Constraint-based planner (framework integration of the paper)."""

import numpy as np
import pytest

from repro.distributed import planner


def test_partition_balances_and_respects_memory():
    costs = [5, 5, 5, 8, 8, 8, 8, 5, 5, 5, 5, 9]
    mems = [2] * 12
    stages, T = planner.plan_partition(costs, mems, 4, mem_cap=8,
                                       timeout_s=120)
    assert stages == sorted(stages)               # contiguous
    assert set(stages) == {0, 1, 2, 3}            # no empty stage
    loads = [sum(c for c, s in zip(costs, stages) if s == k)
             for k in range(4)]
    memload = [sum(c for c, s in zip(mems, stages) if s == k)
               for k in range(4)]
    assert max(loads) == T
    assert max(memload) <= 8


def test_partition_infeasible_raises():
    with pytest.raises(ValueError):
        planner.plan_partition([1, 1], [9, 9], 2, mem_cap=8, timeout_s=30)


def test_microbatch_schedule_is_valid_pipeline():
    starts, mk, res = planner.schedule_microbatches([3, 3, 3], 3,
                                                    timeout_s=120)
    # perfectly balanced stages: optimal makespan (M + S - 1) * t
    assert mk == (3 + 3 - 1) * 3
    # stage precedence within each microbatch
    for row in starts:
        for s in range(2):
            assert row[s] + 3 <= row[s + 1]
    # unit stage capacity: no overlap in any stage
    for s in range(3):
        times = sorted(row[s] for row in starts)
        for a, b in zip(times, times[1:]):
            assert a + 3 <= b


def test_pipeline_efficiency_metric():
    assert planner.pipeline_efficiency([3, 3, 3], 15, 3) == 1.0
    assert planner.pipeline_efficiency([3, 3, 3], 30, 3) == 0.5
