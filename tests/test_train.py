"""Training substrate: loss actually decreases, schedules, optimizer
hygiene, deterministic data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import SyntheticLM
from repro.nn import model as MD
from repro.nn.layers import init_params
from repro.train.optimizer import (OptConfig, apply_updates, init_opt_state,
                                   learning_rate, _decay_mask)
from repro.train.train_step import cross_entropy, train_step


def test_loss_decreases_on_learnable_task():
    cfg = configs.get_smoke("llama3-8b")
    data = SyntheticLM(cfg, seq_len=32, global_batch=8, seed=0)
    key = jax.random.PRNGKey(0)
    params = init_params(MD.param_specs(cfg), key)
    opt = init_opt_state(params)
    ocfg = OptConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60,
                     schedule="cosine")
    step = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, ocfg,
                                              remat=False, chunks=(8, 8)))
    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    # affine-recurrence task: must drop clearly below uniform (ln 256≈5.55)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 1.0, losses[-5:]


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 10))
    labels = jnp.array([[1, 2, -1, -1]])
    # uniform logits: nll == ln(10) on the 2 valid positions
    assert abs(float(cross_entropy(logits, labels)) - np.log(10)) < 1e-5


def test_wsd_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                    schedule="wsd", wsd_decay_frac=0.2, min_lr_frac=0.1)
    lrs = [float(learning_rate(s, cfg)) for s in range(101)]
    assert lrs[0] < 0.2                          # warmup start
    assert abs(lrs[10] - 1.0) < 1e-6             # peak after warmup
    assert abs(lrs[50] - 1.0) < 1e-6             # stable plateau
    assert lrs[95] < 0.6                         # decaying tail
    assert abs(lrs[100] - 0.1) < 0.02            # floor


def test_cosine_schedule_endpoints():
    cfg = OptConfig(peak_lr=2.0, warmup_steps=10, total_steps=100,
                    schedule="cosine", min_lr_frac=0.1)
    assert abs(float(learning_rate(10, cfg)) - 2.0) < 1e-6
    assert abs(float(learning_rate(100, cfg)) - 0.2) < 1e-5


def test_decay_mask():
    assert _decay_mask("blocks/attn/wq")
    assert not _decay_mask("blocks/norm1")
    assert not _decay_mask("blocks/attn/wq_b")
    assert not _decay_mask("blocks/ssm/A_log")
    assert not _decay_mask("blocks/rec/a_param")


def test_grad_clipping_bounds_update():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 1e6)}
    st = init_opt_state(params)
    cfg = OptConfig(peak_lr=1.0, warmup_steps=0, total_steps=10,
                    clip_norm=1.0, weight_decay=0.0, schedule="const")
    p2, st2, m = apply_updates(params, grads, st, cfg)
    assert float(m["grad_norm"]) > 1e5
    # post-clip Adam step magnitude is bounded by ~lr
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 3.5


def test_data_deterministic_and_sharded():
    cfg = configs.get_smoke("llama3-8b")
    d = SyntheticLM(cfg, seq_len=16, global_batch=8, seed=3)
    a = d.batch(7)
    b = d.batch(7)
    assert (a["tokens"] == b["tokens"]).all()
    c = d.batch(8)
    assert (a["tokens"] != c["tokens"]).any()
    # shards partition deterministically
    s0 = d.batch(7, shard=0, n_shards=2)
    s1 = d.batch(7, shard=1, n_shards=2)
    assert s0["tokens"].shape[0] == 4 and s1["tokens"].shape[0] == 4
    assert (s0["tokens"] != s1["tokens"]).any()


def test_labels_follow_affine_rule():
    cfg = configs.get_smoke("qwen3-4b")
    d = SyntheticLM(cfg, seq_len=12, global_batch=4, seed=1)
    b = d.batch(0)
    # labels are the next-token shift of the same recurrence
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
