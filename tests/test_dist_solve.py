"""Distributed EPS engine (core/dist_solve.py, DESIGN.md §14).

The multi-device parts run through the `fake_devices` fixture
(conftest.py): a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the flag only
takes effect before jax initializes, so the parent process keeps its
single device.  Host-side pieces (the steal planner, config validation,
a 1-shard mesh on the real device) run in-process.
"""

import json

import numpy as np
import pytest

from repro import solver
from repro.core import dist_solve, eps
from repro.core import models as zoo
from repro.core.api import Solver
from repro.distributed.planner import plan_steal

# ---------------------------------------------------------------------------
# host-side: steal planner properties
# ---------------------------------------------------------------------------


def test_plan_steal_balances_and_preserves_ids():
    rng = np.random.default_rng(0)
    for _ in range(50):
        D = int(rng.integers(1, 6))
        owned = [list(map(int, rng.choice(1000, size=rng.integers(0, 20),
                                          replace=False) + 1000 * d))
                 for d in range(D)]
        before = sorted(x for o in owned for x in o)
        out, moved = plan_steal(owned, D)
        after = sorted(x for o in out for x in o)
        assert after == before                       # nothing lost/invented
        sizes = sorted(len(o) for o in out)
        assert sizes[-1] - sizes[0] <= 1             # balanced to ±1
        assert moved <= len(before)


def test_plan_steal_keeps_local_work_first():
    # a shard under quota keeps everything it had; movement is minimal
    out, moved = plan_steal([[1, 2, 3, 4, 5, 6], []], 2)
    assert set(out[0]) == {1, 2, 3}
    assert set(out[1]) == {4, 5, 6}
    assert moved == 3
    out, moved = plan_steal([[1, 2], [3, 4]], 2)
    assert (out, moved) == ([[1, 2], [3, 4]], 0)


def test_plan_steal_shrink_remesh():
    # the ft path replans D shards' ids over D-1 survivors
    out, _ = plan_steal([[0, 1], [2, 3], [4, 5]], 2)
    assert sorted(x for o in out for x in o) == [0, 1, 2, 3, 4, 5]
    assert [len(o) for o in out] == [3, 3]


# ---------------------------------------------------------------------------
# host-side: config plumbing
# ---------------------------------------------------------------------------


def test_mesh_shards_config_validation():
    with pytest.raises(ValueError, match="mesh_shards"):
        solver.SolveConfig(mesh_shards=0)
    with pytest.raises(ValueError, match="pallas_resident"):
        solver.SolveConfig(mesh_shards=2, backend="pallas_resident")
    with pytest.raises(ValueError, match="mutually exclusive"):
        import jax
        from repro.compat import make_mesh
        mesh = make_mesh((1,), ("lanes",))
        solver.SolveConfig(mesh=mesh, lane_axes=("lanes",), mesh_shards=2)


def test_mesh_shards_needs_devices():
    import jax
    if jax.device_count() >= 64:
        pytest.skip("process already has many devices")
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        dist_solve._mesh_for(64)


def test_solve_many_rejects_mesh_shards():
    m, _ = zoo.ZOO["knapsack"].build_model(zoo.small_instance("knapsack"))
    cm = m.compile()
    with pytest.raises(ValueError, match="single-device"):
        Solver(solver.SolveConfig.preset(
            "prove", mesh_shards=1)).solve_many([cm])


def test_mesh_shards_one_matches_plain_solve():
    """A 1-shard mesh runs the whole dist path (shard_map over one
    device, host chunk loop, incumbent checkpoint) on the real device
    and must reproduce the plain engine bit-for-bit in
    status/objective."""
    for name in ("knapsack", "nqueens"):
        m, _ = zoo.ZOO[name].build_model(zoo.small_instance(name, seed=0))
        cm = m.compile()
        cfg0 = solver.SolveConfig.preset("prove", n_lanes=4, eps_target=16)
        ref = Solver(cfg0).solve(cm)
        res, tr = dist_solve.solve_dist(cm, cfg0.replace(mesh_shards=1))
        assert (res.status, res.objective) == (ref.status, ref.objective)
        assert tr.n_chunks >= 1
        assert tr.n_bound_syncs == tr.n_chunks


def test_solver_session_delegates_and_caches():
    m, _ = zoo.ZOO["knapsack"].build_model(zoo.small_instance("knapsack"))
    cm = m.compile()
    sess = Solver(solver.SolveConfig.preset("prove", n_lanes=4,
                                            eps_target=16, mesh_shards=1))
    evs = list(sess.solve_iter(cm))
    assert evs[-1].final and evs[-1].result is not None
    builds = sess.stats["runner_builds"]
    res2 = sess.solve(cm)
    assert sess.stats["runner_builds"] == builds      # warm: cached runner
    assert sess.stats["runner_hits"] >= 1
    assert res2.status == evs[-1].result.status


# ---------------------------------------------------------------------------
# multi-device: parity matrix, invariants, stealing, device loss
# ---------------------------------------------------------------------------

_PARITY_CODE = r"""
import json
from repro import solver
from repro.core import dist_solve
from repro.core import models as zoo
from repro.core.api import Solver

out = []
for name in ("knapsack", "coloring", "rcpsp"):
    m, _ = zoo.ZOO[name].build_model(zoo.small_instance(name, seed=0))
    cm = m.compile()
    for backend in ("gather", "pallas"):
        cfg0 = solver.SolveConfig.preset("prove", n_lanes=4, eps_target=16,
                                         backend=backend)
        ref = Solver(cfg0).solve(cm)
        for D in (1, 2, 4, 8):
            cfg = cfg0.replace(mesh_shards=D)
            res, tr = dist_solve.solve_dist(cm, cfg, session=Solver(cfg))
            g = tr.gbest_per_chunk
            out.append(dict(
                model=name, backend=backend, mesh=D,
                status=res.status, ref_status=ref.status,
                objective=res.objective, ref_objective=ref.objective,
                monotone=all(a >= b for a, b in zip(g, g[1:])),
                chunks=tr.n_chunks, syncs=tr.n_bound_syncs))
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh_parity_matrix(fake_devices):
    """mesh ∈ {1,2,4,8} × {gather, pallas} × 3 zoo models: bit-equal
    status/objective vs the single-device solve, monotone bound trace,
    one host bound-sync per chunk."""
    out = fake_devices(_PARITY_CODE)
    recs = json.loads(out.split("RESULT ", 1)[1])
    assert len(recs) == 3 * 2 * 4
    for r in recs:
        cell = f"{r['model']}/{r['backend']}/mesh={r['mesh']}"
        assert r["status"] == r["ref_status"], (cell, r)
        assert r["objective"] == r["ref_objective"], (cell, r)
        assert r["status"] in ("OPTIMAL", "SAT"), (cell, r)
        assert r["monotone"], (cell, r)
        assert r["syncs"] == r["chunks"], (cell, r)


_STEAL_CODE = r"""
import json
import numpy as np
from repro import solver
from repro.core import dist_solve, eps
from repro.core import models as zoo
from repro.core.api import Solver

# engineered imbalance: the id space splits contiguously across shards,
# so failing the second half gives shard 1 a frontier that drains almost
# immediately while shard 0 still holds deep subproblems
m, _ = zoo.ZOO["coloring"].build_model(zoo.small_instance("coloring", 0))
cm = m.compile()
cfg0 = solver.SolveConfig.preset("prove", n_lanes=2, eps_target=16)
lb, ub = map(np.asarray, eps.decompose(cm, 16, cfg0.search_options()))
half = (lb.shape[0] + 1) // 2
lb[half:, 0], ub[half:, 0] = 1, 0
ref = Solver(cfg0).solve(cm, subs=(lb, ub))
cfg = cfg0.replace(chunk=1, mesh_shards=2)
res, tr = dist_solve.solve_dist(cm, cfg, subs=(lb, ub), session=Solver(cfg))

ok_partition = True
for owned, consumed in zip(tr.assignments, tr.consumed_per_chunk):
    flat = [i for o in owned for i in o]
    ok_partition &= len(flat) == len(set(flat))          # disjoint shards
    ok_partition &= set(flat).isdisjoint(consumed)       # queue vs consumed
    ok_partition &= set(flat) | set(consumed) == set(tr.all_ids)  # cover
print("RESULT " + json.dumps(dict(
    status=res.status, ref_status=ref.status,
    objective=res.objective, ref_objective=ref.objective,
    steals=tr.n_steals, steal_events=tr.steal_events,
    partition_ok=ok_partition)))
"""


@pytest.mark.slow
def test_steal_fires_and_partition_invariant(fake_devices):
    """A drained shard triggers work stealing, the repartition keeps the
    pool a partition (per-chunk: shard queues pairwise disjoint, queues
    plus consumed ids cover every id ever created), and the result still
    matches the single-device solve."""
    out = fake_devices(_STEAL_CODE)
    r = json.loads(out.split("RESULT ", 1)[1])
    assert r["steals"] >= 1, r
    ev = r["steal_events"][0]
    assert ev["n_moved"] >= 1 and ev["drained_shards"], ev
    assert r["partition_ok"], r
    assert r["status"] == r["ref_status"], r
    assert r["objective"] == r["ref_objective"], r


_LOSS_CODE = r"""
import json
from repro import solver
from repro.core import dist_solve
from repro.core import models as zoo
from repro.core.api import Solver
from repro.ft.fault_tolerance import DeviceLoss

m, _ = zoo.ZOO["coloring"].build_model(zoo.small_instance("coloring", 0))
cm = m.compile()
cfg0 = solver.SolveConfig.preset("prove", n_lanes=2, eps_target=16,
                                 chunk=2)
ref = Solver(cfg0.replace(mesh_shards=1)).solve(cm)
cfg = cfg0.replace(mesh_shards=4)
res, tr = dist_solve.solve_dist(cm, cfg, session=Solver(cfg),
                                fault=DeviceLoss(at_chunk=1, shard=1))
g = tr.gbest_per_chunk
print("RESULT " + json.dumps(dict(
    status=res.status, ref_status=ref.status,
    objective=res.objective, ref_objective=ref.objective,
    complete=res.complete, remesh=tr.remesh_events,
    monotone=all(a >= b for a, b in zip(g, g[1:])))))
"""


@pytest.mark.slow
def test_device_loss_remesh_same_optimum(fake_devices):
    """Losing a shard mid-solve (simulated via the ft heartbeat +
    injector) redistributes its unexplored pool slice over the surviving
    mesh and the solve still terminates with the same proven optimum."""
    out = fake_devices(_LOSS_CODE)
    r = json.loads(out.split("RESULT ", 1)[1])
    assert len(r["remesh"]) == 1, r
    ev = r["remesh"][0]
    assert ev["shards_before"] == 4 and ev["shards_after"] == 3, ev
    assert ev["n_requeued"] >= 1, ev
    assert r["status"] == "OPTIMAL" and r["complete"], r
    assert r["status"] == r["ref_status"], r
    assert r["objective"] == r["ref_objective"], r
    assert r["monotone"], r
