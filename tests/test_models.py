"""Model zoo (DESIGN.md §10): every model solves its seeded small
instance to proven optimum on every registered backend, with identical
objectives, ground-checked solutions, and independent oracles where one
exists (knapsack DP, known n-queens value)."""

import numpy as np
import pytest

from repro.core import baseline, engine, search as S
from util import solve_session
from repro.core import models as zoo
from repro.core.backend import available_backends
from repro.core.models import coloring, jobshop, knapsack, nqueens

OPTS = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=256)


def _solve(cm, backend="gather", **kw):
    opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=256,
                           backend=backend)
    return solve_session(cm, n_lanes=8, eps_target=16, opts=opts, **kw)


@pytest.mark.parametrize("name", sorted(zoo.ZOO))
def test_zoo_optimum_identical_across_backends(name):
    """The acceptance bar: proven optimum on gather/scatter/pallas with
    identical objective values, and a ground-checked solution."""
    mod = zoo.ZOO[name]
    inst = zoo.small_instance(name)
    m, h = mod.build_model(inst)
    cm = m.compile()
    objs = {}
    for backend in available_backends():
        res = _solve(cm, backend=backend)
        assert res.status == engine.OPTIMAL, (name, backend, res.status)
        vals = [int(res.solution[v.idx]) for v in h["check_vars"]]
        ok, obj = mod.check_solution(inst, vals)
        assert ok, (name, backend, vals)
        assert obj == res.objective, (name, backend, obj, res.objective)
        objs[backend] = res.objective
    assert len(set(objs.values())) == 1, (name, objs)


@pytest.mark.parametrize("name", sorted(zoo.ZOO))
def test_zoo_matches_sequential_baseline(name):
    """Engine and the event-driven sequential solver agree per model."""
    mod = zoo.ZOO[name]
    inst = zoo.small_instance(name)
    m, _ = mod.build_model(inst)
    cm = m.compile()
    seq = baseline.SequentialSolver(cm, OPTS).solve(timeout_s=120)
    par = _solve(cm)
    assert seq.status == par.status == engine.OPTIMAL
    assert seq.objective == par.objective


def test_knapsack_matches_dp_oracle():
    for seed in range(3):
        inst = knapsack.generate(7, seed=seed)
        m, _ = knapsack.build_model(inst)
        res = _solve(m.compile())
        assert res.status == engine.OPTIMAL
        assert -res.objective == knapsack.dp_optimum(inst)


def test_nqueens_known_optimum():
    """n=5 has a solution with the first queen in column 0: (0,2,4,1,3)."""
    inst = nqueens.generate(5)
    ok, obj = nqueens.check_solution(inst, [0, 2, 4, 1, 3])
    assert ok and obj == 0
    m, _ = nqueens.build_model(inst)
    res = _solve(m.compile())
    assert res.status == engine.OPTIMAL and res.objective == 0


def test_nqueens_rejects_clashes():
    inst = nqueens.generate(4)
    assert not nqueens.check_solution(inst, [0, 1, 2, 3])[0]   # diagonal
    assert not nqueens.check_solution(inst, [0, 2, 0, 3])[0]   # column


def test_coloring_optimum_is_chromatic_number():
    """Triangle + pendant vertex: χ = 3, so the optimum cmax is 2."""
    inst = coloring.Coloring(n=4, edges=[(0, 1), (0, 2), (1, 2), (2, 3)],
                             name="triangle+1")
    m, _ = coloring.build_model(inst)
    res = _solve(m.compile())
    assert res.status == engine.OPTIMAL and res.objective == 2


def test_jobshop_two_jobs_same_order():
    """Two jobs, both M0→M1, durations [[2,2],[2,2]]: optimum 6 (the
    second job pipelines one machine behind the first)."""
    inst = jobshop.JobShop(machines=np.array([[0, 1], [0, 1]]),
                           durations=np.array([[2, 2], [2, 2]]),
                           name="js-2x2-pipe")
    m, h = jobshop.build_model(inst)
    res = _solve(m.compile())
    assert res.status == engine.OPTIMAL and res.objective == 6
    vals = [int(res.solution[v.idx]) for v in h["check_vars"]]
    ok, mk = jobshop.check_solution(inst, vals)
    assert ok and mk == 6


def test_generators_deterministic():
    for name in sorted(zoo.ZOO):
        a, b = zoo.small_instance(name, seed=3), zoo.small_instance(name,
                                                                    seed=3)
        ma, _ = zoo.ZOO[name].build_model(a)
        mb, _ = zoo.ZOO[name].build_model(b)
        assert ma.lb0 == mb.lb0 and ma.ub0 == mb.ub0
        assert ma.props == mb.props
