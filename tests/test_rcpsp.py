"""RCPSP model, generator, parsers and checker."""

import os
import tempfile

import numpy as np
import pytest

from repro.core.models import rcpsp
from repro.core import engine, search as S
from util import solve_session


def test_generator_deterministic():
    a = rcpsp.generate(8, seed=42)
    b = rcpsp.generate(8, seed=42)
    assert (a.durations == b.durations).all()
    assert a.precedences == b.precedences
    assert (a.usage == b.usage).all()
    assert (a.capacity == b.capacity).all()


def test_generator_feasible_by_construction():
    """Serial schedule (all tasks in topological order) is always feasible
    since capacities >= max single demand."""
    inst = rcpsp.generate(10, seed=3)
    starts = np.zeros(inst.n_tasks, dtype=np.int64)
    t = 0
    for i in range(inst.n_tasks):      # serial: one task at a time
        starts[i] = t
        t += int(inst.durations[i])
    ok, mk = rcpsp.check_solution(inst, starts)
    assert ok and mk == inst.horizon


def test_overlap_booleans_consistent():
    """In any optimal solution, b_ij must equal the overlap predicate
    (the decomposed lowering — the native §12 one has no booleans)."""
    inst = rcpsp.generate(5, n_resources=2, seed=4, edge_prob=0.3)
    m, h = rcpsp.build_model(inst, decompose=True)
    cm = m.compile()
    res = solve_session(cm, n_lanes=4, n_subproblems=8,
                       opts=S.SearchOptions(var_strategy=S.MIN_LB,
                                            max_depth=256))
    assert res.status == engine.OPTIMAL
    sol = res.solution
    s = [int(sol[v.idx]) for v in h["s"]]
    d = [int(x) for x in inst.durations]
    for i in range(inst.n_tasks):
        for j in range(inst.n_tasks):
            b = int(sol[h["b"][i][j].idx])
            expected = int(s[i] <= s[j] < s[i] + d[i]) if d[i] > 0 else 0
            assert b == expected, (i, j, b, expected)


def test_patterson_parser_roundtrip():
    """Write a Patterson-format file for a generated instance, parse it
    back, and check equality."""
    inst = rcpsp.generate(6, n_resources=2, seed=8)
    lines = [f"{inst.n_tasks} {inst.n_resources}",
             " ".join(str(int(c)) for c in inst.capacity)]
    succ = [[] for _ in range(inst.n_tasks)]
    for (i, j) in inst.precedences:
        succ[i].append(j + 1)
    for i in range(inst.n_tasks):
        row = [int(inst.durations[i])] + \
              [int(inst.usage[k, i]) for k in range(inst.n_resources)] + \
              [len(succ[i])] + succ[i]
        lines.append(" ".join(map(str, row)))
    with tempfile.NamedTemporaryFile("w", suffix=".rcp", delete=False) as f:
        f.write("\n".join(lines) + "\n")
        path = f.name
    try:
        back = rcpsp.parse_patterson(path)
        assert (back.durations == inst.durations).all()
        assert sorted(back.precedences) == sorted(inst.precedences)
        assert (back.usage == inst.usage).all()
        assert (back.capacity == inst.capacity).all()
    finally:
        os.unlink(path)


def test_precedence_respected_in_solution():
    inst = rcpsp.generate(6, n_resources=2, seed=12, edge_prob=0.4)
    m, h = rcpsp.build_model(inst)
    res = solve_session(m.compile(), n_lanes=4, n_subproblems=8,
                       opts=S.SearchOptions(var_strategy=S.MIN_LB,
                                            max_depth=256))
    assert res.status == engine.OPTIMAL
    s = [int(res.solution[v.idx]) for v in h["s"]]
    for (i, j) in inst.precedences:
        assert s[i] + int(inst.durations[i]) <= s[j]


def test_zero_duration_tasks():
    """Dummy source/sink tasks (PSPLIB style) must not break the model."""
    inst = rcpsp.RCPSP(
        durations=np.array([0, 3, 2, 0]),
        precedences=[(0, 1), (0, 2), (1, 3), (2, 3)],
        usage=np.array([[0, 2, 2, 0]]),
        capacity=np.array([2]),
        name="dummy-ends")
    m, h = rcpsp.build_model(inst)
    res = solve_session(m.compile(), n_lanes=2, n_subproblems=4,
                       opts=S.SearchOptions(var_strategy=S.MIN_LB,
                                            max_depth=128))
    assert res.status == engine.OPTIMAL
    # resource forces serialization of tasks 1 and 2: makespan 5
    assert res.objective == 5
