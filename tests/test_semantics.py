"""Property tests for the paper's semantic theorems (DESIGN.md §6).

Thm 2   — fix D(P) is a closure operator: extensive, monotone, idempotent.
Prop 3  — fix D(seq P) == fix D(P): sequential and parallel fixpoints agree.
Thm 6   — every fair schedule converges to the same fixpoint.
GNF     — the tabular guarded-command lowering preserves semantics
          (gather sweep == scatter sweep == per-propagator SELECT steps).
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; never hard-error
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.fixpoint import (fixpoint, sweep, sweep_scatter,  # noqa: E402
                                 sequential_fixpoint)
from util import random_model, random_substores  # noqa: E402

SETTINGS = dict(deadline=None, max_examples=20)


def _fix(cm, lb, ub):
    l, u, _, _ = fixpoint(cm, jnp.asarray(lb), jnp.asarray(ub),
                          stop_on_fail=False)
    return np.asarray(l), np.asarray(u)


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_closure_operator(seed):
    """Thm 2: extensive + idempotent (on the full fixpoint)."""
    rng = np.random.default_rng(seed)
    cm = random_model(rng).compile()
    lb, ub = random_substores(rng, cm, 1)
    l1, u1 = _fix(cm, lb[0], ub[0])
    # extensive: result carries at least as much information
    assert (l1 >= lb[0]).all() and (u1 <= ub[0]).all()
    # idempotent
    l2, u2 = _fix(cm, l1, u1)
    assert (l1 == l2).all() and (u1 == u2).all()


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_monotone(seed):
    """Thm 2: s ≤ s' ⇒ fix(s) ≤ fix(s')  (≤ = information order)."""
    rng = np.random.default_rng(seed)
    cm = random_model(rng).compile()
    lb, ub = random_substores(rng, cm, 1)
    # s' = s ⊔ extra tells
    lb2, ub2 = lb[0].copy(), ub[0].copy()
    V = cm.n_vars
    for _ in range(3):
        v = int(rng.integers(1, V))
        if lb2[v] < ub2[v]:
            lb2[v] += 1
    l1, u1 = _fix(cm, lb[0], ub[0])
    l2, u2 = _fix(cm, lb2, ub2)
    assert (l2 >= l1).all() and (u2 <= u1).all()


def _agree(a, b):
    """Comparison spec (kernels/ops.py): equal failed flag; exact equality
    when not failed (failed stores are discarded by search and the two
    formulations legitimately signal failure through different vars)."""
    (la, ua), (lb_, ub_) = a, b
    fa = bool((la > ua).any())
    fb = bool((lb_ > ub_).any())
    if fa or fb:
        return fa == fb
    return (la == lb_).all() and (ua == ub_).all()


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_prop3_seq_equals_par(seed):
    """Prop 3 + Thm 6: program-order sequential chaotic iteration reaches
    the parallel sweep fixpoint."""
    rng = np.random.default_rng(seed)
    cm = random_model(rng).compile()
    lb, ub = random_substores(rng, cm, 1)
    lp, up = _fix(cm, lb[0], ub[0])
    ls, us = sequential_fixpoint(cm, lb[0], ub[0])
    assert _agree((lp, up), (ls, us))


@given(seed=st.integers(0, 10_000), perm_seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_thm6_fair_schedules_agree(seed, perm_seed):
    """Thm 6: a random (fair) round-robin permutation converges to the
    same fixpoint as program order and as the parallel sweep."""
    rng = np.random.default_rng(seed)
    cm = random_model(rng).compile()
    lb, ub = random_substores(rng, cm, 1)
    order = np.random.default_rng(perm_seed).permutation(cm.n_props)
    lf, uf = sequential_fixpoint(cm, lb[0], ub[0], order=list(order))
    ls, us = sequential_fixpoint(cm, lb[0], ub[0])
    lp, up = _fix(cm, lb[0], ub[0])
    # the two sequential schedules share the scatter formulation: exact
    assert (lf == ls).all() and (uf == us).all()
    # vs the parallel gather sweep: modulo failure signalling
    assert _agree((lp, up), (lf, uf))


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_gnf_gather_equals_scatter_sweep(seed):
    """One gather sweep == one scatter sweep (identical *function*, not
    just identical fixpoint): the GNF tabular lowering is consistent."""
    rng = np.random.default_rng(seed)
    cm = random_model(rng).compile()
    lb, ub = random_substores(rng, cm, 4)
    for i in range(4):
        l0, u0 = jnp.asarray(lb[i]), jnp.asarray(ub[i])
        # exclude stores where a plain constraint is already disentailed:
        # the scatter form signals that through the TRUE var, the gather
        # form through term bounds (see kernels/ops.py comparison spec).
        lg, ug = sweep(cm, l0, u0)
        lsc, usc = sweep_scatter(cm, l0, u0)
        failed = bool(jnp.any(lg > ug)) or bool(jnp.any(lsc > usc))
        if failed:
            assert bool(jnp.any(lg > ug)) == bool(jnp.any(lsc > usc))
        else:
            assert (np.asarray(lg) == np.asarray(lsc)).all()
            assert (np.asarray(ug) == np.asarray(usc)).all()


def test_ask_guard_blocks_until_told():
    """ask semantics: a reified propagator must not prune until its guard
    is entailed (no information out of thin air)."""
    from repro.core.model import Model
    m = Model()
    x = m.int_var(0, 10, "x")
    b = m.reify(x <= 3)
    cm = m.compile()
    l, u, _, _ = fixpoint(cm, cm.lb0, cm.ub0)
    # b unknown: x must be untouched
    assert int(l[x.idx]) == 0 and int(u[x.idx]) == 10
    assert int(l[b.idx]) == 0 and int(u[b.idx]) == 1
    # telling b=true prunes x (ask fires)
    lb = np.asarray(cm.lb0).copy()
    lb[b.idx] = 1
    l, u, _, _ = fixpoint(cm, jnp.asarray(lb), cm.ub0)
    assert int(u[x.idx]) == 3
    # telling b=false prunes the complement
    lb = np.asarray(cm.lb0).copy()
    ub = np.asarray(cm.ub0).copy()
    ub[b.idx] = 0
    l, u, _, _ = fixpoint(cm, jnp.asarray(lb), jnp.asarray(ub))
    assert int(l[x.idx]) == 4


def test_entailment_monotone_lemma1():
    """Lemma 1: entailment flags only ever go from unknown to decided as
    the store gains information."""
    from repro.core.model import Model
    m = Model()
    x = m.int_var(0, 10, "x")
    y = m.int_var(0, 10, "y")
    b = m.reify(x + y <= 20)       # eventually entailed (max sum == 20)
    cm = m.compile()
    l, u, _, _ = fixpoint(cm, cm.lb0, cm.ub0)
    assert int(l[b.idx]) == 1      # already entailed at the root
