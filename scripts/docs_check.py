#!/usr/bin/env python
"""Docs gate (`make docs-check`): keep README.md / DESIGN.md honest.

Two checks, both cheap and offline:

1. **Path references resolve.** Every `path/to/file.py`-looking token in
   README.md and DESIGN.md must exist in the repo — as given, relative to
   `src/repro/` (the docs' docstring-style shorthand, e.g.
   `core/engine.py`), or as a bare basename that some repo file carries.
2. **Quickstart commands dry-run.** Every command line in README fenced
   code blocks is exercised without doing real work: `python -m pkg ...`
   and argparse example scripts run with `--help`; non-argparse example
   scripts are checked for existence; `make target` runs `make -n`.

Exit nonzero (with a per-item report) on any failure.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ("README.md", "DESIGN.md")
# the extension must end the token (else `jax.sharding` reads as a
# dangling `jax.sh` reference)
PATH_RE = re.compile(
    r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|sh|md|json|txt)(?![A-Za-z0-9_])")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}


def repo_files():
    rels, basenames = set(), set()
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            rel = os.path.relpath(os.path.join(dirpath, f), ROOT)
            rels.add(rel)
            basenames.add(f)
    return rels, basenames


def check_paths(errors):
    rels, basenames = repo_files()
    for doc in DOCS:
        text = open(os.path.join(ROOT, doc)).read()
        for m in PATH_RE.finditer(text):
            tok = m.group(0).lstrip("./")
            if tok.startswith("http") or "*" in tok:
                continue
            # basename fallback only for bare-filename shorthand — a token
            # WITH directories must resolve as written (or under src/repro)
            # so moved/renamed paths actually fail the gate
            ok = (tok in rels
                  or os.path.join("src", "repro", tok) in rels
                  or ("/" not in tok and tok in basenames))
            if not ok:
                errors.append(f"{doc}: dangling path reference {tok!r}")


def readme_commands():
    text = open(os.path.join(ROOT, "README.md")).read()
    cmds = []
    for block in re.findall(r"```(?:bash|sh)?\n(.*?)```", text, re.S):
        for line in block.splitlines():
            line = line.split("#")[0].strip()
            if line:
                cmds.append(line)
    return cmds


def _run(argv, errors, label):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "")
    try:
        r = subprocess.run(argv, cwd=ROOT, env=env, capture_output=True,
                           text=True, timeout=120)
    except subprocess.TimeoutExpired:
        errors.append(f"quickstart: {label}: timed out")
        return
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-3:]
        errors.append(f"quickstart: {label}: exit {r.returncode} "
                      f"({' | '.join(tail)})")


def check_quickstart(errors):
    for cmd in readme_commands():
        parts = cmd.split()
        if parts[0].startswith("PYTHONPATH="):
            parts = parts[1:]
        if not parts:
            continue
        if parts[0] == "make":
            _run(["make", "-n"] + parts[1:2], errors, cmd)
        elif parts[0] == "python" and parts[1] == "-m":
            _run([sys.executable, "-m", parts[2], "--help"], errors, cmd)
        elif parts[0] == "python":
            script = os.path.join(ROOT, parts[1])
            if not os.path.exists(script):
                errors.append(f"quickstart: {cmd}: missing {parts[1]}")
            elif "argparse" in open(script).read():
                _run([sys.executable, parts[1], "--help"], errors, cmd)
            # non-argparse example scripts: existence is the dry-run


def main():
    errors = []
    check_paths(errors)
    check_quickstart(errors)
    if errors:
        for e in errors:
            print(f"DOCS-CHECK FAIL: {e}", file=sys.stderr)
        return 1
    print(f"docs-check OK ({', '.join(DOCS)} paths resolve; "
          "README quickstart commands dry-run cleanly)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
