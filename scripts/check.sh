#!/usr/bin/env bash
# CI gate: tier-1 test suite + backend-comparison propagation smoke.
#
#   make check            # or: scripts/check.sh
#
# Runs the ROADMAP tier-1 command (full pytest; collection must be clean),
# a 2-size bench_propagation smoke comparing all registered propagation
# backends, a model-zoo solver smoke (all five models through the EPS
# engine, DESIGN.md §10), a session-API smoke (cold+warm compile
# amortization + solve_many batched throughput on 4 knapsack instances,
# DESIGN.md §11) and the docs check, writing BENCH_propagation_smoke.json
# (propagation rows + `solver` + `api` sections) at the repo root so the
# perf trajectory populates per PR.
#
# Exit code: nonzero on collection errors or bench failure.  Known-failing
# tier-1 tests (the seed ships with failing NN-substrate tests; see
# ROADMAP.md "no worse than seed") do NOT fail the gate, but the summary
# line is printed and recorded in the JSON for trend tracking.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests =="
pytest_log=$(mktemp)
python -m pytest -q --continue-on-collection-errors 2>&1 | tee "$pytest_log"
rc=${PIPESTATUS[0]}
# pytest exit codes: 0 = all passed, 1 = some tests failed (tolerated: the
# seed ships with known-failing NN tests); anything else means pytest did
# not complete a run (2 interrupted, 3 internal error, 4 usage, 5 no tests)
if [ "$rc" -ne 0 ] && [ "$rc" -ne 1 ]; then
    echo "FAIL: pytest did not complete (exit $rc)" >&2
    exit 1
fi
summary=$(grep -E "[0-9]+ (passed|failed|skipped|error)" "$pytest_log" | tail -1)
if [ -z "$summary" ]; then
    echo "FAIL: no pytest summary line found" >&2
    exit 1
fi
if grep -qi "error" <<<"$summary"; then
    echo "FAIL: collection errors present ($summary)" >&2
    exit 1
fi

echo
echo "== propagation backend smoke (2 sizes, all backends) =="
python -m benchmarks.bench_propagation \
    --sizes 6 8 --lanes 8 --json BENCH_propagation_smoke.json || exit 1

echo
echo "== model-zoo solver smoke (5 models, EPS engine) =="
python -m benchmarks.bench_solver \
    --zoo-smoke --json BENCH_propagation_smoke.json || exit 1

echo
echo "== session-API smoke (cold+warm solve, solve_many x4, all backends) =="
python -m benchmarks.bench_solver \
    --throughput --json BENCH_propagation_smoke.json || exit 1

echo
echo "== docs check (README/DESIGN references + quickstart dry-run) =="
python scripts/docs_check.py || exit 1

# stamp the test summary into the bench JSON so one file carries the
# whole check result
python - "$summary" <<'EOF'
import json, sys
path = "BENCH_propagation_smoke.json"
doc = json.load(open(path))
doc["tier1_summary"] = sys.argv[1]
json.dump(doc, open(path, "w"), indent=2)
EOF

echo
echo "check OK — wrote BENCH_propagation_smoke.json ($summary)"
