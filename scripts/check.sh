#!/usr/bin/env bash
# CI gate: tier-1 test suite + backend-comparison propagation smoke.
#
#   make check            # or: scripts/check.sh
#
# Runs the ROADMAP tier-1 command (full pytest; ZERO failures required —
# the seed-era "43 known-failing NN tests" carve-out is gone since the
# JAX compat shim, repro/compat.py), a 2-size bench_propagation smoke
# comparing all registered propagation backends, a model-zoo solver smoke
# (every zoo model through the EPS engine, DESIGN.md §10, with per-model
# typed-propagator-table sizes, §12), a session-API smoke (cold+warm
# compile amortization + solve_many batched throughput on 4 knapsack
# instances, DESIGN.md §11), a resident-megakernel smoke (one
# pallas_resident solve in interpret mode on CPU, DESIGN.md §13 — its
# K-launch bit-parity suite tests/test_resident.py already runs inside
# tier-1), the superstep-orchestration bench (ms_per_superstep +
# dispatches_per_solve per backend), the distributed-EPS bench (mesh
# 1→8 on faked host devices: speedup vs mesh=1, steal events,
# bound-all-reduce counts, DESIGN.md §14), the solver-serving bench
# (fixed-seed open-loop Poisson load through the continuous-batching
# scheduler, DESIGN.md §15), the scale-tier bench (sparse-vs-dense peak
# bank-tile bytes, forced dense/sparse objective parity, large-tier
# props/s + nodes/s probes, DESIGN.md §16), the Compact-Table bench
# (bitset-carried props/s + currtable word statics on the extensional
# zoo models, every backend proven + ground-checked, native vs
# decompose=True oracle — hard-fails on any status/objective mismatch,
# DESIGN.md §17) and the docs check, writing
# BENCH_propagation_smoke.json (propagation rows + `solver` + `api` +
# `superstep` + `distributed` + `serving` + `scale` + `compact_table`
# sections) at the repo root so the perf trajectory populates per PR.  The zoo smoke
# sweeps EVERY registered backend, pallas_resident included, and
# hard-fails on any proven-optimum mismatch between backends; the dist
# bench hard-fails on any mesh losing status/objective parity with
# mesh=1; the serving bench hard-fails on parity vs sequential
# Solver.solve, on no request ever batching, or on any bucket
# recompiling after its cold compile; the scale bench hard-fails unless
# the sparse AllDifferent tile is strictly smaller than the dense O(N³)
# tile at N ≥ 128 and on any dense/sparse status/objective mismatch.
#
# Exit code: nonzero on ANY test failure, collection error or bench
# failure.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests (zero-failures gate) =="
pytest_log=$(mktemp)
python -m pytest -q --durations=15 --continue-on-collection-errors 2>&1 | tee "$pytest_log"
rc=${PIPESTATUS[0]}
if [ "$rc" -ne 0 ]; then
    echo "FAIL: tier-1 suite not green (pytest exit $rc)" >&2
    exit 1
fi
summary=$(grep -E "[0-9]+ (passed|failed|skipped|error)" "$pytest_log" | tail -1)
if [ -z "$summary" ]; then
    echo "FAIL: no pytest summary line found" >&2
    exit 1
fi
if grep -qiE "failed|error" <<<"$summary"; then
    echo "FAIL: failures/collection errors present ($summary)" >&2
    exit 1
fi

echo
echo "== propagation backend smoke (2 sizes, all backends) =="
python -m benchmarks.bench_propagation \
    --sizes 6 8 --lanes 8 --json BENCH_propagation_smoke.json || exit 1

echo
echo "== resident megakernel smoke (pallas_resident, interpret on CPU) =="
python -m repro.launch.solve --n 8 --lanes 8 --subs 16 \
    --backend pallas_resident --supersteps-per-launch 16 || exit 1

echo
echo "== model-zoo solver smoke (all zoo models, EPS engine, ALL backends) =="
python -m benchmarks.bench_solver \
    --zoo-smoke --json BENCH_propagation_smoke.json || exit 1

echo
echo "== superstep bench (dispatch amortization, all backends, §13) =="
python -m benchmarks.bench_solver \
    --superstep-bench --json BENCH_propagation_smoke.json || exit 1

echo
echo "== session-API smoke (cold+warm solve, solve_many x4, all backends) =="
python -m benchmarks.bench_solver \
    --throughput --json BENCH_propagation_smoke.json || exit 1

echo
echo "== distributed-EPS bench (mesh 1..8 on faked host devices, §14) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m benchmarks.bench_solver \
    --dist-bench --json BENCH_propagation_smoke.json || exit 1

echo
echo "== solver-serving bench (open-loop load, continuous batching, §15) =="
python -m benchmarks.bench_solver \
    --serve-bench --json BENCH_propagation_smoke.json || exit 1

echo
echo "== scale bench (sparse banks: bytes, parity, large-tier probes, §16) =="
python -m benchmarks.bench_solver \
    --scale-smoke --json BENCH_propagation_smoke.json || exit 1

echo
echo "== compact-table bench (bitset CT: props/s, parity, oracle, §17) =="
python -m benchmarks.bench_solver \
    --ct-smoke --json BENCH_propagation_smoke.json || exit 1

echo
echo "== docs check (README/DESIGN references + quickstart dry-run) =="
python scripts/docs_check.py || exit 1

# stamp the test summary into the bench JSON so one file carries the
# whole check result
python - "$summary" <<'PYEOF'
import json, sys
path = "BENCH_propagation_smoke.json"
doc = json.load(open(path))
doc["tier1_summary"] = sys.argv[1]
json.dump(doc, open(path, "w"), indent=2)
PYEOF

echo
echo "check OK — wrote BENCH_propagation_smoke.json ($summary)"
