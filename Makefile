PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: check test bench docs-check

# tier-1 suite + propagation smoke + model-zoo solver smoke + session-API
# smoke (cold/warm + solve_many) + solver-serving bench (open-loop
# continuous batching, §15) + scale bench (sparse banks, §16) + docs
# check (writes BENCH_propagation_smoke.json; see scripts/check.sh)
check:
	scripts/check.sh

test:
	python -m pytest -x -q

bench:
	python -m benchmarks.run --fast

# README/DESIGN path references resolve + quickstart commands dry-run
docs-check:
	python scripts/docs_check.py
