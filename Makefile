PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: check test bench

# tier-1 suite + 2-size backend-comparison propagation smoke
# (writes BENCH_propagation_smoke.json; see scripts/check.sh)
check:
	scripts/check.sh

test:
	python -m pytest -x -q

bench:
	python -m benchmarks.run --fast
