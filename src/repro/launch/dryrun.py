"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512
placeholder host devices stand in for 2 pods × 256 chips; every cell must
lower, SPMD-partition, and compile, and the compiled artifact yields the
memory/cost/collective numbers for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \\
      --shape train_4k [--multi-pod] [--all] [--out report.json]
"""

# MUST be the very first lines — before any other import, including repro
# (jax locks the device count on first backend initialization).
import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from functools import partial  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro import configs                        # noqa: E402
from repro.configs.base import skip_reason       # noqa: E402
from repro.data.pipeline import input_shapes     # noqa: E402
from repro.distributed import sharding as SH     # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.nn import model as MD                 # noqa: E402
from repro.nn.layers import abstract_params      # noqa: E402
from repro.train.optimizer import OptConfig, init_opt_state  # noqa: E402
from repro.train.train_step import train_step    # noqa: E402
from repro.train.serve_step import decode_step, prefill_step  # noqa: E402

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# HLO shapes like bf16[2,16,128]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _abstract(tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (post-SPMD) HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", ls)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in _COLLECTIVES:
            # match the op name right after the output shape, e.g.
            # "bf16[..] all-reduce(...)" — avoids fusion-comment hits
            if re.search(r"\)?\s" + c + r"(\.\d+)?\(", rhs) or \
               re.search(r"\}\s*" + c + r"(\.\d+)?\(", rhs) or \
               re.search(r"\]\s*" + c + r"(\.\d+)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        shm = _SHAPE_RE.match(rhs) or _SHAPE_RE.search(rhs.split(op)[0])
        if not shm:
            continue
        dt, dims = shm.group(1), shm.group(2)
        if dt == "tuple" or dt not in _BYTES:
            continue
        n = int(np.prod([int(d) for d in dims.split(",") if d])) \
            if dims else 1
        out[op] += n * _BYTES[dt]
        out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def build_cell(arch: str, shape_name: str, mesh, chunks=(1024, 1024),
               cfg=None, microbatches: int = 1):
    """Returns (fn, example_args (abstract), out_shardings, donate).
    `cfg` overrides the registry config (roofline reduced-depth variants);
    `microbatches` enables grad-accumulation in the train cells."""
    cfg = cfg or configs.get(arch)
    shape = configs.get_shape(shape_name)
    mode = "train" if shape.kind == "train" else "serve"
    rules = SH.rules_for(mode)
    specs = MD.param_specs(cfg)
    p_shard = SH.shardings_for_specs(specs, rules, mesh)
    params = _abstract(abstract_params(
        specs, jnp.float32 if mode == "train" else jnp.bfloat16), p_shard)

    n_dev = int(np.prod(list(mesh.shape.values())))
    batch_shapes = input_shapes(cfg, shape)
    b_shard = SH.batch_sharding(batch_shapes, rules, mesh)
    batch = _abstract(batch_shapes, b_shard)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(init_opt_state, params)
        o_shard = {"mu": p_shard, "nu": p_shard,
                   "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        opt = _abstract(opt_shapes, o_shard)
        opt_cfg = OptConfig()
        fn = partial(train_step, cfg=cfg, opt_cfg=opt_cfg, remat=True,
                     chunks=chunks, microbatches=microbatches)
        out_shardings = (p_shard, o_shard, None)
        return fn, (params, opt, batch), out_shardings, (0, 1)

    smax = shape.seq_len
    if shape.kind == "prefill":
        def fn(params, batch):
            return prefill_step(params, cfg, batch, smax, chunks=chunks)

        cache_shapes = jax.eval_shape(
            lambda p, b: prefill_step(p, cfg, b, smax, chunks=chunks)[1],
            params, batch)
        c_shard = SH.cache_shardings(cfg, cache_shapes, mesh)
        out_shardings = (None, c_shard)
        return fn, (params, batch), out_shardings, ()

    # decode: primed cache at length smax-1, one-token step
    B = shape.global_batch
    # closure (not args) so the dims stay static under eval_shape
    cache_shapes = jax.eval_shape(lambda: MD.init_cache(cfg, B, smax))
    c_shard = SH.cache_shardings(cfg, cache_shapes, mesh)
    caches = _abstract(cache_shapes, c_shard)
    tokens = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=SH.batch_sharding(
            {"t": jax.ShapeDtypeStruct((B, 1), jnp.int32)}, rules, mesh)["t"])
    # decode q=1: a single full-length KV chunk keeps the per-layer cache
    # all-gather to ONE op instead of one per 1024-chunk (§Perf P2b);
    # scores are [B,H,1,S] — small at decode
    kv_chunk = min(shape.seq_len, max(chunks))

    def fn(params, tokens, caches):
        return decode_step(params, cfg, tokens, caches,
                           chunks=(1, kv_chunk))

    out_shardings = (None, c_shard, None)
    return fn, (params, tokens, caches), out_shardings, (2,)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             chunks=(1024, 1024)) -> Dict:
    """Lower + compile one cell; return the §Dry-run/§Roofline record."""
    cfg = configs.get(arch)
    shape = configs.get_shape(shape_name)
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "SKIP", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    fn, args, out_shardings, donate = build_cell(arch, shape_name, mesh,
                                                 chunks)
    from repro.compat import use_mesh
    with use_mesh(mesh):
        jitted = jax.jit(fn, out_shardings=out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "status": "OK",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "flops": float(ca.get("flops", -1)),
            "bytes_accessed": float(ca.get("bytes accessed", -1)),
            "transcendentals": float(ca.get("transcendentals", -1)),
        },
        "collectives": coll,
        "hlo_ops": {c: txt.count(f" {c}") for c in _COLLECTIVES},
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) cell")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in configs.ARCH_IDS:
            for s in configs.ALL_SHAPES:
                cells.append((a, s.name))
    else:
        shapes = [args.shape] if args.shape else \
            [s.name for s in configs.ALL_SHAPES]
        cells = [(args.arch, s) for s in shapes]

    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    reports = []
    for arch, shp in cells:
        for mp in meshes:
            rec = run_cell(arch, shp, multi_pod=mp)
            reports.append(rec)
            tag = f"{arch} × {shp} × {'2x16x16' if mp else '16x16'}"
            if rec["status"] == "SKIP":
                print(f"SKIP {tag}: {rec['reason']}")
            else:
                pd = rec["per_device"]
                print(f"OK   {tag}: compile={rec['compile_s']}s "
                      f"args={pd['argument_bytes']/1e9:.2f}GB "
                      f"temp={pd['temp_bytes']/1e9:.2f}GB "
                      f"flops={pd['flops']:.3g} "
                      f"coll={rec['collectives']['total']/1e9:.3f}GB")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
