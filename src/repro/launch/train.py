"""Training launcher: ``--arch`` × ``--shape`` (or smoke dims), mesh-aware,
checkpoint/resume, deterministic data, failure-injection hooks.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \\
      --steps 200 --ckpt-dir /tmp/ckpt [--resume] [--devices 4]
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLM
from repro.distributed import sharding as SH
from repro.ft.fault_tolerance import TrainSupervisor
from repro.launch.mesh import make_host_mesh
from repro.nn import model as MD
from repro.nn.layers import init_params
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import train_step


def build(arch: str, smoke: bool, seq: int, global_batch: int,
          opt_cfg: OptConfig, n_devices: int = 1, chunks=(256, 256),
          seed: int = 0):
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    mesh = make_host_mesh(n_devices) if n_devices > 1 else None
    data = SyntheticLM(cfg, seq, global_batch, seed=seed)
    key = jax.random.PRNGKey(seed)
    specs = MD.param_specs(cfg)
    params = init_params(specs, key)
    opt = init_opt_state(params)
    if mesh is not None:
        rules = SH.rules_for("train")
        p_sh = SH.shardings_for_specs(specs, rules, mesh)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt = {"mu": jax.tree.map(jax.device_put, opt["mu"], p_sh),
               "nu": jax.tree.map(jax.device_put, opt["nu"], p_sh),
               "step": opt["step"]}
    step_jit = jax.jit(partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                               remat=True, chunks=chunks))

    def one_step(params, opt_state, step):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        from repro.compat import use_mesh
        ctx = use_mesh(mesh) if mesh is not None else _null()
        with ctx:
            return step_jit(params, opt_state, batch)

    return cfg, params, opt, one_step


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "const"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    # minicpm trains with WSD per its paper; make that the arch default
    sched = args.schedule
    if args.arch == "minicpm-2b" and sched == "cosine":
        sched = "wsd"
    ocfg = OptConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                     total_steps=args.steps, schedule=sched)
    cfg, params, opt, one_step = build(
        args.arch, args.smoke, args.seq, args.global_batch, ocfg,
        n_devices=args.devices, seed=args.seed)
    print(f"arch={cfg.name} params="
          f"{sum(int(np.prod(v.shape)) for v in params.values()):,}")

    t0 = time.time()
    log = {"last": t0}

    def step_fn(params, opt_state, step):
        params, opt_state, m = one_step(params, opt_state, step)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(m["loss"])
            now = time.time()
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.3f} "
                  f"({now - log['last']:.1f}s)")
            log["last"] = now
        return params, opt_state, m

    if args.ckpt_dir:
        sup = TrainSupervisor(Checkpointer(args.ckpt_dir),
                              ckpt_every=args.ckpt_every)
        params, opt, hist = sup.run(params, opt, step_fn, args.steps)
        losses = [h["loss"] for h in hist]
    else:
        losses = []
        for s in range(args.steps):
            params, opt, m = step_fn(params, opt, s)
            losses.append(float(m["loss"]))
    if losses:
        print(f"done in {time.time() - t0:.1f}s  "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
