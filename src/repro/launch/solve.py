"""Solver launcher + solver-on-production-mesh dry-run.

  PYTHONPATH=src python -m repro.launch.solve --n 10                # solve
  PYTHONPATH=src python -m repro.launch.solve --preset fast --n 12
  PYTHONPATH=src python -m repro.launch.solve --dryrun [--multi-pod]

``--preset {prove,first,fast}`` picks the named `SolveConfig` recipe
(DESIGN.md §11): `prove` runs B&B to a proof (default), `first` stops at
the first solution, `fast` caps fixpoint sweeps (§Perf P0).  The solve
path goes through the session API (`repro.solver`), streaming anytime
incumbents as they improve.

The dry-run lowers+compiles one solver chunk (`api._run_chunk` under
shard_map) for the full production mesh — the paper's own system passing
the same bar as the LM cells: lanes sharded over all 256/512 devices,
bound sharing via pmin visible as `all-reduce` in the HLO.
"""

import os
if "XLA_FLAGS" not in os.environ and "--dryrun" in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import time              # noqa: E402
import warnings          # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

# CLI name -> SolveConfig preset name
_PRESETS = {"prove": "prove", "first": "first_solution", "fast": "fast"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10, help="RCPSP tasks")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resources", type=int, default=4)
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--subs", type=int, default=128)
    ap.add_argument("--eps-target", type=int, default=None,
                    help="EPS pool size (DESIGN.md §9): decompose the root "
                         "into ~this many subproblems; 1 = single-root "
                         "search; default --subs")
    ap.add_argument("--timeout", type=float, default=120)
    ap.add_argument("--preset", choices=sorted(_PRESETS), default="prove",
                    help="SolveConfig preset (DESIGN.md §11): prove = full "
                         "B&B proof, first = stop at first solution, fast "
                         "= capped fixpoint sweeps (§Perf P0)")
    ap.add_argument("--fast", action="store_true",
                    help="DEPRECATED: use --preset fast")
    from repro.core.backend import available_backends
    ap.add_argument("--backend", default="gather",
                    choices=available_backends(),
                    help="propagation backend for the superstep fixpoint "
                         "(core/backend.py; pallas = VMEM kernel, "
                         "interpret-mode on CPU)")
    ap.add_argument("--lane-tile", type=int, default=None,
                    help="pallas backends: lanes per VMEM grid cell "
                         "(default 8 for pallas; 0 = whole batch in one "
                         "cell for pallas_resident, its bit-parity mode)")
    ap.add_argument("--supersteps-per-launch", type=int, default=None,
                    help="pallas_resident: K supersteps fused per "
                         "megakernel launch (DESIGN.md §13; default 16)")
    ap.add_argument("--branch-value", default=None,
                    choices=("min", "split", "middle_out"),
                    help="value branching (DESIGN.md §17): min = x≤lb, "
                         "split = bisect at the midpoint, middle_out = "
                         "x=m | x≠m on the bitset-domain value nearest "
                         "the midpoint (needs no tables — the bitset "
                         "store is carried automatically)")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="distributed EPS (core/dist_solve.py, DESIGN.md "
                         "§14): shard the lane pool over N devices with "
                         "per-chunk bound sharing, work stealing and "
                         "elastic device-loss recovery; on CPU fake "
                         "devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--file", default=None)
    args = ap.parse_args()

    from repro import solver
    from repro.core.models import rcpsp

    if args.fast:
        warnings.warn("--fast is deprecated; use --preset fast",
                      DeprecationWarning)
        args.preset = "fast"

    if args.file:
        inst = (rcpsp.parse_psplib_sm(args.file) if args.file.endswith(".sm")
                else rcpsp.parse_patterson(args.file))
    else:
        inst = rcpsp.generate(args.n, n_resources=args.resources,
                              seed=args.seed)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    if args.supersteps_per_launch and args.backend != "pallas_resident":
        ap.error("--supersteps-per-launch needs --backend pallas_resident")
    bo = {}
    if args.lane_tile is not None and args.backend.startswith("pallas"):
        bo["lane_tile"] = args.lane_tile
    extra = {}
    if args.branch_value is not None:
        extra["val_strategy"] = args.branch_value
    cfg = solver.SolveConfig.preset(
        _PRESETS[args.preset],
        n_lanes=args.lanes,
        eps_target=(args.eps_target if args.eps_target is not None
                    else args.subs),
        timeout_s=args.timeout, backend=args.backend,
        backend_opts=tuple(sorted(bo.items())),
        supersteps_per_launch=args.supersteps_per_launch,
        mesh_shards=args.mesh, **extra)

    if args.dryrun:
        from repro.launch.mesh import make_production_mesh
        from repro.core.api import _run_chunk, _init_carry
        from repro.core import search as S
        from jax.sharding import PartitionSpec as P
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        axes = tuple(mesh.axis_names)
        n_dev = int(np.prod(list(mesh.shape.values())))
        lanes = 8                                  # per device
        V = cm.n_vars
        Spool = n_dev * 16
        opts = cfg.search_options()
        carry = _init_carry(cm, lanes * n_dev, opts, n_heads=n_dev)
        spec = P(axes)
        state_spec = jax.tree.map(lambda _: spec, carry[0])
        carry_spec = (state_spec, P(), P(), P(), spec)
        dev_fn = lambda sl, su, c: _run_chunk(   # noqa: E731
            opts, False, 64, axes, cm, sl, su, c)
        from repro.compat import shard_map, use_mesh
        f = jax.jit(shard_map(dev_fn, mesh=mesh,
                              in_specs=(spec, spec, carry_spec),
                              out_specs=carry_spec, check_vma=False))
        t0 = time.time()
        with use_mesh(mesh):
            lowered = f.lower(
                jax.ShapeDtypeStruct((Spool, V), cm.jdtype,
                                     sharding=jax.NamedSharding(mesh, spec)),
                jax.ShapeDtypeStruct((Spool, V), cm.jdtype,
                                     sharding=jax.NamedSharding(mesh, spec)),
                jax.tree.map(
                    lambda x, s: jax.ShapeDtypeStruct(
                        x.shape, x.dtype,
                        sharding=jax.NamedSharding(mesh, s)),
                    carry, carry_spec))
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        txt = compiled.as_text()
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        print(f"SOLVER dry-run OK on {mesh_tag} ({n_dev} devices): "
              f"compile={time.time()-t0:.1f}s "
              f"args={ma.argument_size_in_bytes/1e6:.1f}MB/dev "
              f"temp={ma.temp_size_in_bytes/1e6:.1f}MB/dev "
              f"all-reduce ops={txt.count(' all-reduce')} "
              f"(B&B bound pmin + done/any-sol flags)")
        return

    t0 = time.time()
    sess = solver.Solver(cfg)
    res, trace = None, None
    if args.mesh is not None:
        # dist path driven directly so the solve's DistTrace (steal /
        # remesh / bound-sync counters) is printable at the end
        from repro.core import dist_solve
        from repro.core.api import _canonical
        trace = dist_solve.DistTrace()
        events = dist_solve.solve_iter_dist(sess, _canonical(cm), cfg,
                                            trace=trace)
    else:
        events = sess.solve_iter(cm)
    for ev in events:
        if ev.final:
            res = ev.result
        elif ev.best_objective is not None and ev.incumbent is not None:
            # a fresh incumbent this chunk — the anytime answer
            print(f"  [{ev.wall_s:6.1f}s] superstep={ev.superstep:6d} "
                  f"incumbent={ev.best_objective} nodes={ev.n_nodes}")
    print(f"{inst.name}: {res.status} objective={res.objective} "
          f"nodes={res.n_nodes} ({res.nodes_per_sec:.0f}/s) "
          f"supersteps={res.n_supersteps} improvements="
          f"{[i.objective for i in res.improvements]} "
          f"wall={time.time()-t0:.1f}s complete={res.complete}")
    if trace is not None:
        print(f"  distributed: shards={args.mesh} chunks={trace.n_chunks} "
              f"bound_syncs={trace.n_bound_syncs} steals={trace.n_steals} "
              f"remeshes={len(trace.remesh_events)}")


if __name__ == "__main__":
    main()
