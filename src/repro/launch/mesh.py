"""Production mesh construction (dry-run spec).

A function — not a module-level constant — so importing this module never
touches jax device state (device count locks on first use).
"""

from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(n: int = 1, axis: str = "data"):
    """Small mesh over locally visible devices (tests / examples)."""
    n = min(n, jax.device_count())
    return make_mesh((n,), (axis,), axis_types=(AxisType.Auto,))
