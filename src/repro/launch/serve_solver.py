"""Solver-as-a-service launcher: continuous-batching scheduler under a
seeded open-loop load (DESIGN.md §15).

  PYTHONPATH=src python -m repro.launch.serve_solver \\
      --requests 20 --rate 50 --seed 0 --max-batch 4 --chunk 16

Generates a Poisson arrival trace over the default zoo mix (two
seed-stable shape buckets), drives a `SolverScheduler` on the host
clock, and prints the latency/occupancy summary plus per-bucket compile
counters.  With ``--parity`` every result is also checked bit-identical
against a sequential `Solver.solve` reference (deadline evictions
excepted).

Scope note: this serves the *constraint solver*.  The NN token-serving
demo lives in `repro.launch.serve`.
"""

from __future__ import annotations

import argparse
import json

from repro.core.api import SolveConfig, Solver
from repro.serve.loadgen import (poisson_trace, run_open_loop,
                                 sequential_reference)
from repro.serve.scheduler import SolverScheduler


def build_config(args) -> SolveConfig:
    return SolveConfig.preset(
        args.preset, backend=args.backend, n_lanes=args.lanes,
        eps_target=args.eps_target, chunk=args.chunk,
        max_depth=args.max_depth)


def main():
    ap = argparse.ArgumentParser(
        description="Serve the solver under open-loop Poisson load")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="arrival rate (requests/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="lane-batch slots per bucket")
    ap.add_argument("--preset", default="prove")
    ap.add_argument("--backend", default="gather")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--eps-target", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--max-depth", type=int, default=256)
    ap.add_argument("--max-wall-s", type=float, default=600.0)
    ap.add_argument("--parity", action="store_true",
                    help="check results against sequential Solver.solve")
    ap.add_argument("--json", default=None,
                    help="also dump the metrics summary to this file")
    args = ap.parse_args()

    cfg = build_config(args)
    trace = poisson_trace(args.requests, args.rate, seed=args.seed)
    sched = SolverScheduler(cfg, max_batch=args.max_batch)
    handles = run_open_loop(sched, trace, max_wall_s=args.max_wall_s)

    summary = sched.recorder.summary()
    print(json.dumps(summary, indent=2, default=str))
    print("buckets:", json.dumps(sched.buckets(), indent=2))

    if args.parity:
        ref = sequential_reference(trace, build_config(args))
        n_bad = 0
        for _, h in handles:
            res = h.result()
            want = ref[h.request.request_id]
            got = (res.status, res.objective)
            if res.complete and got != want:
                n_bad += 1
                print(f"PARITY MISMATCH {h.request.request_id}: "
                      f"served={got} sequential={want}")
        print(f"parity: {'OK' if n_bad == 0 else f'{n_bad} MISMATCHES'} "
              f"over {len(handles)} requests")
        if n_bad:
            raise SystemExit(1)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(dict(summary=summary, buckets=sched.buckets()), f,
                      indent=2, default=str)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
