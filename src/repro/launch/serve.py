"""NN serving launcher: batched prefill + greedy decode demo.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
      --batch 4 --prompt-len 32 --gen 16

Scope note: this serves the *neural-network scaffolding* (repro.nn token
generation) and has nothing to do with serving the constraint solver.
For solver-as-a-service — the continuous-batching request scheduler over
`Solver.solve` with open-loop load generation and latency metrics
(DESIGN.md §15) — use `repro.launch.serve_solver` instead.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.nn import model as MD
from repro.nn.layers import init_params
from repro.train.serve_step import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(MD.param_specs(cfg), key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.frontend == "vit_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.frontend_tokens, cfg.d_model))

    smax = args.prompt_len + args.gen + 8
    t0 = time.time()
    out = generate(params, cfg, batch, steps=args.gen, smax=smax,
                   temperature=args.temperature, seed=args.seed,
                   chunks=(32, 32))
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
