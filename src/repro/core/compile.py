"""⟦.⟧ — lower a Model to dense guarded-command tables (paper Prop. 4).

Every constraint becomes one row of a *typed propagator table*
(DESIGN.md §12): the table is split into per-kind **banks** —

* ``ReifLinLe``   (vidx/coef/rhs/bidx): reified linear inequalities, the
  paper's guarded-normal-form rows;
* ``AllDifferent`` (ad_vars/ad_offs/ad_mask): one row per alldifferent,
  filtered with Hall-interval bounds(Z) consistency;
* ``Cumulative``  (cu_svar/cu_dur/cu_dem/cu_cap): one row per cumulative,
  filtered with time-table (compulsory-part) reasoning.

Each bank gets its own variable-centric occurrence tables so every kind
joins into the store by pure gathers (TPU-native, no atomics); each bank
carries one trailing neutral dummy row that occurrence padding points at.

For the linear bank, two dual views of the same program are produced:

* **propagator-centric** (`vidx/coef/rhs/bidx`): one row per propagator —
  this is what a CUDA thread would execute; used by the scatter oracle
  (`kernels/ref.py`) and by the sequential baseline.
* **variable-centric** (`occ_prop/occ_slot`): for each variable, the list
  of (propagator, slot) occurrences that may tighten it — the TPU-native
  gather formulation used by the fixpoint engine and the Pallas kernel.
  Joins become per-variable min/max reductions: associativity of ⊔ makes
  the two views compute the same sweep (validated by tests).

Overflow policy: all candidate bounds are clamped into the *initial box*
``[lb0-1, ub0+1]`` (sound: a candidate outside the box still crosses the
opposite bound, so failure is preserved), and compile-time checks ensure
``Σ_j |a_j| · (max(|lb0_j|, |ub0_j|) + 1)`` fits the dtype with headroom.
Models that exceed int32 headroom are auto-promoted to int64.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import Model, ReifLinLe, TRUE_VAR

# slot code for "this occurrence is the reified boolean of the propagator"
# (stored as slot == K, one past the last term slot).


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompiledModel:
    """Dense, fixed-shape program. All arrays are device-ready.

    Shapes: V vars, P props (+1 trailing dummy row), K padded terms,
    D padded occurrences per var, B branch vars.
    """

    # store init
    lb0: jax.Array          # i[V]
    ub0: jax.Array          # i[V]
    box_lo: jax.Array       # i[V]  = lb0 - 1 (clamp floor)
    box_hi: jax.Array       # i[V]  = ub0 + 1 (clamp ceil)
    # propagator-centric tables (row P is the neutral dummy)
    vidx: jax.Array         # i[P+1, K] var index per term (0 for padding)
    coef: jax.Array         # i[P+1, K] coefficient (0 for padding)
    rhs: jax.Array          # i[P+1]
    bidx: jax.Array         # i[P+1]   reif bool var (TRUE_VAR for plain)
    # variable-centric occurrence tables (padding points at dummy row, slot 0)
    occ_prop: jax.Array     # i[V, D]
    occ_slot: jax.Array     # i[V, D]  in [0, K]; K == reif-entailment slot
    # alldifferent bank (row A is the neutral dummy; DESIGN.md §12)
    ad_vars: jax.Array      # i[A+1, N]  member var index (0 for padding)
    ad_offs: jax.Array      # i[A+1, N]  member offset (x_i + off_i distinct)
    ad_mask: jax.Array      # i[A+1, N]  1 = real member, 0 = padding
    ad_occ_inst: jax.Array  # i[V, Dad]  alldiff row per occurrence
    ad_occ_pos: jax.Array   # i[V, Dad]  member position per occurrence
    # cumulative bank (row C is the neutral dummy)
    cu_svar: jax.Array      # i[C+1, T]  start var per task (0 for padding)
    cu_dur: jax.Array       # i[C+1, T]  duration (0 for padding)
    cu_dem: jax.Array       # i[C+1, T]  demand   (0 for padding)
    cu_cap: jax.Array       # i[C+1]     capacity
    cu_occ_inst: jax.Array  # i[V, Dcu]
    cu_occ_pos: jax.Array   # i[V, Dcu]
    # search
    branch_vars: jax.Array  # i[B] decision vars in branching order
    # static metadata
    n_vars: int = dataclasses.field(metadata=dict(static=True))
    n_props: int = dataclasses.field(metadata=dict(static=True))
    k_terms: int = dataclasses.field(metadata=dict(static=True))
    d_occ: int = dataclasses.field(metadata=dict(static=True))
    n_alldiff: int = dataclasses.field(metadata=dict(static=True))
    ad_width: int = dataclasses.field(metadata=dict(static=True))
    ad_docc: int = dataclasses.field(metadata=dict(static=True))
    n_cumulative: int = dataclasses.field(metadata=dict(static=True))
    cu_width: int = dataclasses.field(metadata=dict(static=True))
    cu_docc: int = dataclasses.field(metadata=dict(static=True))
    horizon: int = dataclasses.field(metadata=dict(static=True))
    obj_var: int = dataclasses.field(metadata=dict(static=True))  # -1 if satisfaction
    dtype: str = dataclasses.field(metadata=dict(static=True))
    name: str = dataclasses.field(metadata=dict(static=True))

    @property
    def jdtype(self):
        return np.dtype(self.dtype)

    @property
    def total_props(self) -> int:
        """Propagator-table rows across all kinds (dummies excluded) —
        the count the §12 bench/regression guards compare."""
        return self.n_props + self.n_alldiff + self.n_cumulative


def compile_model(
    m: Model,
    pad_terms_to: int = 8,
    pad_occ_to: int = 8,
    pad_horizon_to: int = 32,
    force_dtype: str | None = None,
) -> CompiledModel:
    V = m.n_vars
    props: List[ReifLinLe] = m.props
    P = len(props)
    if P == 0 and not (m.alldiffs or m.cumulatives):
        raise ValueError("model has no constraints")

    K = max((len(p.lin.terms) for p in props), default=1)
    K = max(_round_up(K, pad_terms_to), pad_terms_to)

    lb0 = np.asarray(m.lb0, dtype=np.int64)
    ub0 = np.asarray(m.ub0, dtype=np.int64)

    vidx = np.zeros((P + 1, K), dtype=np.int64)
    coef = np.zeros((P + 1, K), dtype=np.int64)
    rhs = np.zeros((P + 1,), dtype=np.int64)
    bidx = np.full((P + 1,), TRUE_VAR, dtype=np.int64)

    occs: List[List[Tuple[int, int]]] = [[] for _ in range(V)]
    for p, rp in enumerate(props):
        terms = rp.lin.terms
        if len(terms) > K:
            raise ValueError("term overflow")
        for k, (v, a) in enumerate(terms):
            vidx[p, k] = v
            coef[p, k] = a
            occs[v].append((p, k))
        rhs[p] = rp.lin.rhs
        bidx[p] = rp.bvar
        if rp.bvar != TRUE_VAR:
            # genuinely reified: b can be tightened by (dis)entailment.
            occs[rp.bvar].append((p, K))
        # plain props (b == TRUE) fail through term tightening alone; we
        # skip their reif occurrence so the TRUE var's degree stays 0.

    # dummy row P: coef 0 everywhere -> all candidates neutral; rhs huge so
    # it is "entailed" but its reif slot is never gathered.
    rhs[P] = int(np.iinfo(np.int32).max // 4)

    D = max(max((len(o) for o in occs), default=1), 1)
    D = max(_round_up(D, pad_occ_to), pad_occ_to)
    occ_prop = np.full((V, D), P, dtype=np.int64)   # pad -> dummy row
    occ_slot = np.zeros((V, D), dtype=np.int64)     # pad -> term slot 0 (coef 0)
    for v, o in enumerate(occs):
        for d, (p, k) in enumerate(o):
            occ_prop[v, d] = p
            occ_slot[v, d] = k

    # ---- alldifferent bank (DESIGN.md §12) -----------------------------
    A = len(m.alldiffs)
    N = max((len(ad.vars) for ad in m.alldiffs), default=2)
    N = max(_round_up(N, 4), 2) if A else 2
    ad_vars = np.zeros((A + 1, N), dtype=np.int64)
    ad_offs = np.zeros((A + 1, N), dtype=np.int64)
    ad_mask = np.zeros((A + 1, N), dtype=np.int64)
    ad_occs: List[List[Tuple[int, int]]] = [[] for _ in range(V)]
    for a, ad in enumerate(m.alldiffs):
        for n, (v, off) in enumerate(zip(ad.vars, ad.offsets)):
            ad_vars[a, n] = v
            ad_offs[a, n] = off
            ad_mask[a, n] = 1
            ad_occs[v].append((a, n))
    Dad = max(max((len(o) for o in ad_occs), default=1), 1)
    Dad = _round_up(Dad, 4) if A else 1
    ad_occ_inst = np.full((V, Dad), A, dtype=np.int64)   # pad -> dummy row
    ad_occ_pos = np.zeros((V, Dad), dtype=np.int64)
    for v, o in enumerate(ad_occs):
        for d, (a, n) in enumerate(o):
            ad_occ_inst[v, d] = a
            ad_occ_pos[v, d] = n

    # ---- cumulative bank (DESIGN.md §12) -------------------------------
    C = len(m.cumulatives)
    T = max((len(cu.starts) for cu in m.cumulatives), default=2)
    T = max(_round_up(T, 4), 2) if C else 2
    cu_svar = np.zeros((C + 1, T), dtype=np.int64)
    cu_dur = np.zeros((C + 1, T), dtype=np.int64)
    cu_dem = np.zeros((C + 1, T), dtype=np.int64)
    cu_cap = np.zeros((C + 1,), dtype=np.int64)
    cu_occs: List[List[Tuple[int, int]]] = [[] for _ in range(V)]
    horizon = 1
    for c, cu in enumerate(m.cumulatives):
        cu_cap[c] = cu.capacity
        for t, (v, d_, r_) in enumerate(zip(cu.starts, cu.durations,
                                            cu.demands)):
            cu_svar[c, t] = v
            cu_dur[c, t] = d_
            cu_dem[c, t] = r_
            if d_ > 0 and r_ > 0:
                if int(lb0[v]) < 0:
                    # the time-table grid is [0, horizon); a negative
                    # feasible start would be silently pruned (wrong
                    # UNSAT) — demand a shifted model instead
                    raise ValueError(
                        f"cumulative start var {v} has negative domain "
                        f"({int(lb0[v])}, {int(ub0[v])}); native time-table "
                        "filtering needs nonnegative starts — shift the "
                        "model (or use decompose=True)")
                # only effective tasks are ever tightened by the row
                cu_occs[v].append((c, t))
                horizon = max(horizon, int(ub0[v]) + d_ + 2)
    Dcu = max(max((len(o) for o in cu_occs), default=1), 1)
    Dcu = _round_up(Dcu, 4) if C else 1
    # bucket the (static, trace-shaping) time grid so same-family
    # instances across seeds keep one shape signature (api.py cache /
    # solve_many; same spirit as the pool pow2 buckets, DESIGN.md §11)
    if C:
        horizon = _round_up(horizon, pad_horizon_to)
    cu_occ_inst = np.full((V, Dcu), C, dtype=np.int64)   # pad -> dummy row
    cu_occ_pos = np.zeros((V, Dcu), dtype=np.int64)
    for v, o in enumerate(cu_occs):
        for d, (c, t) in enumerate(o):
            cu_occ_inst[v, d] = c
            cu_occ_pos[v, d] = t

    # ---- dtype selection with overflow headroom ------------------------
    absmax = np.maximum(np.abs(lb0), np.abs(ub0)) + 1           # per var
    worst = int((np.abs(coef[:P]) * absmax[vidx[:P]]).sum(axis=1).max()) \
        if P else 0
    worst = max(worst, int(np.abs(rhs[:P]).max()) if P else 0)
    # native banks: shifted alldiff values x+off (±1 Hall push), cumulative
    # time points up to `horizon` and per-row demand sums
    if A:
        worst = max(worst, int((absmax[ad_vars[:A]] + np.abs(ad_offs[:A])
                                ).max()) + 2)
    if C:
        worst = max(worst, horizon + 2,
                    int(cu_dem[:C].sum(axis=1).max()), int(cu_cap[:C].max()))
    if force_dtype is not None:
        dtype = force_dtype
    elif worst * 4 < np.iinfo(np.int32).max:
        dtype = "int32"
    else:
        dtype = "int64"
    if worst * 4 >= np.iinfo(np.int64).max:
        raise OverflowError("model exceeds int64 headroom")

    branch = list(m.branch_order) if m.branch_order else list(range(1, V))
    # ensure every non-fixed var is ultimately branchable: append leftovers
    missing = [v for v in range(1, V) if v not in set(branch)]
    branch = branch + missing

    if dtype == "int64" and not jax.config.jax_enable_x64:
        raise OverflowError(
            f"model '{m.name}' needs int64 headroom (worst sum {worst}); "
            "set JAX_ENABLE_X64=1 or pass force_dtype after re-scaling")
    # leaves are jnp so the tables work when closed over (not jit args)
    cast = lambda a: jnp.asarray(np.asarray(a, dtype=dtype))  # noqa: E731
    return CompiledModel(
        lb0=cast(lb0), ub0=cast(ub0),
        box_lo=cast(lb0 - 1), box_hi=cast(ub0 + 1),
        vidx=cast(vidx), coef=cast(coef), rhs=cast(rhs), bidx=cast(bidx),
        occ_prop=cast(occ_prop), occ_slot=cast(occ_slot),
        ad_vars=cast(ad_vars), ad_offs=cast(ad_offs), ad_mask=cast(ad_mask),
        ad_occ_inst=cast(ad_occ_inst), ad_occ_pos=cast(ad_occ_pos),
        cu_svar=cast(cu_svar), cu_dur=cast(cu_dur), cu_dem=cast(cu_dem),
        cu_cap=cast(cu_cap),
        cu_occ_inst=cast(cu_occ_inst), cu_occ_pos=cast(cu_occ_pos),
        branch_vars=cast(np.asarray(branch)),
        n_vars=V, n_props=P, k_terms=K, d_occ=D,
        n_alldiff=A, ad_width=N, ad_docc=Dad,
        n_cumulative=C, cu_width=T, cu_docc=Dcu, horizon=horizon,
        obj_var=(m.objective if m.objective is not None else -1),
        dtype=dtype, name=m.name,
    )
