"""⟦.⟧ — lower a Model to dense guarded-command tables (paper Prop. 4).

Every constraint becomes one row of the *propagator table*; the row is the
guarded-normal-form of the paper: the ask set is {b} (plus the implicit
guard "still consistent"), the tells are the interval tightenings of the
reified linear inequality.

Two dual views of the same program are produced:

* **propagator-centric** (`vidx/coef/rhs/bidx`): one row per propagator —
  this is what a CUDA thread would execute; used by the scatter oracle
  (`kernels/ref.py`) and by the sequential baseline.
* **variable-centric** (`occ_prop/occ_slot`): for each variable, the list
  of (propagator, slot) occurrences that may tighten it — the TPU-native
  gather formulation used by the fixpoint engine and the Pallas kernel.
  Joins become per-variable min/max reductions: associativity of ⊔ makes
  the two views compute the same sweep (validated by tests).

Overflow policy: all candidate bounds are clamped into the *initial box*
``[lb0-1, ub0+1]`` (sound: a candidate outside the box still crosses the
opposite bound, so failure is preserved), and compile-time checks ensure
``Σ_j |a_j| · (max(|lb0_j|, |ub0_j|) + 1)`` fits the dtype with headroom.
Models that exceed int32 headroom are auto-promoted to int64.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import Model, ReifLinLe, TRUE_VAR

# slot code for "this occurrence is the reified boolean of the propagator"
# (stored as slot == K, one past the last term slot).


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompiledModel:
    """Dense, fixed-shape program. All arrays are device-ready.

    Shapes: V vars, P props (+1 trailing dummy row), K padded terms,
    D padded occurrences per var, B branch vars.
    """

    # store init
    lb0: jax.Array          # i[V]
    ub0: jax.Array          # i[V]
    box_lo: jax.Array       # i[V]  = lb0 - 1 (clamp floor)
    box_hi: jax.Array       # i[V]  = ub0 + 1 (clamp ceil)
    # propagator-centric tables (row P is the neutral dummy)
    vidx: jax.Array         # i[P+1, K] var index per term (0 for padding)
    coef: jax.Array         # i[P+1, K] coefficient (0 for padding)
    rhs: jax.Array          # i[P+1]
    bidx: jax.Array         # i[P+1]   reif bool var (TRUE_VAR for plain)
    # variable-centric occurrence tables (padding points at dummy row, slot 0)
    occ_prop: jax.Array     # i[V, D]
    occ_slot: jax.Array     # i[V, D]  in [0, K]; K == reif-entailment slot
    # search
    branch_vars: jax.Array  # i[B] decision vars in branching order
    # static metadata
    n_vars: int = dataclasses.field(metadata=dict(static=True))
    n_props: int = dataclasses.field(metadata=dict(static=True))
    k_terms: int = dataclasses.field(metadata=dict(static=True))
    d_occ: int = dataclasses.field(metadata=dict(static=True))
    obj_var: int = dataclasses.field(metadata=dict(static=True))  # -1 if satisfaction
    dtype: str = dataclasses.field(metadata=dict(static=True))
    name: str = dataclasses.field(metadata=dict(static=True))

    @property
    def jdtype(self):
        return np.dtype(self.dtype)


def compile_model(
    m: Model,
    pad_terms_to: int = 8,
    pad_occ_to: int = 8,
    force_dtype: str | None = None,
) -> CompiledModel:
    V = m.n_vars
    props: List[ReifLinLe] = m.props
    P = len(props)
    if P == 0:
        raise ValueError("model has no constraints")

    K = max(len(p.lin.terms) for p in props)
    K = max(_round_up(K, pad_terms_to), pad_terms_to)

    lb0 = np.asarray(m.lb0, dtype=np.int64)
    ub0 = np.asarray(m.ub0, dtype=np.int64)

    vidx = np.zeros((P + 1, K), dtype=np.int64)
    coef = np.zeros((P + 1, K), dtype=np.int64)
    rhs = np.zeros((P + 1,), dtype=np.int64)
    bidx = np.full((P + 1,), TRUE_VAR, dtype=np.int64)

    occs: List[List[Tuple[int, int]]] = [[] for _ in range(V)]
    for p, rp in enumerate(props):
        terms = rp.lin.terms
        if len(terms) > K:
            raise ValueError("term overflow")
        for k, (v, a) in enumerate(terms):
            vidx[p, k] = v
            coef[p, k] = a
            occs[v].append((p, k))
        rhs[p] = rp.lin.rhs
        bidx[p] = rp.bvar
        if rp.bvar != TRUE_VAR:
            # genuinely reified: b can be tightened by (dis)entailment.
            occs[rp.bvar].append((p, K))
        # plain props (b == TRUE) fail through term tightening alone; we
        # skip their reif occurrence so the TRUE var's degree stays 0.

    # dummy row P: coef 0 everywhere -> all candidates neutral; rhs huge so
    # it is "entailed" but its reif slot is never gathered.
    rhs[P] = int(np.iinfo(np.int32).max // 4)

    D = max(max((len(o) for o in occs), default=1), 1)
    D = max(_round_up(D, pad_occ_to), pad_occ_to)
    occ_prop = np.full((V, D), P, dtype=np.int64)   # pad -> dummy row
    occ_slot = np.zeros((V, D), dtype=np.int64)     # pad -> term slot 0 (coef 0)
    for v, o in enumerate(occs):
        for d, (p, k) in enumerate(o):
            occ_prop[v, d] = p
            occ_slot[v, d] = k

    # ---- dtype selection with overflow headroom ------------------------
    absmax = np.maximum(np.abs(lb0), np.abs(ub0)) + 1           # per var
    per_prop_sum = np.abs(coef[:P]) @ np.ones((K,), np.int64)   # not used alone
    worst = int((np.abs(coef[:P]) * absmax[vidx[:P]]).sum(axis=1).max()) \
        if P else 0
    worst = max(worst, int(np.abs(rhs[:P]).max()) if P else 0)
    del per_prop_sum
    if force_dtype is not None:
        dtype = force_dtype
    elif worst * 4 < np.iinfo(np.int32).max:
        dtype = "int32"
    else:
        dtype = "int64"
    if worst * 4 >= np.iinfo(np.int64).max:
        raise OverflowError("model exceeds int64 headroom")

    branch = list(m.branch_order) if m.branch_order else list(range(1, V))
    # ensure every non-fixed var is ultimately branchable: append leftovers
    missing = [v for v in range(1, V) if v not in set(branch)]
    branch = branch + missing

    if dtype == "int64" and not jax.config.jax_enable_x64:
        raise OverflowError(
            f"model '{m.name}' needs int64 headroom (worst sum {worst}); "
            "set JAX_ENABLE_X64=1 or pass force_dtype after re-scaling")
    # leaves are jnp so the tables work when closed over (not jit args)
    cast = lambda a: jnp.asarray(np.asarray(a, dtype=dtype))  # noqa: E731
    return CompiledModel(
        lb0=cast(lb0), ub0=cast(ub0),
        box_lo=cast(lb0 - 1), box_hi=cast(ub0 + 1),
        vidx=cast(vidx), coef=cast(coef), rhs=cast(rhs), bidx=cast(bidx),
        occ_prop=cast(occ_prop), occ_slot=cast(occ_slot),
        branch_vars=cast(np.asarray(branch)),
        n_vars=V, n_props=P, k_terms=K, d_occ=D,
        obj_var=(m.objective if m.objective is not None else -1),
        dtype=dtype, name=m.name,
    )
