"""⟦.⟧ — lower a Model to dense guarded-command tables (paper Prop. 4).

Every constraint becomes one row of a *typed propagator table*
(DESIGN.md §12): the table is split into per-kind **banks** —

* ``ReifLinLe``   (vidx/coef/rhs/bidx): reified linear inequalities, the
  paper's guarded-normal-form rows;
* ``AllDifferent`` (ad_vars/ad_offs/ad_mask): one row per alldifferent,
  filtered with Hall-interval bounds(Z) consistency;
* ``Cumulative``  (cu_svar/cu_dur/cu_dem/cu_cap): one row per cumulative,
  filtered with time-table (compulsory-part) reasoning.

Each bank gets its own variable-centric occurrence tables so every kind
joins into the store by pure gathers (TPU-native, no atomics); each bank
carries one trailing neutral dummy row that occurrence padding points at.

Since ISSUE-9 the native banks also come in a **CSR-style packed view**
(DESIGN.md §16): all members of all rows of a kind concatenated along one
packed axis (``ad_pk_*``/``cu_pk_*`` with a segment id per member and
``ad_ptr``/``cu_ptr`` row pointers), so the O(N³) dense Hall tensor and
the dense ``[.., horizon]`` time grid can be replaced by O(M²) segmented
tiles at scale.  The layout each bank's *tile* uses is chosen here at
compile time (``bank_layout="auto"``): dense below `DENSE_TILE_MAX_BYTES`
of estimated per-lane sweep scratch, sparse above — and the choice is a
static field (`ad_layout`/`cu_layout`) that flows into
`api.shape_signature`, so cached runners never mix layouts.  Both views
are always emitted (the packed tables are O(model size)); forcing
``bank_layout="dense"`` past `DENSE_TILE_HARD_BYTES` raises instead of
letting XLA/Mosaic OOM opaquely.

For the linear bank, two dual views of the same program are produced:

* **propagator-centric** (`vidx/coef/rhs/bidx`): one row per propagator —
  this is what a CUDA thread would execute; used by the scatter oracle
  (`kernels/ref.py`) and by the sequential baseline.
* **variable-centric** (`occ_prop/occ_slot`): for each variable, the list
  of (propagator, slot) occurrences that may tighten it — the TPU-native
  gather formulation used by the fixpoint engine and the Pallas kernel.
  Joins become per-variable min/max reductions: associativity of ⊔ makes
  the two views compute the same sweep (validated by tests).

Overflow policy: all candidate bounds are clamped into the *initial box*
``[lb0-1, ub0+1]`` (sound: a candidate outside the box still crosses the
opposite bound, so failure is preserved), and compile-time checks ensure
``Σ_j |a_j| · (max(|lb0_j|, |ub0_j|) + 1)`` fits the dtype with headroom.
Models that exceed int32 headroom are auto-promoted to int64.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitset import WORD_BITS, n_words_for
from repro.core.model import Model, ReifLinLe, TRUE_VAR

# slot code for "this occurrence is the reified boolean of the propagator"
# (stored as slot == K, one past the last term slot).


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---- dense-tile scratch estimates & layout crossover (DESIGN.md §16) ----
# Per-lane sweep scratch of the *dense* tiles, in bytes.  These are the
# allocations that explode with instance size (the bank tables themselves
# are O(model) and always emitted).  Above DENSE_TILE_MAX_BYTES the auto
# crossover flips the bank to the packed/segmented tile; a *forced* dense
# bank above DENSE_TILE_HARD_BYTES raises instead of OOMing inside
# XLA/Mosaic.  The same estimators feed `kernels.vmem_budget` and the
# `scale` bench section so guard, budget, and bench agree on one number.
DENSE_TILE_MAX_BYTES = 2 * 1024 * 1024
DENSE_TILE_HARD_BYTES = 64 * 1024 * 1024


def alldiff_dense_tile_bytes(n_alldiff: int, ad_width: int,
                             itemsize: int) -> int:
    """Per-lane scratch of `alldiff_candidates_tile`: the [A+1, N, N, N]
    `inside` tensor plus the cnt/width reductions (~3 live copies)."""
    if not n_alldiff:
        return 0
    return 3 * (n_alldiff + 1) * ad_width ** 3 * itemsize


def cumulative_dense_tile_bytes(n_cumulative: int, cu_width: int,
                                horizon: int, itemsize: int) -> int:
    """Per-lane scratch of `cumulative_candidates_tile`: the
    [C+1, T, horizon] run/contrib/feas grids (~4 live copies)."""
    if not n_cumulative:
        return 0
    return 4 * (n_cumulative + 1) * cu_width * horizon * itemsize


def alldiff_sparse_tile_bytes(ad_packed: int, itemsize: int) -> int:
    """Per-lane scratch of `alldiff_candidates_sparse_tile`: a handful of
    [M, M] pairwise tensors over the packed member axis (~6 live)."""
    return 6 * ad_packed ** 2 * itemsize


def cumulative_sparse_tile_bytes(cu_packed: int, itemsize: int) -> int:
    """Per-lane scratch of `cumulative_candidates_sparse_tile`: event
    arrays linear in M plus one [M, 2M] boolean overload reduction."""
    return (2 * cu_packed ** 2) + 16 * cu_packed * itemsize


def ct_tile_bytes(n_table: int, ct_arity: int, n_words: int,
                  ct_words: int) -> int:
    """Per-lane sweep scratch of `ct_candidates_tile` (DESIGN.md §17):
    the [T+1, R, 32W] member-value bits, the [T+1, R, 32W, TW] survivor
    intersection, and the OR-reduced support words (~3 live u32 copies).
    """
    if not n_table:
        return 0
    return 3 * (n_table + 1) * ct_arity * (32 * n_words) * ct_words * 4


def _resolve_layout(bank_layout: str, dense_bytes: int, kind: str,
                    name: str) -> str:
    """Pick this bank's tile layout; guard forced-dense explosions."""
    if dense_bytes == 0:        # bank absent — layout is inert
        return "dense"
    if bank_layout == "sparse":
        return "sparse"
    if bank_layout == "auto" and dense_bytes > DENSE_TILE_MAX_BYTES:
        return "sparse"
    # dense selected (forced, or auto under the crossover)
    if dense_bytes > DENSE_TILE_HARD_BYTES:
        raise ValueError(
            f"model '{name}': dense {kind} tile needs ~{dense_bytes:,} "
            f"bytes of per-lane sweep scratch (> {DENSE_TILE_HARD_BYTES:,}"
            " hard cap) — compile with bank_layout='sparse' (or 'auto') "
            "to use the packed segmented tile instead (DESIGN.md §16)")
    return "dense"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompiledModel:
    """Dense, fixed-shape program. All arrays are device-ready.

    Shapes: V vars, P props (+1 trailing dummy row), K padded terms,
    D padded occurrences per var, B branch vars.
    """

    # store init
    lb0: jax.Array          # i[V]
    ub0: jax.Array          # i[V]
    box_lo: jax.Array       # i[V]  = lb0 - 1 (clamp floor)
    box_hi: jax.Array       # i[V]  = ub0 + 1 (clamp ceil)
    # propagator-centric tables (row P is the neutral dummy)
    vidx: jax.Array         # i[P+1, K] var index per term (0 for padding)
    coef: jax.Array         # i[P+1, K] coefficient (0 for padding)
    rhs: jax.Array          # i[P+1]
    bidx: jax.Array         # i[P+1]   reif bool var (TRUE_VAR for plain)
    # variable-centric occurrence tables (padding points at dummy row, slot 0)
    occ_prop: jax.Array     # i[V, D]
    occ_slot: jax.Array     # i[V, D]  in [0, K]; K == reif-entailment slot
    # alldifferent bank (row A is the neutral dummy; DESIGN.md §12)
    ad_vars: jax.Array      # i[A+1, N]  member var index (0 for padding)
    ad_offs: jax.Array      # i[A+1, N]  member offset (x_i + off_i distinct)
    ad_mask: jax.Array      # i[A+1, N]  1 = real member, 0 = padding
    ad_occ_inst: jax.Array  # i[V, Dad]  alldiff row per occurrence
    ad_occ_pos: jax.Array   # i[V, Dad]  member position per occurrence
    # cumulative bank (row C is the neutral dummy)
    cu_svar: jax.Array      # i[C+1, T]  start var per task (0 for padding)
    cu_dur: jax.Array       # i[C+1, T]  duration (0 for padding)
    cu_dem: jax.Array       # i[C+1, T]  demand   (0 for padding)
    cu_cap: jax.Array       # i[C+1]     capacity
    cu_occ_inst: jax.Array  # i[V, Dcu]
    cu_occ_pos: jax.Array   # i[V, Dcu]
    # CSR-style packed views of the native banks (DESIGN.md §16): members
    # of all rows concatenated (row-contiguous, so member (a, n) sits at
    # flat index ptr[a] + n and the dense occ tables double as flat
    # indices); padding slots carry seg == n_rows (the dummy).
    ad_ptr: jax.Array       # i[A+2]   row pointers into the packed axis
    ad_pk_var: jax.Array    # i[Mad]   packed member var index
    ad_pk_off: jax.Array    # i[Mad]   packed member offset
    ad_pk_seg: jax.Array    # i[Mad]   owning row; == A for padding
    cu_ptr: jax.Array       # i[C+2]
    cu_pk_svar: jax.Array   # i[Mcu]
    cu_pk_dur: jax.Array    # i[Mcu]
    cu_pk_dem: jax.Array    # i[Mcu]
    cu_pk_seg: jax.Array    # i[Mcu]   owning row; == C for padding
    # compact-table bank (row T is the neutral dummy; DESIGN.md §17):
    # supports are packed tuple bitsets per (member slot, value index k),
    # where k indexes value dom_off[var] + k in the bitset domain layout
    ct_vars: jax.Array      # i[T+1, R]          member var (0 for padding)
    ct_mask: jax.Array      # i[T+1, R]          1 = real member
    ct_supp: jax.Array      # u32[T+1, R, 32W, TW]  tuple bitset per value
    ct_occ_inst: jax.Array  # i[V, Dct]
    ct_occ_pos: jax.Array   # i[V, Dct]
    # bitset domain layout (DESIGN.md §17): value of bit k of var v is
    # dom_off[v] + k; vars wider than 32·n_words are untracked (their
    # words pinned to all-ones and never consulted)
    dom_off: jax.Array      # i[V]   per-var value offset (= lb0)
    dom_track: jax.Array    # u32[V] 1 = domain representable in n_words
    # search
    branch_vars: jax.Array  # i[B] decision vars in branching order
    # static metadata
    n_vars: int = dataclasses.field(metadata=dict(static=True))
    n_props: int = dataclasses.field(metadata=dict(static=True))
    k_terms: int = dataclasses.field(metadata=dict(static=True))
    d_occ: int = dataclasses.field(metadata=dict(static=True))
    n_alldiff: int = dataclasses.field(metadata=dict(static=True))
    ad_width: int = dataclasses.field(metadata=dict(static=True))
    ad_docc: int = dataclasses.field(metadata=dict(static=True))
    n_cumulative: int = dataclasses.field(metadata=dict(static=True))
    cu_width: int = dataclasses.field(metadata=dict(static=True))
    cu_docc: int = dataclasses.field(metadata=dict(static=True))
    horizon: int = dataclasses.field(metadata=dict(static=True))
    # tile layout per native bank ("dense" | "sparse") + packed lengths;
    # static so the choice shapes the trace and the runner cache key
    ad_layout: str = dataclasses.field(metadata=dict(static=True))
    cu_layout: str = dataclasses.field(metadata=dict(static=True))
    ad_packed: int = dataclasses.field(metadata=dict(static=True))
    cu_packed: int = dataclasses.field(metadata=dict(static=True))
    # compact-table / bitset statics (DESIGN.md §17)
    n_table: int = dataclasses.field(metadata=dict(static=True))
    ct_arity: int = dataclasses.field(metadata=dict(static=True))
    ct_words: int = dataclasses.field(metadata=dict(static=True))
    ct_docc: int = dataclasses.field(metadata=dict(static=True))
    n_words: int = dataclasses.field(metadata=dict(static=True))
    obj_var: int = dataclasses.field(metadata=dict(static=True))  # -1 if satisfaction
    dtype: str = dataclasses.field(metadata=dict(static=True))
    name: str = dataclasses.field(metadata=dict(static=True))

    @property
    def jdtype(self):
        return np.dtype(self.dtype)

    @property
    def total_props(self) -> int:
        """Propagator-table rows across all kinds (dummies excluded) —
        the count the §12/§17 bench/regression guards compare."""
        return self.n_props + self.n_alldiff + self.n_cumulative + self.n_table


def compile_model(
    m: Model,
    pad_terms_to: int = 8,
    pad_occ_to: int = 8,
    pad_horizon_to: int = 32,
    force_dtype: str | None = None,
    bank_layout: str = "auto",
) -> CompiledModel:
    if bank_layout not in ("auto", "dense", "sparse"):
        raise ValueError(
            f"bank_layout must be 'auto', 'dense' or 'sparse', "
            f"got {bank_layout!r}")
    V = m.n_vars
    props: List[ReifLinLe] = m.props
    P = len(props)
    if P == 0 and not (m.alldiffs or m.cumulatives or m.tables):
        raise ValueError("model has no constraints")

    K = max((len(p.lin.terms) for p in props), default=1)
    K = max(_round_up(K, pad_terms_to), pad_terms_to)

    lb0 = np.asarray(m.lb0, dtype=np.int64)
    ub0 = np.asarray(m.ub0, dtype=np.int64)

    vidx = np.zeros((P + 1, K), dtype=np.int64)
    coef = np.zeros((P + 1, K), dtype=np.int64)
    rhs = np.zeros((P + 1,), dtype=np.int64)
    bidx = np.full((P + 1,), TRUE_VAR, dtype=np.int64)

    occs: List[List[Tuple[int, int]]] = [[] for _ in range(V)]
    for p, rp in enumerate(props):
        terms = rp.lin.terms
        if len(terms) > K:
            raise ValueError("term overflow")
        for k, (v, a) in enumerate(terms):
            vidx[p, k] = v
            coef[p, k] = a
            occs[v].append((p, k))
        rhs[p] = rp.lin.rhs
        bidx[p] = rp.bvar
        if rp.bvar != TRUE_VAR:
            # genuinely reified: b can be tightened by (dis)entailment.
            occs[rp.bvar].append((p, K))
        # plain props (b == TRUE) fail through term tightening alone; we
        # skip their reif occurrence so the TRUE var's degree stays 0.

    # dummy row P: coef 0 everywhere -> all candidates neutral; rhs huge so
    # it is "entailed" but its reif slot is never gathered.
    rhs[P] = int(np.iinfo(np.int32).max // 4)

    D = max(max((len(o) for o in occs), default=1), 1)
    D = max(_round_up(D, pad_occ_to), pad_occ_to)
    occ_prop = np.full((V, D), P, dtype=np.int64)   # pad -> dummy row
    occ_slot = np.zeros((V, D), dtype=np.int64)     # pad -> term slot 0 (coef 0)
    for v, o in enumerate(occs):
        for d, (p, k) in enumerate(o):
            occ_prop[v, d] = p
            occ_slot[v, d] = k

    # ---- alldifferent bank (DESIGN.md §12) -----------------------------
    A = len(m.alldiffs)
    N = max((len(ad.vars) for ad in m.alldiffs), default=2)
    N = max(_round_up(N, 4), 2) if A else 2
    ad_vars = np.zeros((A + 1, N), dtype=np.int64)
    ad_offs = np.zeros((A + 1, N), dtype=np.int64)
    ad_mask = np.zeros((A + 1, N), dtype=np.int64)
    ad_occs: List[List[Tuple[int, int]]] = [[] for _ in range(V)]
    for a, ad in enumerate(m.alldiffs):
        for n, (v, off) in enumerate(zip(ad.vars, ad.offsets)):
            ad_vars[a, n] = v
            ad_offs[a, n] = off
            ad_mask[a, n] = 1
            ad_occs[v].append((a, n))
    Dad = max(max((len(o) for o in ad_occs), default=1), 1)
    Dad = _round_up(Dad, 4) if A else 1
    ad_occ_inst = np.full((V, Dad), A, dtype=np.int64)   # pad -> dummy row
    ad_occ_pos = np.zeros((V, Dad), dtype=np.int64)
    for v, o in enumerate(ad_occs):
        for d, (a, n) in enumerate(o):
            ad_occ_inst[v, d] = a
            ad_occ_pos[v, d] = n

    # packed (CSR) view: row-contiguous members; always ≥ 1 padding slot
    # so the dummy occurrence (inst=A, pos=0) lands at flat ad_ptr[A]
    mad_real = sum(len(ad.vars) for ad in m.alldiffs)
    Mad = max(_round_up(mad_real + 1, 8), 8)
    ad_ptr = np.zeros((A + 2,), dtype=np.int64)
    ad_pk_var = np.zeros((Mad,), dtype=np.int64)
    ad_pk_off = np.zeros((Mad,), dtype=np.int64)
    ad_pk_seg = np.full((Mad,), A, dtype=np.int64)
    k_ = 0
    for a, ad in enumerate(m.alldiffs):
        ad_ptr[a] = k_
        for v, off in zip(ad.vars, ad.offsets):
            ad_pk_var[k_] = v
            ad_pk_off[k_] = off
            ad_pk_seg[k_] = a
            k_ += 1
    ad_ptr[A] = k_          # padding region start
    ad_ptr[A + 1] = Mad

    # ---- cumulative bank (DESIGN.md §12) -------------------------------
    C = len(m.cumulatives)
    T = max((len(cu.starts) for cu in m.cumulatives), default=2)
    T = max(_round_up(T, 4), 2) if C else 2
    cu_svar = np.zeros((C + 1, T), dtype=np.int64)
    cu_dur = np.zeros((C + 1, T), dtype=np.int64)
    cu_dem = np.zeros((C + 1, T), dtype=np.int64)
    cu_cap = np.zeros((C + 1,), dtype=np.int64)
    cu_occs: List[List[Tuple[int, int]]] = [[] for _ in range(V)]
    horizon = 1
    for c, cu in enumerate(m.cumulatives):
        if cu.capacity < 0:
            # the segmented profile only inspects event intervals, so a
            # negative cap (0 > cap on empty time) would need the whole
            # grid; dense fails everywhere — reject the degenerate model
            raise ValueError(
                f"cumulative row {c} has negative capacity "
                f"{cu.capacity}; capacities must be >= 0")
        cu_cap[c] = cu.capacity
        for t, (v, d_, r_) in enumerate(zip(cu.starts, cu.durations,
                                            cu.demands)):
            cu_svar[c, t] = v
            cu_dur[c, t] = d_
            cu_dem[c, t] = r_
            if d_ > 0 and r_ > 0:
                if int(lb0[v]) < 0:
                    # the time-table grid is [0, horizon); a negative
                    # feasible start would be silently pruned (wrong
                    # UNSAT) — demand a shifted model instead
                    raise ValueError(
                        f"cumulative start var {v} has negative domain "
                        f"({int(lb0[v])}, {int(ub0[v])}); native time-table "
                        "filtering needs nonnegative starts — shift the "
                        "model (or use decompose=True)")
                # only effective tasks are ever tightened by the row
                cu_occs[v].append((c, t))
                horizon = max(horizon, int(ub0[v]) + d_ + 2)
    Dcu = max(max((len(o) for o in cu_occs), default=1), 1)
    Dcu = _round_up(Dcu, 4) if C else 1
    # bucket the (static, trace-shaping) time grid so same-family
    # instances across seeds keep one shape signature (api.py cache /
    # solve_many; same spirit as the pool pow2 buckets, DESIGN.md §11)
    if C:
        horizon = _round_up(horizon, pad_horizon_to)
    cu_occ_inst = np.full((V, Dcu), C, dtype=np.int64)   # pad -> dummy row
    cu_occ_pos = np.zeros((V, Dcu), dtype=np.int64)
    for v, o in enumerate(cu_occs):
        for d, (c, t) in enumerate(o):
            cu_occ_inst[v, d] = c
            cu_occ_pos[v, d] = t

    # packed (CSR) view of the cumulative bank (same invariants as ad_*)
    mcu_real = sum(len(cu.starts) for cu in m.cumulatives)
    Mcu = max(_round_up(mcu_real + 1, 8), 8)
    cu_ptr = np.zeros((C + 2,), dtype=np.int64)
    cu_pk_svar = np.zeros((Mcu,), dtype=np.int64)
    cu_pk_dur = np.zeros((Mcu,), dtype=np.int64)
    cu_pk_dem = np.zeros((Mcu,), dtype=np.int64)
    cu_pk_seg = np.full((Mcu,), C, dtype=np.int64)
    k_ = 0
    for c, cu in enumerate(m.cumulatives):
        cu_ptr[c] = k_
        for v, d_, r_ in zip(cu.starts, cu.durations, cu.demands):
            cu_pk_svar[k_] = v
            cu_pk_dur[k_] = d_
            cu_pk_dem[k_] = r_
            cu_pk_seg[k_] = c
            k_ += 1
    cu_ptr[C] = k_
    cu_ptr[C + 1] = Mcu

    # ---- compact-table bank + bitset domain layout (DESIGN.md §17) ------
    branch = list(m.branch_order) if m.branch_order else list(range(1, V))
    # ensure every non-fixed var is ultimately branchable: append leftovers
    missing = [v for v in range(1, V) if v not in set(branch)]
    branch = branch + missing

    Tn = len(m.tables)
    R = max((len(t.vars) for t in m.tables), default=1)
    widths = ub0 - lb0 + 1
    # With tables, n_words covers every table member AND every branch
    # var (tables need the member domains as bitsets; covering the
    # branch vars too lets middle-out track them for free — table
    # models' bank shapes are instance-dependent anyway).  WITHOUT
    # tables n_words is pinned to 1 so same-shaped instances keep
    # hitting the compiled-runner cache regardless of their bounds;
    # middle-out leaves vars wider than 32 values untracked, where its
    # selection and branching degrade per-var to exactly VAL_SPLIT
    # (pinned all-ones words put the nearest remaining value at the
    # interval midpoint, and apply_path_tile tells x ≥ m+1 instead of
    # a bit clear).
    if Tn:
        dom_vars = sorted({v for t in m.tables for v in t.vars}
                          | set(branch))
        n_words = n_words_for(int(widths[dom_vars].max()))
    else:
        n_words = 1
    K32 = WORD_BITS * n_words
    maxT = max((len(t.tuples) for t in m.tables), default=1)
    TW = max(1, -(-maxT // WORD_BITS))
    ct_vars = np.zeros((Tn + 1, R), dtype=np.int64)
    ct_mask = np.zeros((Tn + 1, R), dtype=np.int64)
    ct_supp = np.zeros((Tn + 1, R, K32, TW), dtype=np.uint32)
    ct_occs: List[List[Tuple[int, int]]] = [[] for _ in range(V)]
    for ti, tb in enumerate(m.tables):
        for r, v in enumerate(tb.vars):
            ct_vars[ti, r] = v
            ct_mask[ti, r] = 1
            ct_occs[v].append((ti, r))
        for j, tup in enumerate(tb.tuples):
            for r, (v, val) in enumerate(zip(tb.vars, tup)):
                k = int(val) - int(lb0[v])  # in [0, width) by Model.table
                ct_supp[ti, r, k, j // WORD_BITS] |= (
                    np.uint32(1) << np.uint32(j % WORD_BITS))
    Dct = max(max((len(o) for o in ct_occs), default=1), 1)
    Dct = _round_up(Dct, 4) if Tn else 1
    ct_occ_inst = np.full((V, Dct), Tn, dtype=np.int64)  # pad -> dummy row
    ct_occ_pos = np.zeros((V, Dct), dtype=np.int64)
    for v, o in enumerate(ct_occs):
        for d, (ti, r) in enumerate(o):
            ct_occ_inst[v, d] = ti
            ct_occ_pos[v, d] = r
    dom_track = (widths <= K32).astype(np.uint32)

    # ---- dtype selection with overflow headroom ------------------------
    absmax = np.maximum(np.abs(lb0), np.abs(ub0)) + 1           # per var
    worst = int((np.abs(coef[:P]) * absmax[vidx[:P]]).sum(axis=1).max()) \
        if P else 0
    worst = max(worst, int(np.abs(rhs[:P]).max()) if P else 0)
    # native banks: shifted alldiff values x+off (±1 Hall push), cumulative
    # time points up to `horizon` and per-row demand sums
    if A:
        worst = max(worst, int((absmax[ad_vars[:A]] + np.abs(ad_offs[:A])
                                ).max()) + 2)
    if C:
        worst = max(worst, horizon + 2,
                    int(cu_dem[:C].sum(axis=1).max()), int(cu_cap[:C].max()))
    # sparse tiles compare member *counts* against interval widths
    worst = max(worst, Mad, Mcu)
    # bitset hull bridge: an empty tracked domain reads back as
    # (off + 32·n_words, off - 1)
    worst = max(worst, int(np.abs(lb0).max()) + K32 + 2)
    if force_dtype is not None:
        dtype = force_dtype
    elif worst * 4 < np.iinfo(np.int32).max:
        dtype = "int32"
    else:
        dtype = "int64"
    if worst * 4 >= np.iinfo(np.int64).max:
        raise OverflowError("model exceeds int64 headroom")

    if dtype == "int64" and not jax.config.jax_enable_x64:
        raise OverflowError(
            f"model '{m.name}' needs int64 headroom (worst sum {worst}); "
            "set JAX_ENABLE_X64=1 or pass force_dtype after re-scaling")

    # ---- per-bank tile layout (decided after dtype: bytes need itemsize)
    itemsize = np.dtype(dtype).itemsize
    ad_layout = _resolve_layout(
        bank_layout, alldiff_dense_tile_bytes(A, N, itemsize),
        "AllDifferent", m.name)
    cu_layout = _resolve_layout(
        bank_layout, cumulative_dense_tile_bytes(C, T, horizon, itemsize),
        "Cumulative", m.name)
    # leaves are jnp so the tables work when closed over (not jit args)
    cast = lambda a: jnp.asarray(np.asarray(a, dtype=dtype))  # noqa: E731
    return CompiledModel(
        lb0=cast(lb0), ub0=cast(ub0),
        box_lo=cast(lb0 - 1), box_hi=cast(ub0 + 1),
        vidx=cast(vidx), coef=cast(coef), rhs=cast(rhs), bidx=cast(bidx),
        occ_prop=cast(occ_prop), occ_slot=cast(occ_slot),
        ad_vars=cast(ad_vars), ad_offs=cast(ad_offs), ad_mask=cast(ad_mask),
        ad_occ_inst=cast(ad_occ_inst), ad_occ_pos=cast(ad_occ_pos),
        cu_svar=cast(cu_svar), cu_dur=cast(cu_dur), cu_dem=cast(cu_dem),
        cu_cap=cast(cu_cap),
        cu_occ_inst=cast(cu_occ_inst), cu_occ_pos=cast(cu_occ_pos),
        ad_ptr=cast(ad_ptr), ad_pk_var=cast(ad_pk_var),
        ad_pk_off=cast(ad_pk_off), ad_pk_seg=cast(ad_pk_seg),
        cu_ptr=cast(cu_ptr), cu_pk_svar=cast(cu_pk_svar),
        cu_pk_dur=cast(cu_pk_dur), cu_pk_dem=cast(cu_pk_dem),
        cu_pk_seg=cast(cu_pk_seg),
        ct_vars=cast(ct_vars), ct_mask=cast(ct_mask),
        ct_supp=jnp.asarray(ct_supp),
        ct_occ_inst=cast(ct_occ_inst), ct_occ_pos=cast(ct_occ_pos),
        dom_off=cast(lb0), dom_track=jnp.asarray(dom_track),
        branch_vars=cast(np.asarray(branch)),
        n_vars=V, n_props=P, k_terms=K, d_occ=D,
        n_alldiff=A, ad_width=N, ad_docc=Dad,
        n_cumulative=C, cu_width=T, cu_docc=Dcu, horizon=horizon,
        ad_layout=ad_layout, cu_layout=cu_layout,
        ad_packed=Mad, cu_packed=Mcu,
        n_table=Tn, ct_arity=R, ct_words=TW, ct_docc=Dct, n_words=n_words,
        obj_var=(m.objective if m.objective is not None else -1),
        dtype=dtype, name=m.name,
    )
