"""Constraint model AST — the PCCP modelling layer (paper §PCCP).

The paper's PCCP has three statements (ask / tell / parallel) plus a
modelling layer with generators and a compilation function ⟦.⟧ from
constraints to PCCP processes.  We mirror that split:

* this module is the *modelling layer*: integer/boolean variables, linear
  expressions and (reified) linear inequalities, with the paper's reified
  conjunction/equivalence combinators;
* ``compile.py`` is ⟦.⟧ — it lowers every constraint to *guarded commands*
  in a dense tabular form (the guarded normal form of Prop. 4) executable
  by the parallel fixpoint engine.

Everything reduces to one propagator shape,

    b  ⇔  Σ_j a_j · x_j  ≤  c        (ReifLinLe)

with plain inequalities using the always-true variable as ``b``.  This is
exactly the paper's indexical-style compilation: ask on the reif bool,
tell interval tightenings; entailment per its `entailed` function.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

# Variable 0 of every model is pinned to (1, 1) and acts as the constant
# `true` of BInc; plain constraints are reified on it.
TRUE_VAR = 0


@dataclasses.dataclass(frozen=True)
class IntVar:
    """Handle to a store index.  Arithmetic builds LinExpr; comparisons
    build constraints (so models read like the paper's examples)."""

    idx: int
    model: "Model" = dataclasses.field(repr=False, compare=False)

    # -- arithmetic sugar → LinExpr -------------------------------------
    def _as_expr(self) -> "LinExpr":
        return LinExpr({self.idx: 1}, 0)

    def __add__(self, other):
        return self._as_expr() + other

    __radd__ = __add__

    def __sub__(self, other):
        return self._as_expr() - other

    def __rsub__(self, other):
        return (-1 * self._as_expr()) + other

    def __mul__(self, k: int):
        return self._as_expr() * k

    __rmul__ = __mul__

    def __neg__(self):
        return self._as_expr() * -1

    def __le__(self, other):
        return self._as_expr() <= other

    def __ge__(self, other):
        return self._as_expr() >= other

    def __lt__(self, other):
        return self._as_expr() < other

    def __gt__(self, other):
        return self._as_expr() > other

    def eq(self, other):
        return self._as_expr().eq(other)


@dataclasses.dataclass
class LinExpr:
    """Σ coef_i · x_i + const, over store indices."""

    terms: Dict[int, int]
    const: int = 0

    @staticmethod
    def of(x) -> "LinExpr":
        if isinstance(x, LinExpr):
            return LinExpr(dict(x.terms), x.const)
        if isinstance(x, IntVar):
            return LinExpr({x.idx: 1}, 0)
        if isinstance(x, (int,)):
            return LinExpr({}, int(x))
        raise TypeError(f"cannot coerce {type(x)} to LinExpr")

    def __add__(self, other):
        o = LinExpr.of(other)
        t = dict(self.terms)
        for v, c in o.terms.items():
            t[v] = t.get(v, 0) + c
        return LinExpr({v: c for v, c in t.items() if c != 0},
                       self.const + o.const)

    __radd__ = __add__

    def __sub__(self, other):
        return self + (LinExpr.of(other) * -1)

    def __rsub__(self, other):
        return LinExpr.of(other) + (self * -1)

    def __mul__(self, k: int):
        k = int(k)
        return LinExpr({v: c * k for v, c in self.terms.items() if c * k != 0},
                       self.const * k)

    __rmul__ = __mul__

    def __neg__(self):
        return self * -1

    # -- comparisons → LinLe --------------------------------------------
    def __le__(self, other) -> "LinLe":
        d = self - other            # d <= 0
        return LinLe(tuple(sorted(d.terms.items())), -d.const)

    def __ge__(self, other) -> "LinLe":
        return LinExpr.of(other) <= self

    def __lt__(self, other) -> "LinLe":
        return self <= (LinExpr.of(other) - 1)

    def __gt__(self, other) -> "LinLe":
        return self >= (LinExpr.of(other) + 1)

    def eq(self, other) -> List["LinLe"]:
        return [self <= other, self >= other]


@dataclasses.dataclass(frozen=True)
class LinLe:
    """Σ a_j x_j ≤ c  (terms sorted by var index, coefficients nonzero)."""

    terms: Tuple[Tuple[int, int], ...]   # ((var, coef), ...)
    rhs: int

    def negated(self) -> "LinLe":
        """¬(Σ a x ≤ c)  ≡  Σ -a x ≤ -c - 1."""
        return LinLe(tuple((v, -c) for v, c in self.terms), -self.rhs - 1)


@dataclasses.dataclass(frozen=True)
class ReifLinLe:
    """b ⇔ (Σ a_j x_j ≤ c).  The linear propagator shape of the engine."""

    bvar: int
    lin: LinLe


@dataclasses.dataclass(frozen=True)
class AllDifferent:
    """alldifferent(x_i + off_i) — native typed propagator (DESIGN.md §12).

    Bounds(Z)-consistent filtering via Hall intervals in the engine; one
    table row replaces the O(n²) reified-disequality decomposition."""

    vars: Tuple[int, ...]
    offsets: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Cumulative:
    """cumulative(s, d, r, c) — native typed propagator (DESIGN.md §12).

    Time-table filtering from compulsory parts in the engine; one table
    row replaces the O(n²) overlap-boolean decomposition (and, with
    capacity 1, the job-shop disjunctive pair encoding)."""

    starts: Tuple[int, ...]
    durations: Tuple[int, ...]
    demands: Tuple[int, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class Table:
    """(x₁, …, x_r) ∈ tuples — native extensional propagator
    (DESIGN.md §17).

    Compact-Table filtering over bit-packed finite domains in the
    engine: per variable–value supports as tuple bitsets, a reset-based
    current-table intersection, and domain words filtered by OR-ing the
    surviving supports.  One row replaces the O(|tuples|·arity)
    reified-disjunction decomposition."""

    vars: Tuple[int, ...]
    tuples: Tuple[Tuple[int, ...], ...]


class Model:
    """A PCCP model: local statements (∃x:IZ) + parallel constraint tells."""

    def __init__(self, name: str = "model", dtype_bits: int = 32):
        self.name = name
        self.dtype_bits = dtype_bits
        self.lb0: List[int] = []
        self.ub0: List[int] = []
        self.names: List[str] = []
        self.props: List[ReifLinLe] = []
        self.alldiffs: List[AllDifferent] = []
        self.cumulatives: List[Cumulative] = []
        self.tables: List[Table] = []
        self.objective: Optional[int] = None      # var index to minimize
        self.branch_order: List[int] = []         # decision vars, in order
        # var 0 == constant true
        t = self._new_var(1, 1, "TRUE")
        assert t.idx == TRUE_VAR

    # -- local statements (∃x : IZ, ...) ---------------------------------
    def _new_var(self, lo: int, hi: int, name: str) -> IntVar:
        self.lb0.append(int(lo))
        self.ub0.append(int(hi))
        self.names.append(name)
        return IntVar(len(self.lb0) - 1, self)

    def int_var(self, lo: int, hi: int, name: str = "") -> IntVar:
        if lo > hi:
            raise ValueError(f"empty initial domain for {name}: ({lo},{hi})")
        return self._new_var(lo, hi, name or f"x{len(self.lb0)}")

    def bool_var(self, name: str = "") -> IntVar:
        return self._new_var(0, 1, name or f"b{len(self.lb0)}")

    @property
    def n_vars(self) -> int:
        return len(self.lb0)

    # -- tells (constraint posting) ---------------------------------------
    def add(self, c) -> None:
        """Post a constraint (or a list of them — e.g. from ``eq``)."""
        if isinstance(c, list):
            for ci in c:
                self.add(ci)
        elif isinstance(c, LinLe):
            if not c.terms:               # constant constraint
                if 0 > c.rhs:             # trivially false: post 1 <= 0 on TRUE
                    self.props.append(ReifLinLe(
                        TRUE_VAR, LinLe(((TRUE_VAR, 1),), 0)))
                return
            self.props.append(ReifLinLe(TRUE_VAR, c))
        elif isinstance(c, ReifLinLe):
            self.props.append(c)
        else:
            raise TypeError(f"cannot post {type(c)}")

    def reify(self, lin: LinLe, name: str = "") -> IntVar:
        """∃b:BInc, ⟦b ⇔ lin⟧ — returns b."""
        b = self.bool_var(name or "reif")
        self.props.append(ReifLinLe(b.idx, lin))
        return b

    def iff(self, b: IntVar, lin: LinLe) -> None:
        """⟦b ⇔ lin⟧ for an existing boolean b (paper's ⇔ compilation:
        ask-entailed / ask-disentailed in both directions — realized by the
        single reified propagator which implements all four asks)."""
        self.props.append(ReifLinLe(b.idx, lin))

    def neq(self, a, b) -> None:
        """a ≠ b for linear expressions, via the paper's reified-disjunction
        encoding: b< ⇔ (a < b)  ∥  b> ⇔ (a > b)  ∥  b< + b> ≥ 1.  This is
        the decomposition the model zoo (DESIGN.md §10) uses for all
        disequality/disjunctive constraints so everything stays ReifLinLe."""
        ea, eb = LinExpr.of(a), LinExpr.of(b)
        lt = self.reify(ea < eb, "neq_lt")
        gt = self.reify(ea > eb, "neq_gt")
        self.add(lt + gt >= 1)

    # -- typed global constraints (native propagator table, DESIGN.md §12)

    @property
    def n_constraints(self) -> int:
        """Total propagator-table rows across all kinds."""
        return (len(self.props) + len(self.alldiffs)
                + len(self.cumulatives) + len(self.tables))

    def alldifferent(self, xs: Sequence[IntVar],
                     offsets: Optional[Sequence[int]] = None,
                     decompose: bool = False) -> None:
        """alldifferent(x_i + off_i).

        Default: ONE native `AllDifferent` table row (bounds(Z)-consistent
        Hall-interval filtering in the fixpoint engine).  With
        ``decompose=True`` the pre-§12 lowering is emitted instead — the
        pairwise reified-disequality blowup (3·n·(n-1)/2 `ReifLinLe` rows
        + n·(n-1) fresh booleans) — kept as the parity oracle
        (tests/test_propagators.py).
        """
        offs = [0] * len(xs) if offsets is None else [int(o) for o in offsets]
        if len(offs) != len(xs):
            raise ValueError(f"alldifferent: {len(xs)} vars but "
                             f"{len(offs)} offsets")
        if len(xs) < 2:
            return
        if decompose:
            for i in range(len(xs)):
                for j in range(i + 1, len(xs)):
                    self.neq(xs[i] + offs[i], xs[j] + offs[j])
            return
        self.alldiffs.append(AllDifferent(tuple(x.idx for x in xs),
                                          tuple(offs)))

    def cumulative(self, starts: Sequence[IntVar],
                   durations: Sequence[int], demands: Sequence[int],
                   capacity: int, decompose: bool = False) -> None:
        """cumulative(s, d, r, c): at every time t,
        Σ_{i : s_i ≤ t < s_i + d_i} r_i ≤ c.

        Default: ONE native `Cumulative` table row (time-table filtering
        from compulsory parts).  With ``decompose=True`` the pre-§12
        lowering is emitted instead — the paper's overlap-boolean
        decomposition (Schutt et al. 2009): b_ij ⇔ (s_i ≤ s_j ∧
        s_j ≤ s_i + d_i - 1) plus one capacity row per task — kept as
        the parity oracle.  Capacity 1 is the job-shop disjunctive case.
        """
        n = len(starts)
        d = [int(x) for x in durations]
        r = [int(x) for x in demands]
        if not (len(d) == len(r) == n):
            raise ValueError("cumulative: length mismatch")
        if not decompose:
            self.cumulatives.append(Cumulative(
                tuple(s.idx for s in starts), tuple(d), tuple(r),
                int(capacity)))
            return
        b = [[None] * n for _ in range(n)]
        for i in range(n):
            for j in range(n):
                bij = self.bool_var(f"cu{len(self.cumulatives)}_b{i}_{j}")
                b[i][j] = bij
                if d[i] == 0:
                    self.add(bij <= 0)     # zero-duration: never overlaps
                    continue
                self.iff_and(bij, [starts[i] - starts[j] <= 0,
                                   starts[j] - starts[i] <= d[i] - 1])
        for j in range(n):
            terms = [(r[i], b[i][j]) for i in range(n) if r[i] > 0]
            if not terms:
                continue
            expr = sum((coef * var for coef, var in terms), start=0)
            self.add(expr <= int(capacity))

    def table(self, xs: Sequence[IntVar],
              tuples: Sequence[Sequence[int]],
              decompose: bool = False) -> None:
        """(x₁, …, x_r) ∈ tuples — the extensional (arbitrary-relation)
        constraint.

        Default: ONE native `Table` row, filtered by Compact-Table on
        bit-packed finite domains (DESIGN.md §17).  With
        ``decompose=True`` the reified-disjunction lowering is emitted
        instead — per tuple t, b_t ⇔ ∧_i (x_i = t_i), plus Σ b_t ≥ 1 —
        an O(|tuples|·arity)-row `ReifLinLe` blowup kept as the parity
        oracle (tests/test_compact_table.py).  Tuples with values outside
        a member's initial domain can never be taken and are dropped.
        """
        xs = list(xs)
        if not xs:
            raise ValueError("table: no variables")
        rows = []
        for t in tuples:
            t = tuple(int(v) for v in t)
            if len(t) != len(xs):
                raise ValueError(
                    f"table: tuple {t} has arity {len(t)}, expected "
                    f"{len(xs)}")
            if all(self.lb0[x.idx] <= v <= self.ub0[x.idx]
                   for x, v in zip(xs, t)):
                rows.append(t)
        if not rows:                      # no tuple fits: trivially false
            self.add(LinLe(((TRUE_VAR, 1),), 0))
            return
        if decompose:
            bs = []
            for j, t in enumerate(rows):
                bj = self.bool_var(f"tab{len(self.tables)}_t{j}")
                lins = []
                for x, v in zip(xs, t):
                    lins += [x <= v, x >= v]
                self.iff_and(bj, lins)
                bs.append(bj)
            self.add(sum(bs, LinExpr({}, 0)) >= 1)
            return
        self.tables.append(Table(tuple(x.idx for x in xs), tuple(rows)))

    def iff_and(self, b: IntVar, lins: Sequence[LinLe]) -> None:
        """⟦b ⇔ (φ₁ ∧ ... ∧ φ_m)⟧ via the standard decomposition
        bᵢ ⇔ φᵢ  ∥  b ⇔ ∧ bᵢ  (the conjunction itself compiles to linear:
        b ≤ bᵢ and b ≥ Σ bᵢ - (m-1))."""
        bs = [self.reify(l, name=f"{self.names[b.idx]}&{i}")
              for i, l in enumerate(lins)]
        for bi in bs:
            self.add(b <= bi)                       # b → bᵢ
        self.add(sum(bs, LinExpr({}, 0)) - (len(bs) - 1) <= b)  # ∧bᵢ → b

    # -- search / objective ------------------------------------------------
    def minimize(self, v: IntVar) -> None:
        self.objective = v.idx

    def branch_on(self, vs: Sequence[IntVar]) -> None:
        self.branch_order = [v.idx for v in vs]

    # -- ⟦.⟧ ---------------------------------------------------------------
    def compile(self, **kw):
        from repro.core.compile import compile_model
        return compile_model(self, **kw)
