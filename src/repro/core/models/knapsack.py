"""0/1 knapsack — pure linear arithmetic on booleans (DESIGN.md §10).

Item booleans `x_i ∈ (0, 1)`, one capacity constraint
`Σ w_i x_i ≤ C`, and profit channelled into the minimization objective:

    negprofit ∈ (-Σp, 0),   Σ p_i x_i + negprofit = 0,   minimize negprofit

so the model objective is the *negated* best profit (the engine only
minimizes).  No reification is needed — the zoo's stress test for the
plain K-ary linear propagator with mixed-sign coefficients.

`dp_optimum` is the exact dynamic program over capacity, the independent
oracle the tests compare the solver against.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.core.model import LinExpr, Model


@dataclasses.dataclass
class Knapsack:
    weights: np.ndarray        # i[n]
    profits: np.ndarray        # i[n]
    capacity: int
    name: str = "knapsack"

    @property
    def n_items(self) -> int:
        return len(self.weights)


def generate(n: int, seed: int = 0, max_weight: int = 9,
             max_profit: int = 9) -> Knapsack:
    """Seeded instance: uniform weights/profits, capacity = half the
    total weight (the classic hard regime)."""
    rng = np.random.default_rng(seed)
    w = rng.integers(1, max_weight + 1, size=n)
    p = rng.integers(1, max_profit + 1, size=n)
    cap = max(int(w.sum()) // 2, int(w.max()))
    return Knapsack(weights=w, profits=p, capacity=cap,
                    name=f"knapsack-n{n}-s{seed}")


def build_model(inst: Knapsack) -> Tuple[Model, dict]:
    n = inst.n_items
    w = [int(x) for x in inst.weights]
    p = [int(x) for x in inst.profits]
    m = Model(name=inst.name)
    x = [m.bool_var(f"x{i}") for i in range(n)]
    neg = m.int_var(-sum(p), 0, "negprofit")
    m.add(sum((w[i] * x[i] for i in range(n)), start=LinExpr({}, 0))
          <= inst.capacity)
    m.add((sum((p[i] * x[i] for i in range(n)), start=LinExpr({}, 0))
           + neg).eq(0))
    m.minimize(neg)
    m.branch_on(x)                     # negprofit follows by propagation
    return m, dict(x=x, neg=neg, check_vars=x)


def check_solution(inst: Knapsack, take: Sequence[int]) -> Tuple[bool, int]:
    """Ground checker. Returns (feasible, objective) with objective the
    model's minimized value, i.e. the *negated* profit."""
    t = np.asarray([int(v) for v in take])
    if len(t) != inst.n_items or ((t != 0) & (t != 1)).any():
        return False, 0
    if int((inst.weights * t).sum()) > inst.capacity:
        return False, 0
    return True, -int((inst.profits * t).sum())


def dp_optimum(inst: Knapsack) -> int:
    """Exact max profit by DP over capacity (independent oracle)."""
    best = np.zeros(inst.capacity + 1, dtype=np.int64)
    for w, p in zip(inst.weights, inst.profits):
        w, p = int(w), int(p)
        for c in range(inst.capacity, w - 1, -1):
            best[c] = max(best[c], best[c - w] + p)
    return int(best[inst.capacity])
