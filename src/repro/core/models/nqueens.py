"""N-queens — the classic CP benchmark (DESIGN.md §10, §12).

Place n queens, one per row, so that no two share a column or diagonal.
Column variable `q_i ∈ (0, n-1)` per row; the three all-different families

    alldifferent(q_i),  alldifferent(q_i + i),  alldifferent(q_i - i)

lower (since §12) to THREE native `AllDifferent` propagator-table rows —
bounds(Z)-consistent Hall-interval filtering in the fixpoint engine.
``build_model(inst, decompose=True)`` emits the pre-§12 lowering instead:
each family decomposed by `Model.neq` into the paper's reified
disjunction b< ⇔ (lhs < rhs) ∥ b> ⇔ (lhs > rhs) ∥ b< + b> ≥ 1 — a
3·3·n(n-1)/2-row `ReifLinLe` blowup kept as the parity oracle
(tests/test_propagators.py); both run unchanged on every backend.

The engine is branch & bound, so the zoo's satisfaction problems carry a
canonical objective: minimize `q_0` (the first queen's column).  Its
optimum is a deterministic instance invariant — ideal for cross-backend
identity checks.  `generate` takes (size, seed) for protocol uniformity
with the rest of the zoo; the instance is fully determined by `n`, so the
seed only stamps the name.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.core.model import Model


@dataclasses.dataclass
class NQueens:
    n: int
    name: str = "nqueens"


def generate(n: int, seed: int = 0) -> NQueens:
    """Seeded generator (zoo protocol): n-queens of size `n`."""
    return NQueens(n=n, name=f"nqueens-n{n}-s{seed}")


def build_model(inst: NQueens, decompose: bool = False) -> Tuple[Model, dict]:
    n = inst.n
    m = Model(name=inst.name)
    q = [m.int_var(0, n - 1, f"q{i}") for i in range(n)]
    if decompose:
        for i in range(n):
            for j in range(i + 1, n):
                # q_i ≠ q_j + c for c ∈ {0, j-i, i-j}: column + diagonals
                for c in (0, j - i, i - j):
                    m.neq(q[i], q[j] + c)
    else:
        # columns, ↗ diagonals (q_i + i), ↘ diagonals (q_i - i): one
        # native row each (q_i = q_j + (j-i) ⇔ q_i + i = q_j + j, etc.)
        m.alldifferent(q)
        m.alldifferent(q, offsets=[i for i in range(n)])
        m.alldifferent(q, offsets=[-i for i in range(n)])
    m.minimize(q[0])
    m.branch_on(q)
    return m, dict(q=q, check_vars=q)


def check_solution(inst: NQueens, cols: Sequence[int]) -> Tuple[bool, int]:
    """Ground checker: pairwise column/diagonal clashes.
    Returns (feasible, objective) with objective = q_0."""
    n = inst.n
    c = [int(x) for x in cols]
    if len(c) != n or any(not (0 <= x < n) for x in c):
        return False, -1
    for i in range(n):
        for j in range(i + 1, n):
            if c[i] == c[j] or abs(c[i] - c[j]) == j - i:
                return False, -1
    return True, c[0]
