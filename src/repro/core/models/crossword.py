"""Crossword grid fill — the Compact-Table flagship workload (DESIGN.md
§10, §17).

Fill an n×n grid with letters (0..25) so every row *and* every column,
read left-to-right / top-to-bottom, is a word from a shared lexicon.
Each of the 2n line constraints lowers to ONE native extensional
`Table` row over the packed-support bank — the classic CT benchmark
shape: few constraints, wide arity, shared tuple set.
``build_model(inst, decompose=True)`` emits the paper-style oracle
instead: one reified conjunction per (line, word) plus a Σb ≥ 1
disjunction row — a |lexicon|·2n `ReifLinLe` blowup kept for parity.

`generate(n, seed)` plants a uniformly random grid, takes its rows and
columns as the lexicon core (so the instance is always SAT), and mixes
in seeded decoy words.  The canonical objective is the top-left cell
`g[0][0]` (satisfaction model, zoo protocol) — a deterministic instance
invariant for cross-backend identity checks.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.model import Model


@dataclasses.dataclass
class Crossword:
    n: int
    lexicon: List[Tuple[int, ...]]
    name: str = "crossword"


def generate(n: int, seed: int = 0, n_decoys: int = -1) -> Crossword:
    """Seeded instance: planted random grid + `n_decoys` decoy words
    (default 2n).  The planted grid guarantees satisfiability."""
    rng = np.random.default_rng(seed)
    grid = rng.integers(0, 26, size=(n, n))
    words = {tuple(int(x) for x in row) for row in grid}
    words |= {tuple(int(x) for x in col) for col in grid.T}
    if n_decoys < 0:
        n_decoys = 2 * n
    target = len(words) + n_decoys
    while len(words) < target:
        words.add(tuple(int(x) for x in rng.integers(0, 26, size=n)))
    return Crossword(n=n, lexicon=sorted(words),
                     name=f"crossword-n{n}-s{seed}")


def build_model(inst: Crossword, decompose: bool = False) -> Tuple[Model, dict]:
    n = inst.n
    m = Model(name=inst.name)
    g = [[m.int_var(0, 25, f"g{i}_{j}") for j in range(n)] for i in range(n)]
    for i in range(n):
        m.table(g[i], inst.lexicon, decompose=decompose)
    for j in range(n):
        m.table([g[i][j] for i in range(n)], inst.lexicon,
                decompose=decompose)
    cells = [g[i][j] for i in range(n) for j in range(n)]
    m.minimize(g[0][0])
    m.branch_on(cells)
    return m, dict(g=g, check_vars=cells)


def check_solution(inst: Crossword, letters: Sequence[int]) -> Tuple[bool, int]:
    """Ground checker: every row and column word is in the lexicon.
    Returns (feasible, objective) with objective = g[0][0]."""
    n = inst.n
    v = [int(x) for x in letters]
    if len(v) != n * n or any(not (0 <= x < 26) for x in v):
        return False, -1
    grid = [v[i * n:(i + 1) * n] for i in range(n)]
    lex = set(inst.lexicon)
    for i in range(n):
        if tuple(grid[i]) not in lex:
            return False, -1
    for j in range(n):
        if tuple(grid[i][j] for i in range(n)) not in lex:
            return False, -1
    return True, grid[0][0]
