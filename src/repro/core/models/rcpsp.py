"""RCPSP — resource-constrained project scheduling (paper §PCCP example).

The exact PCCP model of the paper:

    ∃s_i : IZ (starting dates),  ∃b_{ij} : IZ over (0,1) (overlap booleans)
    s_i ← (0, h)   ∥   b_{ij} ← (0, 1)
    ∥  ∀(i ≪ j) ∈ P,   ⟦ s_i + d_i ≤ s_j ⟧
    ∥  ∀i, j,          ⟦ b_{ij} ⇔ (s_i ≤ s_j ∧ s_j < s_i + d_i) ⟧
    ∥  ∀k, j,          ⟦ Σ_i r_{k,i} · b_{i,j} ≤ c_k ⟧

i.e. the standard cumulative decomposition (Schutt et al. 2009).  The
paper's `lsum` helper variable in the resource compilation is an indexical
implementation detail — the direct K-ary linear propagator here has the
same propagation strength and entailment condition.

Makespan objective: minimize `mk` with ∀i, s_i + d_i ≤ mk (classic).

Offline data policy (DESIGN.md §8): the Patterson / PSPLIB j30 suites are
not shipped in this container, so `generate(...)` produces seeded random
instances of the same family (n tasks, precedence DAG, ≤4 renewable
resources, capacities between max single demand and total demand).  The
`.rcp` (Patterson) and `.sm` (PSPLIB) parsers below accept the real files
whenever they are available.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.model import Model
from repro.core import search as S


@dataclasses.dataclass
class RCPSP:
    """⟨T, P, R⟩ with durations d, usages r[k,i], capacities c[k]."""

    durations: np.ndarray                  # i[n]
    precedences: List[Tuple[int, int]]     # (i, j): i ≪ j
    usage: np.ndarray                      # i[K, n]
    capacity: np.ndarray                   # i[K]
    name: str = "rcpsp"

    @property
    def n_tasks(self) -> int:
        return len(self.durations)

    @property
    def n_resources(self) -> int:
        return len(self.capacity)

    @property
    def horizon(self) -> int:
        return int(self.durations.sum())


def build_model(inst: RCPSP, var_strategy: str = S.MIN_LB,
                decompose: bool = False) -> Tuple[Model, dict]:
    """Compile the paper's PCCP model for an instance.

    Since §12 each renewable resource lowers to ONE native `Cumulative`
    table row (time-table filtering).  ``decompose=True`` emits the
    paper-faithful pre-§12 lowering instead — the overlap-boolean
    decomposition (Schutt et al. 2009) with its O(n²) booleans and
    ~4·n² `ReifLinLe` rows — kept as the parity oracle.

    Returns (model, handles) where handles maps names to variable lists
    (``b`` is None in the native lowering).
    """
    n = inst.n_tasks
    h = inst.horizon
    d = [int(x) for x in inst.durations]
    m = Model(name=inst.name)

    s = [m.int_var(0, h, f"s{i}") for i in range(n)]
    mk = m.int_var(0, h, "makespan")

    b = None
    if decompose:
        # b[i][j] ⇔ (s_i ≤ s_j ∧ s_j ≤ s_i + d_i - 1): i runs at s_j's start
        b = [[None] * n for _ in range(n)]
        for i in range(n):
            for j in range(n):
                bij = m.bool_var(f"b{i}_{j}")
                b[i][j] = bij
                if d[i] == 0:
                    m.add(bij <= 0)        # zero-duration: never overlaps
                    continue
                m.iff_and(bij, [s[i] - s[j] <= 0,
                                s[j] - s[i] <= d[i] - 1])

    for (i, j) in inst.precedences:
        m.add(s[i] + d[i] <= s[j])

    for k in range(inst.n_resources):
        c_k = int(inst.capacity[k])
        used = [i for i in range(n) if int(inst.usage[k, i]) > 0]
        if not used:
            continue
        if decompose:
            for j in range(n):
                terms = [(int(inst.usage[k, i]), b[i][j]) for i in used]
                expr = sum((coef * var for coef, var in terms), start=0)
                m.add(expr <= c_k)
        else:
            m.cumulative([s[i] for i in used], [d[i] for i in used],
                         [int(inst.usage[k, i]) for i in used], c_k)

    for i in range(n):
        m.add(s[i] + d[i] <= mk)
    m.minimize(mk)
    m.branch_on(s + [mk])                  # booleans follow by propagation
    return m, dict(s=s, b=b, mk=mk, check_vars=s)


def check_solution(inst: RCPSP, starts: Sequence[int]) -> Tuple[bool, int]:
    """Ground checker (independent of the solver): precedence + resource
    profile over time. Returns (feasible, makespan)."""
    st = np.asarray(starts, dtype=np.int64)
    d = np.asarray(inst.durations, dtype=np.int64)
    for (i, j) in inst.precedences:
        if st[i] + d[i] > st[j]:
            return False, -1
    mk = int((st + d).max()) if len(st) else 0
    for t in range(mk):
        run = (st <= t) & (t < st + d)
        for k in range(inst.n_resources):
            if inst.usage[k][run].sum() > inst.capacity[k]:
                return False, -1
    return True, mk


def generate(n_tasks: int, n_resources: int = 4, seed: int = 0,
             edge_prob: float = 0.15, max_duration: int = 8,
             max_usage: int = 6, tightness: float = 0.55) -> RCPSP:
    """Seeded generator in the Patterson/j30 family.

    `tightness` interpolates capacities between the max single demand
    (hard) and the max concurrent demand (trivial): lower = harder.
    """
    rng = np.random.default_rng(seed)
    d = rng.integers(1, max_duration + 1, size=n_tasks)
    prec = []
    for i in range(n_tasks):
        for j in range(i + 1, n_tasks):
            if rng.random() < edge_prob:
                prec.append((i, j))
    usage = rng.integers(0, max_usage + 1, size=(n_resources, n_tasks))
    # every task uses at least one resource (j30 style)
    for i in range(n_tasks):
        if usage[:, i].sum() == 0:
            usage[rng.integers(0, n_resources), i] = 1 + int(
                rng.integers(0, max_usage))
    single = usage.max(axis=1)
    total = usage.sum(axis=1)
    cap = np.maximum(single,
                     (single + tightness * (total - single)).astype(np.int64))
    return RCPSP(durations=d, precedences=prec, usage=usage, capacity=cap,
                 name=f"gen-n{n_tasks}-k{n_resources}-s{seed}")


# ---------------------------------------------------------------------------
# parsers for the real suites (used when files are present)
# ---------------------------------------------------------------------------

def parse_patterson(path: str) -> RCPSP:
    """Patterson .rcp format: n, K / capacities / per-task: d, r_1..r_K,
    n_succ, successors (1-based, includes dummy source/sink)."""
    toks: List[int] = []
    with open(path) as f:
        for line in f:
            toks += [int(t) for t in line.split()]
    it = iter(toks)
    n = next(it)
    k = next(it)
    cap = np.array([next(it) for _ in range(k)], dtype=np.int64)
    dur = np.zeros(n, dtype=np.int64)
    usage = np.zeros((k, n), dtype=np.int64)
    prec: List[Tuple[int, int]] = []
    for i in range(n):
        dur[i] = next(it)
        for r in range(k):
            usage[r, i] = next(it)
        ns = next(it)
        for _ in range(ns):
            prec.append((i, next(it) - 1))
    return RCPSP(dur, prec, usage, cap, name=path.rsplit("/", 1)[-1])


def parse_psplib_sm(path: str) -> RCPSP:
    """PSPLIB single-mode .sm parser (j30/j60/...)."""
    with open(path) as f:
        lines = f.readlines()
    n = None
    i = 0
    prec: List[Tuple[int, int]] = []
    dur = usage = cap = None
    while i < len(lines):
        ln = lines[i]
        if "jobs (incl. supersource" in ln:
            n = int(ln.split(":")[1].strip())
        if ln.strip().startswith("jobnr.") and "#successors" in ln.replace(" ", ""):
            i += 1
            for _ in range(n):
                parts = [int(x) for x in lines[i].split()]
                j = parts[0] - 1
                for succ in parts[3:3 + parts[2]]:
                    prec.append((j, succ - 1))
                i += 1
            continue
        if ln.strip().startswith("jobnr.") and "duration" in ln:
            i += 2
            dur = np.zeros(n, dtype=np.int64)
            rows = []
            for _ in range(n):
                parts = [int(x) for x in lines[i].split()]
                dur[parts[0] - 1] = parts[2]
                rows.append(parts[3:])
                i += 1
            usage = np.asarray(rows, dtype=np.int64).T
            continue
        if "RESOURCEAVAILABILITIES" in ln.replace(" ", ""):
            i += 2
            cap = np.array([int(x) for x in lines[i].split()], dtype=np.int64)
        i += 1
    assert n is not None and dur is not None and cap is not None
    return RCPSP(dur, prec, usage, cap, name=path.rsplit("/", 1)[-1])
