"""Product configuration — mixed extensional + linear optimization
(DESIGN.md §10, §17).

Pick one option per component (`x_i ∈ (0, m-1)`) subject to pairwise
compatibility: each dependent pair (i, j) carries an arity-2 `Table` of
the allowed option combinations.  Cost couples in through a second CT
shape — a per-component *weight-link* table `{(o, w_i[o])}` binding the
option var to its price var — and a linear row sums the price vars into
the minimized objective.  This is the mixed workload the bounds-only
engine handles worst (compatibility sets are full of holes) and
Compact-Table handles natively; ``decompose=True`` emits the reified
disjunction oracle for every table.

`generate(k, m, seed)` plants a random full assignment and makes it
compatible on every pair (always SAT), then mixes in seeded extra
compatible pairs so the optimum is a non-trivial search.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.model import Model


@dataclasses.dataclass
class Configuration:
    k: int                                  # components
    m: int                                  # options per component
    weights: List[List[int]]                # k×m option prices
    pairs: List[Tuple[int, int]]            # dependent component pairs
    compat: List[List[Tuple[int, int]]]     # allowed option pairs, per pair
    name: str = "configuration"


def generate(k: int, m: int, seed: int = 0,
             extra_prob: float = 0.3) -> Configuration:
    """Seeded instance: ring + random-chord dependency graph, planted
    compatible assignment, `extra_prob` of the remaining option pairs
    allowed per edge."""
    rng = np.random.default_rng(seed)
    weights = [[int(w) for w in rng.integers(1, 9, size=m)]
               for _ in range(k)]
    planted = [int(o) for o in rng.integers(0, m, size=k)]
    pairs = [(i, i + 1) for i in range(k - 1)]
    if k > 2:
        pairs.append((0, k - 1))
    chords = [(i, j) for i in range(k) for j in range(i + 2, k - 1)]
    pairs += [p for p in chords if rng.random() < 0.2]
    compat = []
    for (i, j) in pairs:
        allowed = {(planted[i], planted[j])}
        for a in range(m):
            for b in range(m):
                if rng.random() < extra_prob:
                    allowed.add((a, b))
        compat.append(sorted(allowed))
    return Configuration(k=k, m=m, weights=weights, pairs=pairs,
                         compat=compat,
                         name=f"configuration-k{k}-m{m}-s{seed}")


def build_model(inst: Configuration,
                decompose: bool = False) -> Tuple[Model, dict]:
    k, m_opts = inst.k, inst.m
    m = Model(name=inst.name)
    xs = [m.int_var(0, m_opts - 1, f"x{i}") for i in range(k)]
    ws = []
    for i in range(k):
        wi = inst.weights[i]
        w = m.int_var(min(wi), max(wi), f"w{i}")
        # weight link: one CT row binding the option to its price
        m.table([xs[i], w], [(o, wi[o]) for o in range(m_opts)],
                decompose=decompose)
        ws.append(w)
    for (i, j), allowed in zip(inst.pairs, inst.compat):
        m.table([xs[i], xs[j]], allowed, decompose=decompose)
    total = m.int_var(0, sum(max(wi) for wi in inst.weights), "total")
    expr = ws[0]._as_expr()
    for w in ws[1:]:
        expr = expr + w
    for c in expr.eq(total):
        m.add(c)
    m.minimize(total)
    m.branch_on(xs)
    return m, dict(x=xs, w=ws, total=total, check_vars=xs)


def check_solution(inst: Configuration,
                   options: Sequence[int]) -> Tuple[bool, int]:
    """Ground checker: every dependent pair compatible.
    Returns (feasible, objective) with objective = Σ price."""
    v = [int(x) for x in options]
    if len(v) != inst.k or any(not (0 <= x < inst.m) for x in v):
        return False, -1
    for (i, j), allowed in zip(inst.pairs, inst.compat):
        if (v[i], v[j]) not in set(allowed):
            return False, -1
    return True, sum(inst.weights[i][v[i]] for i in range(inst.k))
