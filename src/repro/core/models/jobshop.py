"""Job-shop scheduling — disjunctive machines (DESIGN.md §10, §12).

Each job is a fixed sequence of operations, one per machine, with
durations; operations of different jobs on the same machine must not
overlap.  Start variable `s_{j,k}` per operation:

    within-job precedence:  s_{j,k} + d_{j,k} ≤ s_{j,k+1}        (plain)
    machine exclusivity:    cumulative(ops on machine, dem 1, cap 1)
    makespan:               s_{j,last} + d ≤ mk,  minimize mk

Since §12 each machine lowers to ONE native unit-capacity `Cumulative`
row (time-table filtering — the disjunctive case).  ``build_model(inst,
decompose=True)`` emits the pre-§12 lowering instead: the pairwise
before/after reified disjunction b ⇔ (end_a ≤ start_b) ∥ b' ⇔ (end_b ≤
start_a) ∥ b + b' ≥ 1 per op pair — kept as the parity oracle.  RCPSP's
cumulative generalizes this to capacities > 1.

`generate(n_jobs, n_machines, seed)` samples a square-ish Taillard-style
instance: each job visits every machine once in a random order.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.core.model import Model


@dataclasses.dataclass
class JobShop:
    machines: np.ndarray       # i[J, M] machine of op k of job j
    durations: np.ndarray      # i[J, M] duration of op k of job j
    name: str = "jobshop"

    @property
    def n_jobs(self) -> int:
        return self.machines.shape[0]

    @property
    def n_machines(self) -> int:
        return self.machines.shape[1]

    @property
    def horizon(self) -> int:
        return int(self.durations.sum())


def generate(n_jobs: int, n_machines: int = 2, seed: int = 0,
             max_duration: int = 5) -> JobShop:
    """Seeded Taillard-style instance (every job visits every machine)."""
    rng = np.random.default_rng(seed)
    mach = np.stack([rng.permutation(n_machines) for _ in range(n_jobs)])
    dur = rng.integers(1, max_duration + 1, size=(n_jobs, n_machines))
    return JobShop(machines=mach, durations=dur,
                   name=f"jobshop-j{n_jobs}-m{n_machines}-s{seed}")


def build_model(inst: JobShop, decompose: bool = False) -> Tuple[Model, dict]:
    J, M = inst.n_jobs, inst.n_machines
    h = inst.horizon
    d = inst.durations
    m = Model(name=inst.name)
    s = [[m.int_var(0, h, f"s{j}_{k}") for k in range(M)] for j in range(J)]
    mk = m.int_var(0, h, "makespan")

    for j in range(J):
        for k in range(M - 1):
            m.add(s[j][k] + int(d[j, k]) <= s[j][k + 1])
        m.add(s[j][M - 1] + int(d[j, M - 1]) <= mk)

    # per-machine exclusivity between operations of different jobs
    for mach in range(M):
        ops = [(j, int(np.where(inst.machines[j] == mach)[0][0]))
               for j in range(J)]
        if not decompose:
            # one native unit-capacity cumulative row per machine (§12)
            m.cumulative([s[j][k] for j, k in ops],
                         [int(d[j, k]) for j, k in ops],
                         [1] * len(ops), 1)
            continue
        for a in range(len(ops)):
            for b in range(a + 1, len(ops)):
                (ja, ka), (jb, kb) = ops[a], ops[b]
                ab = m.reify(s[ja][ka] + int(d[ja, ka]) <= s[jb][kb],
                             f"m{mach}_{ja}b4{jb}")
                ba = m.reify(s[jb][kb] + int(d[jb, kb]) <= s[ja][ka],
                             f"m{mach}_{jb}b4{ja}")
                m.add(ab + ba >= 1)

    m.minimize(mk)
    flat = [v for job in s for v in job]
    m.branch_on(flat + [mk])
    return m, dict(s=s, mk=mk, check_vars=flat)


def check_solution(inst: JobShop, starts: Sequence[int]) -> Tuple[bool, int]:
    """Ground checker: within-job precedence + machine exclusivity.
    `starts` is the row-major flattening of s[j][k].
    Returns (feasible, makespan)."""
    J, M = inst.n_jobs, inst.n_machines
    st = np.asarray([int(x) for x in starts]).reshape(J, M)
    d = inst.durations
    if (st < 0).any():
        return False, -1
    for j in range(J):
        for k in range(M - 1):
            if st[j, k] + d[j, k] > st[j, k + 1]:
                return False, -1
    for mach in range(M):
        ivals = []
        for j in range(J):
            k = int(np.where(inst.machines[j] == mach)[0][0])
            ivals.append((int(st[j, k]), int(st[j, k] + d[j, k])))
        ivals.sort()
        for (s0, e0), (s1, _) in zip(ivals, ivals[1:]):
            if s1 < e0:
                return False, -1
    return True, int((st + d).max())
