"""The model zoo (DESIGN.md §10).

Every workload compiles through the same `ReifLinLe` guarded normal form
(`core/model.py` → `core/compile.py`), so each runs unchanged on every
propagation backend and through the EPS-decomposed engine.  A zoo module
exposes the uniform protocol:

* ``generate(size..., seed=0)`` — seeded reproducible instance;
* ``build_model(inst) -> (Model, handles)`` — handles include
  ``check_vars``, the IntVars (in order) that ``check_solution`` expects;
* ``check_solution(inst, values) -> (feasible, objective)`` — ground
  checker independent of the solver, with `objective` in *model* terms
  (i.e. what the engine minimizes — negated profit for knapsack).

``ZOO`` maps the canonical names to the modules; ``small_instance``
yields the seeded smoke instances used by tests, `make check`'s solver
section and `examples/model_zoo.py`.
"""

from __future__ import annotations

from repro.core.models import (coloring, configuration, crossword, jobshop,
                               knapsack, nqueens, rcpsp)

ZOO = {
    "rcpsp": rcpsp,
    "nqueens": nqueens,
    "coloring": coloring,
    "knapsack": knapsack,
    "jobshop": jobshop,
    "crossword": crossword,
    "configuration": configuration,
}


# per-model generate() kwargs for the three instance tiers:
# smoke (seconds-to-optimum on every backend), bench (heavier), and
# large (industrial sizes exercising the sparse bank layouts,
# DESIGN.md §16 — compiled/bench-inspected everywhere; solved to proven
# optimum only where the `large`-marked tests say so)
_TIERS = {
    "rcpsp": (dict(n_tasks=5, n_resources=2, edge_prob=0.3),
              dict(n_tasks=8, n_resources=3, edge_prob=0.25),
              dict(n_tasks=96, n_resources=4, edge_prob=0.06)),
    "nqueens": (dict(n=5), dict(n=7), dict(n=256)),
    "coloring": (dict(n=6, edge_prob=0.5), dict(n=9, edge_prob=0.45),
                 dict(n=64, edge_prob=0.12)),
    "knapsack": (dict(n=6), dict(n=10), dict(n=512)),
    "jobshop": (dict(n_jobs=2, n_machines=2), dict(n_jobs=3, n_machines=2),
                dict(n_jobs=20, n_machines=15)),
    "crossword": (dict(n=3), dict(n=4), dict(n=8)),
    "configuration": (dict(k=4, m=4), dict(k=6, m=5),
                      dict(k=24, m=8)),
}
assert set(_TIERS) == set(ZOO)


def _instance(name: str, tier: int, seed: int):
    try:
        kw = _TIERS[name][tier]
    except KeyError:
        raise ValueError(
            f"unknown zoo model {name!r}; have {sorted(ZOO)}") from None
    return ZOO[name].generate(seed=seed, **kw)


def small_instance(name: str, seed: int = 0):
    """Seeded small instance of each zoo model: solvable to proven
    optimum in seconds on every backend (the smoke/CI tier)."""
    return _instance(name, 0, seed)


def bench_instance(name: str, seed: int = 0):
    """Larger seeded instance per model (the benchmark tier)."""
    return _instance(name, 1, seed)


def large_instance(name: str, seed: int = 0):
    """Industrial-size seeded instance per model (the scale tier,
    DESIGN.md §16): 10²–10³ variables, compiled onto the sparse bank
    layouts by the auto crossover.  Used by the `scale` bench section
    and the `large`-marked tests (`REPRO_RUN_LARGE=1`)."""
    return _instance(name, 2, seed)


def ground_check(mod, inst, handles, res):
    """Ground-check a SolveResult against `mod.check_solution`: True/False
    for a checked solution, None when there is no solution to check
    (timeout/UNSAT — distinct from a checker failure)."""
    if res.solution is None:
        return None
    vals = [int(res.solution[v.idx]) for v in handles["check_vars"]]
    ok, obj = mod.check_solution(inst, vals)
    return bool(ok and obj == res.objective)
