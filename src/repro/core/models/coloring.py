"""Graph coloring — chromatic-number search (DESIGN.md §10, §12).

Color variable `c_i` per vertex, `c_i ≠ c_j` per edge, and a `cmax`
variable with `c_i ≤ cmax` minimized by branch & bound — the optimum is
χ(G) - 1.  Since §12 each edge lowers to ONE native two-member
`AllDifferent` row (1 table row per edge instead of the 3 `ReifLinLe`
rows + 2 fresh booleans of the reified-disjunction `Model.neq`
decomposition, which ``build_model(inst, decompose=True)`` still emits
as the parity oracle).

Value-symmetry breaking: vertex i's domain is `(0, min(i, n-1))` — any
coloring can be relabeled so colors appear in first-use order, so
restricting vertex i to the first i+1 colors preserves the chromatic
number while cutting the k! color-permutation symmetry.

`generate(n, seed)` samples a G(n, p) Erdős–Rényi graph.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.model import Model


@dataclasses.dataclass
class Coloring:
    n: int
    edges: List[Tuple[int, int]]
    name: str = "coloring"


def generate(n: int, seed: int = 0, edge_prob: float = 0.5) -> Coloring:
    """Seeded G(n, p) instance; isolated vertices are fine (color 0)."""
    rng = np.random.default_rng(seed)
    edges = [(i, j) for i in range(n) for j in range(i + 1, n)
             if rng.random() < edge_prob]
    return Coloring(n=n, edges=edges,
                    name=f"coloring-n{n}-p{edge_prob}-s{seed}")


def build_model(inst: Coloring, decompose: bool = False) -> Tuple[Model, dict]:
    n = inst.n
    m = Model(name=inst.name)
    c = [m.int_var(0, min(i, n - 1), f"c{i}") for i in range(n)]
    cmax = m.int_var(0, n - 1, "cmax")
    for (i, j) in inst.edges:
        if decompose:
            m.neq(c[i], c[j])
        else:
            m.alldifferent([c[i], c[j]])
    for i in range(n):
        m.add(c[i] <= cmax)
    m.minimize(cmax)
    m.branch_on(c + [cmax])
    return m, dict(c=c, cmax=cmax, check_vars=c)


def check_solution(inst: Coloring, colors: Sequence[int]) -> Tuple[bool, int]:
    """Ground checker: proper coloring. Returns (feasible, max color)."""
    col = [int(x) for x in colors]
    if len(col) != inst.n:
        return False, -1
    for (i, j) in inst.edges:
        if col[i] == col[j]:
            return False, -1
    return True, max(col) if col else 0
