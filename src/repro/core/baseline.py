"""Sequential propagate-and-search baseline (the paper's GECODE stand-in).

GECODE is not installable offline, so the CPU baseline the benchmarks
compare against is this classic *sequential* solver: an event-driven
propagation loop (propagators re-queued only when a watched variable
changes — the standard AC-3/AC-5-style engine the paper contrasts its
eventless AC-1 loop with), depth-first search with chronological
backtracking on copied stores, and branch & bound.

It shares the Model/CompiledModel representation and uses the *same*
propagator math (one numpy transcription per propagator kind of the
`fixpoint` tile semantics — ReifLinLe rows, AllDifferent Hall-interval
bounds consistency, Cumulative time-table filtering, Compact-Table
extensional rows on bitset domains; DESIGN.md §12, §17), so objective
values must agree exactly with the parallel engine — that agreement is
itself a correctness test of both.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core import bitset as B
from repro.core.compile import CompiledModel
from repro.core import search as S
from repro.core.engine import OPTIMAL, SAT, UNSAT, UNKNOWN, SolveResult


def _row_update(cm, lb, ub, p: int,
                vidx, coef, rhs, bidx, box_lo, box_hi) -> List[int]:
    """Apply propagator row p in place; return list of changed var indices."""
    a = coef[p]
    vs = vidx[p]
    c = int(rhs[p])
    b = int(bidx[p])
    xl = lb[vs].astype(np.int64)
    xu = ub[vs].astype(np.int64)
    al = a.astype(np.int64)
    tl = np.where(al > 0, al * xl, al * xu)
    tu = np.where(al > 0, al * xu, al * xl)
    smin = int(tl.sum())
    smax = int(tu.sum())
    changed: List[int] = []

    def tighten_ub(v: int, val: int):
        val = max(val, int(box_lo[v]))
        if val < ub[v]:
            ub[v] = val
            changed.append(v)

    def tighten_lb(v: int, val: int):
        val = min(val, int(box_hi[v]))
        if val > lb[v]:
            lb[v] = val
            changed.append(v)

    if lb[b] >= 1:                           # ask b: Σ a x ≤ c
        for k in range(len(vs)):
            ak = int(al[k])
            if ak == 0:
                continue
            slack = c - (smin - int(tl[k]))
            if ak > 0:
                tighten_ub(int(vs[k]), slack // ak)
            else:
                tighten_lb(int(vs[k]), -((-slack) // ak))
    if ub[b] <= 0:                           # ask ¬b: Σ -a x ≤ -c-1
        for k in range(len(vs)):
            ak = -int(al[k])
            if ak == 0:
                continue
            slack = (-c - 1) - (-smax + int(tu[k]))
            if ak > 0:
                tighten_ub(int(vs[k]), slack // ak)
            else:
                tighten_lb(int(vs[k]), -((-slack) // ak))
    if smax <= c:
        tighten_lb(b, 1)                     # entailed
    if smin > c:
        tighten_ub(b, 0)                     # disentailed
    return changed


def _alldiff_update(lb, ub, vs, offs, box_lo, box_hi) -> List[int]:
    """Hall-interval bounds(Z) consistency for one AllDifferent row —
    numpy transcription of `fixpoint.alldiff_candidates_tile`."""
    yl = lb[vs].astype(np.int64) + offs
    yu = ub[vs].astype(np.int64) + offs
    n = len(vs)
    changed: List[int] = []

    def tighten_lb(v: int, val: int):
        val = min(val, int(box_hi[v]))
        if val > lb[v]:
            lb[v] = val
            changed.append(v)

    def tighten_ub(v: int, val: int):
        val = max(val, int(box_lo[v]))
        if val < ub[v]:
            ub[v] = val
            changed.append(v)

    for i in range(n):
        a = int(yl[i])
        for j in range(n):
            b = int(yu[j])
            if a > b:
                continue
            inside = (yl >= a) & (yu <= b)
            cnt = int(inside.sum())
            width = b - a + 1
            if cnt > width:                  # pigeonhole: unsatisfiable
                tighten_lb(int(vs[0]), int(box_hi[vs[0]]) + 1)
                return changed
            if cnt == width:                 # Hall interval: push others out
                for k in range(n):
                    if inside[k]:
                        continue
                    if a <= yl[k] <= b:
                        tighten_lb(int(vs[k]), b + 1 - int(offs[k]))
                    if a <= yu[k] <= b:
                        tighten_ub(int(vs[k]), a - 1 - int(offs[k]))
    return changed


def _cumulative_update(lb, ub, svars, durs, dems, cap, horizon,
                       box_lo, box_hi) -> List[int]:
    """Time-table filtering for one Cumulative row — numpy transcription
    of `fixpoint.cumulative_candidates_tile`."""
    est = lb[svars].astype(np.int64)
    lst = ub[svars].astype(np.int64)
    n = len(svars)
    changed: List[int] = []
    profile = np.zeros(horizon, dtype=np.int64)
    for t in range(n):
        if durs[t] > 0 and dems[t] > 0 and lst[t] < est[t] + durs[t]:
            profile[max(int(lst[t]), 0):int(est[t] + durs[t])] += dems[t]
    if (profile > cap).any():                # overload: unsatisfiable
        # fail through the first effective task (profile > 0 implies one
        # exists): box_hi = ub0 + 1 always crosses the upper bound
        t0 = next(t for t in range(n) if durs[t] > 0 and dems[t] > 0)
        v0 = int(svars[t0])
        if lb[v0] < int(box_hi[v0]):
            lb[v0] = int(box_hi[v0])
            changed.append(v0)
        return changed
    for t in range(n):
        if durs[t] <= 0 or dems[t] <= 0:
            continue
        own = np.zeros(horizon, dtype=np.int64)
        if lst[t] < est[t] + durs[t]:
            own[max(int(lst[t]), 0):int(est[t] + durs[t])] = dems[t]
        bad = profile - own + dems[t] > cap
        csum = np.concatenate([[0], np.cumsum(bad)])
        ends = np.minimum(np.arange(horizon) + int(durs[t]), horizon)
        feas = (csum[ends] - csum[:-1]) == 0
        v = int(svars[t])
        ok_lb = np.nonzero(feas & (np.arange(horizon) >= est[t]))[0]
        new_lb = int(ok_lb[0]) if len(ok_lb) else int(box_hi[v]) + 1
        new_lb = min(new_lb, int(box_hi[v]))
        if new_lb > lb[v]:
            lb[v] = new_lb
            changed.append(v)
        ok_ub = np.nonzero(feas & (np.arange(horizon) <= lst[t]))[0]
        new_ub = int(ok_ub[-1]) if len(ok_ub) else int(box_lo[v]) - 1
        new_ub = max(new_ub, int(box_lo[v]))
        if new_ub < ub[v]:
            ub[v] = new_ub
            changed.append(v)
    return changed


def _ct_update(lb, ub, dom, vs, supp, dom_off, n_words,
               box_lo, box_hi) -> List[int]:
    """Reset-based Compact-Table filtering for one extensional row —
    numpy transcription of `fixpoint.ct_candidates_tile` (DESIGN.md §17).

    `supp` is the de-padded support bank ``[R, K32, TW]``: bit j of word
    ``supp[r, k, j // 32]`` is set iff tuple j takes value
    ``dom_off[vs[r]] + k`` at position r.  All member vars are
    dom-tracked by construction (n_words covers table∪branch widths).
    """
    R = len(vs)
    K32 = B.WORD_BITS * n_words
    off = dom_off[vs]
    vbw = dom[vs] & B.np_from_bounds(lb[vs], ub[vs], off, n_words)
    shifts = np.arange(B.WORD_BITS, dtype=np.uint32)
    vb = ((vbw[:, :, None] >> shifts) & np.uint32(1)).reshape(R, K32)
    # OR of supports over live member values; sum == OR because each
    # tuple has exactly one value per position (disjoint bit columns)
    supp_on = (vb[:, :, None] * supp).sum(axis=1).astype(np.uint32)
    curr = np.bitwise_and.reduce(supp_on, axis=0)
    changed: List[int] = []
    if not curr.any():                       # currtable wiped: unsatisfiable
        v0 = int(vs[0])
        if lb[v0] < int(box_hi[v0]):         # box_hi = ub0+1 crosses ub
            lb[v0] = int(box_hi[v0])
            changed.append(v0)
        return changed
    surv = (supp & curr[None, None, :]).any(axis=2)           # [R, K32]
    nw = ((surv.reshape(R, n_words, B.WORD_BITS).astype(np.uint32)
           << shifts).sum(axis=2).astype(np.uint32))
    for r in range(R):
        v = int(vs[r])
        ndw = dom[v] & nw[r]
        if not np.array_equal(ndw, dom[v]):
            dom[v] = ndw
            changed.append(v)
        lo, hi = B.np_to_bounds(ndw, dom_off[v])
        nlb = min(int(lo), int(box_hi[v]))
        if nlb > lb[v]:
            lb[v] = nlb
            changed.append(v)
        nub = max(int(hi), int(box_lo[v]))
        if nub < ub[v]:
            ub[v] = nub
            changed.append(v)
    return changed


class SequentialSolver:
    """Event-queue propagation + DFS + B&B on numpy stores.

    Propagator ids: ``[0, P)`` are the ReifLinLe rows, ``[P, P+A)`` the
    AllDifferent rows, ``[P+A, P+A+C)`` the Cumulative rows,
    ``[P+A+C, P+A+C+T)`` the Compact-Table rows — all in one event
    queue with per-kind watch lists (DESIGN.md §12, §17).

    When the model has tables (or middle-out branching is selected) a
    packed bitset store rides along the interval stores on the DFS
    stack, exactly like the engine's optional `dom` carry.
    """

    def __init__(self, cm: CompiledModel, opts: Optional[S.SearchOptions] = None):
        self.cm = cm
        self.opts = opts or S.SearchOptions()
        self.vidx = np.asarray(cm.vidx)
        self.coef = np.asarray(cm.coef)
        self.rhs = np.asarray(cm.rhs)
        self.bidx = np.asarray(cm.bidx)
        self.box_lo = np.asarray(cm.box_lo)
        self.box_hi = np.asarray(cm.box_hi)
        self.branch_vars = np.asarray(cm.branch_vars)
        P, A, C = cm.n_props, cm.n_alldiff, cm.n_cumulative
        T = cm.n_table
        self.n_pids = P + A + C + T
        self.dom_off = np.asarray(cm.dom_off)
        self.dom_track = np.asarray(cm.dom_track)
        self.n_words = cm.n_words
        self.use_dom = T > 0 or self.opts.val_strategy == S.VAL_MIDDLE_OUT
        # native banks, de-padded to per-row member lists
        ad_mask = np.asarray(cm.ad_mask)
        self.ad_rows = []
        for a in range(A):
            sel = ad_mask[a] != 0
            self.ad_rows.append((np.asarray(cm.ad_vars)[a][sel],
                                 np.asarray(cm.ad_offs)[a][sel]))
        self.cu_rows = []
        for c in range(C):
            self.cu_rows.append((np.asarray(cm.cu_svar)[c],
                                 np.asarray(cm.cu_dur)[c],
                                 np.asarray(cm.cu_dem)[c],
                                 int(np.asarray(cm.cu_cap)[c])))
        ct_mask = np.asarray(cm.ct_mask)
        self.ct_rows = []
        for t in range(T):
            sel = ct_mask[t] != 0
            self.ct_rows.append((np.asarray(cm.ct_vars)[t][sel],
                                 np.asarray(cm.ct_supp)[t][sel]))
        # watchers: var -> pids that mention it (terms/reif bool/members)
        self.watch: List[List[int]] = [[] for _ in range(cm.n_vars)]
        for p in range(P):
            seen = set()
            for k in range(cm.k_terms):
                if self.coef[p, k] != 0:
                    seen.add(int(self.vidx[p, k]))
            seen.add(int(self.bidx[p]))
            for v in seen:
                self.watch[v].append(p)
        for a, (vs, _) in enumerate(self.ad_rows):
            for v in set(int(x) for x in vs):
                self.watch[v].append(P + a)
        for c, (vs, du, de, _) in enumerate(self.cu_rows):
            eff = set(int(v) for v, d_, r_ in zip(vs, du, de)
                      if d_ > 0 and r_ > 0)
            for v in eff:
                self.watch[v].append(P + A + c)
        for t, (vs, _) in enumerate(self.ct_rows):
            for v in set(int(x) for x in vs):
                self.watch[v].append(P + A + C + t)

    def _apply_pid(self, lb, ub, dom, pid: int) -> List[int]:
        P, A, C = self.cm.n_props, self.cm.n_alldiff, self.cm.n_cumulative
        if pid < P:
            return _row_update(self.cm, lb, ub, pid, self.vidx, self.coef,
                               self.rhs, self.bidx, self.box_lo, self.box_hi)
        if pid < P + A:
            vs, offs = self.ad_rows[pid - P]
            return _alldiff_update(lb, ub, vs, offs, self.box_lo, self.box_hi)
        if pid < P + A + C:
            vs, du, de, cap = self.cu_rows[pid - P - A]
            return _cumulative_update(lb, ub, vs, du, de, cap,
                                      self.cm.horizon,
                                      self.box_lo, self.box_hi)
        vs, supp = self.ct_rows[pid - P - A - C]
        return _ct_update(lb, ub, dom, vs, supp, self.dom_off, self.n_words,
                          self.box_lo, self.box_hi)

    def _normalize(self, lb, ub, dom) -> List[int]:
        """`fixpoint.dom_normalize_tile` transcription: clip the bitset
        store to the interval hull and tighten tracked bounds back to
        the bitset hull.  Returns vars whose bounds moved."""
        dom &= B.np_from_bounds(lb, ub, self.dom_off, self.n_words,
                                track=self.dom_track)
        lo, hi = B.np_to_bounds(dom, self.dom_off)
        trk = self.dom_track != 0
        nlb = np.where(trk, np.maximum(lb, np.minimum(lo, self.box_hi)),
                       lb).astype(lb.dtype)
        nub = np.where(trk, np.minimum(ub, np.maximum(hi, self.box_lo)),
                       ub).astype(ub.dtype)
        ch = np.nonzero((nlb != lb) | (nub != ub))[0]
        lb[:] = nlb
        ub[:] = nub
        return [int(v) for v in ch]

    def propagate(self, lb, ub, dom=None, dirty: Optional[List[int]] = None) -> bool:
        """Event loop to fixpoint (interleaved with dom↔bounds
        normalization when a bitset store rides along).  Returns False
        on failure.  A caller that passes no `dom` on a table model
        gets the engine's transient-dom fallback: a bounds-derived
        bitset per call (sound superset, weaker on holes)."""
        P = self.n_pids
        if dom is None and self.cm.n_table > 0:
            dom = B.np_from_bounds(lb, ub, self.dom_off, self.n_words,
                                   track=self.dom_track)
        if dirty is None:
            queue = list(range(P))
            queued = [True] * P
        else:
            queue = []
            queued = [False] * P
            for v in dirty:
                for p in self.watch[v]:
                    if not queued[p]:
                        queued[p] = True
                        queue.append(p)
        qi = 0
        while True:
            while qi < len(queue):
                p = queue[qi]
                qi += 1
                queued[p] = False
                changed = self._apply_pid(lb, ub, dom, p)
                for v in changed:
                    if lb[v] > ub[v]:
                        return False
                    for q in self.watch[v]:
                        if not queued[q]:
                            queued[q] = True
                            queue.append(q)
                if qi > 4096 * max(P, 1):    # safety valve
                    raise RuntimeError("event loop runaway")
            if dom is None:
                return True
            moved = self._normalize(lb, ub, dom)
            if not moved:
                return True
            for v in moved:
                if lb[v] > ub[v]:
                    return False
                for p in self.watch[v]:
                    if not queued[p]:
                        queued[p] = True
                        queue.append(p)

    def solve(self, timeout_s: Optional[float] = None,
              node_budget: Optional[int] = None) -> SolveResult:
        cm, opts = self.cm, self.opts
        t0 = time.time()
        big = np.iinfo(np.asarray(cm.lb0).dtype).max // 4
        lb = np.asarray(cm.lb0).copy()
        ub = np.asarray(cm.ub0).copy()
        best_obj = big
        best_sol = None
        n_nodes = n_fails = n_sols = 0
        complete = True

        dom0 = (B.np_from_bounds(lb, ub, self.dom_off, self.n_words,
                                 track=self.dom_track)
                if self.use_dom else None)
        ok = self.propagate(lb, ub, dom0)
        stack: List[Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]] = []
        if ok:
            stack.append((lb, ub, dom0))

        while stack:
            if timeout_s is not None and time.time() - t0 > timeout_s:
                complete = False
                break
            if node_budget is not None and n_nodes >= node_budget:
                complete = False
                break
            lb, ub, dom = stack.pop()
            # B&B bound tell (joined on pop => valid for the whole subtree)
            if cm.obj_var >= 0 and best_obj < big:
                if ub[cm.obj_var] > best_obj - 1:
                    ub[cm.obj_var] = best_obj - 1
                if not self.propagate(lb, ub, dom, dirty=[cm.obj_var]):
                    n_nodes += 1
                    n_fails += 1
                    continue
            n_nodes += 1
            bl, bu = lb[self.branch_vars], ub[self.branch_vars]
            unfixed = bl < bu
            if not unfixed.any():
                n_sols += 1
                obj = int(lb[cm.obj_var]) if cm.obj_var >= 0 else 0
                if cm.obj_var < 0 or obj < best_obj:
                    best_obj = obj
                    best_sol = lb.copy()
                if cm.obj_var < 0 and opts.stop_on_first:
                    break
                continue
            # branch
            if opts.var_strategy == S.MIN_DOM:
                w = np.where(unfixed, bu - bl, big)
                pos = int(np.argmin(w))
            elif opts.var_strategy == S.MIN_LB:
                w = np.where(unfixed, bl, big)
                pos = int(np.argmin(w))
            else:
                pos = int(np.argmax(unfixed))
            v = int(self.branch_vars[pos])
            mid_out = (opts.val_strategy == S.VAL_MIDDLE_OUT
                       and dom is not None and self.dom_track[v] != 0)
            if mid_out:
                # pick the live value closest to the interval midpoint
                # (ties to the lower value), branch x = m  |  x ≠ m
                off_v = int(self.dom_off[v])
                vbw = dom[v] & B.np_from_bounds(lb[v], ub[v], off_v,
                                                self.n_words)
                shifts = np.arange(B.WORD_BITS, dtype=np.uint32)
                bits = ((vbw[:, None] >> shifts) & np.uint32(1)).reshape(-1)
                vals = off_v + np.nonzero(bits)[0].astype(np.int64)
                mid = (int(lb[v]) + int(ub[v])) // 2
                score = 2 * np.abs(vals - mid) + (vals > mid)
                mval = int(vals[int(np.argmin(score))])
                rl, ru = lb.copy(), ub.copy()
                rd = dom.copy()
                rd[v] = B.np_clear_value(dom[v], mval, off_v)
                if self.propagate(rl, ru, rd, dirty=[v]):
                    stack.append((rl, ru, rd))
                ll, lu, ld = lb, ub, dom      # reuse parent arrays for left
                ll[v] = lu[v] = mval
                if self.propagate(ll, lu, ld, dirty=[v]):
                    stack.append((ll, lu, ld))
                else:
                    n_fails += 1
                continue
            mval = int(lb[v]) if opts.val_strategy == S.VAL_MIN \
                else int((lb[v] + ub[v]) // 2)
            # right child pushed first => left (x ≤ m) explored first
            rl, ru = lb.copy(), ub.copy()
            rd = dom.copy() if dom is not None else None
            rl[v] = mval + 1
            if rl[v] <= ru[v] and self.propagate(rl, ru, rd, dirty=[v]):
                stack.append((rl, ru, rd))
            ll, lu, ld = lb, ub, dom          # reuse parent arrays for left
            lu[v] = mval
            if ll[v] <= lu[v] and self.propagate(ll, lu, ld, dirty=[v]):
                stack.append((ll, lu, ld))
            else:
                n_fails += 1

        wall = time.time() - t0
        has = best_sol is not None
        if has:
            status = OPTIMAL if complete and cm.obj_var >= 0 else SAT
            if cm.obj_var < 0:
                status = SAT
        else:
            status = UNSAT if complete else UNKNOWN
        return SolveResult(
            status=status,
            objective=(int(best_obj) if has and cm.obj_var >= 0 else None),
            solution=best_sol, n_nodes=n_nodes, n_fails=n_fails,
            n_sols=n_sols, n_sweeps=0, n_supersteps=0, wall_s=wall,
            complete=complete)
