"""Pluggable propagation backends (DESIGN.md §2.3).

The paper's central claim is that eventless propagation is **one
bulk-parallel program**; everything above it (search, EPS, B&B) only ever
needs two entry points:

* ``fixpoint(cm, lb, ub)``        — one store to its least fixed point,
* ``fixpoint_batch(cm, lb, ub)``  — a whole ``[n_lanes, V]`` store tensor
  in one launch (the TURBO superstep shape: grid cells = lane tiles).

`PropagationBackend` is that contract; four implementations register
here and are selected by name everywhere a store is propagated
(`SearchOptions.backend` → `engine.solve` → `launch/solve.py` CLI →
benchmarks → examples):

  ``gather``   variable-centric XLA sweep (`fixpoint.sweep_batch`) — the
               CPU/GPU/TPU-portable production default;
  ``scatter``  propagator-centric scatter-join oracle — the literal
               reading of the paper's atomic load/store compilation;
  ``pallas``   the VMEM-resident Pallas TPU kernel
               (`kernels/fixpoint_kernel.fixpoint_pallas`), interpret-mode
               on CPU, real `pallas_call` on TPU;
  ``pallas_resident``
               the resident *search* megakernel (DESIGN.md §13): K whole
               supersteps — dispatch, branch, fixpoint, commit — fused
               into one `pl.pallas_call` that the host chunk scheduler
               launches once per K supersteps.

All four compute the same least fixed point from the same single
implementation of the propagator math (`fixpoint.candidates_tile`);
parity is property-tested in `tests/test_backends.py`.  The comparison
spec (see `kernels/ops.py`): equal failed-lane masks, bit-identical
stores on non-failed lanes — failed lanes' contents are unspecified and
search discards them.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax

from repro.core.compile import CompiledModel
from repro.core import fixpoint as F

FixpointResult = Tuple[jax.Array, jax.Array, jax.Array, jax.Array]


@runtime_checkable
class PropagationBackend(Protocol):
    """Contract every propagation implementation satisfies.

    Both methods return ``(lb', ub', sweeps, converged)``; for the batch
    form `sweeps` and `converged` are per-lane ``[L]`` arrays.
    ``converged`` is True iff the lane reached a genuine fixed point (or
    failed — failure is definitive); with a `max_iters` cap it may be
    False, and callers must keep sweeping before trusting all-fixed
    stores as solutions (search.py's §Perf H1 soundness guard).
    """

    name: str

    def fixpoint(self, cm: CompiledModel, lb: jax.Array, ub: jax.Array, *,
                 max_iters: Optional[int] = None) -> FixpointResult:
        ...

    def fixpoint_batch(self, cm: CompiledModel, lb: jax.Array,
                       ub: jax.Array, *, dom: Optional[jax.Array] = None,
                       max_iters: Optional[int] = None) -> FixpointResult:
        # with `dom` (the bitset store, DESIGN.md §17) backends return
        # (lb', ub', dom', sweeps, converged) instead of the 4-tuple
        ...


class GatherBackend:
    """Variable-centric gather sweep, batched as one XLA tensor program."""

    name = "gather"

    def fixpoint(self, cm, lb, ub, *, max_iters=None):
        return F.fixpoint(cm, lb, ub, max_iters=max_iters)

    def fixpoint_batch(self, cm, lb, ub, *, dom=None, max_iters=None):
        return F.fixpoint_batch(cm, lb, ub, dom, max_iters=max_iters)


class ScatterBackend:
    """Propagator-centric scatter-join form (the reference semantics)."""

    name = "scatter"

    def fixpoint(self, cm, lb, ub, *, max_iters=None):
        return F.fixpoint(cm, lb, ub, max_iters=max_iters, use_scatter=True)

    def fixpoint_batch(self, cm, lb, ub, *, dom=None, max_iters=None):
        return F.fixpoint_batch(cm, lb, ub, dom, max_iters=max_iters,
                                use_scatter=True)


@partial(jax.jit, static_argnames=("lane_tile", "max_sweeps", "interpret"))
def _pallas_batch(cm, lb, ub, dom, lane_tile, max_sweeps, interpret):
    from repro.kernels.fixpoint_kernel import fixpoint_pallas
    return fixpoint_pallas(cm, lb, ub, dom=dom, lane_tile=lane_tile,
                           max_sweeps=max_sweeps, interpret=interpret)


class PallasBackend:
    """VMEM-resident Pallas fixpoint kernel (TPU; interpret-mode on CPU).

    `lane_tile` is the grid-cell width — the number of lanes whose two
    stores co-reside in VMEM for the whole loop (the TURBO shared-memory
    analogue).  The effective tile is clamped to the batch size so tiny
    batches don't pay padding sweeps.

    The per-lane `sweeps` this backend reports are *tile-granular*: a
    tile sweeps in lockstep until nothing in it changes, so the count
    exceeds the XLA backends' per-lane useful-sweep counts on the same
    input (and so do `n_sweeps` search stats under ``backend="pallas"``).
    Stores and convergence are unaffected — only the counter semantics
    differ.
    """

    name = "pallas"

    def __init__(self, lane_tile: int = 8,
                 interpret: Optional[bool] = None,
                 max_sweeps: int = 16384):
        self.lane_tile = lane_tile
        # default: real pallas_call on TPU, interpreter everywhere else
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self.max_sweeps = max_sweeps

    def fixpoint(self, cm, lb, ub, *, max_iters=None):
        nlb, nub, sweeps, conv = self.fixpoint_batch(
            cm, lb[None], ub[None], max_iters=max_iters)
        return nlb[0], nub[0], sweeps[0], conv[0]

    def fixpoint_batch(self, cm, lb, ub, *, dom=None, max_iters=None):
        cap = self.max_sweeps if max_iters is None else int(max_iters)
        tile = max(1, min(self.lane_tile, lb.shape[0]))
        return _pallas_batch(cm, lb, ub, dom, lane_tile=tile,
                             max_sweeps=cap, interpret=self.interpret)


class PallasResidentBackend(PallasBackend):
    """Resident search megakernel (DESIGN.md §13): K supersteps of the
    whole four-phase search loop fused into one `pl.pallas_call`, with
    every piece of lane state (stores, decision paths, status flags,
    pool cursor, tile-best bound) held in VMEM across supersteps
    (`kernels/fixpoint_kernel.search_pallas`).

    As a plain `PropagationBackend` it behaves like `pallas` (the
    inherited unfused fixpoint kernel, with ``lane_tile=8`` when the
    resident tile is the whole-batch default 0) — the fused path is the
    extra `superstep_launch` contract consumed by the host chunk
    scheduler (`core/api._run_chunk`), which calls it once per K
    supersteps instead of driving `search.lanes_step` per superstep.

    ``lane_tile=0`` (default) keeps all lanes in ONE grid cell — the
    bit-parity mode whose EPS dispatch is the exact shared queue of the
    unfused path; a positive tile (or a VMEM auto-shrink) shards the
    pool across cells (sound/complete, different dispatch trajectory).
    """

    name = "pallas_resident"

    def __init__(self, supersteps_per_launch: int = 16, lane_tile: int = 0,
                 interpret: Optional[bool] = None, max_sweeps: int = 16384):
        super().__init__(lane_tile=lane_tile or 8, interpret=interpret,
                         max_sweeps=max_sweeps)
        self.resident_lane_tile = lane_tile
        self.supersteps_per_launch = supersteps_per_launch

    def n_tiles(self, cm: CompiledModel, n_lanes: int, *, max_depth: int,
                pool_size: int) -> int:
        """Grid cells the resident kernel will use for `n_lanes` lanes —
        the host scheduler sizes the per-cell pool-cursor carry
        (`api._init_carry(n_heads=...)`) with this so carry shapes stay
        stable across launches."""
        from repro.kernels.fixpoint_kernel import fit_lane_tile
        tile = (n_lanes if self.resident_lane_tile in (0, None)
                else self.resident_lane_tile)
        tile = fit_lane_tile(cm, tile, n_lanes, resident=True,
                             max_depth=max_depth, pool_size=pool_size)
        return -(n_lanes // -tile)

    def superstep_launch(self, cm: CompiledModel, subs_lb, subs_ub, st,
                         gbest, it, pool_head, *, opts):
        """One K-superstep megakernel launch; returns
        ``(st', gbest', it', pool_head', stopped)``."""
        from repro.kernels.fixpoint_kernel import search_pallas
        return search_pallas(
            cm, subs_lb, subs_ub, st, gbest, it, pool_head,
            supersteps=self.supersteps_per_launch,
            lane_tile=self.resident_lane_tile,
            max_sweeps=self.max_sweeps,
            max_fixpoint_iters=opts.max_fixpoint_iters,
            var_strategy=opts.var_strategy,
            val_strategy=opts.val_strategy,
            stop_on_first=opts.stop_on_first,
            interpret=self.interpret)


_REGISTRY: Dict[str, Callable[..., PropagationBackend]] = {}


def register_backend(name: str,
                     factory: Callable[..., PropagationBackend]) -> None:
    """Register a backend factory under `name` (last registration wins —
    deliberate, so downstream code can swap in a tuned kernel)."""
    _REGISTRY[name] = factory


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str, **opts) -> PropagationBackend:
    """Instantiate a registered backend; `opts` go to its factory."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown propagation backend {name!r}; "
            f"available: {', '.join(available_backends())}") from None
    return factory(**opts)


register_backend("gather", GatherBackend)
register_backend("scatter", ScatterBackend)
register_backend("pallas", PallasBackend)
register_backend("pallas_resident", PallasResidentBackend)
