"""Session-oriented public solver API (DESIGN.md §11) — ``repro.solver``.

The paper's TURBO solves one instance per launch; the ROADMAP north-star
is a serving system, which needs three things a blocking ten-kwarg
``engine.solve`` cannot give:

* **amortized compilation** — `Solver` is a session owning a
  compiled-runner cache keyed by ``(model shape signature, config)``, so
  repeated ``solver.solve(cm)`` calls on same-shape instances skip
  jit/lowering entirely (the warm path);
* **batched dispatch** — ``solver.solve_many([cm...])`` stacks N
  same-shape instances into ONE device dispatch (instances are a vmapped
  leading axis over the whole chunk runner: per-instance lane blocks,
  per-instance EPS pools, per-instance B&B bounds), the throughput
  scenario (instances/s);
* **anytime answers** — ``solver.solve_iter(cm)`` is a generator
  yielding `Progress` events after every host chunk (superstep, best
  bound, incumbent, node counters), so a timeout degrades to the best
  incumbent instead of nothing; `SolveResult.improvements` records the
  bound trace.

Configuration is one frozen `SolveConfig` dataclass with named presets
(``prove`` — the default full B&B proof profile, ``first_solution`` —
stop at the first solution, ``fast`` — capped fixpoint sweeps, §Perf
P0/H1), replacing the flag recipes previously duplicated across
`launch/solve.py`, `benchmarks/bench_solver.py` and the tests.

`engine.solve` remains as a thin deprecation shim over this module.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import (Any, Dict, Iterator, List, NamedTuple, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.compile import CompiledModel
from repro.core import eps
from repro.core import search as S

# terminal statuses (re-exported by repro.core.engine for back-compat)
OPTIMAL = "OPTIMAL"
SAT = "SAT"
UNSAT = "UNSAT"
UNKNOWN = "UNKNOWN"


class Improvement(NamedTuple):
    """One incumbent improvement in a solve's anytime trace."""
    superstep: int
    wall_s: float
    objective: int


@dataclasses.dataclass
class SolveResult:
    status: str
    objective: Optional[int]
    solution: Optional[np.ndarray]
    n_nodes: int
    n_fails: int
    n_sols: int
    n_sweeps: int
    n_supersteps: int
    wall_s: float
    complete: bool
    # anytime trace: every (superstep, wall_s, objective) at which the
    # global incumbent improved, observed at scheduler-quantum
    # granularity (DESIGN.md §11): per host chunk for unfused backends,
    # per K-superstep launch for pallas_resident — improvements landing
    # within one quantum collapse into a single trace entry whose
    # `superstep` is the quantum's end.
    improvements: Tuple[Improvement, ...] = ()

    @property
    def nodes_per_sec(self) -> float:
        return self.n_nodes / max(self.wall_s, 1e-9)


@dataclasses.dataclass
class Progress:
    """One anytime event from `Solver.solve_iter`, emitted per scheduler
    quantum — i.e. once per `_run_chunk` return to the host: every
    ``chunk`` supersteps for the unfused backends, every
    ``supersteps_per_launch`` (K) supersteps for ``pallas_resident``
    (whose megakernel only re-enters the host per launch, DESIGN.md
    §13).  Anytime consumers should key off ``superstep``/``wall_s``,
    not event counts.

    The last event has ``final=True`` and carries the terminal
    `SolveResult` in ``result``; earlier events report the running
    incumbent (``best_objective`` is None for satisfaction models or
    while no solution exists yet).

    Timing contract (the ONE timing source, shared by the serving
    metrics and the superstep bench): ``t_host`` is the absolute host
    wall clock (``time.time()``) at event emission, ``wall_s`` is the
    elapsed time since the solve started (so ``t_host - wall_s`` is the
    solve's start stamp), and ``superstep`` is the cumulative superstep
    counter — downstream consumers must not re-time chunks themselves.
    """
    superstep: int
    best_objective: Optional[int]
    has_solution: bool
    incumbent: Optional[np.ndarray]
    n_nodes: int
    n_sols: int
    wall_s: float
    final: bool = False
    result: Optional[SolveResult] = None
    t_host: float = 0.0


# --------------------------------------------------------------------------
# SolveConfig: one frozen config object + named presets
# --------------------------------------------------------------------------

_VAR_STRATEGIES = (S.INPUT_ORDER, S.MIN_DOM, S.MIN_LB)
_VAL_STRATEGIES = (S.VAL_MIN, S.VAL_SPLIT, S.VAL_MIDDLE_OUT)

# named flag recipes (DESIGN.md §11). `prove` is the proof profile used
# by every benchmark table; `fast` is the §Perf P0/H1 capped-sweep
# profile (identical optima, bounded chaotic iteration); `first_solution`
# is the satisfaction/anytime profile.
PRESETS: Dict[str, Dict[str, Any]] = {
    "prove": dict(var_strategy=S.MIN_LB, max_depth=1024),
    "first_solution": dict(var_strategy=S.MIN_LB, max_depth=1024,
                           stop_on_first=True),
    "fast": dict(var_strategy=S.MIN_LB, max_depth=1024,
                 max_fixpoint_iters=4),
}


@dataclasses.dataclass(frozen=True)
class SolveConfig:
    """Everything `Solver` needs besides the model itself.

    Consolidates the former ``engine.solve`` kwarg sprawl; validated on
    construction, hashable (it is half of the session cache key), and
    buildable from a named preset: ``SolveConfig.preset("fast",
    backend="pallas", n_lanes=128)``.
    """

    # lanes / EPS decomposition (DESIGN.md §9)
    n_lanes: int = 64
    eps_target: Optional[int] = None          # None → 4 * n_lanes
    # host chunking / budgets
    chunk: int = 256
    timeout_s: Optional[float] = None
    max_supersteps: Optional[int] = None
    # propagation backend (core/backend.py)
    backend: str = "gather"
    backend_opts: Tuple[Tuple[str, Any], ...] = ()
    # pallas_resident only: supersteps fused per megakernel launch (K in
    # DESIGN.md §13); merged into backend_opts, so it is part of the
    # compile key.  None → the backend default (16).
    supersteps_per_launch: Optional[int] = None
    # search strategy (core/search.py)
    var_strategy: str = S.INPUT_ORDER
    val_strategy: str = S.VAL_MIN
    max_depth: int = 2048
    max_fixpoint_iters: Optional[int] = None
    stop_on_first: bool = False
    # multi-device engine (explicit-mesh legacy path)
    mesh: Optional[jax.sharding.Mesh] = None
    lane_axes: Tuple[str, ...] = ()
    # distributed EPS engine (core/dist_solve.py, DESIGN.md §14): shard
    # the lane pool over a 1-D `solve` mesh of this many devices, with
    # per-superstep bound all-reduce, chunk-granularity work stealing
    # (`steal`) and elastic device-loss recovery.  None → single-device;
    # the CLI spelling is `launch/solve.py --mesh N`.
    mesh_shards: Optional[int] = None
    steal: bool = True
    # pad EPS pools to the next power of two with explicitly-failed
    # stores so the compiled runner re-lowers per size *bucket*, not per
    # exact pool size (DESIGN.md §11 cache-key discussion)
    pad_pool: bool = True
    # provenance tag only — excluded from equality/hash so a preset and
    # its hand-rolled equivalent share one cache entry
    preset_name: Optional[str] = dataclasses.field(default=None,
                                                   compare=False)

    def __post_init__(self):
        def bad(msg):
            raise ValueError(f"SolveConfig: {msg}")

        if isinstance(self.backend_opts, dict):
            object.__setattr__(self, "backend_opts",
                               tuple(sorted(self.backend_opts.items())))
        else:
            object.__setattr__(self, "backend_opts",
                               tuple(tuple(kv) for kv in self.backend_opts))
        object.__setattr__(self, "lane_axes", tuple(self.lane_axes))

        for name in ("n_lanes", "chunk", "max_depth"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                bad(f"{name} must be a positive int, got {v!r}")
        for name in ("eps_target", "max_supersteps", "max_fixpoint_iters",
                     "supersteps_per_launch"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v < 1):
                bad(f"{name} must be None or a positive int, got {v!r}")
        if self.supersteps_per_launch is not None:
            if self.backend != "pallas_resident":
                bad("supersteps_per_launch is only meaningful with "
                    "backend='pallas_resident'")
            opts = dict(self.backend_opts)
            opts.setdefault("supersteps_per_launch",
                            self.supersteps_per_launch)
            object.__setattr__(self, "backend_opts",
                               tuple(sorted(opts.items())))
        if self.timeout_s is not None and not self.timeout_s > 0:
            bad(f"timeout_s must be None or > 0, got {self.timeout_s!r}")

        from repro.core.backend import available_backends
        if self.backend not in available_backends():
            bad(f"unknown backend {self.backend!r}; "
                f"available: {', '.join(available_backends())}")
        for kv in self.backend_opts:
            if len(kv) != 2 or not isinstance(kv[0], str):
                bad(f"backend_opts must be (name, value) pairs, got "
                    f"{self.backend_opts!r}")
        if self.var_strategy not in _VAR_STRATEGIES:
            bad(f"var_strategy {self.var_strategy!r} not in "
                f"{_VAR_STRATEGIES}")
        if self.val_strategy not in _VAL_STRATEGIES:
            bad(f"val_strategy {self.val_strategy!r} not in "
                f"{_VAL_STRATEGIES}")
        if self.mesh_shards is not None:
            if not isinstance(self.mesh_shards, int) or self.mesh_shards < 1:
                bad(f"mesh_shards must be None or a positive int, got "
                    f"{self.mesh_shards!r}")
            if self.mesh is not None:
                bad("mesh_shards (the dist_solve engine) and mesh (the "
                    "explicit-mesh path) are mutually exclusive")
        if ((self.mesh is not None or self.mesh_shards is not None)
                and self.backend == "pallas_resident"):
            bad("backend 'pallas_resident' does not support mesh "
                "sharding: the EPS pool cursor is per-device VMEM state "
                "inside the megakernel (use backend='pallas' on meshes)")
        if self.lane_axes and self.mesh is None:
            bad("lane_axes given without a mesh")
        if self.mesh is not None:
            if not self.lane_axes:
                bad("mesh given without lane_axes (which mesh axes shard "
                    "the lanes?)")
            missing = [a for a in self.lane_axes
                       if a not in self.mesh.axis_names]
            if missing:
                bad(f"lane_axes {missing} not in mesh axes "
                    f"{tuple(self.mesh.axis_names)}")

    @classmethod
    def preset(cls, name: str, **overrides) -> "SolveConfig":
        """Build a named preset (``prove`` | ``first_solution`` |
        ``fast``), optionally overriding any field."""
        try:
            base = PRESETS[name]
        except KeyError:
            raise ValueError(
                f"unknown preset {name!r}; available: "
                f"{', '.join(sorted(PRESETS))}") from None
        kw = dict(base)
        kw.update(overrides)
        kw.setdefault("preset_name", name)
        return cls(**kw)

    def replace(self, **overrides) -> "SolveConfig":
        if "preset_name" not in overrides:
            overrides["preset_name"] = None if overrides else self.preset_name
        return dataclasses.replace(self, **overrides)

    def search_options(self) -> S.SearchOptions:
        return S.SearchOptions(
            var_strategy=self.var_strategy, val_strategy=self.val_strategy,
            max_depth=self.max_depth,
            max_fixpoint_iters=self.max_fixpoint_iters,
            stop_on_first=self.stop_on_first, backend=self.backend,
            backend_opts=self.backend_opts)

    def resolved_eps_target(self) -> int:
        return (self.eps_target if self.eps_target is not None
                else 4 * self.n_lanes)

    def compile_key(self) -> tuple:
        """The config half of the session cache key: exactly the fields
        that shape the traced/compiled chunk runner.  Budget fields
        (timeout_s, max_supersteps) and eps_target are host-side only —
        two configs differing only there share one compiled runner."""
        return (self.n_lanes, self.chunk, self.backend, self.backend_opts,
                self.supersteps_per_launch,
                self.var_strategy, self.val_strategy, self.max_depth,
                self.max_fixpoint_iters, self.stop_on_first, self.mesh,
                self.lane_axes, self.mesh_shards)


def shape_signature(cm: CompiledModel) -> tuple:
    """The model half of the session cache key: every static field and
    array shape of the compiled tables that participates in tracing
    (incl. the branch-var count).  Two instances with equal signatures
    (e.g. zoo generator outputs across seeds) reuse one compiled
    runner; the table *contents* are runtime arguments."""
    return (cm.n_vars, cm.n_props, cm.k_terms, cm.d_occ,
            cm.n_alldiff, cm.ad_width, cm.ad_docc,
            cm.n_cumulative, cm.cu_width, cm.cu_docc, cm.horizon,
            cm.ad_layout, cm.ad_packed, cm.cu_layout, cm.cu_packed,
            # §17 extensional bank layout + bitset word count: mixed
            # table/bounds models (and different table geometries) must
            # never collide in the compiled-runner cache
            cm.n_table, cm.ct_arity, cm.ct_words, cm.ct_docc, cm.n_words,
            int(cm.branch_vars.shape[0]), cm.obj_var, cm.dtype)


def _canonical(cm: CompiledModel) -> CompiledModel:
    """Blank the (static) model name so same-shape instances share one
    jit trace — the name is display metadata, never computed on."""
    return cm if cm.name == "" else dataclasses.replace(cm, name="")


def _bucket(n: int) -> int:
    """Pool-size padding bucket: next power of two ≥ n up to 1024, then
    the next multiple of 1024.  Uncapped pow2 growth would let a
    large-instance ``eps_target`` silently allocate a pool of padded
    (explicitly failed, but still swept-over) stores up to ~2× the
    request; the 1024-step cap bounds the overhead to < 1024 lanes while
    keeping the bucket count — and thus the number of cached runner
    traces — small (DESIGN.md §16)."""
    if n <= 1:
        return 1
    if n <= 1024:
        return 1 << (n - 1).bit_length()
    return ((n + 1023) // 1024) * 1024


# --------------------------------------------------------------------------
# The jitted chunk runner (moved here from engine.py; engine re-exports)
# --------------------------------------------------------------------------

def _chunk_body(opts: S.SearchOptions, stop_on_first: bool, axis_names,
                cm: CompiledModel, subs_lb, subs_ub, carry):
    st, gbest, gdone, it, pool_head = carry
    st, new_head = S.lanes_step(cm, subs_lb, subs_ub, opts, st, gbest,
                                pool_head[0])
    pool_head = new_head[None].astype(jnp.int32)
    best = jnp.min(st.best_obj)
    done = jnp.all(st.done)
    any_sol = jnp.any(st.has_sol)
    if axis_names:
        from repro.distributed.collectives import solver_bound_sync
        best, done, any_sol = solver_bound_sync(best, done, any_sol,
                                                axis_names)
    gbest = jnp.minimum(gbest, best)
    # guard the counter on the *incoming* done flag: inside the plain
    # while_loop the body never runs once done (no-op guard), but under
    # solve_many's instance-vmap finished instances keep executing the
    # batched body — their superstep count must freeze
    it = it + jnp.where(gdone, 0, 1).astype(jnp.int32)
    gdone = gdone | done
    if stop_on_first:
        gdone = gdone | any_sol
    return st, gbest, gdone, it, pool_head


def _run_chunk(opts: S.SearchOptions, stop_on_first: bool, chunk: int,
               axis_names, cm: CompiledModel, subs_lb, subs_ub, carry):
    """One scheduler quantum — the unit of jit compilation and of host
    control (timeouts, anytime progress events).

    * unfused backends: a `while_loop` of up to `chunk` supersteps, each
      one `lanes_step` (four XLA dispatches per superstep);
    * ``pallas_resident``: ONE megakernel launch covering K =
      ``supersteps_per_launch`` supersteps (DESIGN.md §13) — `chunk` is
      not consulted; the kernel derives the global-done flag from state
      each fused superstep and runs identity steps once stopped, so the
      launch is idempotent and safe to re-issue (solve_many's vmap
      relies on this to freeze finished instances).
    """
    if opts.backend == "pallas_resident":
        from repro.core.backend import get_backend
        be = get_backend(opts.backend, **dict(opts.backend_opts))
        st, gbest, gdone, it, pool_head = carry
        st, gbest, it, pool_head, stopped = be.superstep_launch(
            cm, subs_lb, subs_ub, st, gbest, it, pool_head, opts=opts)
        return st, gbest, gdone | stopped, it, pool_head

    it0 = carry[3]

    def body(c):
        return _chunk_body(opts, stop_on_first, axis_names, cm,
                           subs_lb, subs_ub, c)

    def cond(c):
        return (~c[2]) & (c[3] - it0 < chunk)

    return lax.while_loop(cond, body, carry)


def _carry_heads(cfg: "SolveConfig", cm: CompiledModel,
                 pool_size: int) -> int:
    """Pool-cursor slots in the carry: one per resident-megakernel grid
    cell (`PallasResidentBackend.n_tiles`, usually 1), one otherwise.
    Mesh configs size per-device heads separately (see solve_iter)."""
    if cfg.backend != "pallas_resident":
        return 1
    from repro.core.backend import get_backend
    be = get_backend(cfg.backend, **dict(cfg.backend_opts))
    return be.n_tiles(cm, cfg.n_lanes, max_depth=cfg.max_depth,
                      pool_size=pool_size)


def _init_carry(cm: CompiledModel, n_lanes: int, opts: S.SearchOptions,
                n_heads: int = 1):
    dt = cm.jdtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)
    state0 = S.init_lanes(cm, n_lanes, opts)
    return (state0, big, jnp.asarray(False), jnp.asarray(0, jnp.int32),
            jnp.zeros((n_heads,), jnp.int32))


# --------------------------------------------------------------------------
# Status derivation — the ONE place a terminal SolveResult is assembled
# (fixes the dead/duplicated logic that lived in engine.solve)
# --------------------------------------------------------------------------

def derive_result(cm: CompiledModel, best_obj, has_sol, best_sol,
                  incomplete, done: bool, n_nodes: int, n_fails: int,
                  n_sols: int, n_sweeps: int, n_supersteps: int,
                  wall_s: float,
                  improvements: Tuple[Improvement, ...] = ()
                  ) -> SolveResult:
    """Derive (status, objective, solution) from terminal lane state.

    ``done`` must mean *search exhausted* — every lane drained the pool
    (``st.done.all()``) — NOT merely "the solve loop stopped": a
    ``stop_on_first`` early-out or a budget/timeout is not an
    exhaustiveness proof and must never yield OPTIMAL/UNSAT.

    * optimization (``cm.obj_var >= 0``): the incumbent lane is
      ``best_obj.argmin()``; OPTIMAL iff the search completed, else SAT;
    * satisfaction: the incumbent lane is ``has_sol.argmax()`` — NOT the
      objective argmin, whose all-big tie would always pick lane 0 and
      read a zeroed ``best_sol`` row — and the status is SAT;
    * no solution anywhere: UNSAT iff complete, else UNKNOWN.
    """
    best_obj = np.asarray(best_obj).reshape(-1)
    has_sol = np.asarray(has_sol).reshape(-1)
    best_sol = np.asarray(best_sol).reshape(-1, cm.n_vars)
    complete = bool(done) and not bool(np.asarray(incomplete).any())

    if has_sol.any():
        if cm.obj_var >= 0:
            i = int(best_obj.argmin())
            obj = int(best_obj[i])
            status = OPTIMAL if complete else SAT
        else:
            i = int(has_sol.argmax())
            obj = None
            status = SAT
        sol = best_sol[i]
    else:
        sol, obj = None, None
        status = UNSAT if complete else UNKNOWN

    return SolveResult(status=status, objective=obj, solution=sol,
                       n_nodes=int(n_nodes), n_fails=int(n_fails),
                       n_sols=int(n_sols), n_sweeps=int(n_sweeps),
                       n_supersteps=int(n_supersteps), wall_s=wall_s,
                       complete=complete,
                       improvements=tuple(improvements))


# --------------------------------------------------------------------------
# Compiled-runner cache
# --------------------------------------------------------------------------

def _aval_key(args) -> tuple:
    leaves, treedef = jax.tree.flatten(args)
    from jax.api_util import shaped_abstractify
    return (treedef, tuple(shaped_abstractify(x) for x in leaves))


class CompiledRunner:
    """One cache slot: a jitted chunk runner plus its AOT-compiled
    executables keyed by argument avals (pool-size buckets land here).

    Compilation is explicit (`fn.lower(...).compile()`) so the session
    can *count* compiles and *time* them — `n_compiles` staying flat
    across a second solve is the warm-path proof the tests assert on.
    """

    def __init__(self, fn, aot: bool = True):
        self.fn = fn
        self.aot = aot
        self._execs: Dict[tuple, Any] = {}
        self.n_compiles = 0
        self.n_calls = 0
        self.compile_s = 0.0

    def __call__(self, *args):
        self.n_calls += 1
        if not self.aot:   # mesh path: plain jit (AOT + shard_map varies
            return self.fn(*args)   # across jax versions; counters track
                                    # builds only)
        key = _aval_key(args)
        exe = self._execs.get(key)
        if exe is None:
            t0 = time.time()
            exe = self.fn.lower(*args).compile()
            self.compile_s += time.time() - t0
            self.n_compiles += 1
            self._execs[key] = exe
        return exe(*args)


class Solver:
    """A solving session: one `SolveConfig` (overridable per call) plus a
    compiled-runner cache keyed by ``(shape_signature(cm),
    config.compile_key(), batched?)``.

    Construct once, solve many::

        solver = Solver(SolveConfig.preset("prove", backend="pallas"))
        res = solver.solve(cm)              # cold: lower + compile
        res2 = solver.solve(cm2)            # warm: same shapes, no compile
        many = solver.solve_many(cms)       # one batched device dispatch
        for ev in solver.solve_iter(cm):    # anytime incumbent stream
            ...
    """

    def __init__(self, config: Optional[SolveConfig] = None, **overrides):
        base = config if config is not None else SolveConfig.preset("prove")
        self.config = base.replace(**overrides) if overrides else base
        self._runners: Dict[tuple, CompiledRunner] = {}
        self.stats: Dict[str, Any] = {
            "solves": 0, "runner_builds": 0, "runner_hits": 0,
            "last_solve_cold": None,
        }

    # -- cache ------------------------------------------------------------

    def _config_for(self, config: Optional[SolveConfig],
                    overrides: dict) -> SolveConfig:
        cfg = config if config is not None else self.config
        return cfg.replace(**overrides) if overrides else cfg

    def _runner_for(self, cm: CompiledModel, cfg: SolveConfig,
                    batched: bool) -> CompiledRunner:
        key = (shape_signature(cm), cfg.compile_key(), batched)
        runner = self._runners.get(key)
        if runner is not None:
            self.stats["runner_hits"] += 1
            return runner
        opts = cfg.search_options()
        if cfg.mesh is not None:
            axes = cfg.lane_axes
            dev_fn = partial(_run_chunk, opts, cfg.stop_on_first, cfg.chunk,
                             axes)
            spec = P(axes)
            state0 = S.init_lanes(cm, cfg.n_lanes * self._n_dev(cfg), opts)
            state_spec = jax.tree.map(lambda _: spec, state0)
            carry_spec = (state_spec, P(), P(), P(), spec)
            cm_spec = jax.tree.map(lambda _: P(), cm)
            from repro.compat import shard_map
            fn = jax.jit(shard_map(
                dev_fn, mesh=cfg.mesh,
                in_specs=(cm_spec, spec, spec, carry_spec),
                out_specs=carry_spec, check_vma=False))
            runner = CompiledRunner(fn, aot=False)
        else:
            fn = partial(_run_chunk, opts, cfg.stop_on_first, cfg.chunk, ())
            if batched:
                fn = jax.vmap(fn)
            runner = CompiledRunner(jax.jit(fn), aot=True)
        self._runners[key] = runner
        self.stats["runner_builds"] += 1
        return runner

    @staticmethod
    def _n_dev(cfg: SolveConfig) -> int:
        return int(np.prod([cfg.mesh.shape[a] for a in cfg.lane_axes]))

    def session_stats(self) -> Dict[str, Any]:
        """Aggregate cache/compile counters across all cached runners."""
        out = dict(self.stats)
        out["n_runners"] = len(self._runners)
        out["n_compiles"] = sum(r.n_compiles for r in self._runners.values())
        out["compile_s"] = sum(r.compile_s for r in self._runners.values())
        return out

    def clear_cache(self) -> None:
        """Drop every cached runner and compiled executable.  The cache
        is otherwise unbounded (one executable per shape-signature ×
        compile-key × pool-bucket) — long-lived serving processes that
        churn through many distinct model shapes should evict
        periodically; counters are kept."""
        self._runners.clear()

    # -- pool preparation -------------------------------------------------

    def _pool_for(self, cm: CompiledModel, cfg: SolveConfig,
                  subs: Optional[tuple], opts: S.SearchOptions):
        if subs is None:
            subs_lb, subs_ub = eps.decompose(cm, cfg.resolved_eps_target(),
                                             opts)
        else:
            subs_lb, subs_ub = subs
        subs_lb, subs_ub = np.asarray(subs_lb), np.asarray(subs_ub)
        size = subs_lb.shape[0]
        if cfg.pad_pool:
            size = _bucket(size)
        if cfg.mesh is not None:
            n_dev = self._n_dev(cfg)
            size = size + (-size) % n_dev
        subs_lb, subs_ub = eps.pad_pool(subs_lb, subs_ub, size)
        return jnp.asarray(subs_lb), jnp.asarray(subs_ub)

    # -- solve / solve_iter ----------------------------------------------

    def solve(self, cm: CompiledModel, *, subs: Optional[tuple] = None,
              config: Optional[SolveConfig] = None,
              **overrides) -> SolveResult:
        """Blocking solve; equals the last `solve_iter` event's result."""
        res = None
        for ev in self.solve_iter(cm, subs=subs, config=config, **overrides):
            if ev.final:
                res = ev.result
        return res

    def solve_iter(self, cm: CompiledModel, *,
                   subs: Optional[tuple] = None,
                   config: Optional[SolveConfig] = None,
                   **overrides) -> Iterator[Progress]:
        """Anytime solve: yields a `Progress` event after every
        scheduler quantum (host chunk; one K-superstep megakernel launch
        under ``backend="pallas_resident"``); the final event
        (``final=True``) carries the `SolveResult` (with its
        `improvements` trace)."""
        cfg = self._config_for(config, overrides)
        if cfg.mesh_shards is not None:
            from repro.core import dist_solve
            self.stats["solves"] += 1
            yield from dist_solve.solve_iter_dist(self, _canonical(cm), cfg,
                                                  subs=subs)
            return
        opts = cfg.search_options()
        t0 = time.time()
        self.stats["solves"] += 1
        cm = _canonical(cm)
        subs_lb, subs_ub = self._pool_for(cm, cfg, subs, opts)

        builds0 = self.stats["runner_builds"]
        runner = self._runner_for(cm, cfg, batched=False)
        if cfg.mesh is not None:
            n_dev = self._n_dev(cfg)
            carry = _init_carry(cm, cfg.n_lanes * n_dev, opts,
                                n_heads=n_dev)
        else:
            carry = _init_carry(
                cm, cfg.n_lanes, opts,
                n_heads=_carry_heads(cfg, cm, int(subs_lb.shape[0])))
        compiles0 = runner.n_compiles
        self.stats["last_solve_cold"] = None  # set after first chunk

        improvements: List[Improvement] = []
        dt = cm.jdtype
        big = int(np.iinfo(dt).max // 4)
        best_seen = big
        while True:
            carry = jax.block_until_ready(runner(cm, subs_lb, subs_ub,
                                                 carry))
            if self.stats["last_solve_cold"] is None:
                self.stats["last_solve_cold"] = (
                    runner.n_compiles > compiles0
                    or self.stats["runner_builds"] > builds0)
            st, gbest, gdone, it, _ = carry
            wall = time.time() - t0
            superstep = int(np.asarray(it).max())
            n_nodes = int(np.asarray(st.n_nodes).sum())
            n_sols = int(np.asarray(st.n_sols).sum())
            has = bool(np.asarray(st.has_sol).any())
            obj = None
            incumbent = None
            if cm.obj_var >= 0 and has:
                flat = np.asarray(st.best_obj).reshape(-1)
                i = int(flat.argmin())
                obj = int(flat[i])
                if obj < best_seen:
                    best_seen = obj
                    improvements.append(Improvement(superstep, wall, obj))
                    incumbent = np.asarray(st.best_sol).reshape(
                        -1, cm.n_vars)[i]
            stop = bool(np.asarray(gdone).all())
            if cfg.timeout_s is not None and wall > cfg.timeout_s:
                stop = True
            if (cfg.max_supersteps is not None
                    and superstep >= cfg.max_supersteps):
                stop = True
            if not stop:
                yield Progress(superstep=superstep, best_objective=obj,
                               has_solution=has, incumbent=incumbent,
                               n_nodes=n_nodes, n_sols=n_sols, wall_s=wall,
                               t_host=t0 + wall)
                continue
            totals = S.lane_totals(st)
            # exhaustion, not gdone: a stop_on_first early-out sets gdone
            # without draining the pool and must not claim OPTIMAL/UNSAT
            exhausted = bool(np.asarray(st.done).all())
            res = derive_result(
                cm, st.best_obj, st.has_sol, st.best_sol, st.incomplete,
                exhausted, totals["n_nodes"],
                totals["n_fails"], totals["n_sols"], totals["n_sweeps"],
                superstep, time.time() - t0, tuple(improvements))
            yield Progress(superstep=superstep, best_objective=res.objective,
                           has_solution=has, incumbent=res.solution,
                           n_nodes=res.n_nodes, n_sols=res.n_sols,
                           wall_s=res.wall_s, final=True, result=res,
                           t_host=t0 + res.wall_s)
            return

    # -- solve_many -------------------------------------------------------

    def solve_many(self, cms: Sequence[CompiledModel], *,
                   config: Optional[SolveConfig] = None,
                   **overrides) -> List[SolveResult]:
        """Solve N same-shape instances in ONE batched device dispatch.

        Instances become a vmapped leading axis over the whole chunk
        runner: each gets its own ``n_lanes`` lane block, its own EPS
        pool (pools are padded to a common bucket with explicitly-failed
        stores and stacked ``[N, S, V]``), its own B&B bound and its own
        done flag — so statuses/objectives are identical to N sequential
        `solve` calls, while compilation, dispatch overhead and device
        occupancy are shared.  Single-device only (use the mesh engine
        for scale-out of ONE instance).

        Returns one `SolveResult` per instance, in input order.
        ``wall_s`` is the shared batch wall clock.

        Implemented as the degenerate case of the lane-owning `LaneBatch`
        scheduler core (DESIGN.md §15): splice every instance into a
        width-N batch up front, step until all slots are done, retire
        each slot.  The serving scheduler (`repro.serve`) drives the same
        class with continuous admission instead.
        """
        cms = list(cms)
        if not cms:
            return []
        cfg = self._config_for(config, overrides)
        if cfg.mesh is not None or cfg.mesh_shards is not None:
            raise ValueError("solve_many is single-device; it cannot be "
                             "combined with a mesh config")
        opts = cfg.search_options()
        t0 = time.time()
        self.stats["solves"] += 1
        cms = [_canonical(cm) for cm in cms]
        sig = shape_signature(cms[0])
        for k, cm in enumerate(cms[1:], 1):
            if shape_signature(cm) != sig:
                raise ValueError(
                    f"solve_many needs same-shape instances: instance {k} "
                    f"has signature {shape_signature(cm)} != {sig}")
        N = len(cms)

        pools = [eps.decompose(cm, cfg.resolved_eps_target(), opts)
                 for cm in cms]
        smax = max(p[0].shape[0] for p in pools)
        size = _bucket(smax) if cfg.pad_pool else smax

        builds_before = self.stats["runner_builds"]
        batch = LaneBatch(self, cms[0], cfg, width=N, pool_size=size)
        compiles0 = batch.runner.n_compiles
        for i, (cm, (pl, pu)) in enumerate(zip(cms, pools)):
            batch.splice(i, cm, pl, pu, request_id=i)
        while True:
            snap = batch.step()
            wall = time.time() - t0
            if snap.gdone.all():
                break
            if cfg.timeout_s is not None and wall > cfg.timeout_s:
                break
            if (cfg.max_supersteps is not None
                    and int(snap.superstep.max()) >= cfg.max_supersteps):
                break
        self.stats["last_solve_cold"] = (
            batch.runner.n_compiles > compiles0
            or self.stats["runner_builds"] > builds_before)

        wall = time.time() - t0
        return [batch.retire(i, wall_s=wall) for i in range(N)]

    # -- lane_batch: the continuous-batching scheduler core ---------------

    def lane_batch(self, cm: CompiledModel, *, width: int,
                   pool_size: Optional[int] = None,
                   config: Optional[SolveConfig] = None,
                   **overrides) -> "LaneBatch":
        """A `LaneBatch` of ``width`` slots shaped for instances
        signature-equal to ``cm`` — the lane-owning scheduler core the
        serving layer (`repro.serve`, DESIGN.md §15) admits requests
        into.  ``pool_size`` defaults to the pow2 bucket of the config's
        EPS target, the fixed upper bound on any `eps.decompose` pool
        for that target — so every admitted request's pool fits and the
        bucket compiles at most once."""
        cfg = self._config_for(config, overrides)
        if pool_size is None:
            tgt = cfg.resolved_eps_target()
            pool_size = _bucket(tgt) if cfg.pad_pool else tgt
        return LaneBatch(self, cm, cfg, width=width, pool_size=pool_size)


# --------------------------------------------------------------------------
# LaneBatch: the lane-owning continuous-batching core (DESIGN.md §15)
# --------------------------------------------------------------------------

_IDLE = object()          # slot-empty sentinel (request ids may be None)


class BatchSnapshot(NamedTuple):
    """Host-visible per-slot view of a `LaneBatch` after one quantum."""
    superstep: np.ndarray    # i32[B] per-slot cumulative superstep counters
    gdone: np.ndarray        # bool[B] per-slot global-done flags
    best_obj: np.ndarray     # [B] per-slot incumbent bound (min over lanes)
    has_sol: np.ndarray      # bool[B]
    n_nodes: np.ndarray      # i[B] per-slot node totals
    n_sols: np.ndarray       # i[B]
    t_host: float            # host wall clock (time.time()) at snapshot


class LaneBatch:
    """A fixed-width batch of same-shape instance *slots* driven through
    ONE vmapped chunk runner — the lane-owning scheduler core that
    `_run_chunk`'s host loop became (DESIGN.md §15).

    Each slot owns an ``n_lanes`` lane block, its own EPS pool rows
    (``[pool_size, V]``), its own B&B bound and its own done flag; the
    slot's ``request_id`` is what threads lane ownership back to a
    serving request.  Slots **join** (`splice`) and **leave** (`retire`)
    at chunk boundaries at *fixed compiled shape*: width ``B`` and pool
    bucket ``pool_size`` never change after construction, so admission
    and retirement never recompile — the vLLM-style continuous-batching
    property the serving scheduler (`repro.serve`) relies on.

    An idle slot is frozen: its ``gdone`` is True (the vmapped
    `while_loop` counter stops), its lanes are all ``done`` (every
    superstep is an idempotent no-op) and its pool rows are explicitly
    failed stores (`eps.failed_pool`), so idle slots cannot explore
    phantom subproblems.  `Solver.solve_many` is the degenerate
    splice-all-then-drain use of this class.  Single-device only.
    """

    def __init__(self, session: Solver, cm0: CompiledModel,
                 cfg: SolveConfig, *, width: int, pool_size: int):
        if cfg.mesh is not None or cfg.mesh_shards is not None:
            raise ValueError("LaneBatch (and solve_many on top of it) is "
                             "single-device; it cannot be combined with a "
                             "mesh config")
        if width < 1 or pool_size < 1:
            raise ValueError(f"LaneBatch needs width >= 1 and pool_size >= "
                             f"1, got {width}, {pool_size}")
        self.session = session
        self.cfg = cfg
        self.width = int(width)
        self.pool_size = int(pool_size)
        self.opts = cfg.search_options()
        cm0 = _canonical(cm0)
        self.signature = shape_signature(cm0)
        self._obj_var, self._n_vars = cm0.obj_var, cm0.n_vars
        self.runner = session._runner_for(cm0, cfg, batched=True)
        # the live-slot template: what a spliced slot's carry is reset to
        self._carry1 = _init_carry(cm0, cfg.n_lanes, self.opts,
                                   n_heads=_carry_heads(cfg, cm0, pool_size))
        # idle pool rows: explicitly-failed stores (inert by construction)
        il, iu = eps.failed_pool(np.asarray(cm0.lb0), np.asarray(cm0.ub0),
                                 pool_size)
        self._idle_lb, self._idle_ub = jnp.asarray(il), jnp.asarray(iu)
        B = self.width
        self.cm_b = jax.tree.map(lambda x: jnp.stack([x] * B), cm0)
        self.subs_lb = jnp.stack([self._idle_lb] * B)
        self.subs_ub = jnp.stack([self._idle_ub] * B)
        carry = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (B,) + x.shape),
            self._carry1)
        st, gbest, gdone, it, heads = carry
        st = st._replace(done=jnp.ones_like(st.done),
                         fresh=jnp.zeros_like(st.fresh))
        self.carry = (st, gbest, jnp.ones_like(gdone), it, heads)
        self.request_ids: List[Any] = [_IDLE] * B
        self._cms: List[Optional[CompiledModel]] = [None] * B
        self._host_st = None
        self.n_spliced = 0
        self.n_retired = 0

    # -- occupancy ---------------------------------------------------------

    def idle_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_ids) if r is _IDLE]

    def live_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_ids) if r is not _IDLE]

    @property
    def occupancy(self) -> int:
        return self.width - len(self.idle_slots())

    @property
    def obj_var(self) -> int:
        """The bucket's objective column (static across the batch;
        ``< 0`` for satisfaction models)."""
        return self._obj_var

    def request_id(self, i: int):
        rid = self.request_ids[i]
        return None if rid is _IDLE else rid

    # -- join / leave at chunk boundaries ----------------------------------

    def splice(self, i: int, cm: CompiledModel, subs_lb, subs_ub, *,
               request_id=None) -> None:
        """Admit an instance into idle slot ``i`` at fixed shape: its
        tables overwrite the slot's rows of the stacked model pytree, its
        pool is padded to the bucket (`eps.fit_pool`) and its carry slice
        is reset to a fresh live state.  Takes effect at the next
        `step` — the chunk boundary."""
        if self.request_ids[i] is not _IDLE:
            raise ValueError(f"slot {i} is occupied by request "
                             f"{self.request_ids[i]!r}")
        cm = _canonical(cm)
        if shape_signature(cm) != self.signature:
            raise ValueError(
                f"instance signature {shape_signature(cm)} does not match "
                f"this batch's bucket {self.signature}")
        lb, ub = eps.fit_pool(np.asarray(subs_lb), np.asarray(subs_ub),
                              self.pool_size)
        self.cm_b = jax.tree.map(lambda full, one: full.at[i].set(one),
                                 self.cm_b, cm)
        self.subs_lb = self.subs_lb.at[i].set(jnp.asarray(lb))
        self.subs_ub = self.subs_ub.at[i].set(jnp.asarray(ub))
        self.carry = jax.tree.map(lambda full, one: full.at[i].set(one),
                                  self.carry, self._carry1)
        self.request_ids[i] = request_id
        self._cms[i] = cm
        self._host_st = None
        self.n_spliced += 1

    def retire(self, i: int, *, wall_s: float,
               improvements: Tuple[Improvement, ...] = ()) -> SolveResult:
        """Retire slot ``i``: derive its per-request `SolveResult` from
        the slot's lane-state slice (per-slot exhaustion, per-slot
        superstep counter), then freeze the slot idle.  Valid whether
        the slot finished (``gdone``) or is being evicted early (a
        deadline miss) — eviction derives from the live state *before*
        freezing, so an incomplete search never claims OPTIMAL/UNSAT."""
        if self.request_ids[i] is _IDLE:
            raise ValueError(f"slot {i} is idle")
        st = self._host_state()
        sti = jax.tree.map(lambda x: x[i], st)
        totals = S.lane_totals(sti)
        exhausted = bool(np.asarray(sti.done).all())
        superstep = int(np.asarray(self.carry[3])[i])
        res = derive_result(
            self._cms[i], sti.best_obj, sti.has_sol, sti.best_sol,
            sti.incomplete, exhausted, totals["n_nodes"],
            totals["n_fails"], totals["n_sols"], totals["n_sweeps"],
            superstep, wall_s, tuple(improvements))
        self._freeze(i)
        self.request_ids[i] = _IDLE
        self._cms[i] = None
        self.n_retired += 1
        return res

    def _freeze(self, i: int) -> None:
        """Park slot ``i``: gdone, all lanes done, all-failed pool —
        every subsequent superstep on the slot is an idempotent no-op."""
        st, gbest, gdone, it, heads = self.carry
        st = st._replace(done=st.done.at[i].set(True),
                         fresh=st.fresh.at[i].set(False))
        self.carry = (st, gbest, gdone.at[i].set(True), it, heads)
        self.subs_lb = self.subs_lb.at[i].set(self._idle_lb)
        self.subs_ub = self.subs_ub.at[i].set(self._idle_ub)
        self._host_st = None

    # -- stepping ----------------------------------------------------------

    def step(self) -> BatchSnapshot:
        """Run ONE scheduler quantum (up to ``cfg.chunk`` supersteps per
        live slot; one K-superstep launch under ``pallas_resident``) over
        the whole batch and return the host-visible snapshot."""
        self.carry = jax.block_until_ready(
            self.runner(self.cm_b, self.subs_lb, self.subs_ub, self.carry))
        self._host_st = None
        return self.snapshot()

    def snapshot(self) -> BatchSnapshot:
        st, _, gdone, it, _ = self.carry
        return BatchSnapshot(
            superstep=np.asarray(it),
            gdone=np.asarray(gdone),
            best_obj=np.asarray(st.best_obj).min(axis=1),
            has_sol=np.asarray(st.has_sol).any(axis=1),
            n_nodes=np.asarray(st.n_nodes).sum(axis=1),
            n_sols=np.asarray(st.n_sols).sum(axis=1),
            t_host=time.time())

    def _host_state(self):
        if self._host_st is None:       # one transfer, reused per quantum
            self._host_st = jax.device_get(self.carry[0])
        return self._host_st

    def incumbent(self, i: int):
        """Slot ``i``'s current best ``(objective, solution)`` —
        ``(None, None)`` while no solution exists; objective is None for
        satisfaction models.  Same lane pick as `derive_result`."""
        st = self._host_state()
        has = np.asarray(st.has_sol[i]).reshape(-1)
        if not has.any():
            return None, None
        sols = np.asarray(st.best_sol[i]).reshape(-1, self._n_vars)
        if self._obj_var >= 0:
            objs = np.asarray(st.best_obj[i]).reshape(-1)
            k = int(objs.argmin())
            return int(objs[k]), sols[k]
        return None, sols[int(has.argmax())]


# --------------------------------------------------------------------------
# Module-level convenience: one shared default session
# --------------------------------------------------------------------------

_default_solver: Optional[Solver] = None


def default_solver() -> Solver:
    """The process-wide session used by `repro.solver.solve` and the
    `engine.solve` deprecation shim — so even legacy callers get
    compile caching across calls."""
    global _default_solver
    if _default_solver is None:
        _default_solver = Solver(SolveConfig())
    return _default_solver


def solve(cm: CompiledModel, *, subs=None, config=None,
          **overrides) -> SolveResult:
    return default_solver().solve(cm, subs=subs, config=config, **overrides)


def solve_many(cms: Sequence[CompiledModel], *, config=None,
               **overrides) -> List[SolveResult]:
    return default_solver().solve_many(cms, config=config, **overrides)


def solve_iter(cm: CompiledModel, *, subs=None, config=None,
               **overrides) -> Iterator[Progress]:
    return default_solver().solve_iter(cm, subs=subs, config=config,
                                       **overrides)
