"""Session-oriented public solver API (DESIGN.md §11) — ``repro.solver``.

The paper's TURBO solves one instance per launch; the ROADMAP north-star
is a serving system, which needs three things a blocking ten-kwarg
``engine.solve`` cannot give:

* **amortized compilation** — `Solver` is a session owning a
  compiled-runner cache keyed by ``(model shape signature, config)``, so
  repeated ``solver.solve(cm)`` calls on same-shape instances skip
  jit/lowering entirely (the warm path);
* **batched dispatch** — ``solver.solve_many([cm...])`` stacks N
  same-shape instances into ONE device dispatch (instances are a vmapped
  leading axis over the whole chunk runner: per-instance lane blocks,
  per-instance EPS pools, per-instance B&B bounds), the throughput
  scenario (instances/s);
* **anytime answers** — ``solver.solve_iter(cm)`` is a generator
  yielding `Progress` events after every host chunk (superstep, best
  bound, incumbent, node counters), so a timeout degrades to the best
  incumbent instead of nothing; `SolveResult.improvements` records the
  bound trace.

Configuration is one frozen `SolveConfig` dataclass with named presets
(``prove`` — the default full B&B proof profile, ``first_solution`` —
stop at the first solution, ``fast`` — capped fixpoint sweeps, §Perf
P0/H1), replacing the flag recipes previously duplicated across
`launch/solve.py`, `benchmarks/bench_solver.py` and the tests.

`engine.solve` remains as a thin deprecation shim over this module.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import (Any, Dict, Iterator, List, NamedTuple, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.compile import CompiledModel
from repro.core import eps
from repro.core import search as S

# terminal statuses (re-exported by repro.core.engine for back-compat)
OPTIMAL = "OPTIMAL"
SAT = "SAT"
UNSAT = "UNSAT"
UNKNOWN = "UNKNOWN"


class Improvement(NamedTuple):
    """One incumbent improvement in a solve's anytime trace."""
    superstep: int
    wall_s: float
    objective: int


@dataclasses.dataclass
class SolveResult:
    status: str
    objective: Optional[int]
    solution: Optional[np.ndarray]
    n_nodes: int
    n_fails: int
    n_sols: int
    n_sweeps: int
    n_supersteps: int
    wall_s: float
    complete: bool
    # anytime trace: every (superstep, wall_s, objective) at which the
    # global incumbent improved, observed at scheduler-quantum
    # granularity (DESIGN.md §11): per host chunk for unfused backends,
    # per K-superstep launch for pallas_resident — improvements landing
    # within one quantum collapse into a single trace entry whose
    # `superstep` is the quantum's end.
    improvements: Tuple[Improvement, ...] = ()

    @property
    def nodes_per_sec(self) -> float:
        return self.n_nodes / max(self.wall_s, 1e-9)


@dataclasses.dataclass
class Progress:
    """One anytime event from `Solver.solve_iter`, emitted per scheduler
    quantum — i.e. once per `_run_chunk` return to the host: every
    ``chunk`` supersteps for the unfused backends, every
    ``supersteps_per_launch`` (K) supersteps for ``pallas_resident``
    (whose megakernel only re-enters the host per launch, DESIGN.md
    §13).  Anytime consumers should key off ``superstep``/``wall_s``,
    not event counts.

    The last event has ``final=True`` and carries the terminal
    `SolveResult` in ``result``; earlier events report the running
    incumbent (``best_objective`` is None for satisfaction models or
    while no solution exists yet).
    """
    superstep: int
    best_objective: Optional[int]
    has_solution: bool
    incumbent: Optional[np.ndarray]
    n_nodes: int
    n_sols: int
    wall_s: float
    final: bool = False
    result: Optional[SolveResult] = None


# --------------------------------------------------------------------------
# SolveConfig: one frozen config object + named presets
# --------------------------------------------------------------------------

_VAR_STRATEGIES = (S.INPUT_ORDER, S.MIN_DOM, S.MIN_LB)
_VAL_STRATEGIES = (S.VAL_MIN, S.VAL_SPLIT)

# named flag recipes (DESIGN.md §11). `prove` is the proof profile used
# by every benchmark table; `fast` is the §Perf P0/H1 capped-sweep
# profile (identical optima, bounded chaotic iteration); `first_solution`
# is the satisfaction/anytime profile.
PRESETS: Dict[str, Dict[str, Any]] = {
    "prove": dict(var_strategy=S.MIN_LB, max_depth=1024),
    "first_solution": dict(var_strategy=S.MIN_LB, max_depth=1024,
                           stop_on_first=True),
    "fast": dict(var_strategy=S.MIN_LB, max_depth=1024,
                 max_fixpoint_iters=4),
}


@dataclasses.dataclass(frozen=True)
class SolveConfig:
    """Everything `Solver` needs besides the model itself.

    Consolidates the former ``engine.solve`` kwarg sprawl; validated on
    construction, hashable (it is half of the session cache key), and
    buildable from a named preset: ``SolveConfig.preset("fast",
    backend="pallas", n_lanes=128)``.
    """

    # lanes / EPS decomposition (DESIGN.md §9)
    n_lanes: int = 64
    eps_target: Optional[int] = None          # None → 4 * n_lanes
    # host chunking / budgets
    chunk: int = 256
    timeout_s: Optional[float] = None
    max_supersteps: Optional[int] = None
    # propagation backend (core/backend.py)
    backend: str = "gather"
    backend_opts: Tuple[Tuple[str, Any], ...] = ()
    # pallas_resident only: supersteps fused per megakernel launch (K in
    # DESIGN.md §13); merged into backend_opts, so it is part of the
    # compile key.  None → the backend default (16).
    supersteps_per_launch: Optional[int] = None
    # search strategy (core/search.py)
    var_strategy: str = S.INPUT_ORDER
    val_strategy: str = S.VAL_MIN
    max_depth: int = 2048
    max_fixpoint_iters: Optional[int] = None
    stop_on_first: bool = False
    # multi-device engine (explicit-mesh legacy path)
    mesh: Optional[jax.sharding.Mesh] = None
    lane_axes: Tuple[str, ...] = ()
    # distributed EPS engine (core/dist_solve.py, DESIGN.md §14): shard
    # the lane pool over a 1-D `solve` mesh of this many devices, with
    # per-superstep bound all-reduce, chunk-granularity work stealing
    # (`steal`) and elastic device-loss recovery.  None → single-device;
    # the CLI spelling is `launch/solve.py --mesh N`.
    mesh_shards: Optional[int] = None
    steal: bool = True
    # pad EPS pools to the next power of two with explicitly-failed
    # stores so the compiled runner re-lowers per size *bucket*, not per
    # exact pool size (DESIGN.md §11 cache-key discussion)
    pad_pool: bool = True
    # provenance tag only — excluded from equality/hash so a preset and
    # its hand-rolled equivalent share one cache entry
    preset_name: Optional[str] = dataclasses.field(default=None,
                                                   compare=False)

    def __post_init__(self):
        def bad(msg):
            raise ValueError(f"SolveConfig: {msg}")

        if isinstance(self.backend_opts, dict):
            object.__setattr__(self, "backend_opts",
                               tuple(sorted(self.backend_opts.items())))
        else:
            object.__setattr__(self, "backend_opts",
                               tuple(tuple(kv) for kv in self.backend_opts))
        object.__setattr__(self, "lane_axes", tuple(self.lane_axes))

        for name in ("n_lanes", "chunk", "max_depth"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                bad(f"{name} must be a positive int, got {v!r}")
        for name in ("eps_target", "max_supersteps", "max_fixpoint_iters",
                     "supersteps_per_launch"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v < 1):
                bad(f"{name} must be None or a positive int, got {v!r}")
        if self.supersteps_per_launch is not None:
            if self.backend != "pallas_resident":
                bad("supersteps_per_launch is only meaningful with "
                    "backend='pallas_resident'")
            opts = dict(self.backend_opts)
            opts.setdefault("supersteps_per_launch",
                            self.supersteps_per_launch)
            object.__setattr__(self, "backend_opts",
                               tuple(sorted(opts.items())))
        if self.timeout_s is not None and not self.timeout_s > 0:
            bad(f"timeout_s must be None or > 0, got {self.timeout_s!r}")

        from repro.core.backend import available_backends
        if self.backend not in available_backends():
            bad(f"unknown backend {self.backend!r}; "
                f"available: {', '.join(available_backends())}")
        for kv in self.backend_opts:
            if len(kv) != 2 or not isinstance(kv[0], str):
                bad(f"backend_opts must be (name, value) pairs, got "
                    f"{self.backend_opts!r}")
        if self.var_strategy not in _VAR_STRATEGIES:
            bad(f"var_strategy {self.var_strategy!r} not in "
                f"{_VAR_STRATEGIES}")
        if self.val_strategy not in _VAL_STRATEGIES:
            bad(f"val_strategy {self.val_strategy!r} not in "
                f"{_VAL_STRATEGIES}")
        if self.mesh_shards is not None:
            if not isinstance(self.mesh_shards, int) or self.mesh_shards < 1:
                bad(f"mesh_shards must be None or a positive int, got "
                    f"{self.mesh_shards!r}")
            if self.mesh is not None:
                bad("mesh_shards (the dist_solve engine) and mesh (the "
                    "explicit-mesh path) are mutually exclusive")
        if ((self.mesh is not None or self.mesh_shards is not None)
                and self.backend == "pallas_resident"):
            bad("backend 'pallas_resident' does not support mesh "
                "sharding: the EPS pool cursor is per-device VMEM state "
                "inside the megakernel (use backend='pallas' on meshes)")
        if self.lane_axes and self.mesh is None:
            bad("lane_axes given without a mesh")
        if self.mesh is not None:
            if not self.lane_axes:
                bad("mesh given without lane_axes (which mesh axes shard "
                    "the lanes?)")
            missing = [a for a in self.lane_axes
                       if a not in self.mesh.axis_names]
            if missing:
                bad(f"lane_axes {missing} not in mesh axes "
                    f"{tuple(self.mesh.axis_names)}")

    @classmethod
    def preset(cls, name: str, **overrides) -> "SolveConfig":
        """Build a named preset (``prove`` | ``first_solution`` |
        ``fast``), optionally overriding any field."""
        try:
            base = PRESETS[name]
        except KeyError:
            raise ValueError(
                f"unknown preset {name!r}; available: "
                f"{', '.join(sorted(PRESETS))}") from None
        kw = dict(base)
        kw.update(overrides)
        kw.setdefault("preset_name", name)
        return cls(**kw)

    def replace(self, **overrides) -> "SolveConfig":
        if "preset_name" not in overrides:
            overrides["preset_name"] = None if overrides else self.preset_name
        return dataclasses.replace(self, **overrides)

    def search_options(self) -> S.SearchOptions:
        return S.SearchOptions(
            var_strategy=self.var_strategy, val_strategy=self.val_strategy,
            max_depth=self.max_depth,
            max_fixpoint_iters=self.max_fixpoint_iters,
            stop_on_first=self.stop_on_first, backend=self.backend,
            backend_opts=self.backend_opts)

    def resolved_eps_target(self) -> int:
        return (self.eps_target if self.eps_target is not None
                else 4 * self.n_lanes)

    def compile_key(self) -> tuple:
        """The config half of the session cache key: exactly the fields
        that shape the traced/compiled chunk runner.  Budget fields
        (timeout_s, max_supersteps) and eps_target are host-side only —
        two configs differing only there share one compiled runner."""
        return (self.n_lanes, self.chunk, self.backend, self.backend_opts,
                self.supersteps_per_launch,
                self.var_strategy, self.val_strategy, self.max_depth,
                self.max_fixpoint_iters, self.stop_on_first, self.mesh,
                self.lane_axes, self.mesh_shards)


def shape_signature(cm: CompiledModel) -> tuple:
    """The model half of the session cache key: every static field and
    array shape of the compiled tables that participates in tracing
    (incl. the branch-var count).  Two instances with equal signatures
    (e.g. zoo generator outputs across seeds) reuse one compiled
    runner; the table *contents* are runtime arguments."""
    return (cm.n_vars, cm.n_props, cm.k_terms, cm.d_occ,
            cm.n_alldiff, cm.ad_width, cm.ad_docc,
            cm.n_cumulative, cm.cu_width, cm.cu_docc, cm.horizon,
            int(cm.branch_vars.shape[0]), cm.obj_var, cm.dtype)


def _canonical(cm: CompiledModel) -> CompiledModel:
    """Blank the (static) model name so same-shape instances share one
    jit trace — the name is display metadata, never computed on."""
    return cm if cm.name == "" else dataclasses.replace(cm, name="")


def _bucket(n: int) -> int:
    """Next power of two ≥ n — the pool-size padding bucket."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


# --------------------------------------------------------------------------
# The jitted chunk runner (moved here from engine.py; engine re-exports)
# --------------------------------------------------------------------------

def _chunk_body(opts: S.SearchOptions, stop_on_first: bool, axis_names,
                cm: CompiledModel, subs_lb, subs_ub, carry):
    st, gbest, gdone, it, pool_head = carry
    st, new_head = S.lanes_step(cm, subs_lb, subs_ub, opts, st, gbest,
                                pool_head[0])
    pool_head = new_head[None].astype(jnp.int32)
    best = jnp.min(st.best_obj)
    done = jnp.all(st.done)
    any_sol = jnp.any(st.has_sol)
    if axis_names:
        from repro.distributed.collectives import solver_bound_sync
        best, done, any_sol = solver_bound_sync(best, done, any_sol,
                                                axis_names)
    gbest = jnp.minimum(gbest, best)
    # guard the counter on the *incoming* done flag: inside the plain
    # while_loop the body never runs once done (no-op guard), but under
    # solve_many's instance-vmap finished instances keep executing the
    # batched body — their superstep count must freeze
    it = it + jnp.where(gdone, 0, 1).astype(jnp.int32)
    gdone = gdone | done
    if stop_on_first:
        gdone = gdone | any_sol
    return st, gbest, gdone, it, pool_head


def _run_chunk(opts: S.SearchOptions, stop_on_first: bool, chunk: int,
               axis_names, cm: CompiledModel, subs_lb, subs_ub, carry):
    """One scheduler quantum — the unit of jit compilation and of host
    control (timeouts, anytime progress events).

    * unfused backends: a `while_loop` of up to `chunk` supersteps, each
      one `lanes_step` (four XLA dispatches per superstep);
    * ``pallas_resident``: ONE megakernel launch covering K =
      ``supersteps_per_launch`` supersteps (DESIGN.md §13) — `chunk` is
      not consulted; the kernel derives the global-done flag from state
      each fused superstep and runs identity steps once stopped, so the
      launch is idempotent and safe to re-issue (solve_many's vmap
      relies on this to freeze finished instances).
    """
    if opts.backend == "pallas_resident":
        from repro.core.backend import get_backend
        be = get_backend(opts.backend, **dict(opts.backend_opts))
        st, gbest, gdone, it, pool_head = carry
        st, gbest, it, pool_head, stopped = be.superstep_launch(
            cm, subs_lb, subs_ub, st, gbest, it, pool_head, opts=opts)
        return st, gbest, gdone | stopped, it, pool_head

    it0 = carry[3]

    def body(c):
        return _chunk_body(opts, stop_on_first, axis_names, cm,
                           subs_lb, subs_ub, c)

    def cond(c):
        return (~c[2]) & (c[3] - it0 < chunk)

    return lax.while_loop(cond, body, carry)


def _carry_heads(cfg: "SolveConfig", cm: CompiledModel,
                 pool_size: int) -> int:
    """Pool-cursor slots in the carry: one per resident-megakernel grid
    cell (`PallasResidentBackend.n_tiles`, usually 1), one otherwise.
    Mesh configs size per-device heads separately (see solve_iter)."""
    if cfg.backend != "pallas_resident":
        return 1
    from repro.core.backend import get_backend
    be = get_backend(cfg.backend, **dict(cfg.backend_opts))
    return be.n_tiles(cm, cfg.n_lanes, max_depth=cfg.max_depth,
                      pool_size=pool_size)


def _init_carry(cm: CompiledModel, n_lanes: int, opts: S.SearchOptions,
                n_heads: int = 1):
    dt = cm.jdtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)
    state0 = S.init_lanes(cm, n_lanes, opts)
    return (state0, big, jnp.asarray(False), jnp.asarray(0, jnp.int32),
            jnp.zeros((n_heads,), jnp.int32))


# --------------------------------------------------------------------------
# Status derivation — the ONE place a terminal SolveResult is assembled
# (fixes the dead/duplicated logic that lived in engine.solve)
# --------------------------------------------------------------------------

def derive_result(cm: CompiledModel, best_obj, has_sol, best_sol,
                  incomplete, done: bool, n_nodes: int, n_fails: int,
                  n_sols: int, n_sweeps: int, n_supersteps: int,
                  wall_s: float,
                  improvements: Tuple[Improvement, ...] = ()
                  ) -> SolveResult:
    """Derive (status, objective, solution) from terminal lane state.

    ``done`` must mean *search exhausted* — every lane drained the pool
    (``st.done.all()``) — NOT merely "the solve loop stopped": a
    ``stop_on_first`` early-out or a budget/timeout is not an
    exhaustiveness proof and must never yield OPTIMAL/UNSAT.

    * optimization (``cm.obj_var >= 0``): the incumbent lane is
      ``best_obj.argmin()``; OPTIMAL iff the search completed, else SAT;
    * satisfaction: the incumbent lane is ``has_sol.argmax()`` — NOT the
      objective argmin, whose all-big tie would always pick lane 0 and
      read a zeroed ``best_sol`` row — and the status is SAT;
    * no solution anywhere: UNSAT iff complete, else UNKNOWN.
    """
    best_obj = np.asarray(best_obj).reshape(-1)
    has_sol = np.asarray(has_sol).reshape(-1)
    best_sol = np.asarray(best_sol).reshape(-1, cm.n_vars)
    complete = bool(done) and not bool(np.asarray(incomplete).any())

    if has_sol.any():
        if cm.obj_var >= 0:
            i = int(best_obj.argmin())
            obj = int(best_obj[i])
            status = OPTIMAL if complete else SAT
        else:
            i = int(has_sol.argmax())
            obj = None
            status = SAT
        sol = best_sol[i]
    else:
        sol, obj = None, None
        status = UNSAT if complete else UNKNOWN

    return SolveResult(status=status, objective=obj, solution=sol,
                       n_nodes=int(n_nodes), n_fails=int(n_fails),
                       n_sols=int(n_sols), n_sweeps=int(n_sweeps),
                       n_supersteps=int(n_supersteps), wall_s=wall_s,
                       complete=complete,
                       improvements=tuple(improvements))


# --------------------------------------------------------------------------
# Compiled-runner cache
# --------------------------------------------------------------------------

def _aval_key(args) -> tuple:
    leaves, treedef = jax.tree.flatten(args)
    from jax.api_util import shaped_abstractify
    return (treedef, tuple(shaped_abstractify(x) for x in leaves))


class CompiledRunner:
    """One cache slot: a jitted chunk runner plus its AOT-compiled
    executables keyed by argument avals (pool-size buckets land here).

    Compilation is explicit (`fn.lower(...).compile()`) so the session
    can *count* compiles and *time* them — `n_compiles` staying flat
    across a second solve is the warm-path proof the tests assert on.
    """

    def __init__(self, fn, aot: bool = True):
        self.fn = fn
        self.aot = aot
        self._execs: Dict[tuple, Any] = {}
        self.n_compiles = 0
        self.n_calls = 0
        self.compile_s = 0.0

    def __call__(self, *args):
        self.n_calls += 1
        if not self.aot:   # mesh path: plain jit (AOT + shard_map varies
            return self.fn(*args)   # across jax versions; counters track
                                    # builds only)
        key = _aval_key(args)
        exe = self._execs.get(key)
        if exe is None:
            t0 = time.time()
            exe = self.fn.lower(*args).compile()
            self.compile_s += time.time() - t0
            self.n_compiles += 1
            self._execs[key] = exe
        return exe(*args)


class Solver:
    """A solving session: one `SolveConfig` (overridable per call) plus a
    compiled-runner cache keyed by ``(shape_signature(cm),
    config.compile_key(), batched?)``.

    Construct once, solve many::

        solver = Solver(SolveConfig.preset("prove", backend="pallas"))
        res = solver.solve(cm)              # cold: lower + compile
        res2 = solver.solve(cm2)            # warm: same shapes, no compile
        many = solver.solve_many(cms)       # one batched device dispatch
        for ev in solver.solve_iter(cm):    # anytime incumbent stream
            ...
    """

    def __init__(self, config: Optional[SolveConfig] = None, **overrides):
        base = config if config is not None else SolveConfig.preset("prove")
        self.config = base.replace(**overrides) if overrides else base
        self._runners: Dict[tuple, CompiledRunner] = {}
        self.stats: Dict[str, Any] = {
            "solves": 0, "runner_builds": 0, "runner_hits": 0,
            "last_solve_cold": None,
        }

    # -- cache ------------------------------------------------------------

    def _config_for(self, config: Optional[SolveConfig],
                    overrides: dict) -> SolveConfig:
        cfg = config if config is not None else self.config
        return cfg.replace(**overrides) if overrides else cfg

    def _runner_for(self, cm: CompiledModel, cfg: SolveConfig,
                    batched: bool) -> CompiledRunner:
        key = (shape_signature(cm), cfg.compile_key(), batched)
        runner = self._runners.get(key)
        if runner is not None:
            self.stats["runner_hits"] += 1
            return runner
        opts = cfg.search_options()
        if cfg.mesh is not None:
            axes = cfg.lane_axes
            dev_fn = partial(_run_chunk, opts, cfg.stop_on_first, cfg.chunk,
                             axes)
            spec = P(axes)
            state0 = S.init_lanes(cm, cfg.n_lanes * self._n_dev(cfg), opts)
            state_spec = jax.tree.map(lambda _: spec, state0)
            carry_spec = (state_spec, P(), P(), P(), spec)
            cm_spec = jax.tree.map(lambda _: P(), cm)
            from repro.compat import shard_map
            fn = jax.jit(shard_map(
                dev_fn, mesh=cfg.mesh,
                in_specs=(cm_spec, spec, spec, carry_spec),
                out_specs=carry_spec, check_vma=False))
            runner = CompiledRunner(fn, aot=False)
        else:
            fn = partial(_run_chunk, opts, cfg.stop_on_first, cfg.chunk, ())
            if batched:
                fn = jax.vmap(fn)
            runner = CompiledRunner(jax.jit(fn), aot=True)
        self._runners[key] = runner
        self.stats["runner_builds"] += 1
        return runner

    @staticmethod
    def _n_dev(cfg: SolveConfig) -> int:
        return int(np.prod([cfg.mesh.shape[a] for a in cfg.lane_axes]))

    def session_stats(self) -> Dict[str, Any]:
        """Aggregate cache/compile counters across all cached runners."""
        out = dict(self.stats)
        out["n_runners"] = len(self._runners)
        out["n_compiles"] = sum(r.n_compiles for r in self._runners.values())
        out["compile_s"] = sum(r.compile_s for r in self._runners.values())
        return out

    def clear_cache(self) -> None:
        """Drop every cached runner and compiled executable.  The cache
        is otherwise unbounded (one executable per shape-signature ×
        compile-key × pool-bucket) — long-lived serving processes that
        churn through many distinct model shapes should evict
        periodically; counters are kept."""
        self._runners.clear()

    # -- pool preparation -------------------------------------------------

    def _pool_for(self, cm: CompiledModel, cfg: SolveConfig,
                  subs: Optional[tuple], opts: S.SearchOptions):
        if subs is None:
            subs_lb, subs_ub = eps.decompose(cm, cfg.resolved_eps_target(),
                                             opts)
        else:
            subs_lb, subs_ub = subs
        subs_lb, subs_ub = np.asarray(subs_lb), np.asarray(subs_ub)
        size = subs_lb.shape[0]
        if cfg.pad_pool:
            size = _bucket(size)
        if cfg.mesh is not None:
            n_dev = self._n_dev(cfg)
            size = size + (-size) % n_dev
        subs_lb, subs_ub = eps.pad_pool(subs_lb, subs_ub, size)
        return jnp.asarray(subs_lb), jnp.asarray(subs_ub)

    # -- solve / solve_iter ----------------------------------------------

    def solve(self, cm: CompiledModel, *, subs: Optional[tuple] = None,
              config: Optional[SolveConfig] = None,
              **overrides) -> SolveResult:
        """Blocking solve; equals the last `solve_iter` event's result."""
        res = None
        for ev in self.solve_iter(cm, subs=subs, config=config, **overrides):
            if ev.final:
                res = ev.result
        return res

    def solve_iter(self, cm: CompiledModel, *,
                   subs: Optional[tuple] = None,
                   config: Optional[SolveConfig] = None,
                   **overrides) -> Iterator[Progress]:
        """Anytime solve: yields a `Progress` event after every
        scheduler quantum (host chunk; one K-superstep megakernel launch
        under ``backend="pallas_resident"``); the final event
        (``final=True``) carries the `SolveResult` (with its
        `improvements` trace)."""
        cfg = self._config_for(config, overrides)
        if cfg.mesh_shards is not None:
            from repro.core import dist_solve
            self.stats["solves"] += 1
            yield from dist_solve.solve_iter_dist(self, _canonical(cm), cfg,
                                                  subs=subs)
            return
        opts = cfg.search_options()
        t0 = time.time()
        self.stats["solves"] += 1
        cm = _canonical(cm)
        subs_lb, subs_ub = self._pool_for(cm, cfg, subs, opts)

        builds0 = self.stats["runner_builds"]
        runner = self._runner_for(cm, cfg, batched=False)
        if cfg.mesh is not None:
            n_dev = self._n_dev(cfg)
            carry = _init_carry(cm, cfg.n_lanes * n_dev, opts,
                                n_heads=n_dev)
        else:
            carry = _init_carry(
                cm, cfg.n_lanes, opts,
                n_heads=_carry_heads(cfg, cm, int(subs_lb.shape[0])))
        compiles0 = runner.n_compiles
        self.stats["last_solve_cold"] = None  # set after first chunk

        improvements: List[Improvement] = []
        dt = cm.jdtype
        big = int(np.iinfo(dt).max // 4)
        best_seen = big
        while True:
            carry = jax.block_until_ready(runner(cm, subs_lb, subs_ub,
                                                 carry))
            if self.stats["last_solve_cold"] is None:
                self.stats["last_solve_cold"] = (
                    runner.n_compiles > compiles0
                    or self.stats["runner_builds"] > builds0)
            st, gbest, gdone, it, _ = carry
            wall = time.time() - t0
            superstep = int(np.asarray(it).max())
            n_nodes = int(np.asarray(st.n_nodes).sum())
            n_sols = int(np.asarray(st.n_sols).sum())
            has = bool(np.asarray(st.has_sol).any())
            obj = None
            incumbent = None
            if cm.obj_var >= 0 and has:
                flat = np.asarray(st.best_obj).reshape(-1)
                i = int(flat.argmin())
                obj = int(flat[i])
                if obj < best_seen:
                    best_seen = obj
                    improvements.append(Improvement(superstep, wall, obj))
                    incumbent = np.asarray(st.best_sol).reshape(
                        -1, cm.n_vars)[i]
            stop = bool(np.asarray(gdone).all())
            if cfg.timeout_s is not None and wall > cfg.timeout_s:
                stop = True
            if (cfg.max_supersteps is not None
                    and superstep >= cfg.max_supersteps):
                stop = True
            if not stop:
                yield Progress(superstep=superstep, best_objective=obj,
                               has_solution=has, incumbent=incumbent,
                               n_nodes=n_nodes, n_sols=n_sols, wall_s=wall)
                continue
            totals = S.lane_totals(st)
            # exhaustion, not gdone: a stop_on_first early-out sets gdone
            # without draining the pool and must not claim OPTIMAL/UNSAT
            exhausted = bool(np.asarray(st.done).all())
            res = derive_result(
                cm, st.best_obj, st.has_sol, st.best_sol, st.incomplete,
                exhausted, totals["n_nodes"],
                totals["n_fails"], totals["n_sols"], totals["n_sweeps"],
                superstep, time.time() - t0, tuple(improvements))
            yield Progress(superstep=superstep, best_objective=res.objective,
                           has_solution=has, incumbent=res.solution,
                           n_nodes=res.n_nodes, n_sols=res.n_sols,
                           wall_s=res.wall_s, final=True, result=res)
            return

    # -- solve_many -------------------------------------------------------

    def solve_many(self, cms: Sequence[CompiledModel], *,
                   config: Optional[SolveConfig] = None,
                   **overrides) -> List[SolveResult]:
        """Solve N same-shape instances in ONE batched device dispatch.

        Instances become a vmapped leading axis over the whole chunk
        runner: each gets its own ``n_lanes`` lane block, its own EPS
        pool (pools are padded to a common bucket with explicitly-failed
        stores and stacked ``[N, S, V]``), its own B&B bound and its own
        done flag — so statuses/objectives are identical to N sequential
        `solve` calls, while compilation, dispatch overhead and device
        occupancy are shared.  Single-device only (use the mesh engine
        for scale-out of ONE instance).

        Returns one `SolveResult` per instance, in input order.
        ``wall_s`` is the shared batch wall clock.
        """
        cms = list(cms)
        if not cms:
            return []
        cfg = self._config_for(config, overrides)
        if cfg.mesh is not None or cfg.mesh_shards is not None:
            raise ValueError("solve_many is single-device; it cannot be "
                             "combined with a mesh config")
        opts = cfg.search_options()
        t0 = time.time()
        self.stats["solves"] += 1
        cms = [_canonical(cm) for cm in cms]
        sig = shape_signature(cms[0])
        for k, cm in enumerate(cms[1:], 1):
            if shape_signature(cm) != sig:
                raise ValueError(
                    f"solve_many needs same-shape instances: instance {k} "
                    f"has signature {shape_signature(cm)} != {sig}")
        cm0 = cms[0]
        N = len(cms)

        pools = [eps.decompose(cm, cfg.resolved_eps_target(), opts)
                 for cm in cms]
        smax = max(p[0].shape[0] for p in pools)
        size = _bucket(smax) if cfg.pad_pool else smax
        padded = [eps.pad_pool(np.asarray(l), np.asarray(u), size)
                  for l, u in pools]
        subs_lb = jnp.asarray(np.stack([p[0] for p in padded]))
        subs_ub = jnp.asarray(np.stack([p[1] for p in padded]))

        cm_b = jax.tree.map(lambda *xs: jnp.stack(xs), *cms)
        carry1 = _init_carry(cm0, cfg.n_lanes, opts,
                             n_heads=_carry_heads(cfg, cm0, size))
        carry = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (N,) + x.shape), carry1)

        runner = self._runner_for(cm0, cfg, batched=True)
        compiles0 = runner.n_compiles
        builds_before = self.stats["runner_builds"]
        while True:
            carry = jax.block_until_ready(runner(cm_b, subs_lb, subs_ub,
                                                 carry))
            st, gbest, gdone, it, _ = carry
            wall = time.time() - t0
            if bool(np.asarray(gdone).all()):
                break
            if cfg.timeout_s is not None and wall > cfg.timeout_s:
                break
            if (cfg.max_supersteps is not None
                    and int(np.asarray(it).max()) >= cfg.max_supersteps):
                break
        self.stats["last_solve_cold"] = (
            runner.n_compiles > compiles0
            or self.stats["runner_builds"] > builds_before)

        st, gbest, gdone, it, _ = carry
        wall = time.time() - t0
        st = jax.device_get(st)       # one transfer for the whole batch
        it = np.asarray(it)
        results = []
        for i in range(N):
            sti = jax.tree.map(lambda x, i=i: x[i], st)
            totals = S.lane_totals(sti)
            # per-instance exhaustion (not gdone: see derive_result)
            exhausted = bool(np.asarray(sti.done).all())
            results.append(derive_result(
                cms[i], sti.best_obj, sti.has_sol, sti.best_sol,
                sti.incomplete, exhausted, totals["n_nodes"],
                totals["n_fails"], totals["n_sols"], totals["n_sweeps"],
                int(it[i]), wall))
        return results


# --------------------------------------------------------------------------
# Module-level convenience: one shared default session
# --------------------------------------------------------------------------

_default_solver: Optional[Solver] = None


def default_solver() -> Solver:
    """The process-wide session used by `repro.solver.solve` and the
    `engine.solve` deprecation shim — so even legacy callers get
    compile caching across calls."""
    global _default_solver
    if _default_solver is None:
        _default_solver = Solver(SolveConfig())
    return _default_solver


def solve(cm: CompiledModel, *, subs=None, config=None,
          **overrides) -> SolveResult:
    return default_solver().solve(cm, subs=subs, config=config, **overrides)


def solve_many(cms: Sequence[CompiledModel], *, config=None,
               **overrides) -> List[SolveResult]:
    return default_solver().solve_many(cms, config=config, **overrides)


def solve_iter(cm: CompiledModel, *, subs=None, config=None,
               **overrides) -> Iterator[Progress]:
    return default_solver().solve_iter(cm, subs=subs, config=config,
                                       **overrides)
