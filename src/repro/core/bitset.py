"""Bit-packed finite domains (DESIGN.md §17).

The source paper's solver operates over abstract domains richer than the
plain bounds intervals of `lattice.py`; extensional (Compact-Table)
propagation in particular needs the *set* of remaining values per
variable, not just its hull.  This module materializes that domain as
packed machine words

    dom : u32[..., V, W]     (bit k of word w of var v  ⇔
                              value  off[v] + 32·w + k  is still possible)

where ``off[v]`` is the variable's initial lower bound and ``W`` (the
compile-time static ``n_words``) covers the widest tracked variable.
Like the interval store, the bitset store is a lattice — ordered by
*information*: fewer values = more information, so

    join (⊔)  =  bitwise AND   (intersection of value sets)
    meet      =  bitwise OR
    bottom    =  all bits of the initial range set
    top       =  no bits set   (empty domain == failure)

Word-level primitives only — popcount / count-leading-zeros /
count-trailing-zeros are branch-free SWAR forms so the same code lowers
on XLA and inside Pallas kernel bodies.  `from_bounds` / `to_bounds`
bridge to the interval lattice: the sweep re-derives ``dom`` from a
bounds tell and re-tightens bounds from the domain hull each sweep, so
the two lattices stay mutually consistent (a Galois connection, tested
in tests/test_bitset_props.py).

Variables wider than 32·W words cannot be represented; they are left
*untracked* (their words pinned to all-ones and never consulted) — the
compile-time ``dom_track`` mask says which is which.  Host-side numpy
mirrors at the bottom serve the sequential baseline and the property
tests (same SWAR code on np.uint32).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
FULL = np.uint32(0xFFFFFFFF)

_M1 = np.uint32(0x55555555)
_M2 = np.uint32(0x33333333)
_M4 = np.uint32(0x0F0F0F0F)
_H01 = np.uint32(0x01010101)


def n_words_for(width: int) -> int:
    """Words needed for a domain of `width` values (host-side static)."""
    return max(1, -(-int(width) // WORD_BITS))


# --- word-level SWAR primitives (uint32 in, uint32 out) -------------------

def popcount(x):
    """Set bits per word (SWAR — no table, no loop; Pallas-safe)."""
    x = x - ((x >> 1) & _M1)
    x = (x & _M2) + ((x >> 2) & _M2)
    x = (x + (x >> 4)) & _M4
    return (x * _H01) >> 24            # uint32 wraparound is intended


def ctz(x):
    """Trailing zeros per word; 32 for an empty word."""
    # x & -x isolates the lowest set bit; minus one masks the zeros below
    return popcount((x & (~x + np.uint32(1))) - np.uint32(1))


def clz(x):
    """Leading zeros per word; 32 for an empty word."""
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return np.uint32(WORD_BITS) - popcount(x)


def low_mask(n):
    """Word with bits [0, n) set, for n clipped into [0, 32]."""
    n = jnp.clip(n, 0, WORD_BITS).astype(jnp.uint32)
    shift = jnp.minimum(n, np.uint32(WORD_BITS - 1))
    return jnp.where(n >= WORD_BITS, FULL,
                     (np.uint32(1) << shift) - np.uint32(1))


# --- lattice contract ------------------------------------------------------

def join(a, b):
    """⊔ in the bitset lattice: intersection of value sets (AND)."""
    return a & b


def meet(a, b):
    """⊓: union of value sets (OR)."""
    return a | b


def leq(a, b):
    """a ≤ b in information order: b's value set ⊆ a's.  Per-var bool."""
    return jnp.all((b & ~a) == 0, axis=-1)


def is_empty(dom):
    """Top of the lattice per variable == failure (no value left)."""
    return jnp.all(dom == 0, axis=-1)


def count(dom):
    """|dom| per variable (uint32)."""
    return popcount(dom).sum(axis=-1)


# --- interval bridges ------------------------------------------------------

def from_bounds(lb, ub, off, n_words: int, track=None):
    """Bitset of the interval [lb, ub] per var: ``u32[..., V, W]``.

    `lb`/`ub` are ``[..., V]`` int stores, `off` the per-var value offset
    (the initial lower bound).  An empty interval (lb > ub) packs to all
    zeros.  With `track` (``[V]``, nonzero = tracked), untracked vars are
    pinned to all-ones — their words carry no information and are never
    consulted by the normalizer.
    """
    base = (jnp.arange(n_words, dtype=jnp.int32) * WORD_BITS)   # [W]
    rel_lo = (lb - off[..., :])[..., None].astype(jnp.int32) - base
    rel_hi = (ub - off[..., :] + 1)[..., None].astype(jnp.int32) - base
    words = low_mask(rel_hi) & ~low_mask(rel_lo)                # [..., V, W]
    if track is not None:
        words = jnp.where((track != 0)[..., :, None], words, FULL)
    return words


def min_value(dom, off):
    """Smallest remaining value per var; ``off + 32·W`` when empty."""
    W = dom.shape[-1]
    base = jnp.arange(W, dtype=jnp.uint32) * WORD_BITS
    pos = jnp.where(dom != 0, base + ctz(dom),
                    np.uint32(W * WORD_BITS)).min(axis=-1)
    return off + pos.astype(off.dtype)


def max_value(dom, off):
    """Largest remaining value per var; ``off - 1`` when empty."""
    W = dom.shape[-1]
    base = jnp.arange(W, dtype=jnp.int32) * WORD_BITS
    hi = (base + WORD_BITS - 1 - clz(dom).astype(jnp.int32))
    pos = jnp.where(dom != 0, hi, -1).max(axis=-1)
    return off + pos.astype(off.dtype)


def to_bounds(dom, off):
    """Interval hull (lo, hi) of the domain; lo > hi iff empty.

    An empty domain yields ``(off + 32·W, off - 1)``, which crosses the
    initial box in both directions — joining it into a bounds store
    always produces lb > ub (failure), even after the box clamp.
    """
    return min_value(dom, off), max_value(dom, off)


def has_value(dom, val, off):
    """Membership test per var (val/off broadcastable int arrays)."""
    bit = (val - off).astype(jnp.int32)
    W = dom.shape[-1]
    ok = (bit >= 0) & (bit < W * WORD_BITS)
    w = jnp.clip(bit >> 5, 0, W - 1)
    word = jnp.take_along_axis(dom, w[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    mask = np.uint32(1) << (bit & 31).astype(jnp.uint32)
    return ok & ((word & mask) != 0)


# --- host-side mirrors (sequential baseline & property tests) --------------

def np_popcount(x):
    x = np.asarray(x, dtype=np.uint32)
    x = x - ((x >> 1) & _M1)
    x = (x & _M2) + ((x >> 2) & _M2)
    x = (x + (x >> 4)) & _M4
    return (x * _H01) >> 24


def np_from_bounds(lb, ub, off, n_words: int, track=None):
    lb = np.asarray(lb)
    ub = np.asarray(ub)
    off = np.asarray(off)
    base = np.arange(n_words, dtype=np.int64) * WORD_BITS
    rel_lo = np.clip((lb - off)[..., None] - base, 0, WORD_BITS)
    rel_hi = np.clip((ub - off + 1)[..., None] - base, 0, WORD_BITS)

    def lowm(n):
        n = n.astype(np.uint64)
        return ((np.uint64(1) << n) - np.uint64(1)).astype(np.uint32)

    words = lowm(rel_hi) & ~lowm(rel_lo)
    if track is not None:
        words = np.where((np.asarray(track) != 0)[..., :, None], words, FULL)
    return words


def np_count(dom):
    return np_popcount(dom).sum(axis=-1)


def np_is_empty(dom):
    return np.all(np.asarray(dom) == 0, axis=-1)


def np_to_bounds(dom, off):
    dom = np.asarray(dom, dtype=np.uint32)
    off = np.asarray(off)
    W = dom.shape[-1]
    base = np.arange(W, dtype=np.int64) * WORD_BITS
    tz = np_popcount((dom & (~dom + np.uint32(1))) - np.uint32(1))
    lo_pos = np.where(dom != 0, base + tz, W * WORD_BITS).min(axis=-1)
    sm = dom.copy()
    for s in (1, 2, 4, 8, 16):
        sm = sm | (sm >> s)
    lz = WORD_BITS - np_popcount(sm)
    hi_pos = np.where(dom != 0, base + WORD_BITS - 1 - lz.astype(np.int64),
                      -1).max(axis=-1)
    return off + lo_pos.astype(off.dtype), off + hi_pos.astype(off.dtype)


def np_has_value(dom, val, off):
    dom = np.asarray(dom, dtype=np.uint32)
    bit = np.asarray(val - off, dtype=np.int64)
    W = dom.shape[-1]
    ok = (bit >= 0) & (bit < W * WORD_BITS)
    w = np.clip(bit >> 5, 0, W - 1)
    word = np.take_along_axis(dom, w[..., None], axis=-1)[..., 0]
    mask = (np.uint32(1) << (bit & 31).astype(np.uint32))
    return ok & ((word & mask) != 0)


def np_clear_value(dom, val, off):
    """Remove one value (x ≠ v branching); out-of-range vals are no-ops."""
    dom = np.asarray(dom, dtype=np.uint32).copy()
    bit = np.asarray(val - off, dtype=np.int64)
    W = dom.shape[-1]
    ok = (bit >= 0) & (bit < W * WORD_BITS)
    w = np.clip(bit >> 5, 0, W - 1)
    mask = np.where(ok, np.uint32(1) << (bit & 31).astype(np.uint32),
                    np.uint32(0))
    cur = np.take_along_axis(dom, w[..., None], axis=-1)[..., 0]
    np.put_along_axis(dom, w[..., None], (cur & ~mask)[..., None], axis=-1)
    return dom
