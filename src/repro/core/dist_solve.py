"""Distributed EPS: the lane pool sharded over a device mesh
(DESIGN.md §14).

The paper's EPS design is intrinsically multi-device: the pool of
consistent subproblems produced by `eps.decompose` partitions the root
search space, so shards of the pool can be explored by disjoint device
lane blocks with only two pieces of shared state — the global best
bound (a min, DESIGN.md §9) and the global done flag (an and).  This
module runs that regime on a 1-D ``solve`` mesh axis:

* **Sharding** — the `[S, V]` pool and the `[D·L, …]` lane state shard
  over ``solve`` with specs derived from `distributed/sharding.py`'s
  `SOLVE_RULES`; model tables and the scalar bound/flags replicate.
  Each device runs the existing four-phase superstep
  (`search.lanes_step`) on its shard, unchanged, under `shard_map`.
* **Bound sharing** — every superstep inside the sharded chunk ends
  with `distributed/collectives.solver_bound_sync` (pmin of the
  incumbent bound, AND of done, OR of has-solution), so all lanes on
  all devices prune against the best objective found anywhere; the host
  additionally folds the bound into its incumbent checkpoint once per
  chunk (the anytime stream).
* **Work stealing** — at host-chunk granularity: when a shard's
  frontier drains (some lane done, no undispatched entries) while work
  remains elsewhere, `distributed/planner.plan_steal` deterministically
  repartitions the undispatched pool ids (minimal movement, balanced to
  within one entry) and the drained shard's lanes are revived.
* **Elastic device loss** — a simulated loss (`ft.DeviceLoss`) is
  detected by the same Heartbeat/FailureInjector pair the training
  supervisor uses and recovered by `ft.solver_shard_loss`: everyone
  rolls back to the last chunk-boundary snapshot (the failed chunk's
  collective never completed), the lost shard's undispatched slice and
  the *root* stores of its in-flight subproblems are requeued, the
  survivors re-mesh over ``D-1`` devices via `ft.elastic_remesh`, and
  the solve continues to the same proven optimum.  The incumbent
  survives because the host checkpoints (objective, solution) every
  chunk — never the lost device's memory.

**Completeness** (§14): the pool partitions the root space (eps.py);
steals move only *undispatched* entries, so at every chunk boundary the
per-shard undispatched id sets are pairwise disjoint and, together with
the consumed ids, cover the pool — the invariant
`tests/test_dist_solve.py` asserts.  Device loss requeues a superset of
the lost shard's unexplored work (re-exploring part of a subtree only
repeats nodes), and the post-loss bound is recomputed from surviving
lanes plus the host checkpoint, never taken on faith from the failed
chunk.  Hence status/objective equal the single-device solve for every
mesh size and any single loss.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import eps
from repro.core import search as S
from repro.core.api import (CompiledRunner, Improvement, Progress,
                            SolveConfig, SolveResult, _bucket, _init_carry,
                            _run_chunk, derive_result, shape_signature)
from repro.core.compile import CompiledModel
from repro.distributed import planner
from repro.distributed.sharding import SOLVE_RULES, dist_solve_specs
from repro.ft.fault_tolerance import (DeviceLoss, elastic_remesh,
                                      solver_heartbeat, solver_shard_loss)

AXIS = "solve"


@dataclasses.dataclass
class DistTrace:
    """Host-side observability for one distributed solve — what the
    tests assert on and what `bench_solver --dist-bench` records."""
    n_chunks: int = 0
    n_bound_syncs: int = 0              # chunk-boundary host bound folds
    n_supersteps: int = 0               # per-superstep device all-reduces
    gbest_per_chunk: List[int] = dataclasses.field(default_factory=list)
    steal_events: List[dict] = dataclasses.field(default_factory=list)
    remesh_events: List[dict] = dataclasses.field(default_factory=list)
    # per chunk boundary: per-shard undispatched id lists + consumed ids
    assignments: List[List[List[int]]] = dataclasses.field(
        default_factory=list)
    consumed_per_chunk: List[List[int]] = dataclasses.field(
        default_factory=list)
    all_ids: List[int] = dataclasses.field(default_factory=list)

    @property
    def n_steals(self) -> int:
        return len(self.steal_events)


class _Pool:
    """Host bookkeeping for the sharded EPS pool.

    Identity lives in integer *ids* (rows of the original decomposition,
    plus fresh ids for roots requeued by device-loss recovery); layout
    (which contiguous device slice a row occupies) is recomputed on
    every steal/remesh while ids are stable — that is what makes the
    disjointness/cover invariant checkable.
    """

    def __init__(self, subs_lb: np.ndarray, subs_ub: np.ndarray,
                 n_shards: int, pad_bucket: bool):
        self.store: Dict[int, Tuple[np.ndarray, np.ndarray]] = {
            i: (subs_lb[i].copy(), subs_ub[i].copy())
            for i in range(subs_lb.shape[0])}
        self.next_id = subs_lb.shape[0]
        self.consumed: set = set()
        self.n_shards = n_shards
        self.pad_bucket = pad_bucket
        self.template = (subs_lb[0].copy(), subs_ub[0].copy())
        self.owned, _ = planner.plan_steal([sorted(self.store)], n_shards)
        self.shard_size = self._shard_size()
        self.heads = np.zeros(n_shards, np.int64)
        self._layout()

    def _shard_size(self) -> int:
        need = max(max((len(o) for o in self.owned), default=1), 1)
        return _bucket(need) if self.pad_bucket else need

    def _layout(self):
        """Materialize `owned` into contiguous per-shard slices, padding
        with explicitly-failed stores (popped and failed in one
        superstep — `eps.pad_pool` semantics)."""
        D, Ssh = self.n_shards, self.shard_size
        V = self.template[0].shape[0]
        lb = np.empty((D * Ssh, V), self.template[0].dtype)
        ub = np.empty((D * Ssh, V), self.template[1].dtype)
        ids = np.full(D * Ssh, -1, np.int64)
        pad_lb, pad_ub = self.template[0].copy(), self.template[1].copy()
        pad_lb[0], pad_ub[0] = 1, 0
        for d in range(D):
            for k in range(Ssh):
                row = d * Ssh + k
                if k < len(self.owned[d]):
                    i = self.owned[d][k]
                    lb[row], ub[row] = self.store[i]
                    ids[row] = i
                else:
                    lb[row], ub[row] = pad_lb, pad_ub
        self.lb, self.ub, self.ids = lb, ub, ids
        self.heads = np.zeros(D, np.int64)

    def advance(self, heads: np.ndarray):
        """Consume the entries dispatched to lanes since the last chunk
        boundary (everything below the new per-shard cursor)."""
        Ssh = self.shard_size
        for d in range(self.n_shards):
            lo, hi = int(self.heads[d]), min(int(heads[d]), Ssh)
            for pos in range(lo, hi):
                i = int(self.ids[d * Ssh + pos])
                if i >= 0:
                    self.consumed.add(i)
                    self.store.pop(i, None)
            self.heads[d] = hi
        self.owned = [
            [int(i) for i in self.ids[d * Ssh + int(self.heads[d]):
                                      (d + 1) * Ssh] if i >= 0]
            for d in range(self.n_shards)]

    def remaining(self) -> int:
        return sum(len(o) for o in self.owned)

    def steal(self) -> int:
        """Repartition the undispatched ids (planner.plan_steal) and
        re-layout.  Returns the number of entries that moved."""
        self.owned, moved = planner.plan_steal(self.owned, self.n_shards)
        self._layout()
        return moved

    def requeue(self, ids: List[int],
                roots: Tuple[np.ndarray, np.ndarray]) -> List[int]:
        """Device-loss recovery: `ids` come back verbatim (their rows
        are still in `store`); in-flight roots get fresh ids."""
        new_ids = list(ids)
        r_lb, r_ub = roots
        for k in range(r_lb.shape[0]):
            i = self.next_id
            self.next_id += 1
            self.store[i] = (r_lb[k].copy(), r_ub[k].copy())
            new_ids.append(i)
        return new_ids

    def remesh(self, owned: List[List[int]], extra: List[int]):
        """Shrink to ``len(owned)`` shards, folding ``extra`` (the lost
        shard's requeued work) into a balanced repartition."""
        self.n_shards = len(owned)
        self.owned, _ = planner.plan_steal(owned + [extra], self.n_shards)
        self.shard_size = self._shard_size()
        self._layout()

    def all_ids(self) -> List[int]:
        return sorted(self.consumed | set(self.store))


def _mesh_for(n_shards: int, devices=None) -> Mesh:
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < n_shards:
        raise RuntimeError(
            f"mesh_shards={n_shards} but only {len(devs)} JAX device(s) "
            f"are visible; on CPU-only hosts fake them with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_shards} (set before the process starts)")
    return Mesh(np.asarray(devs[:n_shards]), (AXIS,))


def _build_runner(session, cm: CompiledModel, cfg: SolveConfig,
                  mesh: Mesh, state0, n_pool: int) -> CompiledRunner:
    """One sharded chunk runner per (model shape, config, mesh size),
    cached in the session's runner cache like every other runner."""
    n_dev = int(mesh.shape[AXIS])
    key = (shape_signature(cm), cfg.compile_key(), ("dist", n_dev))
    runner = session._runners.get(key)
    if runner is not None:
        session.stats["runner_hits"] += 1
        return runner
    opts = cfg.search_options()
    pool_spec, carry_spec = dist_solve_specs(state0, n_pool, mesh)
    cm_spec = jax.tree.map(lambda _: P(), cm)
    dev_fn = partial(_run_chunk, opts, cfg.stop_on_first, cfg.chunk,
                     (AXIS,))
    fn = jax.jit(shard_map(dev_fn, mesh=mesh,
                           in_specs=(cm_spec, pool_spec, pool_spec,
                                     carry_spec),
                           out_specs=carry_spec, check_vma=False))
    runner = CompiledRunner(fn, aot=False)
    session._runners[key] = runner
    session.stats["runner_builds"] += 1
    return runner


def _place_state(state, mesh: Mesh):
    """Re-place a host lane-state pytree (leaves ``[D·L, …]``) onto the
    mesh via the ft elastic-remesh path: shardings are recomputed from
    the logical SOLVE_RULES, device_put moves the bytes."""
    def shardings_fn(m):
        def leaf(x):
            from repro.distributed.sharding import spec_for
            axes = ("lanes",) + (None,) * (np.asarray(x).ndim - 1)
            return NamedSharding(m, spec_for(np.asarray(x).shape, axes,
                                             SOLVE_RULES, m))
        return jax.tree.map(leaf, state)
    return elastic_remesh(state, mesh, shardings_fn)


class _Incumbent:
    """The host-side incumbent checkpoint: streamed once per chunk, and
    the only thing that survives a device loss."""

    def __init__(self, cm: CompiledModel):
        self.cm = cm
        self.big = int(np.iinfo(cm.jdtype).max // 4)
        self.obj = self.big
        self.sol: Optional[np.ndarray] = None
        self.has_sol = False

    def fold(self, st) -> None:
        has = np.asarray(st.has_sol).reshape(-1)
        if not has.any():
            return
        if self.cm.obj_var >= 0:
            best = np.asarray(st.best_obj).reshape(-1)
            i = int(best.argmin())
            if int(best[i]) < self.obj or not self.has_sol:
                self.obj = int(best[i])
                self.sol = np.asarray(st.best_sol).reshape(
                    -1, self.cm.n_vars)[i].copy()
        elif not self.has_sol:
            i = int(has.argmax())
            self.sol = np.asarray(st.best_sol).reshape(
                -1, self.cm.n_vars)[i].copy()
        self.has_sol = True

    def rows(self, V: int):
        """One extra lane row carrying the checkpoint, appended to the
        terminal device state before derive_result."""
        sol = self.sol if self.sol is not None else np.zeros(V, np.int64)
        return (np.asarray([self.obj]), np.asarray([self.has_sol]),
                np.asarray(sol).reshape(1, V))


def solve_iter_dist(session, cm: CompiledModel, cfg: SolveConfig, *,
                    subs: Optional[tuple] = None,
                    fault: Optional[DeviceLoss] = None,
                    trace: Optional[DistTrace] = None
                    ) -> Iterator[Progress]:
    """Anytime distributed solve over ``cfg.mesh_shards`` devices;
    yields the same `Progress` stream as the single-device engine (one
    event per host chunk), final event carrying the `SolveResult`."""
    trace = trace if trace is not None else DistTrace()
    opts = cfg.search_options()
    t0 = time.time()
    D = int(cfg.mesh_shards or 1)
    mesh = _mesh_for(D)
    hb, injector = solver_heartbeat(D, fault)

    # -- pool ---------------------------------------------------------------
    if subs is None:
        subs_lb, subs_ub = eps.decompose(cm, cfg.resolved_eps_target(), opts)
    else:
        subs_lb, subs_ub = np.asarray(subs[0]), np.asarray(subs[1])
    pool = _Pool(np.asarray(subs_lb), np.asarray(subs_ub), D,
                 pad_bucket=cfg.pad_pool)
    trace.all_ids = pool.all_ids()

    # -- carry --------------------------------------------------------------
    carry = _init_carry(cm, cfg.n_lanes * D, opts, n_heads=D)
    runner = _build_runner(session, cm, cfg, mesh, carry[0],
                           pool.lb.shape[0])
    inc = _Incumbent(cm)
    improvements: List[Improvement] = []
    best_seen = inc.big
    lost_totals = dict(n_nodes=0, n_fails=0, n_sols=0, n_sweeps=0)
    snapshot: Optional[dict] = None
    chunk_idx = 0
    stop, exhausted = False, False

    def host_state(st):
        return jax.tree.map(lambda x: np.asarray(x), st)

    def boundary_snapshot(st_h):
        """Checkpoint for ft recovery: per-shard lane state, owned ids
        and in-flight subproblem roots (only kept when a fault is
        scheduled — real deployments would persist this instead)."""
        L = cfg.n_lanes
        Dn = pool.n_shards
        inflight = []
        for d in range(Dn):
            sl = slice(d * L, (d + 1) * L)
            mask = (~st_h.done[sl]) & (~st_h.fresh[sl])
            inflight.append((st_h.root_lb[sl][mask].copy(),
                             st_h.root_ub[sl][mask].copy()))
        state = jax.tree.map(lambda x: x.reshape((Dn, L) + x.shape[1:]),
                             st_h)
        return dict(state=state, owned=[list(o) for o in pool.owned],
                    inflight=inflight,
                    heads=pool.heads.copy())

    while not stop:
        # -- failure detection + elastic remesh (ft/) ----------------------
        hb.clock.t = float(chunk_idx)
        injector.advance(chunk_idx, hb)
        dead = hb.dead_hosts()
        if dead and pool.n_shards > 1 and snapshot is not None:
            lostd = int(dead[0].replace("shard", ""))
            rec = solver_shard_loss(snapshot, lostd)
            requeued = pool.requeue(rec["requeue_ids"],
                                    rec["requeue_roots"])
            # roll everyone back to the checkpoint: the failed chunk's
            # collective never completed on a real mesh
            st_prev = rec["state"]
            # the checkpoint (host memory) keeps the lost shard's search
            # *counters*; its incumbents need no special handling — the
            # host folded them into `inc` when the snapshot was taken
            lost_state = jax.tree.map(
                lambda x: np.asarray(x)[lostd], snapshot["state"])
            for k in lost_totals:
                lost_totals[k] += int(np.asarray(
                    getattr(lost_state, k)).sum())
            Dn = pool.n_shards - 1
            pool.remesh([list(o) for o in rec["owned"]], requeued)
            mesh = _mesh_for(Dn, devices=[
                d for i, d in enumerate(mesh.devices.reshape(-1))
                if i != lostd])
            st_h = jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), st_prev)
            # revive drained survivor lanes so they pick up requeued work
            st_h = st_h._replace(done=np.zeros_like(st_h.done))
            state_dev = _place_state(st_h, mesh)
            # bound restart: the host incumbent checkpoint (whose
            # solution vector we hold) plus the survivors' own
            # incumbents — never the failed epoch's all-reduced value
            gbest = jnp.asarray(
                min(inc.obj, int(np.asarray(st_h.best_obj).min()))
                if cm.obj_var >= 0 else inc.big, cm.jdtype)
            # scalars re-materialize on the host: the old carry's arrays
            # are committed to the dead mesh and must not leak in
            it_h = jnp.asarray(int(np.asarray(carry[3])), jnp.int32)
            carry = (state_dev, gbest, jnp.asarray(False), it_h,
                     jnp.zeros((Dn,), jnp.int32))
            runner = _build_runner(session, cm, cfg, mesh, carry[0],
                                   pool.lb.shape[0])
            # fresh heartbeat AND injector: shards renumber after the
            # remesh, so the old failed-host name must not shadow a
            # survivor (the single scheduled loss is consumed)
            hb, injector = solver_heartbeat(Dn, None)
            trace.remesh_events.append(dict(
                chunk=chunk_idx, lost_shard=lostd,
                n_requeued=len(requeued), shards_before=Dn + 1,
                shards_after=Dn))
            snapshot = None

        # -- one sharded chunk ---------------------------------------------
        carry = jax.block_until_ready(
            runner(cm, jnp.asarray(pool.lb), jnp.asarray(pool.ub), carry))
        st, gbest, gdone, it, heads = carry
        chunk_idx += 1
        trace.n_chunks += 1
        trace.n_bound_syncs += 1
        st_h = host_state(st)
        pool.advance(np.asarray(heads).reshape(-1))
        inc.fold(st_h)
        superstep = int(np.asarray(it))
        trace.n_supersteps = superstep
        wall = time.time() - t0
        trace.gbest_per_chunk.append(inc.obj)
        trace.assignments.append([list(o) for o in pool.owned])
        trace.consumed_per_chunk.append(sorted(pool.consumed))
        if fault is not None:
            snapshot = boundary_snapshot(st_h)

        # -- anytime event --------------------------------------------------
        n_nodes = int(st_h.n_nodes.sum()) + lost_totals["n_nodes"]
        n_sols = int(st_h.n_sols.sum()) + lost_totals["n_sols"]
        has = bool(st_h.has_sol.any()) or inc.has_sol
        obj = None
        incumbent = None
        if cm.obj_var >= 0 and has:
            obj = inc.obj
            if obj < best_seen:
                best_seen = obj
                improvements.append(Improvement(superstep, wall, obj))
                incumbent = inc.sol

        # -- termination / stealing ----------------------------------------
        gdone_h = bool(np.asarray(gdone))
        if gdone_h:
            if cfg.stop_on_first and has:
                stop = True
            else:
                stop = pool.remaining() == 0
                exhausted = stop and bool(st_h.done.all())
        if not stop and cfg.steal and pool.n_shards > 1:
            L = cfg.n_lanes
            done_by_shard = st_h.done.reshape(pool.n_shards, L)
            drained = [d for d in range(pool.n_shards)
                       if done_by_shard[d].any()
                       and len(pool.owned[d]) == 0]
            if drained and pool.remaining() > 0:
                before = [len(o) for o in pool.owned]
                moved = pool.steal()
                st_h = st_h._replace(done=np.zeros_like(st_h.done))
                carry = (jax.tree.map(jnp.asarray, st_h), gbest,
                         jnp.asarray(False), it,
                         jnp.zeros((pool.n_shards,), jnp.int32))
                trace.steal_events.append(dict(
                    chunk=chunk_idx, drained_shards=drained,
                    n_moved=moved, owned_before=before,
                    owned_after=[len(o) for o in pool.owned]))
        if cfg.timeout_s is not None and wall > cfg.timeout_s:
            stop = True
        if (cfg.max_supersteps is not None
                and superstep >= cfg.max_supersteps):
            stop = True

        if not stop:
            yield Progress(superstep=superstep, best_objective=obj,
                           has_solution=has, incumbent=incumbent,
                           n_nodes=n_nodes, n_sols=n_sols, wall_s=wall,
                           t_host=t0 + wall)
            continue

        # -- terminal result ------------------------------------------------
        totals = S.lane_totals(st_h)
        for k, v in lost_totals.items():
            totals[k] += v
        xo, xh, xs = inc.rows(cm.n_vars)
        best_obj = np.concatenate([st_h.best_obj.reshape(-1), xo])
        has_sol = np.concatenate([st_h.has_sol.reshape(-1), xh])
        best_sol = np.concatenate(
            [np.asarray(st_h.best_sol).reshape(-1, cm.n_vars), xs])
        res = derive_result(
            cm, best_obj, has_sol, best_sol, st_h.incomplete,
            exhausted, totals["n_nodes"], totals["n_fails"],
            totals["n_sols"], totals["n_sweeps"], superstep,
            time.time() - t0, tuple(improvements))
        yield Progress(superstep=superstep, best_objective=res.objective,
                       has_solution=has, incumbent=res.solution,
                       n_nodes=res.n_nodes, n_sols=res.n_sols,
                       wall_s=res.wall_s, final=True, result=res,
                       t_host=t0 + res.wall_s)
        return


def solve_dist(cm: CompiledModel, config: Optional[SolveConfig] = None, *,
               subs: Optional[tuple] = None,
               fault: Optional[DeviceLoss] = None,
               session=None, **overrides
               ) -> Tuple[SolveResult, DistTrace]:
    """Blocking distributed solve; returns ``(result, trace)``.  The
    trace carries the per-chunk bound history, steal events, remesh
    events and pool-assignment snapshots (tests + dist bench)."""
    from repro.core.api import Solver
    cfg = (config or SolveConfig(mesh_shards=jax.device_count()))
    if overrides:
        cfg = cfg.replace(**overrides)
    if cfg.mesh_shards is None:
        cfg = cfg.replace(mesh_shards=jax.device_count())
    sess = session if session is not None else Solver(cfg)
    trace = DistTrace()
    res = None
    for ev in solve_iter_dist(sess, cm, cfg, subs=subs, fault=fault,
                              trace=trace):
        if ev.final:
            res = ev.result
    return res, trace
