"""Batched propagate-and-search (paper §TURBO).

A *lane* is the TPU analogue of a TURBO CUDA block: it owns one EPS
subproblem at a time and runs depth-first search on it.  Lanes are a batch
axis (`vmap`), sharded over mesh devices by the engine.

Per the paper's design choices, faithfully kept:
  * two stores per lane: the subproblem **root** store and the current
    store; backtracking copies the root and re-commits the decision path
    (full recomputation, no trail).  Because decisions are `tell`s (joins),
    the whole path is re-joined in one scatter and then a single fixpoint
    runs — recomputation is one propagation, not depth many;
  * eventless propagation (fixpoint.py) — every propagator, every sweep;
  * branch & bound through a shared best objective (global-memory cell in
    the paper; a cross-lane min + `lax.pmin` here).

Branching is (var, m) with left = `x ≤ m`, right = `x ≥ m+1`; value
strategies: `m = lb` (assign-min, the scheduling default) or the domain
midpoint (split).  Variable strategies: input order / min domain / min lb.

All control flow is mask-based so the step functions vmap; a lane that is
`done` keeps sweeping its converged store, which is a no-op by
idempotence (Thm. 2) — correctness never depends on lane divergence.

Superstep structure (the TURBO shape, DESIGN.md §2.3 and §9): propagation
is **hoisted out of the per-lane vmap**.  `lanes_step` runs four phases —
`dispatch_pool` (idle lanes pop the next EPS subproblems off the shared
per-device pool, DESIGN.md §9), then `lane_load_tile` (subproblem load +
B&B bound tell), then **one lane-batched backend fixpoint over the whole
[n_lanes, V] store tensor** (`SearchOptions.backend` picks
gather / scatter / pallas / pallas_resident), then `lane_commit_tile`
(solution recording, backtrack-or-branch bookkeeping).  The pool itself
comes from `eps.decompose` (engine.solve's ``eps_target``); the shared
incumbent `gbest` each lane prunes against is min-reduced across lanes
and mesh devices by the engine between supersteps (DESIGN.md §9 bound
sharing).

All four phases are **pure-array tile functions** over ``[L, …]``
batches (no `CompiledModel`, no vmap — the same discipline as
`fixpoint.sweep_tile`), so the resident search megakernel
(`kernels/fixpoint_kernel.search_pallas`, DESIGN.md §13) runs the exact
same branch/commit math on VMEM refs that the unfused path runs as XLA
ops — one implementation of the search semantics, two execution
strategies.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset as B
from repro.core.compile import CompiledModel
from repro.core.backend import get_backend

# variable-selection strategies
INPUT_ORDER = "input_order"
MIN_DOM = "min_dom"
MIN_LB = "min_lb"

# sentinel: lane has no assigned subproblem (shared-queue dispatch)
UNASSIGNED = np.iinfo(np.int32).max // 2
# value-selection strategies
VAL_MIN = "min"       # m = lb  (assign lower bound)
VAL_SPLIT = "split"   # m = (lb+ub)//2
# m = remaining domain value nearest the interval midpoint (ties low);
# branches left x = m, right x ≠ m (a bitset-store tell — the strategy
# activates the bitset domain even on pure-bounds models, DESIGN.md §17)
VAL_MIDDLE_OUT = "middle_out"


@dataclasses.dataclass(frozen=True)
class SearchOptions:
    var_strategy: str = INPUT_ORDER
    val_strategy: str = VAL_MIN
    max_depth: int = 2048
    max_fixpoint_iters: Optional[int] = None
    stop_on_first: bool = False      # satisfaction: stop at first solution
    # propagation backend for the superstep's lane-batched fixpoint:
    # "gather" | "scatter" | "pallas" (see core/backend.py)
    backend: str = "gather"
    # backend construction options (e.g. lane_tile/interpret for pallas);
    # must be hashable — a tuple of (key, value) pairs
    backend_opts: Tuple = ()


class LaneState(NamedTuple):
    # current + root stores (the paper's two stores per block)
    lb: jax.Array            # i[V]
    ub: jax.Array            # i[V]
    root_lb: jax.Array       # i[V]
    root_ub: jax.Array       # i[V]
    # decision path
    dec_var: jax.Array       # i32[MD]
    dec_val: jax.Array       # i[MD]   branch point m
    dec_flip: jax.Array      # bool[MD] True once on the right branch
    depth: jax.Array         # i32
    # subproblem queue cursor (static round-robin over the shard)
    next_sub: jax.Array      # i32
    fresh: jax.Array         # bool — needs to load a new subproblem
    done: jax.Array          # bool — queue exhausted
    incomplete: jax.Array    # bool — hit depth limit (search not exhaustive)
    # incumbent
    best_obj: jax.Array      # i
    best_sol: jax.Array      # i[V]
    has_sol: jax.Array       # bool
    # stats
    n_nodes: jax.Array       # i32
    n_fails: jax.Array       # i64
    n_sols: jax.Array        # i64
    n_sweeps: jax.Array      # i64
    # bitset domain stores (DESIGN.md §17) — None unless the model has
    # tables or the value strategy is middle_out (None is an empty pytree
    # leaf set, so inactive states keep the legacy carry structure)
    dom: Optional[jax.Array] = None        # u32[L, V, W]
    root_dom: Optional[jax.Array] = None   # u32[L, V, W]


def use_dom(cm: CompiledModel, opts: SearchOptions) -> bool:
    """Whether search must carry the bitset store: extensional models
    always (Compact-Table filters value sets), and `middle_out` value
    ordering on any model (its right branch x ≠ m is a bitset tell)."""
    return cm.n_table > 0 or opts.val_strategy == VAL_MIDDLE_OUT


def init_lanes(cm: CompiledModel, n_lanes: int, opts: SearchOptions) -> LaneState:
    V = cm.n_vars
    dt = cm.jdtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)
    z = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
    dom = (jnp.zeros((n_lanes, V, cm.n_words), jnp.uint32)
           if use_dom(cm, opts) else None)
    return LaneState(
        dom=dom, root_dom=dom,
        lb=jnp.zeros((n_lanes, V), dt), ub=jnp.zeros((n_lanes, V), dt),
        root_lb=jnp.zeros((n_lanes, V), dt), root_ub=jnp.zeros((n_lanes, V), dt),
        dec_var=jnp.zeros((n_lanes, opts.max_depth), jnp.int32),
        dec_val=jnp.zeros((n_lanes, opts.max_depth), dt),
        dec_flip=jnp.zeros((n_lanes, opts.max_depth), bool),
        depth=jnp.zeros((n_lanes,), jnp.int32),
        next_sub=jnp.full((n_lanes,), UNASSIGNED, jnp.int32),
        fresh=jnp.ones((n_lanes,), bool),
        done=jnp.zeros((n_lanes,), bool),
        incomplete=jnp.zeros((n_lanes,), bool),
        best_obj=jnp.full((n_lanes,), big, dt),
        best_sol=jnp.zeros((n_lanes, V), dt),
        has_sol=jnp.zeros((n_lanes,), bool),
        n_nodes=z(n_lanes), n_fails=z(n_lanes), n_sols=z(n_lanes),
        n_sweeps=z(n_lanes),
    )


def dispatch_pool_tile(st: LaneState, pool_head, n_subs: int,
                       tile_id=0, n_tiles: int = 1):
    """Shared subproblem queue (the paper's dynamic EPS, DESIGN.md §9):
    fresh lanes pop the next pool indices; when the pool is drained they
    are marked done.  Replaces static round-robin — no straggler lane can
    sit on a long private queue while others idle.  Runs as phase 0 of
    every superstep, so a lane that exhausts its subproblem is
    replenished on the very next superstep.

    With ``n_tiles > 1`` (a resident megakernel auto-shrunk into several
    VMEM grid cells, DESIGN.md §13) the pool is strided across tiles:
    tile ``t`` owns indices ``t, t + n_tiles, t + 2·n_tiles, …`` and
    ``pool_head`` is its private cursor into that shard — complete (the
    shards partition the pool) but without cross-tile work stealing
    inside a launch.  ``n_tiles == 1`` is exactly the shared-queue
    semantics of the unfused path."""
    want = st.fresh & ~st.done & (st.next_sub >= n_subs)
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    slot = pool_head + rank
    idx = tile_id + n_tiles * slot if n_tiles > 1 else slot
    got = want & (idx < n_subs)
    next_sub = jnp.where(got, idx.astype(jnp.int32), st.next_sub)
    done = st.done | (want & (idx >= n_subs))
    shard = (n_subs if n_tiles == 1
             else -((n_subs - tile_id) // -n_tiles))     # ceil shard size
    new_head = jnp.minimum(pool_head + want.astype(jnp.int32).sum(),
                           shard)
    return st._replace(next_sub=next_sub, done=done), new_head


def dispatch_pool(st: LaneState, pool_head, n_subs: int):
    """Single-queue view of `dispatch_pool_tile` (the unfused path)."""
    return dispatch_pool_tile(st, pool_head, n_subs)


def apply_path_tile(root_lb, root_ub, dec_var, dec_val, dec_flip, depth, *,
                    val_strategy: str = VAL_MIN, root_dom=None,
                    dom_off=None, dom_track=None):
    """Full recomputation for a ``[L, V]`` tile: root ⊔ all decision
    tells, in one flat scatter-min/max (per-lane duplicate indices are
    handled by the associative scatter join).  Pure-array form shared
    verbatim by the unfused commit and the resident megakernel.

    Interval strategies branch left x ≤ m / right x ≥ m+1.  Under
    `middle_out` the left branch is the assignment x = m (both bounds
    tell) and the right branch is x ≠ m — a *bitset* tell: the flipped
    decisions' value bits are cleared from `root_dom` via one flat
    scatter-add of their one-hot word masks (exact because a well-formed
    path never flips the same (var, value) twice, so the added masks are
    disjoint).  Decisions on *untracked* vars (dom_track == 0 — wider
    than the 32·W bitset) fall back per-decision to the interval split
    x ≤ m / x ≥ m+1, matching `select_branch_tile`'s degradation.
    Returns (lb, ub) — plus the recomputed dom when `root_dom` is
    carried.
    """
    L, V = root_lb.shape
    md = dec_var.shape[1]
    lvl = jnp.arange(md)
    on = lvl[None, :] < depth[:, None]
    dt = root_lb.dtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)
    if val_strategy == VAL_MIDDLE_OUT:
        trk = jnp.take(dom_track, dec_var.astype(jnp.int32)) != 0  # [L, MD]
        ub_tell = jnp.where(on & ~dec_flip, dec_val, big)      # left: x = m
        lb_tell = jnp.where(on & ~dec_flip & trk, dec_val,     # (x ≤ m wide)
                            jnp.where(on & dec_flip & ~trk,    # wide right:
                                      dec_val + 1, -big))      # x ≥ m+1
    else:
        ub_tell = jnp.where(on & ~dec_flip, dec_val, big)      # left: x ≤ m
        lb_tell = jnp.where(on & dec_flip, dec_val + 1, -big)  # right: x ≥ m+1
    rows = jnp.arange(L, dtype=jnp.int32)[:, None] * V
    flat = (rows + dec_var.astype(jnp.int32)).reshape(-1)
    ub = root_ub.reshape(L * V).at[flat].min(ub_tell.reshape(-1))
    lb = root_lb.reshape(L * V).at[flat].max(lb_tell.reshape(-1))
    lb, ub = lb.reshape(L, V), ub.reshape(L, V)
    if root_dom is None:
        return lb, ub
    dom = root_dom
    if val_strategy == VAL_MIDDLE_OUT:
        # right branches: clear bit (dec_val - off) of the decision var
        W = root_dom.shape[-1]
        bit = (dec_val - jnp.take(dom_off, dec_var.astype(jnp.int32))
               ).astype(jnp.int32)                             # [L, MD]
        hit = on & dec_flip & trk & (bit >= 0) & (bit < W * B.WORD_BITS)
        word = jnp.clip(bit >> 5, 0, W - 1)
        mask = jnp.where(hit,
                         np.uint32(1) << (bit & 31).astype(jnp.uint32),
                         np.uint32(0))
        flat_w = (rows * W + dec_var.astype(jnp.int32) * W + word
                  ).reshape(-1)
        acc = jnp.zeros((L * V * W,), jnp.uint32
                        ).at[flat_w].add(mask.reshape(-1))
        dom = dom & ~acc.reshape(L, V, W)
    return lb, ub, dom


def select_branch_tile(lb, ub, branch_vars, *, var_strategy: str,
                       val_strategy: str, dom=None, dom_off=None):
    """Pick (var, m) for each lane's next decision over a ``[L, V]``
    tile.  Returns (var[L], m[L], any_unfixed[L]).  Pure-array form
    shared verbatim by the unfused commit and the resident megakernel.

    `middle_out` (requires the carried bitset `dom`) picks the remaining
    domain value nearest the interval midpoint, ties to the lower value
    — the fail-first ordering the ROADMAP flags as blocking dense
    nqueens backtracking."""
    bv = branch_vars
    blb = jnp.take(lb, bv, axis=1)                          # [L, B]
    bub = jnp.take(ub, bv, axis=1)
    unfixed = blb < bub
    width = bub - blb
    big = jnp.iinfo(lb.dtype).max // 4
    if var_strategy == INPUT_ORDER:
        pos = jnp.argmax(unfixed, axis=1)                   # first True
    elif var_strategy == MIN_DOM:
        pos = jnp.argmin(jnp.where(unfixed, width, big), axis=1)
    elif var_strategy == MIN_LB:
        pos = jnp.argmin(jnp.where(unfixed, blb, big), axis=1)
    else:
        raise ValueError(var_strategy)
    var = jnp.take(bv, pos)                                 # [L]
    idx = var.astype(jnp.int32)[:, None]
    vlb = jnp.take_along_axis(lb, idx, axis=1)[:, 0]
    vub = jnp.take_along_axis(ub, idx, axis=1)[:, 0]
    if val_strategy == VAL_MIN:
        m = vlb
    elif val_strategy == VAL_SPLIT:
        m = (vlb + vub) // 2
    elif val_strategy == VAL_MIDDLE_OUT:
        if dom is None:
            raise ValueError("middle_out value ordering needs the bitset "
                             "domain store (search carries it whenever "
                             "the strategy is selected)")
        L, _, W = dom.shape
        K32 = W * B.WORD_BITS
        vdom = jnp.take_along_axis(
            dom, var.astype(jnp.int32)[:, None, None], axis=1)[:, 0]
        bits = ((vdom[:, :, None]
                 >> jnp.arange(B.WORD_BITS, dtype=jnp.uint32))
                & np.uint32(1)).reshape(L, K32)              # [L, 32W]
        voff = jnp.take(dom_off, var.astype(jnp.int32))       # [L]
        vals = voff[:, None] + jnp.arange(K32, dtype=lb.dtype)[None, :]
        mid = (vlb + vub) // 2
        ok = (bits != 0) & (vals >= vlb[:, None]) & (vals <= vub[:, None])
        # 2·distance + 1 for the upper side: nearest wins, ties go low
        score = 2 * jnp.abs(vals - mid[:, None]) + (vals > mid[:, None])
        pos = jnp.argmin(jnp.where(ok, score, big), axis=1)
        m = voff + pos.astype(lb.dtype)
    else:
        raise ValueError(val_strategy)
    return var, m, jnp.any(unfixed, axis=1)


class LanePrep(NamedTuple):
    """Lane-batched carry between `lane_load_tile` and `lane_commit_tile`
    — everything the post-propagation bookkeeping needs besides the
    propagated store.  All fields carry a leading ``[L]`` lane axis."""
    lb: jax.Array            # i[L, V] store with decision + bound tells
    ub: jax.Array            # i[L, V]
    root_lb: jax.Array       # i[L, V]
    root_ub: jax.Array       # i[L, V]
    depth: jax.Array         # i32[L]
    next_sub: jax.Array      # i32[L]
    fresh: jax.Array         # bool[L]
    active: jax.Array        # bool[L] — lane participates this superstep
    dom: Optional[jax.Array] = None        # u32[L, V, W] (bitset store)
    root_dom: Optional[jax.Array] = None


def lane_load_tile(subs_lb, subs_ub, st: LaneState, gbest, *,
                   obj_var: int, dom_off=None, dom_track=None,
                   n_words: int = 1) -> LanePrep:
    """Pre-propagation phase over a lane tile: subproblem load + B&B tell.

    `subs_lb/ub`: the (tile-visible) subproblem pool [S, V] (assignment
    happens in dispatch_pool_tile — the shared queue, TURBO's dynamic
    EPS; `done` is also decided there).
    `gbest`: scalar global incumbent bound (already cross-lane/device
    min'd).  Pure-array over ``[L, V]`` — no vmap, no `CompiledModel` —
    so the resident megakernel runs this exact function on VMEM refs.
    """
    S, V = subs_lb.shape
    dt = subs_lb.dtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)

    # -- 1. load the dispatcher-assigned subproblem when fresh -------------
    can_load = st.next_sub < S
    load = st.fresh & can_load
    sub = jnp.clip(st.next_sub, 0, S - 1)
    loadc = load[:, None]
    root_lb = jnp.where(loadc, jnp.take(subs_lb, sub, axis=0), st.root_lb)
    root_ub = jnp.where(loadc, jnp.take(subs_ub, sub, axis=0), st.root_ub)
    lb = jnp.where(loadc, root_lb, st.lb)
    ub = jnp.where(loadc, root_ub, st.ub)
    depth = jnp.where(load, 0, st.depth)
    next_sub = jnp.where(load, UNASSIGNED, st.next_sub)  # consumed
    fresh = st.fresh & ~load & ~st.done
    active = ~st.done & ~fresh
    dom = root_dom = None
    if st.dom is not None:
        # the EPS pool is interval-only (eps.decompose splits boxes), so
        # the subproblem's root bitset is exactly its box — lossless
        fresh_dom = B.from_bounds(root_lb, root_ub, dom_off, n_words,
                                  track=dom_track)
        root_dom = jnp.where(loadc[..., None], fresh_dom, st.root_dom)
        dom = jnp.where(loadc[..., None], root_dom, st.dom)

    # -- 2. branch & bound tell ------------------------------------------
    if obj_var >= 0:
        inc = jnp.minimum(gbest, st.best_obj)      # global ⊓ own incumbent
        bound = jnp.where(inc < big, inc - 1, big)
        tell = jnp.where(active, bound, big)                       # [L]
        vcols = jnp.arange(V)
        ub = jnp.where(vcols[None, :] == obj_var,
                       jnp.minimum(ub, tell[:, None]), ub)
    return LanePrep(lb=lb, ub=ub, root_lb=root_lb, root_ub=root_ub,
                    depth=depth, next_sub=next_sub, fresh=fresh,
                    active=active, dom=dom, root_dom=root_dom)


def lane_commit_tile(st: LaneState, pre: LanePrep, lb, ub, sweeps,
                     converged, branch_vars, *, obj_var: int,
                     var_strategy: str, val_strategy: str,
                     dom=None, dom_off=None, dom_track=None) -> LaneState:
    """Post-propagation phase over a lane tile: record / backtrack-or-
    branch.  `lb`, `ub`, `sweeps`, `converged` are the batched backend
    fixpoint outputs.  Pure-array over ``[L, V]`` (shared verbatim by the
    resident megakernel); the path depth limit is the static ``MD`` of
    the decision arrays."""
    L, V = lb.shape
    md = st.dec_var.shape[1]
    dt = lb.dtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)
    root_lb, root_ub = pre.root_lb, pre.root_ub
    depth, next_sub = pre.depth, pre.next_sub
    fresh, active, done = pre.fresh, pre.active, st.done

    failed = jnp.any(lb > ub, axis=1)
    # a fully-fixed store is only a SOLUTION at a (per-lane) fixed point:
    # with capped sweeps (§Perf H1), unconverged lanes keep propagating on
    # the next superstep instead of branching/recording (soundness guard).
    solved = active & converged & ~failed & jnp.all(lb == ub, axis=1)
    failed = active & failed

    # a node = one propagate-to-completion event (failed counts; an
    # unconverged capped superstep is a partial node, not counted)
    n_nodes = st.n_nodes + (failed | (active & converged)).astype(jnp.int32)
    n_fails = st.n_fails + failed.astype(jnp.int32)
    n_sols = st.n_sols + solved.astype(jnp.int32)
    n_sweeps = st.n_sweeps + jnp.asarray(sweeps, jnp.int32)

    # -- 3. record incumbent ------------------------------------------------
    if obj_var >= 0:
        better = solved & (lb[:, obj_var] < st.best_obj)
        best_obj = jnp.where(better, lb[:, obj_var], st.best_obj)
    else:
        better = solved & ~st.has_sol
        best_obj = jnp.where(better, big, st.best_obj)
    best_sol = jnp.where(better[:, None], lb, st.best_sol)
    has_sol = st.has_sol | solved

    # -- 4. backtrack or branch ---------------------------------------------
    bt = failed | solved
    lvl = jnp.arange(md)
    open_mask = (~st.dec_flip) & (lvl[None, :] < depth[:, None])
    has_open = jnp.any(open_mask, axis=1)
    bt_level = jnp.max(jnp.where(open_mask, lvl[None, :], -1), axis=1)
    exhausted = active & bt & ~has_open

    do_bt = active & bt & has_open
    # pop everything deeper than bt_level, flip bt_level to its right branch
    dec_flip = jnp.where(
        do_bt[:, None],
        (st.dec_flip & (lvl[None, :] < bt_level[:, None]))
        | (lvl[None, :] == bt_level[:, None]),
        st.dec_flip)
    depth_bt = (bt_level + 1).astype(jnp.int32)

    # full recomputation for backtracking lanes
    root_dom = pre.root_dom
    if dom is None:
        rlb, rub = apply_path_tile(root_lb, root_ub, st.dec_var,
                                   st.dec_val, dec_flip, depth_bt,
                                   val_strategy=val_strategy,
                                   dom_track=dom_track)
    else:
        rlb, rub, rdom = apply_path_tile(root_lb, root_ub, st.dec_var,
                                         st.dec_val, dec_flip, depth_bt,
                                         val_strategy=val_strategy,
                                         root_dom=root_dom,
                                         dom_off=dom_off,
                                         dom_track=dom_track)

    # branching lanes (only at per-lane fixed points: unconverged lanes
    # do nothing this superstep and propagate further on the next)
    var, m, any_unfixed = select_branch_tile(
        lb, ub, branch_vars, var_strategy=var_strategy,
        val_strategy=val_strategy, dom=dom, dom_off=dom_off)
    do_branch = active & ~bt & converged & any_unfixed
    overflow = do_branch & (depth >= md)
    do_branch = do_branch & ~overflow
    at_lvl = lvl[None, :] == jnp.clip(depth, 0, md - 1)[:, None]  # [L, MD]
    upd = do_branch[:, None] & at_lvl
    dec_var = jnp.where(upd, var.astype(jnp.int32)[:, None], st.dec_var)
    dec_val = jnp.where(upd, m[:, None], st.dec_val)
    dec_flip = jnp.where(upd, False, dec_flip)
    vcols = jnp.arange(V)
    btell = jnp.where(do_branch, m, big)                          # [L]
    bub = jnp.where(vcols[None, :] == var[:, None],               # left: x ≤ m
                    jnp.minimum(ub, btell[:, None]), ub)
    if val_strategy == VAL_MIDDLE_OUT:                    # left: x = m
        trk_var = jnp.take(dom_track, var.astype(jnp.int32)) != 0
        btell_lo = jnp.where(do_branch & trk_var, m, -big)  # wide: x ≤ m
        blb = jnp.where(vcols[None, :] == var[:, None],
                        jnp.maximum(lb, btell_lo[:, None]), lb)
    else:
        blb = lb

    # -- 5. commit per-lane outcome ------------------------------------------
    new_lb = jnp.where(do_bt[:, None], rlb, blb)
    new_ub = jnp.where(do_bt[:, None], rub, bub)
    new_depth = jnp.where(do_bt, depth_bt,
                          jnp.where(do_branch, depth + 1, depth))
    fresh = fresh | exhausted | overflow
    incomplete = st.incomplete | overflow
    new_dom = (None if dom is None
               else jnp.where(do_bt[:, None, None], rdom, dom))

    return LaneState(
        lb=new_lb, ub=new_ub, root_lb=root_lb, root_ub=root_ub,
        dec_var=dec_var, dec_val=dec_val, dec_flip=dec_flip,
        depth=new_depth, next_sub=next_sub, fresh=fresh, done=done,
        incomplete=incomplete, best_obj=best_obj, best_sol=best_sol,
        has_sol=has_sol, n_nodes=n_nodes, n_fails=n_fails, n_sols=n_sols,
        n_sweeps=n_sweeps, dom=new_dom, root_dom=root_dom)


def lanes_step(cm: CompiledModel, subs_lb, subs_ub, opts: SearchOptions,
               st: LaneState, gbest, pool_head):
    """One superstep over all lanes: pool dispatch (idle-lane
    replenishment) → tile load → **one** lane-batched backend fixpoint
    over the whole [n_lanes, V] store tensor → tile commit.  Every phase
    is a pure-array tile function; propagation is a single batched call
    (one kernel invocation per superstep — the TURBO shape, DESIGN.md
    §9).  The `pallas_resident` backend fuses K of these supersteps into
    one kernel launch by running the same tile functions inside Pallas
    (DESIGN.md §13).

    `pool_head` is the device-local cursor into the EPS pool; the updated
    cursor is returned alongside the new lane state.
    """
    st, pool_head = dispatch_pool(st, pool_head, subs_lb.shape[0])
    pre = lane_load_tile(subs_lb, subs_ub, st, gbest, obj_var=cm.obj_var,
                         dom_off=cm.dom_off, dom_track=cm.dom_track,
                         n_words=cm.n_words)
    backend = get_backend(opts.backend, **dict(opts.backend_opts))
    if pre.dom is not None:
        lb, ub, dom, sweeps, converged = backend.fixpoint_batch(
            cm, pre.lb, pre.ub, dom=pre.dom,
            max_iters=opts.max_fixpoint_iters)
    else:
        dom = None
        lb, ub, sweeps, converged = backend.fixpoint_batch(
            cm, pre.lb, pre.ub, max_iters=opts.max_fixpoint_iters)
    st = lane_commit_tile(st, pre, lb, ub, sweeps, converged,
                          cm.branch_vars, obj_var=cm.obj_var,
                          var_strategy=opts.var_strategy,
                          val_strategy=opts.val_strategy,
                          dom=dom, dom_off=cm.dom_off,
                          dom_track=cm.dom_track)
    return st, pool_head


def lanes_best(st: LaneState, dt):
    """Cross-lane incumbent (the shared global-memory bound of the paper)."""
    return jnp.min(st.best_obj)


def all_done(st: LaneState) -> jax.Array:
    return jnp.all(st.done)


def lane_totals(st: LaneState) -> dict:
    """Cross-lane counter totals, as host ints — the stats block every
    terminal `SolveResult` is assembled from (api.derive_result).  Works
    on device lane states and on host-side (numpy) slices alike."""
    return dict(n_nodes=int(np.asarray(st.n_nodes).sum()),
                n_fails=int(np.asarray(st.n_fails).sum()),
                n_sols=int(np.asarray(st.n_sols).sum()),
                n_sweeps=int(np.asarray(st.n_sweeps).sum()))
