"""Batched propagate-and-search (paper §TURBO).

A *lane* is the TPU analogue of a TURBO CUDA block: it owns one EPS
subproblem at a time and runs depth-first search on it.  Lanes are a batch
axis (`vmap`), sharded over mesh devices by the engine.

Per the paper's design choices, faithfully kept:
  * two stores per lane: the subproblem **root** store and the current
    store; backtracking copies the root and re-commits the decision path
    (full recomputation, no trail).  Because decisions are `tell`s (joins),
    the whole path is re-joined in one scatter and then a single fixpoint
    runs — recomputation is one propagation, not depth many;
  * eventless propagation (fixpoint.py) — every propagator, every sweep;
  * branch & bound through a shared best objective (global-memory cell in
    the paper; a cross-lane min + `lax.pmin` here).

Branching is (var, m) with left = `x ≤ m`, right = `x ≥ m+1`; value
strategies: `m = lb` (assign-min, the scheduling default) or the domain
midpoint (split).  Variable strategies: input order / min domain / min lb.

All control flow is mask-based so the step functions vmap; a lane that is
`done` keeps sweeping its converged store, which is a no-op by
idempotence (Thm. 2) — correctness never depends on lane divergence.

Superstep structure (the TURBO shape, DESIGN.md §2.3 and §9): propagation
is **hoisted out of the per-lane vmap**.  `lanes_step` runs four phases —
`dispatch_pool` (idle lanes pop the next EPS subproblems off the shared
per-device pool, DESIGN.md §9), then a vmapped `lane_load` (subproblem
load + B&B bound tell), then **one lane-batched backend fixpoint over the
whole [n_lanes, V] store tensor** (`SearchOptions.backend` picks
gather / scatter / pallas), then a vmapped `lane_commit` (solution
recording, backtrack-or-branch bookkeeping).  The pool itself comes from
`eps.decompose` (engine.solve's ``eps_target``); the shared incumbent
`gbest` each lane prunes against is min-reduced across lanes and mesh
devices by the engine between supersteps (DESIGN.md §9 bound sharing).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.compile import CompiledModel
from repro.core.backend import get_backend

# variable-selection strategies
INPUT_ORDER = "input_order"
MIN_DOM = "min_dom"
MIN_LB = "min_lb"

# sentinel: lane has no assigned subproblem (shared-queue dispatch)
UNASSIGNED = np.iinfo(np.int32).max // 2
# value-selection strategies
VAL_MIN = "min"       # m = lb  (assign lower bound)
VAL_SPLIT = "split"   # m = (lb+ub)//2


@dataclasses.dataclass(frozen=True)
class SearchOptions:
    var_strategy: str = INPUT_ORDER
    val_strategy: str = VAL_MIN
    max_depth: int = 2048
    max_fixpoint_iters: Optional[int] = None
    stop_on_first: bool = False      # satisfaction: stop at first solution
    # propagation backend for the superstep's lane-batched fixpoint:
    # "gather" | "scatter" | "pallas" (see core/backend.py)
    backend: str = "gather"
    # backend construction options (e.g. lane_tile/interpret for pallas);
    # must be hashable — a tuple of (key, value) pairs
    backend_opts: Tuple = ()


class LaneState(NamedTuple):
    # current + root stores (the paper's two stores per block)
    lb: jax.Array            # i[V]
    ub: jax.Array            # i[V]
    root_lb: jax.Array       # i[V]
    root_ub: jax.Array       # i[V]
    # decision path
    dec_var: jax.Array       # i32[MD]
    dec_val: jax.Array       # i[MD]   branch point m
    dec_flip: jax.Array      # bool[MD] True once on the right branch
    depth: jax.Array         # i32
    # subproblem queue cursor (static round-robin over the shard)
    next_sub: jax.Array      # i32
    fresh: jax.Array         # bool — needs to load a new subproblem
    done: jax.Array          # bool — queue exhausted
    incomplete: jax.Array    # bool — hit depth limit (search not exhaustive)
    # incumbent
    best_obj: jax.Array      # i
    best_sol: jax.Array      # i[V]
    has_sol: jax.Array       # bool
    # stats
    n_nodes: jax.Array       # i32
    n_fails: jax.Array       # i64
    n_sols: jax.Array        # i64
    n_sweeps: jax.Array      # i64


def init_lanes(cm: CompiledModel, n_lanes: int, opts: SearchOptions) -> LaneState:
    V = cm.n_vars
    dt = cm.jdtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)
    z = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
    return LaneState(
        lb=jnp.zeros((n_lanes, V), dt), ub=jnp.zeros((n_lanes, V), dt),
        root_lb=jnp.zeros((n_lanes, V), dt), root_ub=jnp.zeros((n_lanes, V), dt),
        dec_var=jnp.zeros((n_lanes, opts.max_depth), jnp.int32),
        dec_val=jnp.zeros((n_lanes, opts.max_depth), dt),
        dec_flip=jnp.zeros((n_lanes, opts.max_depth), bool),
        depth=jnp.zeros((n_lanes,), jnp.int32),
        next_sub=jnp.full((n_lanes,), UNASSIGNED, jnp.int32),
        fresh=jnp.ones((n_lanes,), bool),
        done=jnp.zeros((n_lanes,), bool),
        incomplete=jnp.zeros((n_lanes,), bool),
        best_obj=jnp.full((n_lanes,), big, dt),
        best_sol=jnp.zeros((n_lanes, V), dt),
        has_sol=jnp.zeros((n_lanes,), bool),
        n_nodes=z(n_lanes), n_fails=z(n_lanes), n_sols=z(n_lanes),
        n_sweeps=z(n_lanes),
    )


def dispatch_pool(st: LaneState, pool_head, n_subs: int):
    """Shared per-device subproblem queue (the paper's dynamic EPS,
    DESIGN.md §9): fresh lanes pop the next pool indices; when the pool is
    drained they are marked done.  Replaces static round-robin — no
    straggler lane can sit on a long private queue while others idle.
    Runs as phase 0 of every `lanes_step`, so a lane that exhausts its
    subproblem is replenished on the very next superstep."""
    want = st.fresh & ~st.done & (st.next_sub >= n_subs)
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    idx = pool_head + rank
    got = want & (idx < n_subs)
    next_sub = jnp.where(got, idx.astype(jnp.int32), st.next_sub)
    done = st.done | (want & (idx >= n_subs))
    new_head = jnp.minimum(pool_head + want.astype(jnp.int32).sum(),
                           n_subs)
    return st._replace(next_sub=next_sub, done=done), new_head


def _apply_path(cm: CompiledModel, root_lb, root_ub, dec_var, dec_val,
                dec_flip, depth):
    """Full recomputation: root ⊔ all decision tells, in one scatter."""
    md = dec_var.shape[0]
    lvl = jnp.arange(md)
    on = lvl < depth
    dt = cm.jdtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)
    ub_tell = jnp.where(on & ~dec_flip, dec_val, big)           # left: x ≤ m
    lb_tell = jnp.where(on & dec_flip, dec_val + 1, -big)       # right: x ≥ m+1
    ub = root_ub.at[dec_var].min(ub_tell)
    lb = root_lb.at[dec_var].max(lb_tell)
    return lb, ub


def _select_branch(cm: CompiledModel, lb, ub, opts: SearchOptions):
    """Pick (var, m) for the next decision. Returns (var, m, any_unfixed)."""
    bv = cm.branch_vars
    blb, bub = lb[bv], ub[bv]
    unfixed = blb < bub
    width = bub - blb
    big = jnp.iinfo(cm.jdtype).max // 4
    if opts.var_strategy == INPUT_ORDER:
        pos = jnp.argmax(unfixed)                   # first True
    elif opts.var_strategy == MIN_DOM:
        pos = jnp.argmin(jnp.where(unfixed, width, big))
    elif opts.var_strategy == MIN_LB:
        pos = jnp.argmin(jnp.where(unfixed, blb, big))
    else:
        raise ValueError(opts.var_strategy)
    var = bv[pos]
    if opts.val_strategy == VAL_MIN:
        m = lb[var]
    elif opts.val_strategy == VAL_SPLIT:
        m = (lb[var] + ub[var]) // 2
    else:
        raise ValueError(opts.val_strategy)
    return var, m, jnp.any(unfixed)


class LanePrep(NamedTuple):
    """Per-lane carry between `lane_load` and `lane_commit` — everything
    the post-propagation bookkeeping needs besides the propagated store."""
    lb: jax.Array            # i[V] store with decision + bound tells applied
    ub: jax.Array            # i[V]
    root_lb: jax.Array       # i[V]
    root_ub: jax.Array       # i[V]
    depth: jax.Array         # i32
    next_sub: jax.Array      # i32
    fresh: jax.Array         # bool
    active: jax.Array        # bool — lane participates in this superstep


def lane_load(cm: CompiledModel, subs_lb, subs_ub, opts: SearchOptions,
              st: LaneState, gbest) -> LanePrep:
    """Pre-propagation phase of one lane: subproblem load + B&B tell.

    `subs_lb/ub`: the device-local subproblem pool [S, V] (assignment
    happens in dispatch_pool — the shared per-device queue, TURBO's
    dynamic EPS; `done` is also decided there).
    `gbest`: scalar global incumbent bound (already cross-lane/device
    min'd).  Runs under vmap; propagation itself is hoisted out into the
    backend's lane-batched fixpoint (see `lanes_step`).
    """
    S = subs_lb.shape[0]
    dt = cm.jdtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)

    # -- 1. load the dispatcher-assigned subproblem when fresh -------------
    can_load = st.next_sub < S
    load = st.fresh & can_load
    sub = jnp.clip(st.next_sub, 0, S - 1)
    root_lb = jnp.where(load, subs_lb[sub], st.root_lb)
    root_ub = jnp.where(load, subs_ub[sub], st.root_ub)
    lb = jnp.where(load, root_lb, st.lb)
    ub = jnp.where(load, root_ub, st.ub)
    depth = jnp.where(load, 0, st.depth)
    next_sub = jnp.where(load, UNASSIGNED, st.next_sub)  # consumed
    fresh = st.fresh & ~load & ~st.done
    active = ~st.done & ~fresh

    # -- 2. branch & bound tell ------------------------------------------
    if cm.obj_var >= 0:
        inc = jnp.minimum(gbest, st.best_obj)      # global ⊓ own incumbent
        bound = jnp.where(inc < big, inc - 1, big)
        ub = ub.at[cm.obj_var].min(jnp.where(active, bound, big))
    return LanePrep(lb=lb, ub=ub, root_lb=root_lb, root_ub=root_ub,
                    depth=depth, next_sub=next_sub, fresh=fresh,
                    active=active)


def lane_commit(cm: CompiledModel, opts: SearchOptions, st: LaneState,
                pre: LanePrep, lb, ub, sweeps, converged) -> LaneState:
    """Post-propagation phase of one lane: record / backtrack-or-branch.

    `lb`, `ub`, `sweeps`, `converged` are this lane's slice of the batched
    backend fixpoint.  Runs under vmap.
    """
    dt = cm.jdtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)
    root_lb, root_ub = pre.root_lb, pre.root_ub
    depth, next_sub = pre.depth, pre.next_sub
    fresh, active, done = pre.fresh, pre.active, st.done

    failed = jnp.any(lb > ub)
    # a fully-fixed store is only a SOLUTION at a (per-lane) fixed point:
    # with capped sweeps (§Perf H1), unconverged lanes keep propagating on
    # the next superstep instead of branching/recording (soundness guard).
    solved = active & converged & ~failed & jnp.all(lb == ub)
    failed = active & failed

    # a node = one propagate-to-completion event (failed counts; an
    # unconverged capped superstep is a partial node, not counted)
    n_nodes = st.n_nodes + (failed | (active & converged)).astype(jnp.int32)
    n_fails = st.n_fails + failed.astype(jnp.int32)
    n_sols = st.n_sols + solved.astype(jnp.int32)
    n_sweeps = st.n_sweeps + jnp.asarray(sweeps, jnp.int32)

    # -- 3. record incumbent ------------------------------------------------
    if cm.obj_var >= 0:
        better = solved & (lb[cm.obj_var] < st.best_obj)
    else:
        better = solved & ~st.has_sol
    best_obj = jnp.where(better, lb[cm.obj_var] if cm.obj_var >= 0 else big,
                         st.best_obj)
    best_sol = jnp.where(better, lb, st.best_sol)
    has_sol = st.has_sol | solved

    # -- 4. backtrack or branch ---------------------------------------------
    bt = failed | solved
    lvl = jnp.arange(opts.max_depth)
    open_mask = (~st.dec_flip) & (lvl < depth)
    has_open = jnp.any(open_mask)
    bt_level = jnp.max(jnp.where(open_mask, lvl, -1))
    exhausted = active & bt & ~has_open

    do_bt = active & bt & has_open
    # pop everything deeper than bt_level, flip bt_level to its right branch
    dec_flip = jnp.where(
        do_bt,
        (st.dec_flip & (lvl < bt_level)) | (lvl == bt_level),
        st.dec_flip)
    depth_bt = bt_level + 1

    # full recomputation for backtracking lanes
    rlb, rub = _apply_path(cm, root_lb, root_ub, st.dec_var, st.dec_val,
                           dec_flip, depth_bt)

    # branching lanes (only at per-lane fixed points: unconverged lanes
    # do nothing this superstep and propagate further on the next)
    var, m, any_unfixed = _select_branch(cm, lb, ub, opts)
    do_branch = active & ~bt & converged & any_unfixed
    overflow = do_branch & (depth >= opts.max_depth)
    do_branch = do_branch & ~overflow
    dec_var = jnp.where(do_branch,
                        st.dec_var.at[jnp.clip(depth, 0, opts.max_depth - 1)]
                        .set(var.astype(jnp.int32)), st.dec_var)
    dec_val = jnp.where(do_branch,
                        st.dec_val.at[jnp.clip(depth, 0, opts.max_depth - 1)]
                        .set(m), st.dec_val)
    dec_flip = jnp.where(do_branch,
                         dec_flip.at[jnp.clip(depth, 0, opts.max_depth - 1)]
                         .set(False), dec_flip)
    blb, bub = lb, ub.at[var].min(jnp.where(do_branch, m, big))  # left: x ≤ m

    # -- 5. commit per-lane outcome ------------------------------------------
    new_lb = jnp.where(do_bt, rlb, blb)
    new_ub = jnp.where(do_bt, rub, bub)
    new_depth = jnp.where(do_bt, depth_bt,
                          jnp.where(do_branch, depth + 1, depth))
    fresh = fresh | exhausted | overflow
    incomplete = st.incomplete | overflow

    return LaneState(
        lb=new_lb, ub=new_ub, root_lb=root_lb, root_ub=root_ub,
        dec_var=dec_var, dec_val=dec_val, dec_flip=dec_flip,
        depth=new_depth, next_sub=next_sub, fresh=fresh, done=done,
        incomplete=incomplete, best_obj=best_obj, best_sol=best_sol,
        has_sol=has_sol, n_nodes=n_nodes, n_fails=n_fails, n_sols=n_sols,
        n_sweeps=n_sweeps)


def lanes_step(cm: CompiledModel, subs_lb, subs_ub, opts: SearchOptions,
               st: LaneState, gbest, pool_head):
    """One superstep over all lanes: pool dispatch (idle-lane
    replenishment) → vmapped load → **one** lane-batched backend fixpoint
    over the whole [n_lanes, V] store tensor → vmapped commit.  Only the
    bookkeeping is vmapped; propagation is a single batched call (one
    kernel invocation per superstep — the TURBO shape, DESIGN.md §9).

    `pool_head` is the device-local cursor into the EPS pool; the updated
    cursor is returned alongside the new lane state.
    """
    st, pool_head = dispatch_pool(st, pool_head, subs_lb.shape[0])
    pre = jax.vmap(partial(lane_load, cm, subs_lb, subs_ub, opts),
                   in_axes=(0, None))(st, gbest)
    backend = get_backend(opts.backend, **dict(opts.backend_opts))
    lb, ub, sweeps, converged = backend.fixpoint_batch(
        cm, pre.lb, pre.ub, max_iters=opts.max_fixpoint_iters)
    st = jax.vmap(partial(lane_commit, cm, opts))(
        st, pre, lb, ub, sweeps, converged)
    return st, pool_head


def lanes_best(st: LaneState, dt):
    """Cross-lane incumbent (the shared global-memory bound of the paper)."""
    return jnp.min(st.best_obj)


def all_done(st: LaneState) -> jax.Array:
    return jnp.all(st.done)


def lane_totals(st: LaneState) -> dict:
    """Cross-lane counter totals, as host ints — the stats block every
    terminal `SolveResult` is assembled from (api.derive_result).  Works
    on device lane states and on host-side (numpy) slices alike."""
    return dict(n_nodes=int(np.asarray(st.n_nodes).sum()),
                n_fails=int(np.asarray(st.n_fails).sum()),
                n_sols=int(np.asarray(st.n_sols).sum()),
                n_sweeps=int(np.asarray(st.n_sweeps).sum()))
