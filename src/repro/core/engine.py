"""Legacy blocking entry point — now a thin shim over the session API.

The solver proper lives in `repro.core.api` (public façade
``repro.solver``, DESIGN.md §11): `SolveConfig` presets, compile-cached
`Solver` sessions, batched `solve_many` and the streaming `solve_iter`.
This module keeps the original ``engine.solve(cm, n_lanes=..., ...)``
signature working — it maps the kwarg sprawl onto a `SolveConfig` and
delegates to the process-wide default session (so even legacy callers
now get compile caching across calls) — and re-exports the status
constants and `SolveResult` for back-compat (the chunk runner itself
now lives in `api._run_chunk`).

New code should use::

    from repro import solver
    res = solver.solve(cm)                              # one-shot
    sess = solver.Solver(solver.SolveConfig.preset("prove"))
    res = sess.solve(cm)                                # session (cached)
"""

from __future__ import annotations

import warnings
from typing import Optional

import jax

from repro.core.compile import CompiledModel
from repro.core import search as S
from repro.core import api as _api

# re-exports (historical home of these names; baseline.py and the test
# suite import them from here)
from repro.core.api import (  # noqa: F401
    OPTIMAL, SAT, UNSAT, UNKNOWN, SolveResult, Improvement, SolveConfig,
    derive_result)


def solve(cm: CompiledModel,
          n_lanes: int = 64,
          n_subproblems: Optional[int] = None,
          opts: Optional[S.SearchOptions] = None,
          timeout_s: Optional[float] = None,
          max_supersteps: Optional[int] = None,
          chunk: int = 256,
          mesh: Optional[jax.sharding.Mesh] = None,
          lane_axes: tuple = (),
          subs: Optional[tuple] = None,
          eps_target: Optional[int] = None,
          ) -> SolveResult:
    """Deprecated blocking solve — use ``repro.solver`` (DESIGN.md §11).

    Exactly equivalent to building a `SolveConfig` from these kwargs and
    calling ``repro.solver.solve(cm, config=cfg, subs=subs)``; kept so
    existing callers and the paper-era examples keep running.  The
    delegation goes through the shared default session, so repeated
    calls on same-shape models reuse compiled runners.
    """
    warnings.warn(
        "engine.solve is deprecated; use repro.solver "
        "(Solver/SolveConfig sessions — see DESIGN.md §11)",
        DeprecationWarning, stacklevel=2)
    o = opts or S.SearchOptions()
    cfg = SolveConfig(
        n_lanes=n_lanes,
        eps_target=(eps_target if eps_target is not None else n_subproblems),
        chunk=chunk, timeout_s=timeout_s, max_supersteps=max_supersteps,
        backend=o.backend, backend_opts=o.backend_opts,
        var_strategy=o.var_strategy, val_strategy=o.val_strategy,
        max_depth=o.max_depth, max_fixpoint_iters=o.max_fixpoint_iters,
        stop_on_first=o.stop_on_first, mesh=mesh,
        lane_axes=tuple(lane_axes))
    return _api.default_solver().solve(cm, subs=subs, config=cfg)
