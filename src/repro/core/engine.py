"""Top-level solver: EPS pool × lanes × mesh (paper §TURBO, evaluation).

Execution hierarchy (the GPU→TPU mapping of DESIGN.md §2):

    mesh devices (shard_map)  ↔  GPU / SMs            (EPS pool is sharded)
    lanes per device (batch)  ↔  CUDA blocks           (one subproblem each)
    propagator sweep (tensor) ↔  threads within block  (one dense op)

EPS flow (DESIGN.md §9): ``solve`` decomposes the root into
``eps_target`` consistent subproblems (`eps.decompose`), seeds the lane
pool from them, and every superstep (`search.lanes_step`) replenishes
idle lanes from the remaining pool before propagating.  ``eps_target=1``
degrades to single-root search — the baseline the EPS speedup tests
compare against.

Propagation inside the superstep is **one lane-batched backend call**
over the whole [n_lanes, V] store tensor (`SearchOptions.backend`
selects gather / scatter / pallas — see core/backend.py); only the
branch/backtrack bookkeeping is vmapped per lane.

Branch & bound: each superstep ends with a cross-lane ``min`` and a
``lax.pmin`` across every mesh axis — the analogue of TURBO's shared
global-memory best bound, made deterministic by the lattice join — so
every lane prunes against the best objective found *anywhere*
(DESIGN.md §9 bound sharing).

The solve loop runs in fixed-size jitted *chunks* so the host can enforce
wall-clock timeouts (the paper uses 5 min / 30 s budgets) and so the
multi-device while-loop has an identical trip count everywhere (the
global-done flag is all-reduced in the body, never in the cond).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.compile import CompiledModel
from repro.core import eps
from repro.core import search as S

OPTIMAL = "OPTIMAL"
SAT = "SAT"
UNSAT = "UNSAT"
UNKNOWN = "UNKNOWN"


@dataclasses.dataclass
class SolveResult:
    status: str
    objective: Optional[int]
    solution: Optional[np.ndarray]
    n_nodes: int
    n_fails: int
    n_sols: int
    n_sweeps: int
    n_supersteps: int
    wall_s: float
    complete: bool

    @property
    def nodes_per_sec(self) -> float:
        return self.n_nodes / max(self.wall_s, 1e-9)


def _chunk_body(cm: CompiledModel, subs_lb, subs_ub, opts: S.SearchOptions,
                stop_on_first: bool, axis_names, carry):
    st, gbest, gdone, it, pool_head = carry
    st, new_head = S.lanes_step(cm, subs_lb, subs_ub, opts, st, gbest,
                                pool_head[0])
    pool_head = new_head[None].astype(jnp.int32)
    best = jnp.min(st.best_obj)
    done = jnp.all(st.done)
    any_sol = jnp.any(st.has_sol)
    if axis_names:
        best = lax.pmin(best, axis_names)
        done = lax.pmin(done.astype(jnp.int32), axis_names) == 1
        any_sol = lax.pmax(any_sol.astype(jnp.int32), axis_names) == 1
    gbest = jnp.minimum(gbest, best)
    gdone = gdone | done
    if stop_on_first:
        gdone = gdone | any_sol
    return st, gbest, gdone, it + 1, pool_head


def _run_chunk(cm: CompiledModel, subs_lb, subs_ub, opts: S.SearchOptions,
               stop_on_first: bool, chunk: int, axis_names, carry):
    body = partial(_chunk_body, cm, subs_lb, subs_ub, opts, stop_on_first,
                   axis_names)
    it0 = carry[3]

    def cond(c):
        return (~c[2]) & (c[3] - it0 < chunk)

    return lax.while_loop(cond, body, carry)


def solve(cm: CompiledModel,
          n_lanes: int = 64,
          n_subproblems: Optional[int] = None,
          opts: Optional[S.SearchOptions] = None,
          timeout_s: Optional[float] = None,
          max_supersteps: Optional[int] = None,
          chunk: int = 256,
          mesh: Optional[jax.sharding.Mesh] = None,
          lane_axes: tuple = (),
          subs: Optional[tuple] = None,
          eps_target: Optional[int] = None,
          ) -> SolveResult:
    """Solve a compiled model.

    ``eps_target`` controls the EPS decomposition (DESIGN.md §9): the
    root is split into ~``eps_target`` consistent subproblems that seed
    the shared lane pool; idle lanes replenish from it every superstep.
    ``eps_target=1`` is single-root search (one lane does all the work —
    the comparison baseline); the default ``None`` uses
    ``n_subproblems`` or ``4 * n_lanes``, the paper's
    several-subproblems-per-worker EPS rule of thumb.

    Single-device by default; pass ``mesh`` + ``lane_axes`` (mesh axis names
    to shard lanes/subproblems over) for the multi-device engine.  `subs`
    overrides the EPS pool (used by tests and the dry-run).  The
    propagation backend is picked per `opts.backend` ("gather" default;
    "pallas" runs the VMEM kernel, interpret-mode on CPU), e.g.
    ``solve(cm, opts=SearchOptions(backend="pallas"))``.
    """
    opts = opts or S.SearchOptions()
    t0 = time.time()
    if subs is None:
        target = (eps_target if eps_target is not None
                  else (n_subproblems or 4 * n_lanes))
        subs_lb, subs_ub = eps.decompose(cm, target, opts)
    else:
        subs_lb, subs_ub = subs
    subs_lb = jnp.asarray(subs_lb)
    subs_ub = jnp.asarray(subs_ub)

    dt = cm.jdtype
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)

    if mesh is not None and lane_axes:
        n_dev = int(np.prod([mesh.shape[a] for a in lane_axes]))
        # pad the pool to a multiple of the device count, shard it
        Stot = subs_lb.shape[0]
        pad = (-Stot) % n_dev
        if pad:
            # pad with explicitly-failed stores (consumed instantly)
            fl = np.asarray(subs_lb[:1]).repeat(pad, 0)
            fu = np.asarray(subs_ub[:1]).repeat(pad, 0)
            fl[:, 0], fu[:, 0] = 1, 0
            subs_lb = jnp.concatenate([subs_lb, jnp.asarray(fl)])
            subs_ub = jnp.concatenate([subs_ub, jnp.asarray(fu)])

        def device_solver(subs_lb_l, subs_ub_l, carry):
            return _run_chunk(cm, subs_lb_l, subs_ub_l, opts,
                              opts.stop_on_first, chunk, lane_axes, carry)

        spec = P(lane_axes)
        # global lane state: lane axis is sharded over `lane_axes`; each
        # device sees `n_lanes` local lanes indexing its local pool shard.
        state0 = S.init_lanes(cm, n_lanes * n_dev, opts)
        carry = (state0, big, jnp.asarray(False), jnp.asarray(0, jnp.int32),
                 jnp.zeros((n_dev,), jnp.int32))
        state_spec = jax.tree.map(lambda _: spec, state0)
        carry_spec = (state_spec, P(), P(), P(), spec)
        runner = jax.jit(jax.shard_map(
            device_solver, mesh=mesh,
            in_specs=(spec, spec, carry_spec), out_specs=carry_spec,
            check_vma=False))
        run = lambda c: runner(subs_lb, subs_ub, c)  # noqa: E731
    else:
        state0 = S.init_lanes(cm, n_lanes, opts)
        carry = (state0, big, jnp.asarray(False), jnp.asarray(0, jnp.int32),
                 jnp.zeros((1,), jnp.int32))
        runner = jax.jit(partial(_run_chunk, cm, subs_lb, subs_ub, opts,
                                 opts.stop_on_first, chunk, ()))
        run = runner

    while True:
        carry = jax.block_until_ready(run(carry))
        st, gbest, gdone, it, _ = carry
        if bool(gdone):
            break
        if timeout_s is not None and time.time() - t0 > timeout_s:
            break
        if max_supersteps is not None and int(it) >= max_supersteps:
            break

    st, gbest, gdone, it, _ = carry
    # pull incumbent from the lane that owns it (replicated out of shard_map)
    best_obj = np.asarray(st.best_obj)
    has_sol = np.asarray(st.has_sol)
    flat_best = best_obj.reshape(-1)
    wall = time.time() - t0
    complete = bool(gdone) and not bool(np.asarray(st.incomplete).any())

    n_nodes = int(np.asarray(st.n_nodes).sum())
    n_fails = int(np.asarray(st.n_fails).sum())
    n_sols = int(np.asarray(st.n_sols).sum())
    n_sweeps = int(np.asarray(st.n_sweeps).sum())

    if has_sol.any():
        i = int(flat_best.argmin()) if cm.obj_var >= 0 else \
            int(np.asarray(has_sol).reshape(-1).argmax())
        sol = np.asarray(st.best_sol).reshape(-1, cm.n_vars)[i]
        obj = int(flat_best[i]) if cm.obj_var >= 0 else None
        status = (OPTIMAL if complete and cm.obj_var >= 0 else SAT)
        if cm.obj_var < 0:
            status = SAT
    else:
        sol, obj = None, None
        status = UNSAT if complete else UNKNOWN

    return SolveResult(status=status, objective=obj, solution=sol,
                       n_nodes=n_nodes, n_fails=n_fails, n_sols=n_sols,
                       n_sweeps=n_sweeps, n_supersteps=int(it), wall_s=wall,
                       complete=complete)
