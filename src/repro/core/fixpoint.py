"""Eventless parallel fixpoint engine (paper §"Fixed point loop").

One *sweep* executes **every** propagator once and joins all their tells
into the store — this is the denotational parallel composition
``D(P₁) ⊔ … ⊔ D(Pₙ)`` realized as one bulk-synchronous tensor program
(the TPU analogue of the paper's AC-1-style loop; the `lax.while_loop`
carry of a single `changed` flag replaces the rotating ``has_changed[3]``
+ ``__syncthreads()`` scheme, because a BSP step *is* a barrier).

The sweep is *variable-centric* (gather form): each variable reduces over
the candidate bounds of all its occurrences.  Associativity/commutativity
of ⊔ makes this equal to the propagator-centric scatter form
(`kernels/ref.py` oracle), which is itself equal to any fair sequential
chaotic iteration by the paper's Prop. 3 / Thm. 6 — both equalities are
property-tested in `tests/test_semantics.py`.

Propagator semantics for row  b ⇔ Σ_j a_j·x_j ≤ c :

  ask  lb(b) ≥ 1  (b told true):   for each term k,
       slack_k = c - (Smin - min(a_k x_k));
       a_k > 0 → tell x_k ≤ ⌊slack_k / a_k⌋
       a_k < 0 → tell x_k ≥ ⌈slack_k / a_k⌉
  ask  ub(b) ≤ 0  (b told false):  propagate Σ -a_j x_j ≤ -c-1 (negation)
  entailment:   Smax ≤ c  → tell b ≥ 1  ;  Smin > c → tell b ≤ 0
       (paper's `entailed` function, via Lemma 1 monotonicity)

Candidates are clamped into the initial box (see compile.py) so all
arithmetic provably stays in dtype range.

There is exactly **one** implementation of the propagator semantics per
*kind* (the typed propagator table, DESIGN.md §12): `candidates_tile`
(ReifLinLe), `alldiff_candidates_tile` (Hall-interval bounds(Z)
consistency) and `cumulative_candidates_tile` (time-table filtering),
all written over raw tables and lane-batched ``[L, V]`` stores and
dispatched by `sweep_tile` in a fixed kind order.  Everything else — the
single-store `sweep`, the scatter oracle, the lane-batched
`fixpoint_batch` used by the search superstep, and the Pallas VMEM
kernel (`kernels/fixpoint_kernel.py` imports `sweep_tile`) — is a thin
wrapper around these tiles (DESIGN.md §2.3), so all three backends run
the same kind semantics verbatim and stay bit-identical.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compile import CompiledModel
from repro.core.model import TRUE_VAR


def _neutrals(dtype):
    big = jnp.asarray(jnp.iinfo(dtype).max // 4, dtype)
    return big, -big   # NEU_UB, NEU_LB


def _fdiv(p, q):
    return jnp.floor_divide(p, q)


def _cdiv(p, q):
    return -jnp.floor_divide(-p, q)


def candidates_tile(lb: jax.Array, ub: jax.Array, vidx, coef, rhs, bidx
                    ) -> Tuple[jax.Array, jax.Array]:
    """All tells of one sweep for a ``[L, V]`` tile of stores.

    Pure-array form (no `CompiledModel`) so the Pallas kernel body can call
    it on VMEM refs; every other propagation path wraps it.  Returns
    (cand_lb, cand_ub), each ``[L, P+1, K+1]``; slot K is the
    reified-boolean (entailment) slot.  Neutral candidates are ±big so
    they vanish under the min/max joins.
    """
    dt = lb.dtype
    a = coef[None, :, :]                                  # [1, P1, K]
    c = rhs[None, :, None]                                # [1, P1, 1]
    xl = jnp.take(lb, vidx, axis=1)                       # [L, P1, K]
    xu = jnp.take(ub, vidx, axis=1)
    tl = jnp.where(a > 0, a * xl, a * xu)     # min of a_k x_k (0 when a==0)
    tu = jnp.where(a > 0, a * xu, a * xl)     # max of a_k x_k
    smin = tl.sum(-1)                                     # [L, P1]
    smax = tu.sum(-1)

    btrue = (jnp.take(lb, bidx, axis=1) >= 1)[:, :, None]     # ask b
    bfalse = (jnp.take(ub, bidx, axis=1) <= 0)[:, :, None]    # ask ¬b

    neu_ub, neu_lb = _neutrals(dt)
    safe_a = jnp.where(a == 0, 1, a)

    # direction 1: Σ a x ≤ c (guard: b true)
    slack1 = c - (smin[:, :, None] - tl)
    ub1 = jnp.where((a > 0) & btrue, _fdiv(slack1, safe_a), neu_ub)
    lb1 = jnp.where((a < 0) & btrue, _cdiv(slack1, safe_a), neu_lb)

    # direction 2: Σ -a x ≤ -c-1 (guard: b false); with a' = -a:
    #   min(a' x) = -max(a x) = -tu ;  S'min = -smax
    na = -a
    safe_na = jnp.where(na == 0, 1, na)
    slack2 = (-c - 1) - (-smax[:, :, None] + tu)
    ub2 = jnp.where((na > 0) & bfalse, _fdiv(slack2, safe_na), neu_ub)
    lb2 = jnp.where((na < 0) & bfalse, _cdiv(slack2, safe_na), neu_lb)

    term_ub = jnp.minimum(ub1, ub2)           # [L, P1, K]
    term_lb = jnp.maximum(lb1, lb2)

    # entailment slot (tells on the reified boolean)
    one = jnp.asarray(1, dt)
    zero = jnp.asarray(0, dt)
    reif_lb = jnp.where(smax <= rhs[None, :], one, neu_lb)   # entailed → b≥1
    reif_ub = jnp.where(smin > rhs[None, :], zero, neu_ub)   # disent. → b≤0

    cand_ub = jnp.concatenate([term_ub, reif_ub[:, :, None]], axis=2)
    cand_lb = jnp.concatenate([term_lb, reif_lb[:, :, None]], axis=2)
    return cand_lb, cand_ub


def alldiff_candidates_tile(lb, ub, ad_vars, ad_offs, ad_mask
                            ) -> Tuple[jax.Array, jax.Array]:
    """Bounds(Z)-consistency tells for the AllDifferent bank
    (kind-dispatched sweep variant, DESIGN.md §12).

    Pure-array form over a ``[L, V]`` tile; shared verbatim by all three
    backends.  Hall-interval reasoning on the shifted views
    ``y_k = x_k + off_k``: for every endpoint pair (i, j) the interval
    ``I = [yl_i, yu_j]`` is tested —

      |{k : dom(y_k) ⊆ I}| > |I|  →  fail (some member pushed past its
                                     box, which crosses its bounds);
      |{k : dom(y_k) ⊆ I}| = |I|  →  I is a Hall interval: every other
                                     member's bound inside I is pushed
                                     out (lb → sup I + 1, ub → inf I - 1).

    Iterated to fixpoint this is exactly bounds(Z) consistency (all
    candidate Hall intervals have lb endpoints as infima and ub endpoints
    as suprema).  Returns (cand_lb, cand_ub), each ``[L, A1, N]``, in
    *unshifted* variable space; padded members and the dummy row A are
    neutral.
    """
    dt = lb.dtype
    neu_ub, neu_lb = _neutrals(dt)
    msk = (ad_mask[None] != 0)                              # [1, A1, N]
    off = ad_offs[None]
    yl = jnp.take(lb, ad_vars, axis=1) + off                # [L, A1, N]
    yu = jnp.take(ub, ad_vars, axis=1) + off
    a = yl[:, :, :, None]                    # interval inf from i  [L,A1,N,1]
    b = yu[:, :, None, :]                    # interval sup from j  [L,A1,1,N]
    pair_ok = msk[:, :, :, None] & msk[:, :, None, :] & (a <= b)
    inside = (msk[:, :, None, None, :]
              & (yl[:, :, None, None, :] >= a[..., None])
              & (yu[:, :, None, None, :] <= b[..., None]))  # [L,A1,N,N,N]
    cnt = inside.sum(-1).astype(dt)                         # [L, A1, N, N]
    width = b - a + 1
    overflow = pair_ok & (cnt > width)
    hall = pair_ok & (cnt == width)

    # Hall pruning: member k outside I with a bound inside I is pushed out
    out_k = msk[:, :, None, None, :] & ~inside
    a5, b5 = a[..., None], b[..., None]
    klb, kub = yl[:, :, None, None, :], yu[:, :, None, None, :]
    push = hall[..., None]
    lb_cand = jnp.where(push & out_k & (klb >= a5) & (klb <= b5),
                        b5 + 1, neu_lb)                     # [L,A1,N,N,N]
    ub_cand = jnp.where(push & out_k & (kub >= a5) & (kub <= b5),
                        a5 - 1, neu_ub)
    cand_lb = lb_cand.max(axis=(2, 3))                      # [L, A1, N]
    cand_ub = ub_cand.min(axis=(2, 3))

    # pigeonhole overflow: the row is unsatisfiable — fail every member
    # (lb pushed to +big; the box clamp keeps it at box_hi, crossing ub)
    fail = overflow.any(axis=(2, 3))                        # [L, A1]
    cand_lb = jnp.where(fail[:, :, None] & msk, -neu_lb, cand_lb)
    # back to unshifted variable space (neutrals stay effectively neutral)
    return cand_lb - off, cand_ub - off


def cumulative_candidates_tile(lb, ub, cu_svar, cu_dur, cu_dem, cu_cap,
                               horizon: int
                               ) -> Tuple[jax.Array, jax.Array]:
    """Time-table tells for the Cumulative bank (kind-dispatched sweep
    variant, DESIGN.md §12).

    Pure-array form over a ``[L, V]`` tile; shared verbatim by all three
    backends.  Classic compulsory-part reasoning on the dense time grid
    ``t ∈ [0, horizon)`` (horizon is a compile-time static):

      * task t's compulsory part is ``[lst_t, est_t + d_t)`` (nonempty
        iff lst_t < est_t + d_t);
      * profile(τ) = Σ demands of compulsory parts covering τ;
        profile(τ) > cap → fail the row;
      * task t cannot *start* at s if some τ ∈ [s, s+d_t) has
        profile₋t(τ) + r_t > cap; its lb (ub) moves to the first (last)
        feasible start ≥ est_t (≤ lst_t).

    Returns (cand_lb, cand_ub), each ``[L, C1, T]``; zero-duration /
    zero-demand tasks and the dummy row C are neutral.  Monotone: shrink
    the domains and compulsory parts only grow, so feasible starts only
    shrink (a propagator in the paper's Lemma-1 sense).
    """
    dt = lb.dtype
    neu_ub, neu_lb = _neutrals(dt)
    est = jnp.take(lb, cu_svar, axis=1)                     # [L, C1, T]
    lst = jnp.take(ub, cu_svar, axis=1)
    d = cu_dur[None]
    q = cu_dem[None]
    act = (d > 0) & (q > 0)
    cap = cu_cap[None, :, None]                             # [1, C1, 1]
    tgrid = jnp.arange(horizon, dtype=dt)                   # [H]
    run = (act[..., None] & (lst[..., None] <= tgrid)
           & (tgrid < (est + d)[..., None]))                # [L, C1, T, H]
    contrib = jnp.where(run, q[..., None], jnp.asarray(0, dt))
    profile = contrib.sum(axis=2)                           # [L, C1, H]
    overload = (profile > cap).any(-1)                      # [L, C1]

    # per-task residual profile and forbidden time points
    bad = (act[..., None]
           & (profile[:, :, None, :] - contrib + q[..., None] > cap[..., None]))
    csum = jnp.cumsum(bad.astype(dt), axis=-1)
    csum = jnp.concatenate(
        [jnp.zeros_like(csum[..., :1]), csum], axis=-1)     # [L, C1, T, H+1]
    ends = jnp.clip(tgrid[None, None, None, :] + d[..., None], 0, horizon)
    wbad = (jnp.take_along_axis(csum, ends.astype(jnp.int32), axis=-1)
            - csum[..., :-1])                               # [L, C1, T, H]
    feas = wbad == 0                                        # start grid feas.

    cand_lb = jnp.where(feas & (tgrid >= est[..., None]), tgrid,
                        -neu_lb).min(-1)                    # first feasible
    cand_ub = jnp.where(feas & (tgrid <= lst[..., None]), tgrid,
                        -neu_ub).max(-1)                    # last feasible
    cand_lb = jnp.where(act, cand_lb, neu_lb)
    cand_ub = jnp.where(act, cand_ub, neu_ub)
    # overload: fail every effective task of the row
    cand_lb = jnp.where(overload[:, :, None] & act, -neu_lb, cand_lb)
    return cand_lb, cand_ub


def _gather_join(cand_lb, cand_ub, occ_inst, occ_pos, L):
    """Variable-centric join of one bank's candidates: each var reduces
    over its occurrence list (pure gather — no scatter, no atomics)."""
    width = cand_ub.shape[2]
    flat_ub = cand_ub.reshape(L, -1)
    flat_lb = cand_lb.reshape(L, -1)
    occ = (occ_inst * width + occ_pos).reshape(-1)          # [V*D]
    V, D = occ_inst.shape
    g_ub = jnp.take(flat_ub, occ, axis=1).reshape(L, V, D).min(-1)
    g_lb = jnp.take(flat_lb, occ, axis=1).reshape(L, V, D).max(-1)
    return g_lb, g_ub


def sweep_tile(lb, ub, vidx, coef, rhs, bidx, occ_prop, occ_slot,
               ad_vars, ad_offs, ad_mask, ad_occ_inst, ad_occ_pos,
               cu_svar, cu_dur, cu_dem, cu_cap, cu_occ_inst, cu_occ_pos,
               box_lo, box_hi, *, horizon: int, n_alldiff: int = 0,
               n_cumulative: int = 0) -> Tuple[jax.Array, jax.Array]:
    """One eventless sweep over a ``[L, V]`` tile of stores (gather form),
    dispatching over the typed propagator banks (DESIGN.md §12).

    Pure-array form shared verbatim by the XLA backends and the Pallas
    kernel body — the single source of truth for the sweep semantics.
    Every bank computes its candidate tells, every variable reduces over
    its per-bank occurrence lists, and the joins compose by min/max —
    associativity/commutativity of ⊔ makes the kind order irrelevant to
    the result.  ``n_alldiff``/``n_cumulative`` are compile-time statics
    so models without a bank skip its (dummy-only) work entirely.
    """
    L = lb.shape[0]
    cand_lb, cand_ub = candidates_tile(lb, ub, vidx, coef, rhs, bidx)
    # fold the reif-entailment slot in: occ_slot ∈ [0, K] indexes [K+1]
    g_lb, g_ub = _gather_join(cand_lb, cand_ub, occ_prop, occ_slot, L)
    if n_alldiff:
        ad_lb, ad_ub = alldiff_candidates_tile(lb, ub, ad_vars, ad_offs,
                                               ad_mask)
        j_lb, j_ub = _gather_join(ad_lb, ad_ub, ad_occ_inst, ad_occ_pos, L)
        g_lb = jnp.maximum(g_lb, j_lb)
        g_ub = jnp.minimum(g_ub, j_ub)
    if n_cumulative:
        cu_lb, cu_ub = cumulative_candidates_tile(
            lb, ub, cu_svar, cu_dur, cu_dem, cu_cap, horizon)
        j_lb, j_ub = _gather_join(cu_lb, cu_ub, cu_occ_inst, cu_occ_pos, L)
        g_lb = jnp.maximum(g_lb, j_lb)
        g_ub = jnp.minimum(g_ub, j_ub)
    # clamp candidates into the initial box (overflow guard; sound because
    # box_lo-1/box_hi+1 still cross the opposite bound on failure)
    g_ub = jnp.maximum(g_ub, box_lo[None, :])
    g_lb = jnp.minimum(g_lb, box_hi[None, :])
    return jnp.maximum(lb, g_lb), jnp.minimum(ub, g_ub)


def model_tables(cm: CompiledModel) -> Tuple:
    """The positional table args of `sweep_tile`, in order — the ONE
    place the (backend-shared) sweep signature is spelled out."""
    return (cm.vidx, cm.coef, cm.rhs, cm.bidx, cm.occ_prop, cm.occ_slot,
            cm.ad_vars, cm.ad_offs, cm.ad_mask, cm.ad_occ_inst,
            cm.ad_occ_pos, cm.cu_svar, cm.cu_dur, cm.cu_dem, cm.cu_cap,
            cm.cu_occ_inst, cm.cu_occ_pos, cm.box_lo, cm.box_hi)


def model_statics(cm: CompiledModel) -> dict:
    """The static (kind-dispatch) kwargs of `sweep_tile`."""
    return dict(horizon=cm.horizon, n_alldiff=cm.n_alldiff,
                n_cumulative=cm.n_cumulative)


def propagator_candidates(cm: CompiledModel, lb: jax.Array, ub: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
    """Single-store view of `candidates_tile` (each ``[P+1, K+1]``).

    Kept as the entry point for the linear scatter form and the
    sequential SELECT-rule semantics (which are defined on the ReifLinLe
    bank; the native banks have their own tiles).
    """
    cand_lb, cand_ub = candidates_tile(lb[None], ub[None], cm.vidx, cm.coef,
                                       cm.rhs, cm.bidx)
    return cand_lb[0], cand_ub[0]


def sweep(cm: CompiledModel, lb: jax.Array, ub: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """One parallel iteration: D(P₁) ⊔ … ⊔ D(Pₙ) applied to one (lb, ub)."""
    nlb, nub = sweep_tile(lb[None], ub[None], *model_tables(cm),
                          **model_statics(cm))
    return nlb[0], nub[0]


def sweep_batch(cm: CompiledModel, lb: jax.Array, ub: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Gather sweep over lane-batched ``[L, V]`` stores — one tensor op for
    the whole batch (the TURBO shape: every lane's sweep in one launch)."""
    return sweep_tile(lb, ub, *model_tables(cm), **model_statics(cm))


def sweep_scatter(cm: CompiledModel, lb: jax.Array, ub: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Propagator-centric scatter form of the same sweep (oracle).

    This is literally "each propagator writes its variables through an
    atomic join" — the paper's load/store formulation — except the joins
    are XLA scatter-min/max, which are deterministic regardless of
    duplicate indices (associative reduce).  Used as the reference the
    gather sweep and the Pallas kernel are tested against.  The native
    banks reuse the *same* kind tiles as the gather form (DESIGN.md §12)
    and only differ in join strategy: per-row scatter instead of per-var
    occurrence gather — equal results by associativity of ⊔.
    """
    cand_lb, cand_ub = propagator_candidates(cm, lb, ub)
    # plain rows (b == TRUE) must not scatter their (dis)entailment slot:
    # the gather form has no TRUE-var occurrence for it (compile.py), and
    # a disentailed plain row always fails through term tightening in the
    # same sweep — neutralizing here keeps both forms bit-identical per
    # sweep, not just at the fixpoint (test_backend_parity_capped_iters)
    neu_ub, neu_lb = _neutrals(lb.dtype)
    plain = cm.bidx == TRUE_VAR
    cand_ub = cand_ub.at[:, -1].set(
        jnp.where(plain, neu_ub, cand_ub[:, -1]))
    cand_lb = cand_lb.at[:, -1].set(
        jnp.where(plain, neu_lb, cand_lb[:, -1]))
    tgt = jnp.concatenate([cm.vidx, cm.bidx[:, None]], axis=1)  # [P1, K+1]
    flat_v = tgt.reshape(-1)
    new_ub = ub.at[flat_v].min(jnp.maximum(cand_ub.reshape(-1), cm.box_lo[flat_v]))
    new_lb = lb.at[flat_v].max(jnp.minimum(cand_lb.reshape(-1), cm.box_hi[flat_v]))
    if cm.n_alldiff:
        ad_lb, ad_ub = alldiff_candidates_tile(
            lb[None], ub[None], cm.ad_vars, cm.ad_offs, cm.ad_mask)
        v = cm.ad_vars.reshape(-1)
        new_ub = new_ub.at[v].min(
            jnp.maximum(ad_ub[0].reshape(-1), cm.box_lo[v]))
        new_lb = new_lb.at[v].max(
            jnp.minimum(ad_lb[0].reshape(-1), cm.box_hi[v]))
    if cm.n_cumulative:
        cu_lb, cu_ub = cumulative_candidates_tile(
            lb[None], ub[None], cm.cu_svar, cm.cu_dur, cm.cu_dem,
            cm.cu_cap, cm.horizon)
        v = cm.cu_svar.reshape(-1)
        new_ub = new_ub.at[v].min(
            jnp.maximum(cu_ub[0].reshape(-1), cm.box_lo[v]))
        new_lb = new_lb.at[v].max(
            jnp.minimum(cu_lb[0].reshape(-1), cm.box_hi[v]))
    return new_lb, new_ub


def sweep_scatter_batch(cm: CompiledModel, lb: jax.Array, ub: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Scatter sweep over lane-batched ``[L, V]`` stores (vmapped joins)."""
    return jax.vmap(partial(sweep_scatter, cm))(lb, ub)


@partial(jax.jit, static_argnames=("max_iters", "stop_on_fail", "use_scatter"))
def fixpoint(cm: CompiledModel, lb: jax.Array, ub: jax.Array,
             max_iters: Optional[int] = None, stop_on_fail: bool = True,
             use_scatter: bool = False):
    """Run sweeps to the least fixed point (paper Thm. 2 guarantees
    existence/uniqueness; finite lattices guarantee termination).

    Returns (lb', ub', n_sweeps, converged).  `converged` is a per-store
    flag: True iff the last sweep changed nothing (or the store failed —
    failure is definitive).  With ``max_iters`` the loop may stop early
    with converged=False; callers must then keep sweeping before trusting
    all-fixed stores as solutions (search.py does — see §Perf H1).
    With ``stop_on_fail`` the loop exits as soon as some domain empties
    (failed stores are discarded by search — a beyond-paper early-exit).
    """
    step = sweep_scatter if use_scatter else sweep

    def cond(st):
        lb_, ub_, changed, it = st
        ok = changed
        if max_iters is not None:
            ok = ok & (it < max_iters)
        if stop_on_fail:
            ok = ok & jnp.logical_not(jnp.any(lb_ > ub_))
        return ok

    def body(st):
        lb_, ub_, _, it = st
        nlb, nub = step(cm, lb_, ub_)
        changed = jnp.any((nlb != lb_) | (nub != ub_))
        return nlb, nub, changed, it + 1

    init = (lb, ub, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    lb, ub, changed, iters = lax.while_loop(cond, body, init)
    converged = jnp.logical_not(changed) | jnp.any(lb > ub)
    return lb, ub, iters, converged


def fixpoint_tile(lb, ub, *tables, horizon: int, n_alldiff: int = 0,
                  n_cumulative: int = 0, max_iters: Optional[int] = None,
                  stop_on_fail: bool = True, step=None):
    """Per-lane-masked fixpoint loop over a ``[L, V]`` tile (gather form).

    Pure-array form (no `CompiledModel`) so the Pallas kernel bodies —
    the unfused fixpoint kernel and the resident search megakernel
    (DESIGN.md §13) — can run it on VMEM refs; `fixpoint_batch` wraps it
    for the XLA backends.  A lane participates in a sweep iff its own
    per-lane cond (changed ∧ it < max_iters ∧ ¬failed) holds, so results,
    sweep counts and convergence flags are identical across every caller
    (idempotence of ⊔ makes the frozen-lane masking exact).

    `step` overrides the sweep function (the scatter backend passes its
    join strategy through here); default is `sweep_tile` on `tables`.

    Returns (lb', ub', sweeps[L], converged[L]).
    """
    L = lb.shape[0]
    if step is None:
        def step(lb_, ub_):
            return sweep_tile(lb_, ub_, *tables, horizon=horizon,
                              n_alldiff=n_alldiff,
                              n_cumulative=n_cumulative)

    def lane_live(lb_, ub_, changed, it):
        ok = changed
        if max_iters is not None:
            ok = ok & (it < max_iters)
        if stop_on_fail:
            ok = ok & jnp.logical_not(jnp.any(lb_ > ub_, axis=1))
        return ok                                          # bool[L]

    def cond(st):
        lb_, ub_, changed, it = st
        return jnp.any(lane_live(lb_, ub_, changed, it))

    def body(st):
        lb_, ub_, changed, it = st
        active = lane_live(lb_, ub_, changed, it)
        nlb, nub = step(lb_, ub_)
        nlb = jnp.where(active[:, None], nlb, lb_)
        nub = jnp.where(active[:, None], nub, ub_)
        ch = jnp.any((nlb != lb_) | (nub != ub_), axis=1)
        changed = jnp.where(active, ch, changed)
        return nlb, nub, changed, it + active.astype(jnp.int32)

    init = (lb, ub, jnp.ones((L,), bool), jnp.zeros((L,), jnp.int32))
    lb, ub, changed, iters = lax.while_loop(cond, body, init)
    converged = jnp.logical_not(changed) | jnp.any(lb > ub, axis=1)
    return lb, ub, iters, converged


@partial(jax.jit, static_argnames=("max_iters", "stop_on_fail", "use_scatter"))
def fixpoint_batch(cm: CompiledModel, lb: jax.Array, ub: jax.Array,
                   max_iters: Optional[int] = None, stop_on_fail: bool = True,
                   use_scatter: bool = False):
    """Lane-batched fixpoint: one `while_loop` over the whole ``[L, V]``
    store tensor, each sweep a single batched tensor op (`sweep_batch`).

    This is the TURBO superstep shape — one propagation launch for all
    lanes — replacing the per-lane `fixpoint` under `vmap` whose
    while_loop degenerates to lockstep select-masking anyway.  The loop
    itself is `fixpoint_tile`, shared verbatim with the Pallas kernels.

    Returns (lb', ub', sweeps[L], converged[L]).
    """
    step = partial(sweep_scatter_batch, cm) if use_scatter else None
    return fixpoint_tile(lb, ub, *model_tables(cm), **model_statics(cm),
                         max_iters=max_iters, stop_on_fail=stop_on_fail,
                         step=step)


# --------------------------------------------------------------------------
# Sequential / chaotic iteration semantics — test-grade implementations of
# the paper's `seq P` (Prop. 3) and fair schedules (Def. 5 / Thm. 6).
# --------------------------------------------------------------------------

def apply_one(cm: CompiledModel, lb, ub, p: jax.Array):
    """Apply a single guarded command (SELECT rule) — one transition of ↪."""
    cand_lb, cand_ub = propagator_candidates(cm, lb, ub)  # (cheap enough for tests)
    row_ub, row_lb = cand_ub[p], cand_lb[p]
    tgt = jnp.concatenate([cm.vidx[p], cm.bidx[p][None]])
    new_ub = ub.at[tgt].min(jnp.maximum(row_ub, cm.box_lo[tgt]))
    new_lb = lb.at[tgt].max(jnp.minimum(row_lb, cm.box_hi[tgt]))
    return new_lb, new_ub


def sequential_fixpoint(cm: CompiledModel, lb, ub, order=None,
                        max_rounds: int = 10_000):
    """fix D(seq P) under the schedule `order` (default: program order).

    Python-loop driven; used only by tests to validate Prop. 3 / Thm. 6.
    """
    import numpy as np
    order = list(range(cm.n_props)) if order is None else list(order)
    lb = jnp.asarray(lb)
    ub = jnp.asarray(ub)
    for _ in range(max_rounds):
        plb, pub = lb, ub
        for p in order:
            lb, ub = apply_one(cm, lb, ub, jnp.asarray(p))
        if bool(jnp.all(lb == plb) & jnp.all(ub == pub)):
            return np.asarray(lb), np.asarray(ub)
    raise RuntimeError("sequential fixpoint did not converge")
