"""Eventless parallel fixpoint engine (paper §"Fixed point loop").

One *sweep* executes **every** propagator once and joins all their tells
into the store — this is the denotational parallel composition
``D(P₁) ⊔ … ⊔ D(Pₙ)`` realized as one bulk-synchronous tensor program
(the TPU analogue of the paper's AC-1-style loop; the `lax.while_loop`
carry of a single `changed` flag replaces the rotating ``has_changed[3]``
+ ``__syncthreads()`` scheme, because a BSP step *is* a barrier).

The sweep is *variable-centric* (gather form): each variable reduces over
the candidate bounds of all its occurrences.  Associativity/commutativity
of ⊔ makes this equal to the propagator-centric scatter form
(`kernels/ref.py` oracle), which is itself equal to any fair sequential
chaotic iteration by the paper's Prop. 3 / Thm. 6 — both equalities are
property-tested in `tests/test_semantics.py`.

Propagator semantics for row  b ⇔ Σ_j a_j·x_j ≤ c :

  ask  lb(b) ≥ 1  (b told true):   for each term k,
       slack_k = c - (Smin - min(a_k x_k));
       a_k > 0 → tell x_k ≤ ⌊slack_k / a_k⌋
       a_k < 0 → tell x_k ≥ ⌈slack_k / a_k⌉
  ask  ub(b) ≤ 0  (b told false):  propagate Σ -a_j x_j ≤ -c-1 (negation)
  entailment:   Smax ≤ c  → tell b ≥ 1  ;  Smin > c → tell b ≤ 0
       (paper's `entailed` function, via Lemma 1 monotonicity)

Candidates are clamped into the initial box (see compile.py) so all
arithmetic provably stays in dtype range.

There is exactly **one** implementation of the propagator semantics per
*kind* (the typed propagator table, DESIGN.md §12): `candidates_tile`
(ReifLinLe), `alldiff_candidates_tile` (Hall-interval bounds(Z)
consistency) and `cumulative_candidates_tile` (time-table filtering),
all written over raw tables and lane-batched ``[L, V]`` stores and
dispatched by `sweep_tile` in a fixed kind order.  Everything else — the
single-store `sweep`, the scatter oracle, the lane-batched
`fixpoint_batch` used by the search superstep, and the Pallas VMEM
kernel (`kernels/fixpoint_kernel.py` imports `sweep_tile`) — is a thin
wrapper around these tiles (DESIGN.md §2.3), so all three backends run
the same kind semantics verbatim and stay bit-identical.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bitset as B
from repro.core.compile import CompiledModel
from repro.core.model import TRUE_VAR


def _neutrals(dtype):
    big = jnp.asarray(jnp.iinfo(dtype).max // 4, dtype)
    return big, -big   # NEU_UB, NEU_LB


def _fdiv(p, q):
    return jnp.floor_divide(p, q)


def _cdiv(p, q):
    return -jnp.floor_divide(-p, q)


def candidates_tile(lb: jax.Array, ub: jax.Array, vidx, coef, rhs, bidx
                    ) -> Tuple[jax.Array, jax.Array]:
    """All tells of one sweep for a ``[L, V]`` tile of stores.

    Pure-array form (no `CompiledModel`) so the Pallas kernel body can call
    it on VMEM refs; every other propagation path wraps it.  Returns
    (cand_lb, cand_ub), each ``[L, P+1, K+1]``; slot K is the
    reified-boolean (entailment) slot.  Neutral candidates are ±big so
    they vanish under the min/max joins.
    """
    dt = lb.dtype
    a = coef[None, :, :]                                  # [1, P1, K]
    c = rhs[None, :, None]                                # [1, P1, 1]
    xl = jnp.take(lb, vidx, axis=1)                       # [L, P1, K]
    xu = jnp.take(ub, vidx, axis=1)
    tl = jnp.where(a > 0, a * xl, a * xu)     # min of a_k x_k (0 when a==0)
    tu = jnp.where(a > 0, a * xu, a * xl)     # max of a_k x_k
    smin = tl.sum(-1)                                     # [L, P1]
    smax = tu.sum(-1)

    btrue = (jnp.take(lb, bidx, axis=1) >= 1)[:, :, None]     # ask b
    bfalse = (jnp.take(ub, bidx, axis=1) <= 0)[:, :, None]    # ask ¬b

    neu_ub, neu_lb = _neutrals(dt)
    safe_a = jnp.where(a == 0, 1, a)

    # direction 1: Σ a x ≤ c (guard: b true)
    slack1 = c - (smin[:, :, None] - tl)
    ub1 = jnp.where((a > 0) & btrue, _fdiv(slack1, safe_a), neu_ub)
    lb1 = jnp.where((a < 0) & btrue, _cdiv(slack1, safe_a), neu_lb)

    # direction 2: Σ -a x ≤ -c-1 (guard: b false); with a' = -a:
    #   min(a' x) = -max(a x) = -tu ;  S'min = -smax
    na = -a
    safe_na = jnp.where(na == 0, 1, na)
    slack2 = (-c - 1) - (-smax[:, :, None] + tu)
    ub2 = jnp.where((na > 0) & bfalse, _fdiv(slack2, safe_na), neu_ub)
    lb2 = jnp.where((na < 0) & bfalse, _cdiv(slack2, safe_na), neu_lb)

    term_ub = jnp.minimum(ub1, ub2)           # [L, P1, K]
    term_lb = jnp.maximum(lb1, lb2)

    # entailment slot (tells on the reified boolean)
    one = jnp.asarray(1, dt)
    zero = jnp.asarray(0, dt)
    reif_lb = jnp.where(smax <= rhs[None, :], one, neu_lb)   # entailed → b≥1
    reif_ub = jnp.where(smin > rhs[None, :], zero, neu_ub)   # disent. → b≤0

    cand_ub = jnp.concatenate([term_ub, reif_ub[:, :, None]], axis=2)
    cand_lb = jnp.concatenate([term_lb, reif_lb[:, :, None]], axis=2)
    return cand_lb, cand_ub


def alldiff_candidates_tile(lb, ub, ad_vars, ad_offs, ad_mask
                            ) -> Tuple[jax.Array, jax.Array]:
    """Bounds(Z)-consistency tells for the AllDifferent bank
    (kind-dispatched sweep variant, DESIGN.md §12).

    Pure-array form over a ``[L, V]`` tile; shared verbatim by all three
    backends.  Hall-interval reasoning on the shifted views
    ``y_k = x_k + off_k``: for every endpoint pair (i, j) the interval
    ``I = [yl_i, yu_j]`` is tested —

      |{k : dom(y_k) ⊆ I}| > |I|  →  fail (some member pushed past its
                                     box, which crosses its bounds);
      |{k : dom(y_k) ⊆ I}| = |I|  →  I is a Hall interval: every other
                                     member's bound inside I is pushed
                                     out (lb → sup I + 1, ub → inf I - 1).

    Iterated to fixpoint this is exactly bounds(Z) consistency (all
    candidate Hall intervals have lb endpoints as infima and ub endpoints
    as suprema).  Returns (cand_lb, cand_ub), each ``[L, A1, N]``, in
    *unshifted* variable space; padded members and the dummy row A are
    neutral.
    """
    dt = lb.dtype
    neu_ub, neu_lb = _neutrals(dt)
    msk = (ad_mask[None] != 0)                              # [1, A1, N]
    off = ad_offs[None]
    yl = jnp.take(lb, ad_vars, axis=1) + off                # [L, A1, N]
    yu = jnp.take(ub, ad_vars, axis=1) + off
    a = yl[:, :, :, None]                    # interval inf from i  [L,A1,N,1]
    b = yu[:, :, None, :]                    # interval sup from j  [L,A1,1,N]
    pair_ok = msk[:, :, :, None] & msk[:, :, None, :] & (a <= b)
    inside = (msk[:, :, None, None, :]
              & (yl[:, :, None, None, :] >= a[..., None])
              & (yu[:, :, None, None, :] <= b[..., None]))  # [L,A1,N,N,N]
    cnt = inside.sum(-1).astype(dt)                         # [L, A1, N, N]
    width = b - a + 1
    overflow = pair_ok & (cnt > width)
    hall = pair_ok & (cnt == width)

    # Hall pruning: member k outside I with a bound inside I is pushed out
    out_k = msk[:, :, None, None, :] & ~inside
    a5, b5 = a[..., None], b[..., None]
    klb, kub = yl[:, :, None, None, :], yu[:, :, None, None, :]
    push = hall[..., None]
    lb_cand = jnp.where(push & out_k & (klb >= a5) & (klb <= b5),
                        b5 + 1, neu_lb)                     # [L,A1,N,N,N]
    ub_cand = jnp.where(push & out_k & (kub >= a5) & (kub <= b5),
                        a5 - 1, neu_ub)
    cand_lb = lb_cand.max(axis=(2, 3))                      # [L, A1, N]
    cand_ub = ub_cand.min(axis=(2, 3))

    # pigeonhole overflow: the row is unsatisfiable — fail every member
    # (lb pushed to +big; the box clamp keeps it at box_hi, crossing ub)
    fail = overflow.any(axis=(2, 3))                        # [L, A1]
    cand_lb = jnp.where(fail[:, :, None] & msk, -neu_lb, cand_lb)
    # back to unshifted variable space (neutrals stay effectively neutral)
    return cand_lb - off, cand_ub - off


def cumulative_candidates_tile(lb, ub, cu_svar, cu_dur, cu_dem, cu_cap,
                               horizon: int
                               ) -> Tuple[jax.Array, jax.Array]:
    """Time-table tells for the Cumulative bank (kind-dispatched sweep
    variant, DESIGN.md §12).

    Pure-array form over a ``[L, V]`` tile; shared verbatim by all three
    backends.  Classic compulsory-part reasoning on the dense time grid
    ``t ∈ [0, horizon)`` (horizon is a compile-time static):

      * task t's compulsory part is ``[lst_t, est_t + d_t)`` (nonempty
        iff lst_t < est_t + d_t);
      * profile(τ) = Σ demands of compulsory parts covering τ;
        profile(τ) > cap → fail the row;
      * task t cannot *start* at s if some τ ∈ [s, s+d_t) has
        profile₋t(τ) + r_t > cap; its lb (ub) moves to the first (last)
        feasible start ≥ est_t (≤ lst_t).

    Returns (cand_lb, cand_ub), each ``[L, C1, T]``; zero-duration /
    zero-demand tasks and the dummy row C are neutral.  Monotone: shrink
    the domains and compulsory parts only grow, so feasible starts only
    shrink (a propagator in the paper's Lemma-1 sense).
    """
    dt = lb.dtype
    neu_ub, neu_lb = _neutrals(dt)
    est = jnp.take(lb, cu_svar, axis=1)                     # [L, C1, T]
    lst = jnp.take(ub, cu_svar, axis=1)
    d = cu_dur[None]
    q = cu_dem[None]
    act = (d > 0) & (q > 0)
    cap = cu_cap[None, :, None]                             # [1, C1, 1]
    tgrid = jnp.arange(horizon, dtype=dt)                   # [H]
    run = (act[..., None] & (lst[..., None] <= tgrid)
           & (tgrid < (est + d)[..., None]))                # [L, C1, T, H]
    contrib = jnp.where(run, q[..., None], jnp.asarray(0, dt))
    profile = contrib.sum(axis=2)                           # [L, C1, H]
    overload = (profile > cap).any(-1)                      # [L, C1]

    # per-task residual profile and forbidden time points
    bad = (act[..., None]
           & (profile[:, :, None, :] - contrib + q[..., None] > cap[..., None]))
    csum = jnp.cumsum(bad.astype(dt), axis=-1)
    csum = jnp.concatenate(
        [jnp.zeros_like(csum[..., :1]), csum], axis=-1)     # [L, C1, T, H+1]
    ends = jnp.clip(tgrid[None, None, None, :] + d[..., None], 0, horizon)
    wbad = (jnp.take_along_axis(csum, ends.astype(jnp.int32), axis=-1)
            - csum[..., :-1])                               # [L, C1, T, H]
    feas = wbad == 0                                        # start grid feas.

    cand_lb = jnp.where(feas & (tgrid >= est[..., None]), tgrid,
                        -neu_lb).min(-1)                    # first feasible
    cand_ub = jnp.where(feas & (tgrid <= lst[..., None]), tgrid,
                        -neu_ub).max(-1)                    # last feasible
    cand_lb = jnp.where(act, cand_lb, neu_lb)
    cand_ub = jnp.where(act, cand_ub, neu_ub)
    # overload: fail every effective task of the row
    cand_lb = jnp.where(overload[:, :, None] & act, -neu_lb, cand_lb)
    return cand_lb, cand_ub


def alldiff_candidates_sparse_tile(lb, ub, ad_pk_var, ad_pk_off, ad_pk_seg,
                                   n_alldiff: int
                                   ) -> Tuple[jax.Array, jax.Array]:
    """Segmented (packed/CSR) Hall-interval pass — the scale variant of
    `alldiff_candidates_tile` (DESIGN.md §16).

    Same bounds(Z) semantics, O(M²) scratch instead of O(A·N³): members
    of ALL rows live on one packed axis of length M with a segment id
    each (padding slots carry seg == n_alldiff and stay inert).  Members
    are lexsorted by (segment, lb endpoint); the count
    ``|{k : dom(y_k) ⊆ [a_i, b_j]}|`` then becomes a reversed-cumsum
    suffix lookup: with T[p, j] = [seg_p = seg_j ∧ yu_p ≤ yu_j] and
    S = suffix-sum of T over p, cnt(i, j) = S[first_pos(i), j] where
    first_pos counts strictly-smaller (seg, yl) keys — tie-invariant, so
    the (unstable) sort cannot affect results and every backend stays
    bit-identical.  Hall intervals are folded to two O(M) extremal
    tables (min inf per sup; max sup per inf) before the push pass, so
    no O(M³) tensor is ever built.  Bit-equal to the dense tile per
    member on non-failed stores (the only stores the engines sweep).

    Returns (cand_lb, cand_ub), each ``[L, M]`` over the packed axis in
    *unshifted* variable space.
    """
    dt = lb.dtype
    neu_ub, neu_lb = _neutrals(dt)
    off = ad_pk_off[None]                                   # [1, M]
    yl = jnp.take(lb, ad_pk_var, axis=1) + off              # [L, M]
    yu = jnp.take(ub, ad_pk_var, axis=1) + off
    segb = jnp.broadcast_to(ad_pk_seg[None], yl.shape)

    perm = jnp.lexsort((yl, segb), axis=-1)                 # seg-major, then yl
    inv = jnp.argsort(perm, axis=-1)
    syl = jnp.take_along_axis(yl, perm, axis=1)
    syu = jnp.take_along_axis(yu, perm, axis=1)
    sseg = jnp.take_along_axis(segb, perm, axis=1)
    sact = sseg < n_alldiff

    same = sseg[:, :, None] == sseg[:, None, :]             # [L, M, M]
    a_i = syl[:, :, None]               # interval inf from i (axis 1)
    b_j = syu[:, None, :]               # interval sup from j (axis 2)

    # suffix count: S[p, j] = |{x ≥ p : seg_x = seg_j ∧ yu_x ≤ yu_j}|
    T = (same & (syu[:, :, None] <= syu[:, None, :])).astype(dt)
    S = jnp.flip(jnp.cumsum(jnp.flip(T, axis=1), axis=1), axis=1)
    # first sorted position of i's key = |{p : (seg_p, yl_p) < (seg_i, yl_i)}|
    lt = ((sseg[:, None, :] < sseg[:, :, None])
          | (same & (syl[:, None, :] < syl[:, :, None])))   # [L, i, p]
    fp = lt.sum(axis=2).astype(jnp.int32)                   # [L, M]
    cnt = jnp.take_along_axis(
        S, jnp.broadcast_to(fp[:, :, None], S.shape), axis=1)  # [L, i, j]

    pair_ok = same & sact[:, :, None] & sact[:, None, :] & (a_i <= b_j)
    width = b_j - a_i + 1
    overflow = pair_ok & (cnt > width)
    hall = pair_ok & (cnt == width)

    # extremal Hall data: tightest inf per sup endpoint j, and widest sup
    # per inf endpoint i — all O(M) per lane after the fold
    min_inf = jnp.where(hall, jnp.broadcast_to(a_i, hall.shape),
                        neu_ub).min(axis=1)                 # [L, M] per j
    max_sup = jnp.where(hall, jnp.broadcast_to(b_j, hall.shape),
                        neu_lb).max(axis=2)                 # [L, M] per i

    # lb push for member k: ∃ Hall I = [a_i, b_j] with a_i ≤ yl_k ≤ b_j < yu_k
    #   ⇔ ∃j same-seg: min_inf_j ≤ yl_k ≤ b_j < yu_k   → yl_k ↦ b_j + 1
    yl_k, yu_k = syl[:, :, None], syu[:, :, None]           # k on axis 1
    s_lb = jnp.where(same & sact[:, :, None]
                     & (min_inf[:, None, :] <= yl_k)
                     & (yl_k <= b_j) & (b_j < yu_k),
                     b_j + 1, neu_lb).max(axis=2)           # [L, M]
    # ub push, mirrored: yl_k < a_i ≤ yu_k ≤ max_sup_i  → yu_k ↦ a_i - 1
    a_i2 = syl[:, None, :]                                  # i on axis 2
    s_ub = jnp.where(same & sact[:, :, None]
                     & (yl_k < a_i2) & (a_i2 <= yu_k)
                     & (yu_k <= max_sup[:, None, :]),
                     a_i2 - 1, neu_ub).min(axis=2)

    # pigeonhole overflow fails every member of the affected row
    rowfail = overflow.any(axis=2)                          # [L, M] per i
    failk = jnp.any(same & rowfail[:, None, :], axis=2)     # [L, M] per k
    s_lb = jnp.where(failk & sact, -neu_lb, s_lb)

    # unsort to packed order, then back to unshifted variable space
    cand_lb = jnp.take_along_axis(s_lb, inv, axis=1) - off
    cand_ub = jnp.take_along_axis(s_ub, inv, axis=1) - off
    return cand_lb, cand_ub


def cumulative_candidates_sparse_tile(lb, ub, cu_pk_svar, cu_pk_dur,
                                      cu_pk_dem, cu_pk_seg, cu_cap,
                                      n_cumulative: int
                                      ) -> Tuple[jax.Array, jax.Array]:
    """Event-based time-table pass — the scale variant of
    `cumulative_candidates_tile` (DESIGN.md §16).

    Same compulsory-part semantics, never materialises the ``[.., T,
    horizon]`` grid: each effective task with a compulsory part emits two
    events (+q at lst, −q at ect); events lexsorted by (segment, time,
    end-before-start) give the piecewise-constant profile as one global
    cumsum (per-seg exact because each segment's deltas sum to 0 under
    the seg-major sort).  Consecutive same-segment events bound disjoint
    constant-profile intervals [u, v); empty ones (u == v) are guarded
    off.  Overload and per-task forbidden windows are tested per
    interval, and the first/last feasible start is found by one forward
    and one backward `lax.scan` over the 2M events with a monotone jump
    carry — single-pass exact because the intervals are disjoint and
    sorted.  Bit-equal to the dense tile per task on non-failed stores.

    Returns (cand_lb, cand_ub), each ``[L, M]`` over the packed axis.
    """
    dt = lb.dtype
    neu_ub, neu_lb = _neutrals(dt)
    zero = jnp.asarray(0, dt)
    M = cu_pk_svar.shape[0]
    seg = cu_pk_seg
    d = cu_pk_dur[None]                                     # [1, M]
    q = cu_pk_dem[None]
    act = (seg < n_cumulative)[None] & (d > 0) & (q > 0)
    cap = jnp.take(cu_cap, seg)[None]                       # [1, M] per task
    est = jnp.take(lb, cu_pk_svar, axis=1)                  # [L, M]
    lst = jnp.take(ub, cu_pk_svar, axis=1)
    ect = est + d
    has_cp = act & (lst < ect)                              # compulsory part

    times = jnp.concatenate([lst, ect], axis=1)             # [L, 2M]
    delta = jnp.concatenate([jnp.where(has_cp, q, zero),
                             jnp.where(has_cp, -q, zero)], axis=1)
    esegb = jnp.broadcast_to(
        jnp.concatenate([seg, seg])[None], times.shape)
    # ends sort before starts at equal times: transient profiles are then
    # confined to empty [t, t) intervals, which the u < v guard disables
    kindb = jnp.broadcast_to(jnp.concatenate(
        [jnp.ones((M,), jnp.int32), jnp.zeros((M,), jnp.int32)])[None],
        times.shape)
    perm = jnp.lexsort((kindb, times, esegb), axis=-1)      # seg, time, kind
    stime = jnp.take_along_axis(times, perm, axis=1)
    sdelta = jnp.take_along_axis(delta, perm, axis=1)
    sseg = jnp.take_along_axis(esegb, perm, axis=1)
    prof = jnp.cumsum(sdelta, axis=1)                       # [L, 2M]

    # event e owns [u, v) up to the next event while it stays in-segment;
    # the last event of a segment owns an empty (disabled) interval
    nxt_t = jnp.concatenate([stime[:, 1:], stime[:, -1:]], axis=1)
    nxt_s = jnp.concatenate(
        [sseg[:, 1:], jnp.full_like(sseg[:, -1:], -1)], axis=1)
    u_t = stime
    v_t = jnp.where(nxt_s == sseg, nxt_t, stime)
    over_e = (u_t < v_t) & (prof > jnp.take(cu_cap, sseg))  # [L, 2M]
    # per-task overload: any overloaded interval in my segment
    ovl = jnp.any((sseg[:, None, :] == seg[None, :, None])
                  & over_e[:, None, :], axis=2)             # [L, M]

    # forbidden-window scans: task t cannot run through interval [u, v)
    # if profile₋t + q_t > cap there (profile₋t removes t's own
    # compulsory part, tested at u only — CP endpoints are events, so
    # coverage is constant on [u, v))
    def _bad(u_, v_, p_, sg):
        segok = sg[:, None] == seg[None, :]                 # [L, M]
        cov = has_cp & (u_ >= lst) & (u_ < ect)
        return (segok & act & (u_ < v_)
                & (p_ + jnp.where(cov, zero, q) > cap))

    def fwd(s, ev):
        u, v, p, sg = ev
        u_, v_, p_ = u[:, None], v[:, None], p[:, None]
        hit = _bad(u_, v_, p_, sg) & (s < v_) & (s + d > u_)
        return jnp.where(hit, v_, s), None

    def bwd(s, ev):
        u, v, p, sg = ev
        u_, v_, p_ = u[:, None], v[:, None], p[:, None]
        hit = _bad(u_, v_, p_, sg) & (s < v_) & (s + d > u_)
        return jnp.where(hit, u_ - d, s), None

    xs = (jnp.moveaxis(u_t, 1, 0), jnp.moveaxis(v_t, 1, 0),
          jnp.moveaxis(prof, 1, 0), jnp.moveaxis(sseg, 1, 0))
    s_est, _ = lax.scan(fwd, est, xs)                # first feasible ≥ est
    s_lst, _ = lax.scan(bwd, lst, xs, reverse=True)  # last feasible ≤ lst

    cand_lb = s_est
    # no feasible start ≥ 0 ⇒ dense's max over an empty set = −big
    cand_ub = jnp.where(s_lst >= 0, s_lst, -neu_ub + zero)
    # a lone task over capacity: every start is forbidden (dense marks the
    # whole grid bad; events only cover [first, last) — special-case it)
    qbig = act & (q > cap)
    cand_lb = jnp.where(qbig, -neu_lb + zero, cand_lb)
    cand_ub = jnp.where(qbig, -neu_ub + zero, cand_ub)
    cand_lb = jnp.where(act, cand_lb, neu_lb + zero)
    cand_ub = jnp.where(act, cand_ub, neu_ub + zero)
    # overload: fail every effective task of the row
    cand_lb = jnp.where(ovl & act, -neu_lb + zero, cand_lb)
    return cand_lb, cand_ub


def _gather_join(cand_lb, cand_ub, occ_inst, occ_pos, L):
    """Variable-centric join of one bank's candidates: each var reduces
    over its occurrence list (pure gather — no scatter, no atomics)."""
    width = cand_ub.shape[2]
    flat_ub = cand_ub.reshape(L, -1)
    flat_lb = cand_lb.reshape(L, -1)
    occ = (occ_inst * width + occ_pos).reshape(-1)          # [V*D]
    V, D = occ_inst.shape
    g_ub = jnp.take(flat_ub, occ, axis=1).reshape(L, V, D).min(-1)
    g_lb = jnp.take(flat_lb, occ, axis=1).reshape(L, V, D).max(-1)
    return g_lb, g_ub


def _gather_join_flat(cand_lb, cand_ub, occ, L):
    """`_gather_join` for packed-axis candidates: `occ` ``[V, D]`` already
    holds flat indices into the ``[L, M]`` candidate arrays (built as
    ptr[occ_inst] + occ_pos — the CSR row-contiguity invariant)."""
    V, D = occ.shape
    idx = occ.reshape(-1)
    g_ub = jnp.take(cand_ub, idx, axis=1).reshape(L, V, D).min(-1)
    g_lb = jnp.take(cand_lb, idx, axis=1).reshape(L, V, D).max(-1)
    return g_lb, g_ub


def ct_candidates_tile(lb, ub, dom, ct_vars, ct_mask, ct_supp, dom_off,
                       n_table: int):
    """Compact-Table tells for the extensional bank (DESIGN.md §17).

    Pure-array form over a ``[L, V]`` bounds tile plus its ``[L, V, W]``
    bitset domain; shared verbatim by all four backends.  The *reset*
    variant of Compact-Table, stateless per sweep:

      1. gather each member's remaining value bits from `dom`;
      2. per member, OR the supports of its remaining values — the sum
         of disjoint tuple bitsets (each tuple has exactly ONE value per
         position, so the masked supports never share a bit and integer
         SUM is exact OR);
      3. AND the per-member words into the current table; an all-zero
         current table fails the row (every member's lb is pushed past
         its box);
      4. a value survives iff its support intersects the current table:
         the surviving bits give each member a filtered domain word mask
         and a [min, max] hull candidate.

    Monotone: shrink `dom` and the masked supports only shrink, so the
    current table and the surviving sets shrink (a propagator in the
    paper's Lemma-1 sense).  Returns (cand_lb, cand_ub, cand_dom) of
    shapes ``[L, T1, R]`` ×2 and ``[L, T1, R, W]``; padded member slots
    and the dummy row T are neutral (±big bounds, all-ones words).
    """
    dt = lb.dtype
    neu_ub, neu_lb = _neutrals(dt)
    L = lb.shape[0]
    T1, R, K32, TW = ct_supp.shape
    W = K32 // B.WORD_BITS
    # 1. member value bits, unpacked to the [K32] value axis
    mdom = jnp.take(dom, ct_vars.reshape(-1), axis=1
                    ).reshape(L, T1, R, W)                  # [L,T1,R,W]
    shifts = jnp.arange(B.WORD_BITS, dtype=jnp.uint32)
    vb = (mdom[..., None] >> shifts) & np.uint32(1)         # [L,T1,R,W,32]
    vb = vb.reshape(L, T1, R, K32)
    # 2. OR of supports of remaining values == SUM of disjoint bitsets
    supp_on = vb[..., None] * ct_supp[None]                 # [L,T1,R,K32,TW]
    mor = supp_on.sum(axis=3)                               # [L,T1,R,TW]
    # 3. current table = AND over real members (padding slots all-ones)
    real = (ct_mask[None] != 0)                             # [1,T1,R]
    mor = jnp.where(real[..., None], mor, B.FULL)
    curr = mor[:, :, 0, :]
    for r in range(1, R):                       # R is static & small
        curr = curr & mor[:, :, r, :]
    fail = jnp.all(curr == 0, axis=-1)                      # [L,T1]
    # 4. surviving values = supports intersecting the current table
    surv = jnp.any((ct_supp[None] & curr[:, :, None, None, :]) != 0,
                   axis=-1)                                 # [L,T1,R,K32]
    ks = jnp.arange(K32, dtype=dt)
    kmin = jnp.where(surv, ks, neu_ub).min(axis=-1)         # [L,T1,R]
    kmax = jnp.where(surv, ks, neu_lb).max(axis=-1)
    omem = jnp.take(dom_off, ct_vars.reshape(-1)).reshape(T1, R)
    cand_lb = jnp.where(real, omem[None] + kmin, neu_lb)
    cand_ub = jnp.where(real, omem[None] + kmax, neu_ub)
    # row failure: push every real member past its box (like the other
    # kinds, the box clamp turns -neu_lb into box_hi, crossing ub)
    cand_lb = jnp.where(fail[:, :, None] & real, -neu_lb, cand_lb)
    # pack the surviving bits back into domain words
    weights = np.uint32(1) << shifts
    cand_dom = (surv.astype(jnp.uint32).reshape(L, T1, R, W, B.WORD_BITS)
                * weights).sum(axis=-1)                     # [L,T1,R,W]
    cand_dom = jnp.where(real[..., None], cand_dom, B.FULL)
    return cand_lb, cand_ub, cand_dom


def _gather_join_dom(cand_dom, occ_inst, occ_pos, dom):
    """Variable-centric join of the CT bank's domain-word candidates:
    each var ANDs the masks of its occurrences into its words (the
    bitset-lattice ⊔).  Both join strategies use this same gather form —
    there is no scatter-AND primitive, and ⊔-associativity makes the
    strategy irrelevant to the result."""
    L, _, R, W = cand_dom.shape
    V, D = occ_inst.shape
    occ = (occ_inst * R + occ_pos).reshape(-1)
    g = jnp.take(cand_dom.reshape(L, -1, W), occ, axis=1
                 ).reshape(L, V, D, W)
    for d in range(D):                          # D is static & small
        dom = dom & g[:, :, d]
    return dom


def dom_normalize_tile(lb, ub, dom, dom_off, dom_track, box_lo, box_hi,
                       n_words: int):
    """Re-sync the two lattices after a sweep's joins (DESIGN.md §17):
    the bitset loses the values outside [lb, ub], and the bounds tighten
    to the bitset's hull.  Untracked vars (dom_track == 0) pass through
    on both sides.  An empty tracked domain reads back as the crossed
    hull (off + 32W, off - 1), which the box clamp keeps crossed — so
    bitset wipeout is bounds failure, the one failure signal every
    engine layer already watches."""
    trk = (dom_track != 0)[None, :]
    rng = B.from_bounds(lb, ub, dom_off, n_words)
    dom = jnp.where(trk[..., None], dom & rng, dom)
    lo, hi = B.to_bounds(dom, dom_off)
    nlb = jnp.maximum(lb, jnp.minimum(lo, box_hi[None, :]))
    nub = jnp.minimum(ub, jnp.maximum(hi, box_lo[None, :]))
    nlb = jnp.where(trk, nlb, lb)
    nub = jnp.where(trk, nub, ub)
    return nlb, nub, dom


def sweep_tile(lb, ub, vidx, coef, rhs, bidx, occ_prop, occ_slot,
               ad_vars, ad_offs, ad_mask, ad_occ_inst, ad_occ_pos,
               ad_ptr, ad_pk_var, ad_pk_off, ad_pk_seg,
               cu_svar, cu_dur, cu_dem, cu_cap, cu_occ_inst, cu_occ_pos,
               cu_ptr, cu_pk_svar, cu_pk_dur, cu_pk_dem, cu_pk_seg,
               ct_vars, ct_mask, ct_supp, ct_occ_inst, ct_occ_pos,
               dom_off, dom_track,
               box_lo, box_hi, *, horizon: int, n_alldiff: int = 0,
               n_cumulative: int = 0, ad_layout: str = "dense",
               cu_layout: str = "dense", n_table: int = 0,
               n_words: int = 1, dom=None):
    """One eventless sweep over a ``[L, V]`` tile of stores (gather form),
    dispatching over the typed propagator banks (DESIGN.md §12).

    Pure-array form shared verbatim by the XLA backends and the Pallas
    kernel body — the single source of truth for the sweep semantics.
    Every bank computes its candidate tells, every variable reduces over
    its per-bank occurrence lists, and the joins compose by min/max —
    associativity/commutativity of ⊔ makes the kind order irrelevant to
    the result.  ``n_alldiff``/``n_cumulative`` are compile-time statics
    so models without a bank skip its (dummy-only) work entirely;
    ``ad_layout``/``cu_layout`` pick the dense or the packed/segmented
    tile per bank (compile-time crossover, DESIGN.md §16) — same
    semantics, different scratch scaling.

    With ``n_table`` tables (DESIGN.md §17) the sweep also runs the
    Compact-Table tile over the bitset domain.  `dom` (``[L, V, W]``
    uint32 or None) opts the caller into carrying the bitset store:
    when given, the CT tile filters it, the sweep ends with
    `dom_normalize_tile`, and a 3-tuple (lb, ub, dom) is returned.
    When None on a table model, a transient range-set domain is derived
    from the current bounds for the CT tile (sound — a superset of any
    carried domain — just weaker on interval holes) and the legacy
    2-tuple comes back unchanged in shape.
    """
    L = lb.shape[0]
    cand_lb, cand_ub = candidates_tile(lb, ub, vidx, coef, rhs, bidx)
    # fold the reif-entailment slot in: occ_slot ∈ [0, K] indexes [K+1]
    g_lb, g_ub = _gather_join(cand_lb, cand_ub, occ_prop, occ_slot, L)
    if n_alldiff:
        if ad_layout == "sparse":
            ad_lb, ad_ub = alldiff_candidates_sparse_tile(
                lb, ub, ad_pk_var, ad_pk_off, ad_pk_seg, n_alldiff)
            occ = jnp.take(ad_ptr, ad_occ_inst) + ad_occ_pos   # flat [V, Dad]
            j_lb, j_ub = _gather_join_flat(ad_lb, ad_ub, occ, L)
        else:
            ad_lb, ad_ub = alldiff_candidates_tile(lb, ub, ad_vars, ad_offs,
                                                   ad_mask)
            j_lb, j_ub = _gather_join(ad_lb, ad_ub, ad_occ_inst, ad_occ_pos,
                                      L)
        g_lb = jnp.maximum(g_lb, j_lb)
        g_ub = jnp.minimum(g_ub, j_ub)
    if n_cumulative:
        if cu_layout == "sparse":
            cu_lb, cu_ub = cumulative_candidates_sparse_tile(
                lb, ub, cu_pk_svar, cu_pk_dur, cu_pk_dem, cu_pk_seg,
                cu_cap, n_cumulative)
            occ = jnp.take(cu_ptr, cu_occ_inst) + cu_occ_pos   # flat [V, Dcu]
            j_lb, j_ub = _gather_join_flat(cu_lb, cu_ub, occ, L)
        else:
            cu_lb, cu_ub = cumulative_candidates_tile(
                lb, ub, cu_svar, cu_dur, cu_dem, cu_cap, horizon)
            j_lb, j_ub = _gather_join(cu_lb, cu_ub, cu_occ_inst, cu_occ_pos,
                                      L)
        g_lb = jnp.maximum(g_lb, j_lb)
        g_ub = jnp.minimum(g_ub, j_ub)
    if n_table:
        d_in = dom if dom is not None else B.from_bounds(
            lb, ub, dom_off, n_words, track=dom_track)
        ct_lb, ct_ub, ct_dm = ct_candidates_tile(
            lb, ub, d_in, ct_vars, ct_mask, ct_supp, dom_off, n_table)
        j_lb, j_ub = _gather_join(ct_lb, ct_ub, ct_occ_inst, ct_occ_pos, L)
        g_lb = jnp.maximum(g_lb, j_lb)
        g_ub = jnp.minimum(g_ub, j_ub)
        if dom is not None:
            dom = _gather_join_dom(ct_dm, ct_occ_inst, ct_occ_pos, dom)
    # clamp candidates into the initial box (overflow guard; sound because
    # box_lo-1/box_hi+1 still cross the opposite bound on failure)
    g_ub = jnp.maximum(g_ub, box_lo[None, :])
    g_lb = jnp.minimum(g_lb, box_hi[None, :])
    nlb = jnp.maximum(lb, g_lb)
    nub = jnp.minimum(ub, g_ub)
    if dom is None:
        return nlb, nub
    return dom_normalize_tile(nlb, nub, dom, dom_off, dom_track,
                              box_lo, box_hi, n_words)


def model_tables(cm: CompiledModel) -> Tuple:
    """The positional table args of `sweep_tile`, in order — the ONE
    place the (backend-shared) sweep signature is spelled out."""
    return (cm.vidx, cm.coef, cm.rhs, cm.bidx, cm.occ_prop, cm.occ_slot,
            cm.ad_vars, cm.ad_offs, cm.ad_mask, cm.ad_occ_inst,
            cm.ad_occ_pos, cm.ad_ptr, cm.ad_pk_var, cm.ad_pk_off,
            cm.ad_pk_seg, cm.cu_svar, cm.cu_dur, cm.cu_dem, cm.cu_cap,
            cm.cu_occ_inst, cm.cu_occ_pos, cm.cu_ptr, cm.cu_pk_svar,
            cm.cu_pk_dur, cm.cu_pk_dem, cm.cu_pk_seg,
            cm.ct_vars, cm.ct_mask, cm.ct_supp, cm.ct_occ_inst,
            cm.ct_occ_pos, cm.dom_off, cm.dom_track,
            cm.box_lo, cm.box_hi)


def model_statics(cm: CompiledModel) -> dict:
    """The static (kind/layout-dispatch) kwargs of `sweep_tile`."""
    return dict(horizon=cm.horizon, n_alldiff=cm.n_alldiff,
                n_cumulative=cm.n_cumulative,
                ad_layout=cm.ad_layout, cu_layout=cm.cu_layout,
                n_table=cm.n_table, n_words=cm.n_words)


def propagator_candidates(cm: CompiledModel, lb: jax.Array, ub: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
    """Single-store view of `candidates_tile` (each ``[P+1, K+1]``).

    Kept as the entry point for the linear scatter form and the
    sequential SELECT-rule semantics (which are defined on the ReifLinLe
    bank; the native banks have their own tiles).
    """
    cand_lb, cand_ub = candidates_tile(lb[None], ub[None], cm.vidx, cm.coef,
                                       cm.rhs, cm.bidx)
    return cand_lb[0], cand_ub[0]


def sweep(cm: CompiledModel, lb: jax.Array, ub: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """One parallel iteration: D(P₁) ⊔ … ⊔ D(Pₙ) applied to one (lb, ub)."""
    nlb, nub = sweep_tile(lb[None], ub[None], *model_tables(cm),
                          **model_statics(cm))
    return nlb[0], nub[0]


def sweep_batch(cm: CompiledModel, lb: jax.Array, ub: jax.Array, dom=None):
    """Gather sweep over lane-batched ``[L, V]`` stores — one tensor op for
    the whole batch (the TURBO shape: every lane's sweep in one launch).
    Pass `dom` to carry the bitset store (3-tuple return, DESIGN.md §17)."""
    return sweep_tile(lb, ub, *model_tables(cm), **model_statics(cm),
                      dom=dom)


def sweep_scatter(cm: CompiledModel, lb: jax.Array, ub: jax.Array, dom=None):
    """Propagator-centric scatter form of the same sweep (oracle).

    This is literally "each propagator writes its variables through an
    atomic join" — the paper's load/store formulation — except the joins
    are XLA scatter-min/max, which are deterministic regardless of
    duplicate indices (associative reduce).  Used as the reference the
    gather sweep and the Pallas kernel are tested against.  The native
    banks reuse the *same* kind tiles as the gather form (DESIGN.md §12)
    and only differ in join strategy: per-row scatter instead of per-var
    occurrence gather — equal results by associativity of ⊔.
    """
    cand_lb, cand_ub = propagator_candidates(cm, lb, ub)
    # plain rows (b == TRUE) must not scatter their (dis)entailment slot:
    # the gather form has no TRUE-var occurrence for it (compile.py), and
    # a disentailed plain row always fails through term tightening in the
    # same sweep — neutralizing here keeps both forms bit-identical per
    # sweep, not just at the fixpoint (test_backend_parity_capped_iters)
    neu_ub, neu_lb = _neutrals(lb.dtype)
    plain = cm.bidx == TRUE_VAR
    cand_ub = cand_ub.at[:, -1].set(
        jnp.where(plain, neu_ub, cand_ub[:, -1]))
    cand_lb = cand_lb.at[:, -1].set(
        jnp.where(plain, neu_lb, cand_lb[:, -1]))
    tgt = jnp.concatenate([cm.vidx, cm.bidx[:, None]], axis=1)  # [P1, K+1]
    flat_v = tgt.reshape(-1)
    new_ub = ub.at[flat_v].min(jnp.maximum(cand_ub.reshape(-1), cm.box_lo[flat_v]))
    new_lb = lb.at[flat_v].max(jnp.minimum(cand_lb.reshape(-1), cm.box_hi[flat_v]))
    if cm.n_alldiff:
        if cm.ad_layout == "sparse":
            ad_lb, ad_ub = alldiff_candidates_sparse_tile(
                lb[None], ub[None], cm.ad_pk_var, cm.ad_pk_off,
                cm.ad_pk_seg, cm.n_alldiff)
            v = cm.ad_pk_var
        else:
            ad_lb, ad_ub = alldiff_candidates_tile(
                lb[None], ub[None], cm.ad_vars, cm.ad_offs, cm.ad_mask)
            v = cm.ad_vars.reshape(-1)
        new_ub = new_ub.at[v].min(
            jnp.maximum(ad_ub[0].reshape(-1), cm.box_lo[v]))
        new_lb = new_lb.at[v].max(
            jnp.minimum(ad_lb[0].reshape(-1), cm.box_hi[v]))
    if cm.n_cumulative:
        if cm.cu_layout == "sparse":
            cu_lb, cu_ub = cumulative_candidates_sparse_tile(
                lb[None], ub[None], cm.cu_pk_svar, cm.cu_pk_dur,
                cm.cu_pk_dem, cm.cu_pk_seg, cm.cu_cap, cm.n_cumulative)
            v = cm.cu_pk_svar
        else:
            cu_lb, cu_ub = cumulative_candidates_tile(
                lb[None], ub[None], cm.cu_svar, cm.cu_dur, cm.cu_dem,
                cm.cu_cap, cm.horizon)
            v = cm.cu_svar.reshape(-1)
        new_ub = new_ub.at[v].min(
            jnp.maximum(cu_ub[0].reshape(-1), cm.box_lo[v]))
        new_lb = new_lb.at[v].max(
            jnp.minimum(cu_lb[0].reshape(-1), cm.box_hi[v]))
    if cm.n_table:
        d_in = (dom[None] if dom is not None else B.from_bounds(
            lb[None], ub[None], cm.dom_off, cm.n_words, track=cm.dom_track))
        ct_lb, ct_ub, ct_dm = ct_candidates_tile(
            lb[None], ub[None], d_in, cm.ct_vars, cm.ct_mask, cm.ct_supp,
            cm.dom_off, cm.n_table)
        v = cm.ct_vars.reshape(-1)
        new_ub = new_ub.at[v].min(
            jnp.maximum(ct_ub[0].reshape(-1), cm.box_lo[v]))
        new_lb = new_lb.at[v].max(
            jnp.minimum(ct_lb[0].reshape(-1), cm.box_hi[v]))
        if dom is not None:
            # bitset joins stay in gather form under the scatter strategy
            # too: there is no scatter-AND join, and ⊔-associativity makes
            # the strategy irrelevant (see _gather_join_dom)
            dom = _gather_join_dom(ct_dm, cm.ct_occ_inst, cm.ct_occ_pos,
                                   dom[None])[0]
    if dom is None:
        return new_lb, new_ub
    nlb, nub, ndom = dom_normalize_tile(
        new_lb[None], new_ub[None], dom[None], cm.dom_off, cm.dom_track,
        cm.box_lo, cm.box_hi, cm.n_words)
    return nlb[0], nub[0], ndom[0]


def sweep_scatter_batch(cm: CompiledModel, lb: jax.Array, ub: jax.Array,
                        dom=None):
    """Scatter sweep over lane-batched ``[L, V]`` stores (vmapped joins)."""
    if dom is None:
        return jax.vmap(partial(sweep_scatter, cm))(lb, ub)
    return jax.vmap(lambda l, u, d: sweep_scatter(cm, l, u, d))(lb, ub, dom)


@partial(jax.jit, static_argnames=("max_iters", "stop_on_fail", "use_scatter"))
def fixpoint(cm: CompiledModel, lb: jax.Array, ub: jax.Array,
             max_iters: Optional[int] = None, stop_on_fail: bool = True,
             use_scatter: bool = False):
    """Run sweeps to the least fixed point (paper Thm. 2 guarantees
    existence/uniqueness; finite lattices guarantee termination).

    Returns (lb', ub', n_sweeps, converged).  `converged` is a per-store
    flag: True iff the last sweep changed nothing (or the store failed —
    failure is definitive).  With ``max_iters`` the loop may stop early
    with converged=False; callers must then keep sweeping before trusting
    all-fixed stores as solutions (search.py does — see §Perf H1).
    With ``stop_on_fail`` the loop exits as soon as some domain empties
    (failed stores are discarded by search — a beyond-paper early-exit).
    """
    step = sweep_scatter if use_scatter else sweep

    def cond(st):
        lb_, ub_, changed, it = st
        ok = changed
        if max_iters is not None:
            ok = ok & (it < max_iters)
        if stop_on_fail:
            ok = ok & jnp.logical_not(jnp.any(lb_ > ub_))
        return ok

    def body(st):
        lb_, ub_, _, it = st
        nlb, nub = step(cm, lb_, ub_)
        changed = jnp.any((nlb != lb_) | (nub != ub_))
        return nlb, nub, changed, it + 1

    init = (lb, ub, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    lb, ub, changed, iters = lax.while_loop(cond, body, init)
    converged = jnp.logical_not(changed) | jnp.any(lb > ub)
    return lb, ub, iters, converged


def fixpoint_tile(lb, ub, *tables, horizon: int, n_alldiff: int = 0,
                  n_cumulative: int = 0, ad_layout: str = "dense",
                  cu_layout: str = "dense", n_table: int = 0,
                  n_words: int = 1, dom=None,
                  max_iters: Optional[int] = None,
                  stop_on_fail: bool = True, step=None):
    """Per-lane-masked fixpoint loop over a ``[L, V]`` tile (gather form).

    Pure-array form (no `CompiledModel`) so the Pallas kernel bodies —
    the unfused fixpoint kernel and the resident search megakernel
    (DESIGN.md §13) — can run it on VMEM refs; `fixpoint_batch` wraps it
    for the XLA backends.  A lane participates in a sweep iff its own
    per-lane cond (changed ∧ it < max_iters ∧ ¬failed) holds, so results,
    sweep counts and convergence flags are identical across every caller
    (idempotence of ⊔ makes the frozen-lane masking exact).

    `step` overrides the sweep function (the scatter backend passes its
    join strategy through here); default is `sweep_tile` on `tables`.
    With `dom` (``[L, V, W]``) the bitset store rides in the carry (None
    is an empty pytree, so the loop structure is unchanged without it)
    and a sweep counts as "changed" when any domain word moved even if
    the hull did not — interior Compact-Table wipeouts must keep the
    lane sweeping.

    Returns (lb', ub', sweeps[L], converged[L]), with dom' inserted
    before the counters when it is carried.
    """
    L = lb.shape[0]
    have_dom = dom is not None
    if step is None:
        def step(lb_, ub_, dom_):
            return sweep_tile(lb_, ub_, *tables, horizon=horizon,
                              n_alldiff=n_alldiff,
                              n_cumulative=n_cumulative,
                              ad_layout=ad_layout, cu_layout=cu_layout,
                              n_table=n_table, n_words=n_words, dom=dom_)
    elif not have_dom:
        _step2 = step

        def step(lb_, ub_, dom_):
            return _step2(lb_, ub_)

    def lane_live(lb_, ub_, changed, it):
        ok = changed
        if max_iters is not None:
            ok = ok & (it < max_iters)
        if stop_on_fail:
            ok = ok & jnp.logical_not(jnp.any(lb_ > ub_, axis=1))
        return ok                                          # bool[L]

    def cond(st):
        lb_, ub_, dom_, changed, it = st
        return jnp.any(lane_live(lb_, ub_, changed, it))

    def body(st):
        lb_, ub_, dom_, changed, it = st
        active = lane_live(lb_, ub_, changed, it)
        out = step(lb_, ub_, dom_)
        if have_dom:
            nlb, nub, ndom = out
            ndom = jnp.where(active[:, None, None], ndom, dom_)
        else:
            (nlb, nub), ndom = out, dom_
        nlb = jnp.where(active[:, None], nlb, lb_)
        nub = jnp.where(active[:, None], nub, ub_)
        ch = jnp.any((nlb != lb_) | (nub != ub_), axis=1)
        if have_dom:
            ch = ch | jnp.any(ndom != dom_, axis=(1, 2))
        changed = jnp.where(active, ch, changed)
        return nlb, nub, ndom, changed, it + active.astype(jnp.int32)

    init = (lb, ub, dom, jnp.ones((L,), bool), jnp.zeros((L,), jnp.int32))
    lb, ub, dom, changed, iters = lax.while_loop(cond, body, init)
    converged = jnp.logical_not(changed) | jnp.any(lb > ub, axis=1)
    if have_dom:
        return lb, ub, dom, iters, converged
    return lb, ub, iters, converged


@partial(jax.jit, static_argnames=("max_iters", "stop_on_fail", "use_scatter"))
def fixpoint_batch(cm: CompiledModel, lb: jax.Array, ub: jax.Array,
                   dom=None, max_iters: Optional[int] = None,
                   stop_on_fail: bool = True, use_scatter: bool = False):
    """Lane-batched fixpoint: one `while_loop` over the whole ``[L, V]``
    store tensor, each sweep a single batched tensor op (`sweep_batch`).

    This is the TURBO superstep shape — one propagation launch for all
    lanes — replacing the per-lane `fixpoint` under `vmap` whose
    while_loop degenerates to lockstep select-masking anyway.  The loop
    itself is `fixpoint_tile`, shared verbatim with the Pallas kernels.

    Returns (lb', ub', sweeps[L], converged[L]); with `dom` carried the
    bitset store is threaded through and returned before the counters.
    """
    step = partial(sweep_scatter_batch, cm) if use_scatter else None
    return fixpoint_tile(lb, ub, *model_tables(cm), **model_statics(cm),
                         dom=dom, max_iters=max_iters,
                         stop_on_fail=stop_on_fail, step=step)


# --------------------------------------------------------------------------
# Sequential / chaotic iteration semantics — test-grade implementations of
# the paper's `seq P` (Prop. 3) and fair schedules (Def. 5 / Thm. 6).
# --------------------------------------------------------------------------

def apply_one(cm: CompiledModel, lb, ub, p: jax.Array):
    """Apply a single guarded command (SELECT rule) — one transition of ↪."""
    cand_lb, cand_ub = propagator_candidates(cm, lb, ub)  # (cheap enough for tests)
    row_ub, row_lb = cand_ub[p], cand_lb[p]
    tgt = jnp.concatenate([cm.vidx[p], cm.bidx[p][None]])
    new_ub = ub.at[tgt].min(jnp.maximum(row_ub, cm.box_lo[tgt]))
    new_lb = lb.at[tgt].max(jnp.minimum(row_lb, cm.box_hi[tgt]))
    return new_lb, new_ub


def sequential_fixpoint(cm: CompiledModel, lb, ub, order=None,
                        max_rounds: int = 10_000):
    """fix D(seq P) under the schedule `order` (default: program order).

    Python-loop driven; used only by tests to validate Prop. 3 / Thm. 6.
    """
    import numpy as np
    order = list(range(cm.n_props)) if order is None else list(order)
    lb = jnp.asarray(lb)
    ub = jnp.asarray(ub)
    for _ in range(max_rounds):
        plb, pub = lb, ub
        for p in order:
            lb, ub = apply_one(cm, lb, ub, jnp.asarray(p))
        if bool(jnp.all(lb == plb) & jnp.all(ub == pub)):
            return np.asarray(lb), np.asarray(ub)
    raise RuntimeError("sequential fixpoint did not converge")
