"""Lattice primitives (paper §"Parallel Concurrent Constraint Programming").

PCCP stores are Cartesian products of chain lattices.  We materialize the
integer-interval lattice ``IZ = ZInc × ZDec`` as two dense vectors

    lb : i32[V]   -- element of ZInc^V   (join = elementwise max)
    ub : i32[V]   -- element of ZDec^V   (join = elementwise min)

Booleans (BInc/BDec) are embedded as intervals over {0, 1}:
``lb == 1`` means *true is entailed*, ``ub == 0`` means *false is entailed*,
``(0, 1)`` is unknown (bottom), ``lb > ub`` is top (failure).

Pseudo-infinities: true ±inf does not exist on machine ints, so we use a
sentinel ``INF`` chosen small enough that ``coef * bound`` products and
K-term sums stay within the dtype (see ``compile.py`` for the checked
bounds).  All joins clamp back into [-INF, INF].
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Pseudo-infinity for the default int32 build.  Invariant (checked at model
# compile time): |coef| * INF_GUARD and K * max|term| must fit in the dtype.
INF32 = np.int32(1 << 20)
INF64 = np.int64(1 << 40)


def inf_for(dtype) -> np.integer:
    return INF64 if jnp.dtype(dtype).itemsize >= 8 else INF32


# --- chain lattices -------------------------------------------------------

def zinc_join(a, b):
    """Join in ZInc (increasing integers): max."""
    return jnp.maximum(a, b)


def zdec_join(a, b):
    """Join in ZDec = ZInc^op (decreasing integers): min."""
    return jnp.minimum(a, b)


def zinc_leq(a, b):
    """a <= b in ZInc (i.e. b carries at least as much information)."""
    return a <= b


def zdec_leq(a, b):
    return a >= b


# --- interval lattice IZ = ZInc x ZDec ------------------------------------

def iz_join(lb_a, ub_a, lb_b, ub_b):
    """Pointwise join of two interval stores (Cartesian-product join)."""
    return zinc_join(lb_a, lb_b), zdec_join(ub_a, ub_b)


def iz_leq(lb_a, ub_a, lb_b, ub_b):
    """(lb_a,ub_a) <= (lb_b,ub_b) in IZ: b is a sub-interval of a."""
    return jnp.logical_and(lb_a <= lb_b, ub_a >= ub_b)


def is_empty(lb, ub):
    """Top of IZ per variable == failure (empty concretization)."""
    return lb > ub


def is_fixed(lb, ub):
    return lb == ub


def any_failed(lb, ub):
    return jnp.any(is_empty(lb, ub))


def all_fixed(lb, ub):
    return jnp.all(is_fixed(lb, ub))


def clamp(x, dtype):
    inf = inf_for(dtype)
    return jnp.clip(x, -inf, inf).astype(dtype)


# --- boolean embedding -----------------------------------------------------

def bool_true(lb, ub, idx):
    """BInc view: lb[idx] >= 1 <=> `true` has been told."""
    return lb[..., idx] >= 1


def bool_false(lb, ub, idx):
    return ub[..., idx] <= 0


# --- host-side mirrors (used by the sequential baseline & tests) -----------

def np_iz_join(lb_a, ub_a, lb_b, ub_b):
    return np.maximum(lb_a, lb_b), np.minimum(ub_a, ub_b)
