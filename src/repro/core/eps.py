"""Embarrassingly-parallel-search decomposition (paper §TURBO, after
Malapert/Régin/Rezgui 2016; DESIGN.md §9).

TURBO "dynamically generates subproblems following a variant of EPS"; we
generate them by iterative splitting on the host: repeatedly split the
widest-frontier subproblem with the search branching rule, propagate both
children with the *same* fixpoint engine, and drop failed children.  The
resulting pool partitions the root search space (left `x ≤ m` / right
`x ≥ m+1` are complementary), so lane-level DFS over the pool is complete.

The pool feeds `engine.solve(eps_target=...)`: it seeds the per-device
lane pools, and `search.dispatch_pool` replenishes idle lanes from the
remainder every superstep (DESIGN.md §9).
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.compile import CompiledModel
from repro.core.fixpoint import fixpoint
from repro.core import search as S


def decompose(cm: CompiledModel, target: int,
              opts: "S.SearchOptions" = None) -> Tuple[np.ndarray, np.ndarray]:
    """Split the root into ~`target` consistent subproblems.

    Returns (subs_lb, subs_ub) with shape [S, V], S ≥ 1 (S can exceed or
    fall short of `target` when the tree is shallow/unsatisfiable).
    """
    opts = opts or S.SearchOptions()
    lb, ub, _, _ = fixpoint(cm, cm.lb0, cm.ub0)
    lb, ub = np.asarray(lb), np.asarray(ub)
    if (lb > ub).any():
        return lb[None], ub[None]          # failed root: one failed sub

    frontier: List[Tuple[np.ndarray, np.ndarray]] = [(lb, ub)]
    leaves: List[Tuple[np.ndarray, np.ndarray]] = []

    bv = np.asarray(cm.branch_vars)
    while frontier and len(frontier) + len(leaves) < target:
        # widest subproblem first keeps the pool balanced
        widths = [int((u - l)[bv].clip(min=0).sum()) for l, u in frontier]
        i = int(np.argmax(widths))
        l, u = frontier.pop(i)
        unf = l[bv] < u[bv]
        if not unf.any():
            leaves.append((l, u))          # already a solution leaf
            continue
        if opts.var_strategy == S.MIN_DOM:
            w = np.where(unf, u[bv] - l[bv], np.iinfo(l.dtype).max // 4)
            v = int(bv[int(np.argmin(w))])
        elif opts.var_strategy == S.MIN_LB:
            w = np.where(unf, l[bv], np.iinfo(l.dtype).max // 4)
            v = int(bv[int(np.argmin(w))])
        else:
            v = int(bv[int(np.argmax(unf))])
        m = int(l[v]) if opts.val_strategy == S.VAL_MIN else int((l[v] + u[v]) // 2)
        for child in ("le", "ge"):
            cl, cu = l.copy(), u.copy()
            if child == "le":
                cu[v] = min(cu[v], m)
            else:
                cl[v] = max(cl[v], m + 1)
            nlb, nub, _, _ = fixpoint(cm, cl, cu)
            nlb, nub = np.asarray(nlb), np.asarray(nub)
            if not (nlb > nub).any():
                frontier.append((nlb, nub))

    pool = frontier + leaves
    if not pool:                            # everything failed: UNSAT root
        bad_l = lb.copy(); bad_u = ub.copy()
        bad_l[0] = 1; bad_u[0] = 0          # an explicitly failed store
        pool = [(bad_l, bad_u)]
    subs_lb = np.stack([p[0] for p in pool])
    subs_ub = np.stack([p[1] for p in pool])
    return subs_lb, subs_ub


def pad_pool(subs_lb: np.ndarray, subs_ub: np.ndarray,
             size: int) -> Tuple[np.ndarray, np.ndarray]:
    """Pad a pool ``[S, V]`` up to ``size`` entries with explicitly-failed
    stores (``lb[0] > ub[0]``) — a lane that pops one fails it in a
    single superstep and re-arms, so statuses/objectives are unchanged.

    Used by the session API for two shape-stabilization jobs
    (DESIGN.md §11): bucketing pool sizes (`api._bucket`: powers of two
    up to 1024, then multiples of 1024 — capped so a 10³-variable model
    with a large ``eps_target`` can't silently allocate a pool ~2× the
    request, DESIGN.md §16) so the compiled runner is reused across
    instances whose decompositions differ slightly, and rounding the
    pool to a device-count multiple for the sharded mesh engine.
    ``size <= S`` is a no-op.

    The padded rows are inert under BOTH bank layouts: failure is
    carried by store row 0 (``lb[0] > ub[0]``), which the per-lane
    fixpoint masking freezes before any kind tile — dense or sparse —
    ever sweeps the lane (asserted by `tests/test_sparse_tiles.py`).
    """
    s = subs_lb.shape[0]
    if size <= s:
        return subs_lb, subs_ub
    fl = np.repeat(np.asarray(subs_lb[:1]).copy(), size - s, axis=0)
    fu = np.repeat(np.asarray(subs_ub[:1]).copy(), size - s, axis=0)
    fl[:, 0], fu[:, 0] = 1, 0
    return (np.concatenate([np.asarray(subs_lb), fl]),
            np.concatenate([np.asarray(subs_ub), fu]))


def fit_pool(subs_lb: np.ndarray, subs_ub: np.ndarray,
             size: int) -> Tuple[np.ndarray, np.ndarray]:
    """Fit a pool ``[S, V]`` to *exactly* ``size`` entries — the
    fixed-shape splice used by the serving scheduler (DESIGN.md §15):
    a `LaneBatch` slot's pool rows are a fixed ``[size, V]`` block of
    the compiled batch, so an admitted request's pool must be padded up
    (with inert failed stores, `pad_pool`) and can never exceed the
    bucket size without forcing a recompile — that case raises instead.
    """
    s = int(subs_lb.shape[0])
    if s > size:
        raise ValueError(
            f"pool of {s} subproblems does not fit the fixed bucket size "
            f"{size}; decompose with a smaller eps_target or grow the "
            f"bucket (which recompiles the batch runner)")
    return pad_pool(np.asarray(subs_lb), np.asarray(subs_ub), size)


def failed_pool(template_lb: np.ndarray, template_ub: np.ndarray,
                size: int) -> Tuple[np.ndarray, np.ndarray]:
    """An all-failed pool ``[size, V]`` (every store has ``lb[0] >
    ub[0]``) — what an idle/retired `LaneBatch` slot holds so its lanes
    drain in one superstep each and the slot freezes (DESIGN.md §15).
    ``template_lb/ub`` supply the store dtype and width ``V`` (a ``[V]``
    row or any ``[..., V]`` pool)."""
    lb = np.asarray(template_lb).reshape(-1, np.asarray(template_lb).shape[-1])
    ub = np.asarray(template_ub).reshape(-1, np.asarray(template_ub).shape[-1])
    fl = np.repeat(lb[:1].copy(), size, axis=0)
    fu = np.repeat(ub[:1].copy(), size, axis=0)
    fl[:, 0], fu[:, 0] = 1, 0
    return fl, fu
