"""Solver-as-a-service (DESIGN.md §15): continuous batching across
*requests*, not just across subproblems of one instance.

TURBO's thesis is that the device stays saturated when it is fed many
small independent units of work; the serving layer extends that property
across callers.  `SolveRequest`s enter an async ingress queue
(`serve/queue.py`), are bucketed by ``shape_signature`` × config into
per-bucket continuous batches (`serve/scheduler.py`) built on the
lane-owning `api.LaneBatch` core — late-arriving same-shape work joins
at the next chunk boundary, finished requests retire early, vLLM-style —
and per-request incumbent/`Progress` events stream back to callers
(`serve/session.py`).  `serve/loadgen.py` is the open-loop synthetic
load generator and `serve/metrics.py` the latency/occupancy recorder
that make the throughput story honest (p50/p99 time-to-first-incumbent
and time-to-optimal, queue depth, batch occupancy, instances/s).

Quickstart::

    from repro import serve, solver

    with serve.SolverService(solver.SolveConfig.preset("prove")) as svc:
        h1 = svc.submit(cm_a)                  # any thread
        h2 = svc.submit(cm_b, deadline_s=30.0)
        for ev in h1.events():                 # streamed incumbents
            print(ev.superstep, ev.best_objective)
        print(h2.result().status)

or, single-threaded and deterministic (tests, benches, the
`launch/serve_solver.py` CLI)::

    sched = serve.SolverScheduler(cfg, max_batch=4)
    handles = serve.run_open_loop(sched, serve.poisson_trace(50, 100.0))
    print(sched.recorder.summary())
"""

from repro.serve.queue import SolveRequest, RequestQueue            # noqa: F401
from repro.serve.metrics import MetricsRecorder                     # noqa: F401
from repro.serve.scheduler import SolverScheduler                   # noqa: F401
from repro.serve.session import RequestHandle, SolverService        # noqa: F401
from repro.serve.loadgen import (Arrival, DEFAULT_MIX,              # noqa: F401
                                 compile_arrival, poisson_trace,
                                 run_open_loop)

__all__ = [
    "SolveRequest", "RequestQueue", "MetricsRecorder",
    "SolverScheduler", "RequestHandle", "SolverService",
    "Arrival", "DEFAULT_MIX", "compile_arrival", "poisson_trace",
    "run_open_loop",
]
