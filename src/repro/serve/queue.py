"""Serving ingress: `SolveRequest` + the thread-safe admission queue.

The queue is deliberately dumb — an ingress buffer between caller
threads and the scheduler's host loop.  All policy (shape bucketing,
EDF ordering, deadline eviction) lives in `serve/scheduler.py`, which
drains this queue at every scheduler quantum; callers never block on
solver state, only on the queue lock for the microseconds of a push.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import deque
from typing import Any, Dict, List, Optional

from repro.core.compile import CompiledModel
from repro.core.api import SolveConfig

_req_counter = itertools.count()


@dataclasses.dataclass
class SolveRequest:
    """One serving request: a compiled model plus per-request policy.

    ``deadline_s`` is relative to submission; when it elapses before the
    solve completes the scheduler retires the request early with its
    best anytime incumbent (SAT/UNKNOWN, ``complete=False``) — a missed
    deadline degrades to the incumbent, it never blocks the batch.
    ``config`` overrides the scheduler's default `SolveConfig` and
    participates in the bucket key, so differently-configured requests
    never share a compiled batch.
    """
    cm: CompiledModel
    request_id: str = ""
    deadline_s: Optional[float] = None
    config: Optional[SolveConfig] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # stamped by the scheduler at submission (host wall clock)
    t_submit: float = 0.0

    def __post_init__(self):
        if not self.request_id:
            self.request_id = f"req-{next(_req_counter)}"
        if self.deadline_s is not None and not self.deadline_s > 0:
            raise ValueError(f"deadline_s must be None or > 0, got "
                             f"{self.deadline_s!r}")


class RequestQueue:
    """Thread-safe FIFO ingress buffer (submission order preserved);
    the scheduler drains it wholesale once per quantum."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: deque = deque()

    def push(self, item) -> None:
        with self._lock:
            self._items.append(item)

    def drain(self) -> List:
        """Pop everything currently queued, in submission order."""
        with self._lock:
            items = list(self._items)
            self._items.clear()
        return items

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)
