"""Serving observability: per-request latency records + fleet summary.

One `MetricsRecorder` per scheduler.  Every timestamp comes from the
`Progress`/`BatchSnapshot` timing contract (host ``time.time()``,
DESIGN.md §15) — the recorder never re-times anything itself, so the
serving numbers and the superstep bench share one timing source.

Latency definitions (all relative to *submission*, the caller-visible
clock):

* **time-to-first-incumbent (TTFI)** — submit → first solution found
  (the anytime answer the caller could act on);
* **time-to-optimal (TTO)** — submit → terminal result for requests
  that completed their proof (OPTIMAL/UNSAT with ``complete=True``);
* **latency** — submit → terminal result, whatever the status (deadline
  evictions included).

Occupancy is sampled per bucket *step*: live slots / batch width at
every quantum the bucket actually ran — the continuous-batching win is
this number staying > 1 under concurrent load.  Queue depth counts
submitted-but-not-yet-admitted requests (ingress + per-bucket waiting).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    request_id: str
    t_submit: float
    bucket: Optional[str] = None
    t_admit: Optional[float] = None
    t_first_incumbent: Optional[float] = None
    t_done: Optional[float] = None
    status: Optional[str] = None
    objective: Optional[int] = None
    complete: bool = False
    deadline_missed: bool = False
    n_supersteps: int = 0

    @property
    def ttfi_s(self) -> Optional[float]:
        return (None if self.t_first_incumbent is None
                else self.t_first_incumbent - self.t_submit)

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit

    @property
    def queue_wait_s(self) -> Optional[float]:
        return None if self.t_admit is None else self.t_admit - self.t_submit


def _pctl(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return dict(n=0)
    a = np.asarray(xs, float)
    return dict(n=len(xs), p50=round(float(np.percentile(a, 50)), 4),
                p99=round(float(np.percentile(a, 99)), 4),
                mean=round(float(a.mean()), 4),
                max=round(float(a.max()), 4))


class MetricsRecorder:
    """Thread-safe recorder; the scheduler calls the ``record_*`` /
    ``sample_*`` hooks, callers read `summary()`."""

    def __init__(self):
        self._lock = threading.Lock()
        self.requests: Dict[str, RequestRecord] = {}
        self.depth_samples: List[int] = []
        self.occupancy_samples: List[float] = []   # live/width per bucket step
        self.live_samples: List[int] = []          # live slots per bucket step
        self.bucket_stats: Dict[str, Dict[str, Any]] = {}

    # -- per-request lifecycle --------------------------------------------

    def record_submit(self, request_id: str, t: float) -> None:
        with self._lock:
            self.requests[request_id] = RequestRecord(request_id, t)

    def record_admit(self, request_id: str, bucket: str, t: float) -> None:
        with self._lock:
            r = self.requests[request_id]
            r.bucket, r.t_admit = bucket, t

    def record_first_incumbent(self, request_id: str, t: float) -> None:
        with self._lock:
            r = self.requests[request_id]
            if r.t_first_incumbent is None:
                r.t_first_incumbent = t

    def record_done(self, request_id: str, res, t: float, *,
                    deadline_missed: bool = False) -> None:
        with self._lock:
            r = self.requests[request_id]
            r.t_done, r.status, r.objective = t, res.status, res.objective
            r.complete = bool(res.complete)
            r.deadline_missed = deadline_missed
            r.n_supersteps = int(res.n_supersteps)

    # -- per-quantum samples ----------------------------------------------

    def sample_queue_depth(self, depth: int) -> None:
        with self._lock:
            self.depth_samples.append(int(depth))

    def sample_occupancy(self, bucket: str, live: int, width: int) -> None:
        with self._lock:
            self.live_samples.append(int(live))
            self.occupancy_samples.append(live / max(width, 1))
            b = self.bucket_stats.setdefault(
                bucket, dict(n_steps=0, n_requests=0, n_compiles=0,
                             width=width))
            b["n_steps"] += 1

    def record_bucket(self, bucket: str, *, n_requests: int = 0,
                      n_compiles: Optional[int] = None,
                      width: Optional[int] = None) -> None:
        with self._lock:
            b = self.bucket_stats.setdefault(
                bucket, dict(n_steps=0, n_requests=0, n_compiles=0,
                             width=width or 0))
            b["n_requests"] += n_requests
            if n_compiles is not None:
                b["n_compiles"] = n_compiles
            if width is not None:
                b["width"] = width

    # -- summary -----------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            recs = list(self.requests.values())
            depth = list(self.depth_samples)
            live = list(self.live_samples)
            occ = list(self.occupancy_samples)
            buckets = {k: dict(v) for k, v in self.bucket_stats.items()}
        done = [r for r in recs if r.t_done is not None]
        proven = [r for r in done if r.complete]
        span_s = (max(r.t_done for r in done) - min(r.t_submit for r in recs)
                  if done else 0.0)
        statuses: Dict[str, int] = {}
        for r in done:
            statuses[r.status] = statuses.get(r.status, 0) + 1
        return dict(
            n_requests=len(recs),
            n_done=len(done),
            n_deadline_missed=sum(r.deadline_missed for r in done),
            statuses=statuses,
            ttfi_s=_pctl([r.ttfi_s for r in recs if r.ttfi_s is not None]),
            tto_s=_pctl([r.latency_s for r in proven]),
            latency_s=_pctl([r.latency_s for r in done]),
            queue_wait_s=_pctl([r.queue_wait_s for r in recs
                                if r.queue_wait_s is not None]),
            queue_depth=_pctl([float(d) for d in depth]),
            batch_occupancy=_pctl(occ),
            batch_live_slots=_pctl([float(x) for x in live]),
            instances_per_sec=round(len(done) / span_s, 2) if span_s > 0
            else None,
            span_s=round(span_s, 4),
            buckets=buckets,
        )
