"""Open-loop synthetic load generator (DESIGN.md §15).

*Open-loop* means arrivals are scheduled up front from a seeded Poisson
process and submitted at their absolute offsets **independent of
completions** — the generator never waits for the server, so queueing
delay shows up honestly in the latency percentiles instead of being
hidden by closed-loop self-throttling (the standard methodology caveat
for serving benchmarks).

A trace is a seeded mix over zoo models/sizes (≥2 distinct
``shape_signature``s by default, so the scheduler's bucketing is
actually exercised) with mixed deadlines.  `run_open_loop` drives a
`SolverScheduler` on the host clock: submit every due arrival, run one
scheduler quantum, repeat until the trace is exhausted and the queue
drains.  Instance *models* are pre-compiled before the clock starts so
host-side model building doesn't distort arrival timing (the solver's
jit compiles still happen in-band — they are the cold-bucket cost the
metrics are supposed to see).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import models as zoo
from repro.serve.queue import SolveRequest
from repro.serve.scheduler import SolverScheduler
from repro.serve.session import RequestHandle

# (zoo model, generate() kwargs, relative weight).  knapsack and
# jobshop have different store widths and propagator banks, so the
# default mix always produces >= 2 shape buckets — and both families'
# shape signatures are *seed-stable* (instance contents vary per seed,
# table shapes don't), so every request after a bucket's first lands
# warm.  (coloring/rcpsp are deliberately absent: their edge counts are
# seed-dependent, so each seed would cold-compile its own bucket.)
DEFAULT_MIX: Tuple[Tuple[str, dict, float], ...] = (
    ("knapsack", dict(n=6), 2.0),
    ("jobshop", dict(n_jobs=2, n_machines=2), 1.0),
)

# deadline mix (seconds, None = no deadline), cycled over arrivals —
# "mixed deadlines" without ever being tight enough to fire on a healthy
# CI box (tight-deadline eviction is exercised by its own test)
DEFAULT_DEADLINES: Tuple[Optional[float], ...] = (None, 120.0, 600.0)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: when it arrives and what it asks for."""
    t_arrival: float                  # seconds after trace start
    model: str                        # zoo model name
    gen_kwargs: Tuple[Tuple[str, object], ...]
    seed: int
    deadline_s: Optional[float]

    def generate(self):
        return zoo.ZOO[self.model].generate(seed=self.seed,
                                            **dict(self.gen_kwargs))


def poisson_trace(n_requests: int, rate_rps: float, *, seed: int = 0,
                  mix: Sequence[Tuple[str, dict, float]] = DEFAULT_MIX,
                  deadlines: Sequence[Optional[float]] = DEFAULT_DEADLINES,
                  ) -> List[Arrival]:
    """A seeded open-loop trace: exponential inter-arrivals at
    ``rate_rps`` requests/s, models drawn from ``mix`` by weight,
    per-request instance seeds drawn from the same stream (so the whole
    trace is reproducible from ``seed`` alone), deadlines cycled."""
    if n_requests < 1 or not rate_rps > 0:
        raise ValueError(f"need n_requests >= 1 and rate_rps > 0, got "
                         f"{n_requests}, {rate_rps}")
    rng = np.random.default_rng(seed)
    names = [m for m, _, _ in mix]
    w = np.asarray([float(x) for _, _, x in mix])
    w = w / w.sum()
    kwargs = {m: tuple(sorted(kw.items())) for m, kw, _ in mix}
    t = 0.0
    out = []
    for k in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        name = names[int(rng.choice(len(names), p=w))]
        out.append(Arrival(
            t_arrival=t, model=name, gen_kwargs=kwargs[name],
            seed=int(rng.integers(0, 2 ** 31 - 1)),
            deadline_s=deadlines[k % len(deadlines)] if deadlines else None))
    return out


def compile_arrival(arr: Arrival):
    """Generate + build + compile the arrival's instance (host-side)."""
    m, _ = zoo.ZOO[arr.model].build_model(arr.generate())
    return m.compile()


def run_open_loop(scheduler: SolverScheduler, trace: Sequence[Arrival], *,
                  max_wall_s: Optional[float] = None,
                  ) -> List[Tuple[Arrival, RequestHandle]]:
    """Drive ``scheduler`` with ``trace`` on the host clock (open loop:
    submission times never wait for the server) until every request has
    retired.  Returns ``(arrival, handle)`` pairs in arrival order; each
    handle's `result()` is immediately available on return."""
    cms = [compile_arrival(a) for a in trace]      # off the clock
    handles: List[Tuple[Arrival, RequestHandle]] = []
    t0 = time.time()
    i = 0
    while i < len(trace) or scheduler.has_work():
        if max_wall_s is not None and time.time() - t0 > max_wall_s:
            raise TimeoutError(
                f"open-loop run not drained within {max_wall_s}s "
                f"({i}/{len(trace)} submitted, "
                f"{scheduler.queue_depth()} queued)")
        now = time.time() - t0
        while i < len(trace) and trace[i].t_arrival <= now:
            a = trace[i]
            handles.append((a, scheduler.submit(SolveRequest(
                cm=cms[i], request_id=f"r{i}", deadline_s=a.deadline_s,
                meta=dict(model=a.model, seed=a.seed)))))
            i += 1
        if not scheduler.step() and i < len(trace):
            # idle until the next arrival is due (open-loop pacing)
            time.sleep(min(0.002, max(trace[i].t_arrival - (time.time() - t0),
                                      0.0)))
    return handles


def sequential_reference(trace: Sequence[Arrival],
                         config) -> Dict[str, Tuple[str, Optional[int]]]:
    """The parity oracle: solve every trace request sequentially through
    one warm `Solver` session and return ``request_id -> (status,
    objective)`` — what the scheduler must reproduce bit-identically
    (deadlines permitting)."""
    from repro.core.api import Solver
    sess = Solver(config)
    out: Dict[str, Tuple[str, Optional[int]]] = {}
    for k, arr in enumerate(trace):
        res = sess.solve(compile_arrival(arr))
        out[f"r{k}"] = (res.status, res.objective)
    return out
