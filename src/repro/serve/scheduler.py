"""The continuous-batching scheduler (DESIGN.md §15).

One `SolverScheduler` owns a `Solver` session and a set of *buckets*,
keyed by ``(shape_signature, config.compile_key(), pool bucket)``.  Each
bucket wraps an `api.LaneBatch` of ``max_batch`` slots — the lane-owning
batch that `_run_chunk`'s host loop became — compiled once (cold) and
then reused for every request that lands in the bucket (warm).

Per scheduler quantum (`step`):

1. **ingress** — drain the thread-safe `RequestQueue`, routing each
   request to its bucket (creating the bucket, and paying its one cold
   compile, on first sight of a new shape/config);
2. **admission** — earliest-deadline-first over each bucket's waiting
   list, splicing requests into idle slots at the chunk boundary
   (`LaneBatch.splice`; requests whose deadline expired while queued are
   answered UNKNOWN without ever occupying a slot);
3. **stepping** — one `LaneBatch.step` per non-empty bucket (up to
   ``chunk`` supersteps per live slot), then per-slot bookkeeping off
   the `BatchSnapshot`: improvement events stream to the request's
   handle, finished slots retire with their per-request
   `derive_result`, deadline-missed slots are evicted with their best
   anytime incumbent (``complete=False`` — never OPTIMAL/UNSAT);
4. **observability** — queue depth, per-bucket occupancy and compile
   counters sampled into the `MetricsRecorder`.

Fairness/deadline policy: EDF at admission (no-deadline requests rank
last, FIFO among themselves), run-to-completion once admitted (a slot is
never preempted for a later request — eviction happens only at the
request's own deadline).  With one host thread this is cooperative
scheduling at chunk granularity; see the honesty note in DESIGN.md §15.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core import eps
from repro.core.api import (Improvement, LaneBatch, Progress, SolveConfig,
                            SolveResult, Solver, UNKNOWN, _bucket,
                            shape_signature)
from repro.serve.metrics import MetricsRecorder
from repro.serve.queue import RequestQueue, SolveRequest
from repro.serve.session import RequestHandle


@dataclasses.dataclass
class _Active:
    """A request occupying a lane-batch slot."""
    request: SolveRequest
    handle: RequestHandle
    t_admit: float
    deadline_t: Optional[float]            # absolute, None = no deadline
    best_seen: Optional[int] = None
    found_sol: bool = False
    improvements: List[Improvement] = dataclasses.field(default_factory=list)


class _Bucket:
    """One shape×config bucket: a `LaneBatch` plus its waiting list."""

    def __init__(self, label: str, cfg: SolveConfig, batch: LaneBatch):
        self.label = label
        self.cfg = cfg
        self.batch = batch
        self.waiting: List[Tuple[SolveRequest, RequestHandle]] = []
        self.active: Dict[int, _Active] = {}
        self.n_requests = 0


class SolverScheduler:
    """Single-threaded continuous-batching host loop (drive `step`
    yourself, or wrap in `serve.SolverService` for the threaded
    surface).  ``max_batch`` is the slot width of every bucket's
    `LaneBatch` — the max requests co-resident per compiled batch."""

    def __init__(self, config: Optional[SolveConfig] = None, *,
                 max_batch: int = 4, session: Optional[Solver] = None,
                 recorder: Optional[MetricsRecorder] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.config = (config if config is not None
                       else SolveConfig.preset("prove"))
        self.session = session if session is not None else Solver(self.config)
        self.recorder = recorder if recorder is not None else MetricsRecorder()
        self.max_batch = int(max_batch)
        self.queue = RequestQueue()
        self._buckets: Dict[tuple, _Bucket] = {}
        self._open_lock = threading.Lock()
        self._n_open = 0

    # -- submission (any thread) ------------------------------------------

    def submit(self, request: SolveRequest) -> RequestHandle:
        request.t_submit = time.time()
        handle = RequestHandle(request)
        with self._open_lock:
            self._n_open += 1
        self.recorder.record_submit(request.request_id, request.t_submit)
        self.queue.push((request, handle))
        return handle

    # -- introspection -----------------------------------------------------

    def has_work(self) -> bool:
        with self._open_lock:
            return self._n_open > 0

    def queue_depth(self) -> int:
        return len(self.queue) + sum(len(b.waiting)
                                     for b in self._buckets.values())

    def buckets(self) -> Dict[str, Dict[str, Any]]:
        """Per-bucket stats (label → counters), incl. the compile count
        that proves 'at most one cold compile per bucket'."""
        return {
            b.label: dict(
                n_requests=b.n_requests, width=b.batch.width,
                pool_size=b.batch.pool_size,
                n_spliced=b.batch.n_spliced, n_retired=b.batch.n_retired,
                n_compiles=b.batch.runner.n_compiles,
                compile_s=round(b.batch.runner.compile_s, 4))
            for b in self._buckets.values()
        }

    # -- the scheduler quantum ---------------------------------------------

    def step(self) -> bool:
        """One quantum: ingress → admission → step buckets → bookkeeping.
        Returns False when there was nothing at all to do (the idle
        signal the threaded service sleeps on)."""
        now = time.time()
        progressed = False
        for req, handle in self.queue.drain():
            self._route(req, handle)
            progressed = True
        for b in self._buckets.values():
            progressed |= self._admit(b, now)
        for b in self._buckets.values():
            if b.batch.occupancy == 0:
                continue
            snap = b.batch.step()
            self.recorder.sample_occupancy(b.label, b.batch.occupancy,
                                           b.batch.width)
            self.recorder.record_bucket(
                b.label, n_compiles=b.batch.runner.n_compiles,
                width=b.batch.width)
            self._process(b, snap)
            progressed = True
        if progressed:
            self.recorder.sample_queue_depth(self.queue_depth())
        return progressed

    def run_until_drained(self, *, max_wall_s: Optional[float] = None) -> None:
        """Step until every submitted request has retired (library-driven
        deterministic mode; the open-loop driver in `serve/loadgen.py`
        interleaves submission instead)."""
        t0 = time.time()
        while self.has_work():
            self.step()
            if max_wall_s is not None and time.time() - t0 > max_wall_s:
                raise TimeoutError(
                    f"scheduler not drained within {max_wall_s}s "
                    f"({self.queue_depth()} queued)")

    # -- internals ---------------------------------------------------------

    def _route(self, req: SolveRequest, handle: RequestHandle) -> None:
        cfg = req.config if req.config is not None else self.config
        sig = shape_signature(req.cm)
        tgt = cfg.resolved_eps_target()
        pool_size = _bucket(tgt) if cfg.pad_pool else tgt
        key = (sig, cfg.compile_key(), pool_size)
        b = self._buckets.get(key)
        if b is None:
            label = f"b{len(self._buckets)}:{req.cm.name or 'anon'}"
            batch = self.session.lane_batch(
                req.cm, width=self.max_batch, pool_size=pool_size,
                config=cfg)
            b = self._buckets[key] = _Bucket(label, cfg, batch)
            self.recorder.record_bucket(label, width=batch.width)
        b.n_requests += 1
        self.recorder.record_bucket(b.label, n_requests=1)
        b.waiting.append((req, handle))

    @staticmethod
    def _deadline_t(req: SolveRequest) -> Optional[float]:
        return (None if req.deadline_s is None
                else req.t_submit + req.deadline_s)

    def _admit(self, b: _Bucket, now: float) -> bool:
        if not b.waiting:
            return False
        progressed = False
        # expire requests whose deadline passed while still queued
        still: List[Tuple[SolveRequest, RequestHandle]] = []
        for req, handle in b.waiting:
            dt = self._deadline_t(req)
            if dt is not None and now > dt:
                self._expire_waiting(req, handle, now)
                progressed = True
            else:
                still.append((req, handle))
        # EDF: earliest absolute deadline first; no-deadline requests
        # last, FIFO among themselves
        still.sort(key=lambda rh: (self._deadline_t(rh[0])
                                   if self._deadline_t(rh[0]) is not None
                                   else math.inf, rh[0].t_submit))
        b.waiting = still
        for i in b.batch.idle_slots():
            if not b.waiting:
                break
            req, handle = b.waiting.pop(0)
            opts = b.cfg.search_options()
            subs_lb, subs_ub = eps.decompose(
                req.cm, b.cfg.resolved_eps_target(), opts)
            b.batch.splice(i, req.cm, subs_lb, subs_ub,
                           request_id=req.request_id)
            b.active[i] = _Active(request=req, handle=handle, t_admit=now,
                                  deadline_t=self._deadline_t(req))
            self.recorder.record_admit(req.request_id, b.label, now)
            progressed = True
        return progressed

    def _expire_waiting(self, req: SolveRequest, handle: RequestHandle,
                        now: float) -> None:
        """A deadline elapsed before the request ever reached a slot:
        answer UNKNOWN (no search state exists to derive from)."""
        res = SolveResult(status=UNKNOWN, objective=None, solution=None,
                          n_nodes=0, n_fails=0, n_sols=0, n_sweeps=0,
                          n_supersteps=0, wall_s=now - req.t_submit,
                          complete=False)
        with self._open_lock:
            self._n_open -= 1
        self.recorder.record_done(req.request_id, res, now,
                                  deadline_missed=True)
        handle._push(Progress(
            superstep=0, best_objective=None, has_solution=False,
            incumbent=None, n_nodes=0, n_sols=0,
            wall_s=res.wall_s, final=True, result=res, t_host=now))

    def _process(self, b: _Bucket, snap) -> None:
        obj_model = b.batch.obj_var >= 0
        for i in sorted(b.active):
            act = b.active[i]
            rid = act.request.request_id
            wall = snap.t_host - act.t_admit
            superstep = int(snap.superstep[i])
            if bool(snap.has_sol[i]):
                obj = int(snap.best_obj[i]) if obj_model else None
                improved = (not act.found_sol if not obj_model
                            else act.best_seen is None or obj < act.best_seen)
                if improved:
                    act.found_sol = True
                    act.best_seen = obj
                    self.recorder.record_first_incumbent(rid, snap.t_host)
                    _, sol = b.batch.incumbent(i)
                    if obj_model:
                        act.improvements.append(
                            Improvement(superstep, wall, obj))
                    act.handle._push(Progress(
                        superstep=superstep, best_objective=obj,
                        has_solution=True, incumbent=sol,
                        n_nodes=int(snap.n_nodes[i]),
                        n_sols=int(snap.n_sols[i]), wall_s=wall,
                        t_host=snap.t_host))
            done = bool(snap.gdone[i])
            expired = (act.deadline_t is not None
                       and snap.t_host > act.deadline_t)
            if not (done or expired):
                continue
            res = b.batch.retire(i, wall_s=wall,
                                 improvements=act.improvements)
            del b.active[i]
            with self._open_lock:
                self._n_open -= 1
            self.recorder.record_done(rid, res, snap.t_host,
                                      deadline_missed=expired and not done)
            act.handle._push(Progress(
                superstep=superstep, best_objective=res.objective,
                has_solution=res.solution is not None,
                incumbent=res.solution, n_nodes=res.n_nodes,
                n_sols=res.n_sols, wall_s=wall, final=True, result=res,
                t_host=snap.t_host))
