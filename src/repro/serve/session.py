"""Caller-facing serving sessions: per-request event streams + the
threaded service wrapper.

`RequestHandle` is what `submit` returns: a thread-safe stream of the
request's `Progress` events (improvement events while the solve runs,
then exactly one ``final=True`` event carrying the `SolveResult`) plus a
blocking `result()`.  The scheduler pushes into the handle from its host
loop; callers consume from any thread.

`SolverService` wraps a `SolverScheduler` in a daemon thread so
ordinary callers get the async surface — submit-and-stream from any
thread — while the scheduler itself stays a single-threaded host loop
(the same CPU-lockstep honesty note as DESIGN.md §11: on one host
thread, "async" means interleaved at chunk granularity, not parallel
device queues).
"""

from __future__ import annotations

import threading
import time
from typing import Iterator, Optional

from repro.core.api import Progress, SolveConfig, SolveResult
from repro.core.compile import CompiledModel
from repro.serve.queue import SolveRequest


class RequestHandle:
    """One request's stream of `Progress` events + terminal result."""

    def __init__(self, request: SolveRequest):
        self.request = request
        self._cv = threading.Condition()
        self._events = []
        self._result: Optional[SolveResult] = None

    # -- scheduler side ----------------------------------------------------

    def _push(self, ev: Progress) -> None:
        with self._cv:
            self._events.append(ev)
            if ev.final:
                self._result = ev.result
            self._cv.notify_all()

    # -- caller side -------------------------------------------------------

    def done(self) -> bool:
        with self._cv:
            return self._result is not None

    def result(self, timeout: Optional[float] = None) -> SolveResult:
        """Block until the request retires; raises TimeoutError on a
        caller-side wait timeout (the request itself keeps running)."""
        deadline = None if timeout is None else time.time() + timeout
        with self._cv:
            while self._result is None:
                left = (None if deadline is None
                        else max(deadline - time.time(), 0.0))
                if left == 0.0:
                    raise TimeoutError(
                        f"request {self.request.request_id} not done "
                        f"within {timeout}s")
                self._cv.wait(left)
            return self._result

    def events(self, timeout: Optional[float] = None) -> Iterator[Progress]:
        """Yield this request's `Progress` events in order, blocking for
        new ones until the ``final=True`` event; ``timeout`` bounds each
        individual wait."""
        i = 0
        while True:
            with self._cv:
                while i >= len(self._events):
                    if self._result is not None and self._events and \
                            self._events[-1].final:
                        return
                    if not self._cv.wait(timeout):
                        raise TimeoutError(
                            f"no event within {timeout}s for request "
                            f"{self.request.request_id}")
                ev = self._events[i]
            i += 1
            yield ev
            if ev.final:
                return


class SolverService:
    """Threaded serving facade: a `SolverScheduler` host loop running in
    a daemon thread, `submit` callable from any thread.

    ``poll_s`` is how long the loop sleeps when there is no work at all;
    while work exists the loop spins at scheduler-quantum granularity.
    Use as a context manager — `close()` drains in-flight requests by
    default."""

    def __init__(self, config: Optional[SolveConfig] = None, *,
                 max_batch: int = 4, poll_s: float = 0.002, **sched_kw):
        from repro.serve.scheduler import SolverScheduler
        self.scheduler = SolverScheduler(config, max_batch=max_batch,
                                         **sched_kw)
        self._poll_s = poll_s
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-serve-loop")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            if not self.scheduler.step():
                time.sleep(self._poll_s)

    def submit(self, cm: CompiledModel, *,
               deadline_s: Optional[float] = None,
               config: Optional[SolveConfig] = None,
               request_id: str = "", **meta) -> RequestHandle:
        if self._stop.is_set():
            raise RuntimeError("SolverService is closed")
        return self.scheduler.submit(SolveRequest(
            cm=cm, request_id=request_id, deadline_s=deadline_s,
            config=config, meta=meta))

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop the loop; with ``drain`` (default) keep stepping until
        every submitted request has retired first."""
        if drain:
            t0 = time.time()
            while self.scheduler.has_work():
                if timeout is not None and time.time() - t0 > timeout:
                    break
                time.sleep(self._poll_s)
        self._stop.set()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "SolverService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
