"""Pallas TPU flash-attention (beyond-paper kernel for the LM substrate).

The XLA blocked attention (`nn/attention.py`) is the portable baseline;
this kernel keeps the online-softmax state in VMEM across KV blocks and
is the §Perf candidate for the attention-heavy train/prefill cells.

Grid: (batch, q-heads, q-blocks).  Each cell holds one q block [bq, hd]
and streams the (GQA-mapped) KV head's sequence in bk-sized VMEM slices
with the standard m/l/acc online-softmax recurrence.  Causal masking via
absolute positions.  Validated in interpret mode against a dense oracle
(`tests/test_flash_attention.py`); the blocked XLA path remains the
production fallback on any backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, causal: bool,
                  scale: float, bq: int):
    # blocks: q [1, bq, 1, hd]; k/v [1, S, 1, hd]
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale       # [bq, hd]
    S = k_ref.shape[1]
    hd = q.shape[-1]

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(j * bk, bk), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * bk, bk), 0, :].astype(jnp.float32)
        s = q @ k.T                                          # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    nk = S // bk
    if causal:
        # blocks strictly after the diagonal contribute nothing
        nk_eff = jnp.minimum(nk, (qi + 1) * bq // bk + 1)
    else:
        nk_eff = nk
    acc, m, l = lax.fori_loop(0, nk_eff, body, (acc0, m0, l0))
    o_ref[0, :, 0, :] = (acc / jnp.maximum(l, 1e-30)[:, None]
                         ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q [B,S,H,hd], k/v [B,S,KV,hd] (H % KV == 0) → [B,S,H,hd].

    Self-attention over aligned positions (train/prefill); decode uses
    the XLA path.  S is padded to the block size internally.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    pad_q = (-S) % bq
    pad_k = (-S) % bk
    if pad_q or pad_k:
        pad = max(pad_q, pad_k)
        # pad keys with -inf-like positions via causal mask: padded kv
        # rows sit at positions > any query, so causal masking hides
        # them; for non-causal we must mask explicitly — pad q instead
        # and slice (non-causal path requires S % bk == 0 after this pad)
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if not causal:
            raise ValueError("non-causal flash requires S % bk == 0")
    Sp = q.shape[1]
    grid = (B, H, Sp // bq)
    scale = 1.0 / np.sqrt(hd)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bk=bk, causal=causal, scale=scale,
                          bq=bq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, Sp, 1, hd), lambda b, h, i: (b, 0, h // rep, 0)),
            pl.BlockSpec((1, Sp, 1, hd), lambda b, h, i: (b, 0, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
