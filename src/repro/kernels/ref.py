"""Pure-jnp oracle for the propagation fixpoint kernel.

Propagator-centric scatter form, batched over lanes with vmap — the
slow-but-obviously-correct reference (`sweep_scatter` is "each propagator
joins its variables", the literal reading of the paper's load/store
compilation with atomic joins).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compile import CompiledModel
from repro.core.fixpoint import fixpoint


def fixpoint_ref(cm: CompiledModel, lb: jax.Array, ub: jax.Array,
                 max_sweeps: int | None = None):
    """lb, ub: [L, V] lane-batched stores. Returns (lb', ub') at fixpoint."""
    def one(l, u):
        nl, nu, _, _ = fixpoint(cm, l, u, max_iters=max_sweeps,
                                stop_on_fail=True, use_scatter=True)
        return nl, nu

    return jax.vmap(one)(lb, ub)
