"""Pallas TPU kernels: propagation fixpoint and resident search in VMEM.

GPU→TPU mapping (DESIGN.md §2): one grid cell ↔ one TURBO CUDA block ↔ a
*tile of lanes* whose stores live in VMEM for the entire kernel — the
analogue of TURBO keeping both stores in the SM's shared memory.  The
propagator/occurrence tables are broadcast to every grid cell (index_map
pins them to block 0), mirroring the constant problem tables in GPU
constant/global memory.

Two kernels share one semantics implementation:

* `fixpoint_pallas` — the *unfused* propagation kernel: one grid cell
  iterates its lane tile to the least fixed point.  The loop body is
  `fixpoint.fixpoint_tile`, the **same** per-lane-masked sweep loop the
  XLA gather backend runs — one implementation, two execution
  strategies.

* `search_pallas` — the *resident search megakernel* (DESIGN.md §13):
  the whole four-phase superstep — EPS pool dispatch, subproblem load +
  B&B bound tell, fixpoint sweeps, solution/backtrack/branch commit —
  fused into one `pl.pallas_call` that keeps every piece of lane state
  (both stores, the decision path, status flags, the pool cursor and the
  tile-best bound) resident in VMEM across ``supersteps`` supersteps,
  via a `lax.fori_loop` over `search.lane_load_tile` /
  `fixpoint.fixpoint_tile` / `search.lane_commit_tile` — the *same*
  pure-array tile functions `search.lanes_step` composes as separate XLA
  dispatches.  The host is re-entered only once per K supersteps (global
  best all-reduce, incumbent streaming, pool refill — see
  `core/api._run_chunk`).

VMEM budget: `vmem_budget` promotes the DESIGN.md §2 table into code —
per-grid-cell bytes for tables, stores, resident search state and the
dominant sweep intermediates — and `fixpoint_pallas`/`search_pallas`
auto-shrink their lane tile (with a warning) instead of dying in a
Mosaic OOM.

Validated in interpret mode on CPU (this container has no TPU); the ops
used (take/gather along axis 0, elementwise, while_loop/fori_loop/cond)
lower on TPU Pallas for int32.  The one TPU caveat: the decision-path
scatter in `search.apply_path_tile` lowers through
`lax.scatter_min/max`, which Mosaic supports only via serialization —
acceptable because it touches [L, MD] elements, not [L, V] stores.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.compile import (alldiff_dense_tile_bytes,
                                alldiff_sparse_tile_bytes,
                                ct_tile_bytes,
                                cumulative_dense_tile_bytes,
                                cumulative_sparse_tile_bytes)
from repro.core.fixpoint import fixpoint_tile
from repro.core import search as S

# TPU v5e per-core VMEM (DESIGN.md §2); the budget leaves headroom for
# double-buffering and compiler temporaries by charging the dominant
# sweep intermediates explicitly instead of reserving a blanket margin.
VMEM_LIMIT_BYTES = 16 * 1024 * 1024

N_TABLES = 35        # positional args of fixpoint.sweep_tile, in order
# model_tables positions the search kernel reads back out (§17 banks)
_I_DOM_OFF, _I_DOM_TRACK = 31, 32
_BOOL_FIELDS = ("dec_flip", "fresh", "done", "incomplete", "has_sol")


def _nbytes(a) -> int:
    return int(a.size) * a.dtype.itemsize


def vmem_budget(cm, lane_tile: int, *, resident: bool = False,
                max_depth: int = 0, pool_size: int = 0) -> dict:
    """Per-grid-cell VMEM byte footprint (the DESIGN.md §2/§13 budget
    table, in code).

    Returns a breakdown dict with a ``total`` key:

    * ``tables``  — the broadcast propagator/occurrence banks;
    * ``stores``  — lane-tile store I/O (in + out);
    * ``state``   — resident-only: the full `LaneState` beyond the
      stores (decision path [TL, MD]·3, best_sol [TL, V], per-lane
      scalars), in + out, plus the broadcast EPS pool [S, V]·2;
    * ``scratch`` — the dominant sweep intermediates per lane: the
      [P1, K+1] linear candidate tensors, the per-bank tile scratch
      **for the compiled layout** (dense: [A1, N³] Hall tensor /
      [C1, T, H] time-table grid; sparse: the [M, M] packed pairwise
      tensors / the O(M) event arrays — estimators shared with
      `compile.py`'s crossover guard), plus the [V, D] occurrence
      gathers.

    `fixpoint_pallas`/`search_pallas` compare ``total`` against
    `VMEM_LIMIT_BYTES` and halve the lane tile instead of handing Mosaic
    an un-allocatable kernel.
    """
    it = jnp.dtype(cm.jdtype).itemsize
    V = cm.n_vars
    from repro.core.fixpoint import model_tables
    tables = sum(_nbytes(a) for a in model_tables(cm))

    P1, K = cm.vidx.shape
    D = cm.occ_prop.shape[1]
    A1, N = cm.ad_vars.shape
    Dad = cm.ad_occ_inst.shape[1]
    C1, T = cm.cu_svar.shape
    Dcu = cm.cu_occ_inst.shape[1]
    per_lane = 8 * P1 * (K + 1) + 2 * V * (D + Dad + Dcu)
    scratch = lane_tile * per_lane * it
    if cm.n_alldiff:
        scratch += lane_tile * (
            alldiff_sparse_tile_bytes(cm.ad_packed, it)
            if cm.ad_layout == "sparse"
            else alldiff_dense_tile_bytes(cm.n_alldiff, N, it))
    if cm.n_cumulative:
        scratch += lane_tile * (
            cumulative_sparse_tile_bytes(cm.cu_packed, it)
            if cm.cu_layout == "sparse"
            else cumulative_dense_tile_bytes(cm.n_cumulative, T,
                                             cm.horizon, it))
    if cm.n_table:
        scratch += lane_tile * ct_tile_bytes(cm.n_table, cm.ct_arity,
                                             cm.n_words, cm.ct_words)

    stores = 4 * lane_tile * V * it          # lb/ub in + out
    if cm.n_table:
        # the carried bitset store (dom in + out); middle_out on a pure
        # bounds model also carries one, but that is V words/lane of
        # headroom the budget's explicit-scratch margins absorb
        stores += 2 * lane_tile * V * cm.n_words * 4
    state = 0
    if resident:
        tables += _nbytes(cm.branch_vars)
        # root stores + best_sol (in+out), decision path, lane scalars
        state += 2 * (3 * lane_tile * V * it          # root_lb/ub, best_sol
                      + 3 * lane_tile * max_depth * 4  # dec_var/val/flip
                      + 12 * lane_tile * 4)            # flags + counters
        state += 2 * pool_size * V * it                # broadcast EPS pool
        if cm.n_table:
            state += 2 * lane_tile * V * cm.n_words * 4   # root_dom in+out
    else:
        stores += 2 * lane_tile * 4                    # sweeps/conv out
    total = tables + stores + state + scratch
    return dict(tables=tables, stores=stores, state=state, scratch=scratch,
                total=total)


def fit_lane_tile(cm, lane_tile: int, n_lanes: int, *,
                  resident: bool = False, max_depth: int = 0,
                  pool_size: int = 0, limit_bytes: int = None) -> int:
    """Clamp `lane_tile` to `n_lanes` and halve it until the
    `vmem_budget` fits `limit_bytes` (default `VMEM_LIMIT_BYTES`,
    warning on each shrink); raise a clear error when even a single
    lane per cell does not fit."""
    if limit_bytes is None:
        limit_bytes = VMEM_LIMIT_BYTES
    kernel = "search_pallas" if resident else "fixpoint_pallas"
    tile = max(1, min(lane_tile, n_lanes))
    while True:
        b = vmem_budget(cm, tile, resident=resident, max_depth=max_depth,
                        pool_size=pool_size)
        if b["total"] <= limit_bytes:
            return tile
        if tile == 1:
            raise ValueError(
                f"{kernel}: model {cm.name or '<unnamed>'} does not fit "
                f"VMEM even at lane_tile=1: "
                f"{b['total'] / 2**20:.1f} MB needed "
                f"(tables {b['tables'] / 2**20:.1f} MB, scratch "
                f"{b['scratch'] / 2**20:.1f} MB, state "
                f"{b['state'] / 2**20:.1f} MB) vs "
                f"{limit_bytes / 2**20:.1f} MB VMEM — shrink the model "
                f"(horizon/occurrence widths) or use the gather backend")
        new = max(1, tile // 2)
        warnings.warn(
            f"{kernel}: lane_tile={tile} needs {b['total'] / 2**20:.1f} MB "
            f"of VMEM (> {limit_bytes / 2**20:.1f} MB); shrinking to "
            f"{new}", stacklevel=3)
        tile = new


# --------------------------------------------------------------------------
# Unfused propagation kernel (one fixpoint per launch)
# --------------------------------------------------------------------------

def _fixpoint_kernel(*refs, max_sweeps: int, horizon: int, n_alldiff: int,
                     n_cumulative: int, ad_layout: str, cu_layout: str,
                     n_table: int, n_words: int, have_dom: bool):
    table_refs = refs[:N_TABLES]
    k = N_TABLES
    lb_ref, ub_ref = refs[k], refs[k + 1]
    dom_ref = refs[k + 2] if have_dom else None
    outs = refs[k + 2 + int(have_dom):]
    tables = tuple(r[...] for r in table_refs)
    if have_dom:
        out_lb_ref, out_ub_ref, out_dom_ref, sweeps_ref, conv_ref = outs
        lb, ub, dom, sweeps, conv = fixpoint_tile(
            lb_ref[...], ub_ref[...], *tables, horizon=horizon,
            n_alldiff=n_alldiff, n_cumulative=n_cumulative,
            ad_layout=ad_layout, cu_layout=cu_layout,
            n_table=n_table, n_words=n_words, dom=dom_ref[...],
            max_iters=max_sweeps)
        out_dom_ref[...] = dom
    else:
        out_lb_ref, out_ub_ref, sweeps_ref, conv_ref = outs
        lb, ub, sweeps, conv = fixpoint_tile(
            lb_ref[...], ub_ref[...], *tables, horizon=horizon,
            n_alldiff=n_alldiff, n_cumulative=n_cumulative,
            ad_layout=ad_layout, cu_layout=cu_layout,
            n_table=n_table, n_words=n_words,
            max_iters=max_sweeps)
    out_lb_ref[...] = lb
    out_ub_ref[...] = ub
    sweeps_ref[...] = sweeps
    conv_ref[...] = conv.astype(jnp.int32)


def _table_specs(cm):
    """BlockSpecs broadcasting the full propagator banks to every cell."""
    whole = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))  # noqa: E731
    P1, K = cm.vidx.shape
    D = cm.occ_prop.shape[1]
    A1, N = cm.ad_vars.shape
    Dad = cm.ad_occ_inst.shape[1]
    C1, T = cm.cu_svar.shape
    Dcu = cm.cu_occ_inst.shape[1]
    V = cm.n_vars
    Mad, Mcu = cm.ad_packed, cm.cu_packed
    T1, R, K32, TW = cm.ct_supp.shape
    Dct = cm.ct_occ_inst.shape[1]
    return [
        whole(P1, K), whole(P1, K), whole(P1), whole(P1),
        whole(V, D), whole(V, D),
        whole(A1, N), whole(A1, N), whole(A1, N),
        whole(V, Dad), whole(V, Dad),
        whole(A1 + 1), whole(Mad), whole(Mad), whole(Mad),
        whole(C1, T), whole(C1, T), whole(C1, T), whole(C1),
        whole(V, Dcu), whole(V, Dcu),
        whole(C1 + 1), whole(Mcu), whole(Mcu), whole(Mcu), whole(Mcu),
        whole(T1, R), whole(T1, R), whole(T1, R, K32, TW),
        whole(V, Dct), whole(V, Dct), whole(V), whole(V),
        whole(V), whole(V),
    ]


def fixpoint_pallas(cm, lb, ub, dom=None, *, lane_tile: int = 8,
                    max_sweeps: int = 16384, interpret: bool = True):
    """Run the VMEM fixpoint kernel over lane-batched stores [L, V].

    Grid = ceil(L / lane_tile); each cell iterates its tile to fixpoint
    with the shared per-lane-masked loop (`fixpoint.fixpoint_tile`), so
    sweep counts and convergence flags are bit-identical to the XLA
    backends.  The tile auto-shrinks (with a warning) when the
    `vmem_budget` exceeds VMEM.  Returns (lb', ub', sweeps[L],
    converged[L]); with `dom` (the ``[L, V, W]`` bitset store, DESIGN.md
    §17) it rides in VMEM next to the interval stores and the return
    gains dom' before the counters.
    """
    from repro.core.fixpoint import model_tables
    L, V = lb.shape
    lane_tile = fit_lane_tile(cm, lane_tile, L)
    pad = (-L) % lane_tile
    if pad:
        lb = jnp.concatenate([lb, jnp.broadcast_to(lb[-1:], (pad, V))])
        ub = jnp.concatenate([ub, jnp.broadcast_to(ub[-1:], (pad, V))])
        if dom is not None:
            dom = jnp.concatenate(
                [dom, jnp.broadcast_to(dom[-1:], (pad,) + dom.shape[1:])])
    Lp = lb.shape[0]
    grid = (Lp // lane_tile,)

    dt = cm.jdtype
    tiled = pl.BlockSpec((lane_tile, V), lambda i: (i, 0))
    lane1d = pl.BlockSpec((lane_tile,), lambda i: (i,))
    have_dom = dom is not None
    W = dom.shape[-1] if have_dom else 0
    tiled3 = (pl.BlockSpec((lane_tile, V, W), lambda i: (i, 0, 0))
              if have_dom else None)

    outs = pl.pallas_call(
        functools.partial(_fixpoint_kernel, max_sweeps=max_sweeps,
                          horizon=cm.horizon, n_alldiff=cm.n_alldiff,
                          n_cumulative=cm.n_cumulative,
                          ad_layout=cm.ad_layout, cu_layout=cm.cu_layout,
                          n_table=cm.n_table, n_words=cm.n_words,
                          have_dom=have_dom),
        grid=grid,
        in_specs=(_table_specs(cm) + [tiled, tiled]
                  + ([tiled3] if have_dom else [])),
        out_specs=([tiled, tiled] + ([tiled3] if have_dom else [])
                   + [lane1d, lane1d]),
        out_shape=(
            [jax.ShapeDtypeStruct((Lp, V), dt),
             jax.ShapeDtypeStruct((Lp, V), dt)]
            + ([jax.ShapeDtypeStruct((Lp, V, W), jnp.uint32)]
               if have_dom else [])
            + [jax.ShapeDtypeStruct((Lp,), jnp.int32),
               jax.ShapeDtypeStruct((Lp,), jnp.int32)]),
        interpret=interpret,
    )(*model_tables(cm), lb, ub, *([dom] if have_dom else []))
    if have_dom:
        out_lb, out_ub, out_dom, sweeps, conv = outs
        return (out_lb[:L], out_ub[:L], out_dom[:L], sweeps[:L],
                conv[:L].astype(bool))
    out_lb, out_ub, sweeps, conv = outs
    return out_lb[:L], out_ub[:L], sweeps[:L], conv[:L].astype(bool)


# --------------------------------------------------------------------------
# Resident search megakernel (K supersteps per launch, DESIGN.md §13)
# --------------------------------------------------------------------------

def _state_fields(st: S.LaneState):
    """The LaneState fields this state actually carries (the bitset
    stores are None on bounds-only models — skipped, so the kernel ref
    layout matches the pytree exactly)."""
    return tuple(f for f in S.LaneState._fields
                 if getattr(st, f) is not None)


def _pack_state(st: S.LaneState):
    """LaneState → kernel I/O arrays (bools as int32, field order)."""
    return tuple(
        getattr(st, f).astype(jnp.int32) if f in _BOOL_FIELDS
        else getattr(st, f)
        for f in _state_fields(st))


def _unpack_state(arrays, fields) -> S.LaneState:
    return S.LaneState(**{
        f: (a != 0 if f in _BOOL_FIELDS else a)
        for f, a in zip(fields, arrays)})


def _search_kernel(*refs, supersteps: int, max_sweeps: int, horizon: int,
                   n_alldiff: int, n_cumulative: int, ad_layout: str,
                   cu_layout: str, n_table: int, n_words: int,
                   state_fields: tuple, obj_var: int,
                   var_strategy: str, val_strategy: str,
                   stop_on_first: bool, max_fixpoint_iters, n_tiles: int):
    """K fused supersteps over one VMEM-resident lane tile.

    The body composes the *same* tile functions the unfused path runs
    as separate XLA dispatches — `dispatch_pool_tile` → `lane_load_tile`
    → `fixpoint_tile` → `lane_commit_tile` — inside a `fori_loop`, with
    each superstep guarded by the derived global-done flag (`done` and
    `has_sol` are monotone, so the carried `gdone` of the host loop is
    recomputable from state: a stopped tile runs K identity steps,
    keeping the launch idempotent).
    """
    k = N_TABLES
    n_state = len(state_fields)
    tables = tuple(r[...] for r in refs[:k])
    dom_off = tables[_I_DOM_OFF]
    dom_track = tables[_I_DOM_TRACK]
    bv = refs[k][...]
    subs_lb = refs[k + 1][...]
    subs_ub = refs[k + 2][...]
    st = _unpack_state([r[...] for r in refs[k + 3:k + 3 + n_state]],
                       state_fields)
    gbest_ref, it_ref, head_ref = refs[k + 3 + n_state:k + 6 + n_state]
    outs = refs[k + 6 + n_state:]
    out_state = outs[:n_state]
    out_gbest_ref, out_head_ref, out_it_ref, out_stop_ref = outs[n_state:]

    gbest = gbest_ref[0]
    it = it_ref[0]
    head = head_ref[0]
    n_subs = subs_lb.shape[0]
    tile_id = pl.program_id(0) if n_tiles > 1 else 0
    cap = max_sweeps if max_fixpoint_iters is None else max_fixpoint_iters

    def gdone_of(st):
        g = jnp.all(st.done)
        if stop_on_first:
            g = g | jnp.any(st.has_sol)
        return g

    def superstep(_, carry):
        st, gbest, it, head = carry

        def run(c):
            st, gbest, it, head = c
            st, head = S.dispatch_pool_tile(st, head, n_subs,
                                            tile_id=tile_id,
                                            n_tiles=n_tiles)
            pre = S.lane_load_tile(subs_lb, subs_ub, st, gbest,
                                   obj_var=obj_var, dom_off=dom_off,
                                   dom_track=dom_track, n_words=n_words)
            if pre.dom is not None:
                lb, ub, dm, sweeps, conv = fixpoint_tile(
                    pre.lb, pre.ub, *tables, horizon=horizon,
                    n_alldiff=n_alldiff, n_cumulative=n_cumulative,
                    ad_layout=ad_layout, cu_layout=cu_layout,
                    n_table=n_table, n_words=n_words, dom=pre.dom,
                    max_iters=cap)
            else:
                dm = None
                lb, ub, sweeps, conv = fixpoint_tile(
                    pre.lb, pre.ub, *tables, horizon=horizon,
                    n_alldiff=n_alldiff, n_cumulative=n_cumulative,
                    ad_layout=ad_layout, cu_layout=cu_layout,
                    n_table=n_table, n_words=n_words,
                    max_iters=cap)
            st = S.lane_commit_tile(st, pre, lb, ub, sweeps, conv, bv,
                                    obj_var=obj_var,
                                    var_strategy=var_strategy,
                                    val_strategy=val_strategy,
                                    dom=dm, dom_off=dom_off,
                                    dom_track=dom_track)
            gbest = jnp.minimum(gbest, jnp.min(st.best_obj))
            return st, gbest, it + 1, head

        return lax.cond(gdone_of(st), lambda c: c, run,
                        (st, gbest, it, head))

    st, gbest, it, head = lax.fori_loop(0, supersteps, superstep,
                                        (st, gbest, it, head))
    for ref, val in zip(out_state, _pack_state(st)):
        ref[...] = val
    out_gbest_ref[...] = jnp.reshape(gbest, (1,))
    out_head_ref[...] = jnp.reshape(head, (1,)).astype(jnp.int32)
    out_it_ref[...] = jnp.reshape(it, (1,)).astype(jnp.int32)
    out_stop_ref[...] = jnp.reshape(gdone_of(st), (1,)).astype(jnp.int32)


def _pad_lanes(st: S.LaneState, pad: int, dt) -> S.LaneState:
    """Append `pad` inert lanes (done, no subproblem, neutral incumbent)
    so the lane axis tiles evenly; sliced back off after the launch."""
    big = jnp.asarray(jnp.iinfo(dt).max // 4, dt)

    def ext(a, fill):
        tail = jnp.full((pad,) + a.shape[1:], fill, a.dtype)
        return jnp.concatenate([a, tail])

    fills = dict(next_sub=S.UNASSIGNED, done=True, best_obj=big)
    return S.LaneState(**{
        f: ext(getattr(st, f), fills.get(f, 0))
        for f in _state_fields(st)})


def search_pallas(cm, subs_lb, subs_ub, st: S.LaneState, gbest, it,
                  pool_head, *, supersteps: int = 16, lane_tile: int = 0,
                  max_sweeps: int = 16384, max_fixpoint_iters=None,
                  var_strategy: str = S.INPUT_ORDER,
                  val_strategy: str = S.VAL_MIN,
                  stop_on_first: bool = False, interpret: bool = True):
    """Launch the resident search megakernel: K = `supersteps` fused
    supersteps with all lane state held in VMEM (DESIGN.md §13).

    ``lane_tile=0`` (the default, and the bit-parity mode) puts ALL
    lanes in one grid cell so the EPS pool is one shared queue —
    exactly `search.lanes_step`'s dispatch semantics.  A smaller tile
    (set explicitly or by VMEM auto-shrink) splits lanes over
    ``n_tiles`` cells with the pool strided across them (cell t owns
    pool indices t, t+NT, …) — still sound and complete, but a
    different (documented) dispatch trajectory; `pool_head` then
    carries one cursor per cell.

    Arguments mirror one `_run_chunk` carry: `st` the LaneState,
    `gbest` the scalar global bound, `it` the scalar superstep counter,
    `pool_head` the ``[n_tiles]`` pool cursor(s).  Returns
    ``(st', gbest', it', pool_head', stopped)`` where `stopped` is the
    derived global-done flag (all lanes drained, or first solution
    under `stop_on_first`) — the host chunk scheduler ORs it into
    `gdone` and stops relaunching.
    """
    L, V = st.lb.shape
    MD = st.dec_var.shape[1]
    Spool = subs_lb.shape[0]
    dt = cm.jdtype

    tile = L if lane_tile in (0, None) else lane_tile
    tile = fit_lane_tile(cm, tile, L, resident=True, max_depth=MD,
                         pool_size=Spool)
    pad = (-L) % tile
    if pad:
        st = _pad_lanes(st, pad, dt)
    Lp = L + pad
    n_tiles = Lp // tile
    pool_head = jnp.broadcast_to(jnp.asarray(pool_head, jnp.int32),
                                 (n_tiles,))

    whole = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))  # noqa: E731
    cell1 = pl.BlockSpec((1,), lambda i: (i,))

    def state_spec(f):
        a = getattr(st, f)
        if a.ndim == 3:
            return pl.BlockSpec((tile,) + a.shape[1:],
                                lambda i: (i, 0, 0))
        if a.ndim == 2:
            return pl.BlockSpec((tile, a.shape[1]), lambda i: (i, 0))
        return pl.BlockSpec((tile,), lambda i: (i,))

    def state_shape(f):
        a = getattr(st, f)
        d = jnp.int32 if a.dtype == jnp.bool_ else a.dtype
        return jax.ShapeDtypeStruct(a.shape, d)

    fields = _state_fields(st)
    n_state = len(fields)
    in_specs = (_table_specs(cm)
                + [whole(int(cm.branch_vars.shape[0])),
                   whole(Spool, V), whole(Spool, V)]
                + [state_spec(f) for f in fields]
                + [whole(1), whole(1), cell1])
    out_specs = ([state_spec(f) for f in fields]
                 + [cell1, cell1, cell1, cell1])
    out_shape = ([state_shape(f) for f in fields]
                 + [jax.ShapeDtypeStruct((n_tiles,), dt)]
                 + [jax.ShapeDtypeStruct((n_tiles,), jnp.int32)] * 3)

    from repro.core.fixpoint import model_tables
    outs = pl.pallas_call(
        functools.partial(
            _search_kernel, supersteps=supersteps, max_sweeps=max_sweeps,
            horizon=cm.horizon, n_alldiff=cm.n_alldiff,
            n_cumulative=cm.n_cumulative, ad_layout=cm.ad_layout,
            cu_layout=cm.cu_layout, n_table=cm.n_table,
            n_words=cm.n_words, state_fields=fields, obj_var=cm.obj_var,
            var_strategy=var_strategy, val_strategy=val_strategy,
            stop_on_first=stop_on_first,
            max_fixpoint_iters=max_fixpoint_iters, n_tiles=n_tiles),
        grid=(n_tiles,),
        in_specs=in_specs, out_specs=out_specs, out_shape=out_shape,
        interpret=interpret,
    )(*model_tables(cm), cm.branch_vars, subs_lb, subs_ub,
      *_pack_state(st),
      jnp.reshape(jnp.asarray(gbest, dt), (1,)),
      jnp.reshape(jnp.asarray(it, jnp.int32), (1,)),
      pool_head)

    st_out = _unpack_state(outs[:n_state], fields)
    if pad:
        st_out = S.LaneState(**{
            f: getattr(st_out, f)[:L] for f in fields})
    gbest_out, head_out, it_out, stop_out = outs[n_state:]
    return (st_out, jnp.min(gbest_out), jnp.max(it_out),
            head_out.astype(jnp.int32), jnp.all(stop_out != 0))
