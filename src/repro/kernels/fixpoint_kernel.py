"""Pallas TPU kernel: the whole propagation fixpoint in VMEM.

GPU→TPU mapping (DESIGN.md §2): one grid cell ↔ one TURBO CUDA block ↔ a
*tile of lanes* whose stores live in VMEM for the entire fixpoint loop —
the analogue of TURBO keeping both stores in the SM's shared memory.  The
propagator/occurrence tables are broadcast to every grid cell (index_map
pins them to block 0), mirroring the constant problem tables in GPU
constant/global memory.

The kernel body is the *eventless sweep* over the typed propagator table
(DESIGN.md §12): every bank's candidate bounds are computed as dense
tensor ops on the MXU/VPU ([P, K] linear tightenings, [A, N³]
Hall-interval alldifferent checks, [C, T, H] cumulative time-tables),
then each variable gathers the min/max over its per-bank occurrence
lists ([V, D]-style gathers — TPU-native joins, no atomics).  The sweep
itself is `fixpoint.sweep_tile`, the **same** kind-dispatched function
the XLA gather backend runs — one implementation of the semantics, two
execution strategies.  A `lax.while_loop` iterates
sweeps until no bound changes or a domain empties — fixpoint detection is
one reduction, standing in for the paper's has_changed[3] +
__syncthreads().

VMEM budget (per grid cell, int32; see the table in DESIGN.md §2): stores
2·TL·V, tables ≈ 2·P·K + 2·V·D + 4·V; with the j30-class sizes (V≈3k,
P≈5k, K=32, D≈128) that is ≈ 2.1 MB of tables + 24 KB/lane — comfortably
inside the ~16 MB VMEM of a TPU v5e core with TL up to ~512 lanes.

Validated in interpret mode on CPU (this container has no TPU); the ops
used (take/gather along axis 0, elementwise, while_loop) lower on TPU
Pallas for int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.fixpoint import sweep_tile


def _fixpoint_kernel(vidx_ref, coef_ref, rhs_ref, bidx_ref, occp_ref,
                     occs_ref, adv_ref, ado_ref, adm_ref, adoi_ref,
                     adop_ref, cus_ref, cud_ref, cuq_ref, cuc_ref,
                     cuoi_ref, cuop_ref, boxlo_ref, boxhi_ref,
                     lb_ref, ub_ref,
                     out_lb_ref, out_ub_ref, sweeps_ref, conv_ref,
                     *, max_sweeps: int, horizon: int, n_alldiff: int,
                     n_cumulative: int):
    lb = lb_ref[...]
    ub = ub_ref[...]
    tables = (vidx_ref[...], coef_ref[...], rhs_ref[...], bidx_ref[...],
              occp_ref[...], occs_ref[...],
              adv_ref[...], ado_ref[...], adm_ref[...], adoi_ref[...],
              adop_ref[...], cus_ref[...], cud_ref[...], cuq_ref[...],
              cuc_ref[...], cuoi_ref[...], cuop_ref[...],
              boxlo_ref[...], boxhi_ref[...])

    def cond(st):
        lb_, ub_, changed, it = st
        live = jnp.logical_not(jnp.all(jnp.any(lb_ > ub_, axis=1)))
        return changed & (it < max_sweeps) & live

    def body(st):
        lb_, ub_, _, it = st
        nlb, nub = sweep_tile(lb_, ub_, *tables, horizon=horizon,
                              n_alldiff=n_alldiff,
                              n_cumulative=n_cumulative)
        changed = jnp.any((nlb != lb_) | (nub != ub_))
        return nlb, nub, changed, it + 1

    lb, ub, changed, it = lax.while_loop(
        cond, body, (lb, ub, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
    out_lb_ref[...] = lb
    out_ub_ref[...] = ub
    sweeps_ref[...] = jnp.full(sweeps_ref.shape, it, jnp.int32)
    # per-lane convergence: failure is definitive; otherwise the tile-wide
    # no-change flag (conservative for lanes that individually fixed early,
    # which is sound — search just keeps them propagating a no-op sweep)
    failed = jnp.any(lb > ub, axis=1)
    conv_ref[...] = (jnp.logical_not(changed) | failed).astype(jnp.int32)


def fixpoint_pallas(cm, lb, ub, *, lane_tile: int = 8,
                    max_sweeps: int = 16384, interpret: bool = True):
    """Run the VMEM fixpoint kernel over lane-batched stores [L, V].

    Grid = ceil(L / lane_tile); each cell iterates its tile to fixpoint
    independently (cells stop early when all their lanes failed).
    Returns (lb', ub', sweeps[L], converged[L]).
    """
    L, V = lb.shape
    pad = (-L) % lane_tile
    if pad:
        lb = jnp.concatenate([lb, jnp.broadcast_to(lb[-1:], (pad, V))])
        ub = jnp.concatenate([ub, jnp.broadcast_to(ub[-1:], (pad, V))])
    Lp = lb.shape[0]
    grid = (Lp // lane_tile,)

    P1, K = cm.vidx.shape
    D = cm.occ_prop.shape[1]
    A1, N = cm.ad_vars.shape
    Dad = cm.ad_occ_inst.shape[1]
    C1, T = cm.cu_svar.shape
    Dcu = cm.cu_occ_inst.shape[1]
    dt = cm.jdtype

    whole = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))  # noqa: E731
    tiled = pl.BlockSpec((lane_tile, V), lambda i: (i, 0))
    lane1d = pl.BlockSpec((lane_tile,), lambda i: (i,))

    out_lb, out_ub, sweeps, conv = pl.pallas_call(
        functools.partial(_fixpoint_kernel, max_sweeps=max_sweeps,
                          horizon=cm.horizon, n_alldiff=cm.n_alldiff,
                          n_cumulative=cm.n_cumulative),
        grid=grid,
        in_specs=[
            whole(P1, K), whole(P1, K), whole(P1), whole(P1),
            whole(V, D), whole(V, D),
            whole(A1, N), whole(A1, N), whole(A1, N),
            whole(V, Dad), whole(V, Dad),
            whole(C1, T), whole(C1, T), whole(C1, T), whole(C1),
            whole(V, Dcu), whole(V, Dcu),
            whole(V), whole(V),
            tiled, tiled,
        ],
        out_specs=[tiled, tiled, lane1d, lane1d],
        out_shape=[
            jax.ShapeDtypeStruct((Lp, V), dt),
            jax.ShapeDtypeStruct((Lp, V), dt),
            jax.ShapeDtypeStruct((Lp,), jnp.int32),
            jax.ShapeDtypeStruct((Lp,), jnp.int32),
        ],
        interpret=interpret,
    )(cm.vidx, cm.coef, cm.rhs, cm.bidx, cm.occ_prop, cm.occ_slot,
      cm.ad_vars, cm.ad_offs, cm.ad_mask, cm.ad_occ_inst, cm.ad_occ_pos,
      cm.cu_svar, cm.cu_dur, cm.cu_dem, cm.cu_cap,
      cm.cu_occ_inst, cm.cu_occ_pos,
      cm.box_lo, cm.box_hi, lb, ub)
    return out_lb[:L], out_ub[:L], sweeps[:L], conv[:L].astype(bool)
