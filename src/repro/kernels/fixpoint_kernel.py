"""Pallas TPU kernel: the whole propagation fixpoint in VMEM.

GPU→TPU mapping (DESIGN.md §2): one grid cell ↔ one TURBO CUDA block ↔ a
*tile of lanes* whose stores live in VMEM for the entire fixpoint loop —
the analogue of TURBO keeping both stores in the SM's shared memory.  The
propagator/occurrence tables are broadcast to every grid cell (index_map
pins them to block 0), mirroring the constant problem tables in GPU
constant/global memory.

The kernel body is the *eventless sweep*: every propagator's candidate
bounds are computed as dense [P, K] tensor ops on the MXU/VPU, then each
variable gathers the min/max over its occurrence list (a [V, D] gather —
TPU-native join, no atomics; see fixpoint.py for the semantics and the
scatter oracle it is tested against).  A `lax.while_loop` iterates sweeps
until no bound changes or a domain empties — fixpoint detection is one
reduction, standing in for the paper's has_changed[3] + __syncthreads().

VMEM budget (per grid cell, int32): stores 2·TL·V, tables ≈ 2·P·K +
2·V·D + 4·V; with the j30-class sizes (V≈3k, P≈5k, K=32, D≈128) that is
≈ 2.1 MB of tables + 24 KB/lane — comfortably inside the ~16 MB VMEM of a
TPU v5e core with TL up to ~512 lanes.

Validated in interpret mode on CPU (this container has no TPU); the ops
used (take/gather along axis 0, elementwise, while_loop) lower on TPU
Pallas for int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _sweep_tile(lb, ub, vidx, coef, rhs, bidx, occ_prop, occ_slot,
                box_lo, box_hi):
    """One eventless sweep over a (TL, V) tile of stores. Pure jnp —
    shared by the kernel body and (jit'd directly) by the ops wrapper's
    reference path."""
    dt = lb.dtype
    neu = jnp.asarray(jnp.iinfo(dt).max // 4, dt)

    a = coef[None, :, :]                                  # [1, P1, K]
    xl = jnp.take(lb, vidx, axis=1)                       # [TL, P1, K]
    xu = jnp.take(ub, vidx, axis=1)
    tl_ = jnp.where(a > 0, a * xl, a * xu)
    tu_ = jnp.where(a > 0, a * xu, a * xl)
    smin = tl_.sum(-1)                                    # [TL, P1]
    smax = tu_.sum(-1)

    btrue = (jnp.take(lb, bidx, axis=1) >= 1)[:, :, None]
    bfalse = (jnp.take(ub, bidx, axis=1) <= 0)[:, :, None]
    c = rhs[None, :, None]                                # [1, P1, 1]

    safe_a = jnp.where(a == 0, 1, a)
    slack1 = c - (smin[:, :, None] - tl_)
    ub1 = jnp.where((a > 0) & btrue, jnp.floor_divide(slack1, safe_a), neu)
    lb1 = jnp.where((a < 0) & btrue,
                    -jnp.floor_divide(-slack1, safe_a), -neu)

    na = -a
    safe_na = jnp.where(na == 0, 1, na)
    slack2 = (-c - 1) - (-smax[:, :, None] + tu_)
    ub2 = jnp.where((na > 0) & bfalse, jnp.floor_divide(slack2, safe_na), neu)
    lb2 = jnp.where((na < 0) & bfalse,
                    -jnp.floor_divide(-slack2, safe_na), -neu)

    term_ub = jnp.minimum(ub1, ub2)                       # [TL, P1, K]
    term_lb = jnp.maximum(lb1, lb2)
    reif_lb = jnp.where(smax <= rhs[None, :], jnp.asarray(1, dt), -neu)
    reif_ub = jnp.where(smin > rhs[None, :], jnp.asarray(0, dt), neu)

    cand_ub = jnp.concatenate([term_ub, reif_ub[:, :, None]], axis=2)
    cand_lb = jnp.concatenate([term_lb, reif_lb[:, :, None]], axis=2)

    # variable-centric join: gather each var's occurrence candidates
    k1 = cand_ub.shape[2]
    flat_ub = cand_ub.reshape(cand_ub.shape[0], -1)       # [TL, P1*(K+1)]
    flat_lb = cand_lb.reshape(cand_lb.shape[0], -1)
    occ = (occ_prop * k1 + occ_slot).reshape(-1)          # [V*D]
    g_ub = jnp.take(flat_ub, occ, axis=1).reshape(
        lb.shape[0], occ_prop.shape[0], occ_prop.shape[1]).min(-1)
    g_lb = jnp.take(flat_lb, occ, axis=1).reshape(
        lb.shape[0], occ_prop.shape[0], occ_prop.shape[1]).max(-1)

    g_ub = jnp.maximum(g_ub, box_lo[None, :])
    g_lb = jnp.minimum(g_lb, box_hi[None, :])
    return jnp.maximum(lb, g_lb), jnp.minimum(ub, g_ub)


def _fixpoint_kernel(vidx_ref, coef_ref, rhs_ref, bidx_ref, occp_ref,
                     occs_ref, boxlo_ref, boxhi_ref, lb_ref, ub_ref,
                     out_lb_ref, out_ub_ref, sweeps_ref, *, max_sweeps: int):
    lb = lb_ref[...]
    ub = ub_ref[...]
    tables = (vidx_ref[...], coef_ref[...], rhs_ref[...], bidx_ref[...],
              occp_ref[...], occs_ref[...], boxlo_ref[...], boxhi_ref[...])

    def cond(st):
        lb_, ub_, changed, it = st
        live = jnp.logical_not(jnp.all(jnp.any(lb_ > ub_, axis=1)))
        return changed & (it < max_sweeps) & live

    def body(st):
        lb_, ub_, _, it = st
        nlb, nub = _sweep_tile(lb_, ub_, *tables)
        changed = jnp.any((nlb != lb_) | (nub != ub_))
        return nlb, nub, changed, it + 1

    lb, ub, _, it = lax.while_loop(
        cond, body, (lb, ub, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
    out_lb_ref[...] = lb
    out_ub_ref[...] = ub
    sweeps_ref[...] = jnp.full(sweeps_ref.shape, it, jnp.int32)


def fixpoint_pallas(cm, lb, ub, *, lane_tile: int = 8,
                    max_sweeps: int = 16384, interpret: bool = True):
    """Run the VMEM fixpoint kernel over lane-batched stores [L, V].

    Grid = ceil(L / lane_tile); each cell iterates its tile to fixpoint
    independently (cells stop early when all their lanes failed).
    Returns (lb', ub', sweeps[L]).
    """
    L, V = lb.shape
    pad = (-L) % lane_tile
    if pad:
        lb = jnp.concatenate([lb, jnp.broadcast_to(lb[-1:], (pad, V))])
        ub = jnp.concatenate([ub, jnp.broadcast_to(ub[-1:], (pad, V))])
    Lp = lb.shape[0]
    grid = (Lp // lane_tile,)

    P1, K = cm.vidx.shape
    D = cm.occ_prop.shape[1]
    dt = cm.jdtype

    whole = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))  # noqa: E731
    tiled = pl.BlockSpec((lane_tile, V), lambda i: (i, 0))

    out_lb, out_ub, sweeps = pl.pallas_call(
        functools.partial(_fixpoint_kernel, max_sweeps=max_sweeps),
        grid=grid,
        in_specs=[
            whole(P1, K), whole(P1, K), whole(P1), whole(P1),
            whole(V, D), whole(V, D), whole(V), whole(V),
            tiled, tiled,
        ],
        out_specs=[tiled, tiled, pl.BlockSpec((lane_tile,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((Lp, V), dt),
            jax.ShapeDtypeStruct((Lp, V), dt),
            jax.ShapeDtypeStruct((Lp,), jnp.int32),
        ],
        interpret=interpret,
    )(cm.vidx, cm.coef, cm.rhs, cm.bidx, cm.occ_prop, cm.occ_slot,
      cm.box_lo, cm.box_hi, lb, ub)
    return out_lb[:L], out_ub[:L], sweeps[:L]
