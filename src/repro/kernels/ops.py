"""jit'd public wrappers for the propagation kernels.

`batched_fixpoint` picks the best available implementation:

* ``impl="pallas"`` — the VMEM-resident Pallas kernel (TPU target;
  interpret-mode on CPU),
* ``impl="gather"`` — the vmapped XLA gather sweep (fast on CPU, and the
  production fallback on any backend),
* ``impl="scatter"`` — the scatter oracle (reference).

All three compute the same least fixed point (tests sweep shapes/dtypes
and assert exact equality — integer lattice, so allclose is `array_equal`).

Comparison spec: implementations agree (a) on the failed mask, and (b)
exactly on every non-failed lane's store.  Failed lanes' *contents* are
unspecified — search discards them — and legitimately differ: the scatter
oracle signals plain-constraint disentailment through the TRUE var, the
gather forms through term bounds, and early-exit points differ per impl
(a transiently-disentailed plain constraint can only occur on lanes that
end failed, so non-failed lanes see identical sweep sequences).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compile import CompiledModel
from repro.core.fixpoint import fixpoint
from repro.kernels.fixpoint_kernel import fixpoint_pallas
from repro.kernels.ref import fixpoint_ref


@partial(jax.jit, static_argnames=("impl", "lane_tile", "max_sweeps",
                                   "interpret"))
def batched_fixpoint(cm: CompiledModel, lb: jax.Array, ub: jax.Array,
                     impl: str = "gather", lane_tile: int = 8,
                     max_sweeps: int = 16384, interpret: bool = True):
    """Propagate a [L, V] batch of stores to their least fixed points."""
    if impl == "pallas":
        nlb, nub, _ = fixpoint_pallas(cm, lb, ub, lane_tile=lane_tile,
                                      max_sweeps=max_sweeps,
                                      interpret=interpret)
        return nlb, nub
    if impl == "gather":
        def one(l, u):
            nl, nu, _, _ = fixpoint(cm, l, u, max_iters=max_sweeps)
            return nl, nu
        return jax.vmap(one)(lb, ub)
    if impl == "scatter":
        return fixpoint_ref(cm, lb, ub, max_sweeps=max_sweeps)
    raise ValueError(f"unknown impl {impl!r}")
