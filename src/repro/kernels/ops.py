"""jit'd public wrappers for the propagation kernels.

`batched_fixpoint` is a thin façade over the propagation-backend registry
(`core/backend.py`) kept for kernel-level tests and benchmarks:

* ``impl="pallas"`` — the VMEM-resident Pallas kernel (TPU target;
  interpret-mode on CPU),
* ``impl="gather"`` — the lane-batched XLA gather sweep (fast on CPU, and
  the production fallback on any backend),
* ``impl="scatter"`` — the scatter oracle (reference).

All three compute the same least fixed point (tests sweep shapes/dtypes
and assert exact equality — integer lattice, so allclose is `array_equal`).

Comparison spec: implementations agree (a) on the failed mask, and (b)
exactly on every non-failed lane's store.  Since the §12 typed-table
refactor the gather and scatter forms compute bit-identical stores per
*sweep* (the scatter form no longer scatters plain rows' disentailment
slot onto the TRUE var — a disentailed plain row fails through term
tightening in the same sweep), so the XLA backends agree on every lane
even under a sweep cap; failed-lane contents may still differ vs the
Pallas kernel, whose tile-lockstep loop has different early-exit points.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.core.backend import get_backend
from repro.core.compile import CompiledModel


@partial(jax.jit, static_argnames=("impl", "lane_tile", "max_sweeps",
                                   "interpret"))
def batched_fixpoint(cm: CompiledModel, lb: jax.Array, ub: jax.Array,
                     impl: str = "gather", lane_tile: int = 8,
                     max_sweeps: int = 16384, interpret: bool = True):
    """Propagate a [L, V] batch of stores to their least fixed points."""
    if impl == "pallas":
        backend = get_backend("pallas", lane_tile=lane_tile,
                              max_sweeps=max_sweeps, interpret=interpret)
        nlb, nub, _, _ = backend.fixpoint_batch(cm, lb, ub)
    else:
        backend = get_backend(impl)
        nlb, nub, _, _ = backend.fixpoint_batch(cm, lb, ub,
                                                max_iters=max_sweeps)
    return nlb, nub
