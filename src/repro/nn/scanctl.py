"""Scan-or-unroll control.

XLA's `cost_analysis()` counts a while/scan body ONCE regardless of trip
count, so the roofline harness (benchmarks/roofline.py) lowers
reduced-depth variants under `unroll_scans()` — every `scan_layers` site
(layer stacks, attention chunk loops, SSD chunk recurrence) becomes a
python unroll with exact HLO cost — and extrapolates to full depth.
Production code always takes the `lax.scan` path (O(1) HLO size).
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp
from jax import lax

_UNROLL = contextvars.ContextVar("repro_unroll_scans", default=False)
_REMAT_POLICY = contextvars.ContextVar("repro_remat_policy", default=None)


@contextlib.contextmanager
def unroll_scans():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


@contextlib.contextmanager
def remat_policy(name: str):
    """Activation-checkpoint policy for the layer scans.

    None/'full' — recompute everything (lowest memory, paper-ish default);
    'dots' — save matmul outputs with no batch dims (XLA
    dots_with_no_batch_dims_saveable): §Perf P3 measured −21% on the
    compute roofline term for llama3 train at ~6% more activation bytes.
    """
    tok = _REMAT_POLICY.set(name)
    try:
        yield
    finally:
        _REMAT_POLICY.reset(tok)


def checkpoint(fn):
    """jax.checkpoint honoring the ambient remat policy."""
    name = _REMAT_POLICY.get()
    if name in (None, "full"):
        return jax.checkpoint(fn)
    if name == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(name)


def unrolling() -> bool:
    return _UNROLL.get()


def scan_layers(body, carry, xs, length=None):
    """lax.scan, or a python unroll under `unroll_scans()`."""
    if not _UNROLL.get():
        return lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if not ys or not jax.tree_util.tree_leaves(ys[0]):
        return carry, (ys[0] if ys else None)
    return carry, jax.tree.map(lambda *z: jnp.stack(z), *ys)
