"""Mixture-of-experts FFN: top-k token-choice routing with capacity-based
gather dispatch (GShard-style, but index-gather instead of one-hot matmul
so dispatch is O(T·k) memory) and grouped expert matmuls.

Baseline parallelism (DESIGN.md §7): experts' FFN dim is tensor-sharded
over the `model` mesh axis (every device holds a slice of *every* expert);
tokens stay data-sharded, so no all-to-all is needed.  The expert-parallel
all-to-all variant is a §Perf hillclimb alternative in
`distributed/collectives.py`.

Dropped tokens (over capacity) contribute zero — standard for
capacity-factor routing; the router is computed in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.nn.layers import cast_bf16, dense


def topk_route(logits, k: int):
    """logits [T, E] f32 → (probs [T,k], idx [T,k]); probs renormalized
    over the selected experts (deepseek/dbrx convention)."""
    vals, idx = lax.top_k(logits, k)
    probs = jax.nn.softmax(vals, axis=-1)
    return probs, idx


def dispatch_indices(idx, n_experts: int, capacity: int):
    """Build [E, C] token-slot table from [T, k] expert assignments.

    Returns (slot_token [E*C] int32 — flat token index or T_pad sentinel,
    keep_mask [T, k] — False for capacity-dropped assignments,
    pos [T, k] — the slot each assignment landed in).
    """
    T, k = idx.shape
    flat = idx.reshape(-1)                               # [T*k]
    # rank of each assignment within its expert, in (token, slot) order
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    seg_start = jnp.concatenate(
        [jnp.zeros(1, jnp.int32),
         jnp.cumsum(jnp.bincount(sorted_e, length=n_experts)
                    .astype(jnp.int32))[:-1]])
    rank_sorted = jnp.arange(T * k, dtype=jnp.int32) - seg_start[sorted_e]
    rank = jnp.zeros(T * k, jnp.int32).at[order].set(rank_sorted)
    keep = rank < capacity
    dest = jnp.where(keep, flat * capacity + rank, n_experts * capacity)
    slot_token = jnp.full((n_experts * capacity + 1,), T, jnp.int32)
    slot_token = slot_token.at[dest].set(
        jnp.arange(T * k, dtype=jnp.int32) // k)[:-1]
    return slot_token, keep.reshape(T, k), rank.reshape(T, k)


def moe_ffn(p, prefix, x, cfg):
    """x [B, S, d] → MoE SwiGLU output [B, S, d] (+ aux losses dict)."""
    moe = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, K = moe.n_experts, moe.top_k
    C = int(np.ceil(T * K / E * moe.capacity_factor))
    C = max(8, -(-C // 8) * 8)                          # pad for lanes

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p[f"{prefix}/router"].astype(jnp.float32))
    probs, idx = topk_route(logits, K)

    slot_token, keep, rank = dispatch_indices(idx, E, C)
    xpad = jnp.concatenate([cast_bf16(xt), jnp.zeros((1, d), jnp.bfloat16)])
    xe = xpad[slot_token].reshape(E, C, d)              # gather dispatch
    # NOTE (§Perf, refuted hypothesis): forcing a capacity-parallel
    # sharding here (xe/ye constrained to spread C over the data axis)
    # *tripled* temp memory — XLA reshards the dispatch gathers through
    # replicated intermediates.  Microbatch accumulation (train_step) is
    # the effective lever for MoE activation memory instead.

    w_g = cast_bf16(p[f"{prefix}/w_gate"])              # [E, d, ff]
    w_u = cast_bf16(p[f"{prefix}/w_up"])
    w_d = cast_bf16(p[f"{prefix}/w_down"])              # [E, ff, d]
    g = jnp.einsum("ecd,edf->ecf", xe, w_g,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, w_u,
                   preferred_element_type=jnp.float32)
    h = cast_bf16(jax.nn.silu(g) * u)
    ye = cast_bf16(jnp.einsum("ecf,efd->ecd", h, w_d,
                              preferred_element_type=jnp.float32))

    # combine: each (token, slot) gathers its expert output × prob
    # (bf16 gather, f32 accumulation — keeps the [T,K,d] blob at 2 bytes)
    flat_dest = jnp.where(keep.reshape(-1),
                          idx.reshape(-1) * C + rank.reshape(-1),
                          E * C)
    ypad = jnp.concatenate([ye.reshape(E * C, d),
                            jnp.zeros((1, d), jnp.bfloat16)])
    per_assign = ypad[flat_dest].reshape(T, K, d)
    yt = jnp.einsum("tkd,tk->td", per_assign, probs.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)

    # shared experts (deepseek): dense SwiGLU of width n_shared · d_expert
    if moe.n_shared > 0:
        yt = yt + _shared_ffn(p, prefix, xt).astype(jnp.float32)

    # aux: load-balance loss (Switch-style) — used by train_step
    me = jnp.mean(jax.nn.softmax(logits, -1), axis=0)
    ce = jnp.mean((jnp.zeros((T, E)).at[jnp.arange(T)[:, None], idx]
                   .add(1.0) / K), axis=0)
    aux = {"moe_balance": E * jnp.sum(me * ce),
           "moe_dropped": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return cast_bf16(yt).reshape(B, S, d), aux


def _shared_ffn(p, prefix, xt):
    from repro.nn.layers import swiglu
    return swiglu(xt, p[f"{prefix}/shared_gate"], p[f"{prefix}/shared_up"],
                  p[f"{prefix}/shared_down"])
