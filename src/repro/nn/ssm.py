"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) block.

Prefill/train: the chunked SSD algorithm — intra-chunk quadratic
(attention-like with a causal decay mask, MXU-friendly) + inter-chunk
recurrent state passing via `lax.scan` over chunks.  Decode: the O(1)
recurrence h' = dA·h + dt·(B ⊗ x), y = C·h' + D·x — this is what makes
`long_500k` runnable for this family.

Shapes follow the reference: d_inner = expand·d_model, P heads of
head_dim, shared B/C across `n_groups` groups, state N per head channel.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.nn.layers import cast_bf16, dense, rms_norm
from repro.nn.scanctl import scan_layers


class SSMCache(NamedTuple):
    h: jax.Array          # [B, H, hd, N]  SSM state
    conv: jax.Array       # [B, conv-1, conv_dim]  causal-conv tail
    length: jax.Array


def _segsum(x):
    """log-decay matrix: L[i,j] = sum_{j<k<=i} x[k] (lower-tri), else -inf."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    L = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, L, -jnp.inf)


def _causal_conv(x, w, b, cache_tail=None):
    """Depthwise causal conv, width W. x [B,S,Cd], w [W,Cd].
    With cache_tail [B,W-1,Cd]: streaming (decode) mode."""
    W = w.shape[0]
    if cache_tail is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = cache_tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    new_tail = xp[:, -(W - 1):, :] if W > 1 else xp[:, :0, :]
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)
                       ).astype(x.dtype), new_tail


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """SSD over full sequences.
    xh [B,S,H,hd]; dt [B,S,H] (post-softplus); A [H] (negative);
    Bm/Cm [B,S,G,N].  Returns y [B,S,H,hd] and final state [B,H,hd,N].
    """
    B_, S, H, hd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    # pad ragged tails with dt=0 positions: decay exp(0)=1 and zero input
    # contribution make padding state-neutral; padded outputs are sliced.
    S0 = S
    pad = (-S) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +  # noqa: E731
                               [(0, 0)] * (a.ndim - 2))
        xh, dt, Bm, Cm = zp(xh), zp(dt), zp(Bm), zp(Cm)
        S = S + pad
    nc = S // chunk
    rep = H // G

    def r(t, shape):
        return t.reshape(shape)

    xc = r(xh, (B_, nc, chunk, H, hd))
    dtc = r(dt, (B_, nc, chunk, H))
    Bc = r(Bm, (B_, nc, chunk, G, N))
    Cc = r(Cm, (B_, nc, chunk, G, N))
    dA = dtc * A[None, None, None, :]                    # [B,nc,Q,H]

    # ---- intra-chunk (quadratic, attention-like) ----
    Ls = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))      # [B,nc,H,Q,Q]
    CB = jnp.einsum("bnqgs,bnkgs->bngqk",
                    Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    CB = jnp.repeat(CB, rep, axis=2)                     # groups -> heads
    M = CB * Ls * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bnhqk,bnkhd->bnqhd", cast_bf16(M), cast_bf16(xc),
                        preferred_element_type=jnp.float32)

    # ---- chunk states & inter-chunk recurrence ----
    decay_to_end = jnp.exp(jnp.cumsum(dA, axis=2)[:, :, -1:, :]
                           - jnp.cumsum(dA, axis=2))     # [B,nc,Q,H]
    states = jnp.einsum("bnqgs,bnqh,bnqhd->bnhds",
                        Bc.astype(jnp.float32),
                        (dtc * decay_to_end).astype(jnp.float32),
                        xc.astype(jnp.float32))          # [B,nc,H,hd,N]
    chunk_decay = jnp.exp(dA.sum(axis=2))                # [B,nc,H]

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h

    h0 = jnp.zeros((B_, H, hd, N), jnp.float32)
    hT, h_prev = scan_layers(
        scan_fn,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)             # [B,nc,H,hd,N]

    # ---- contribution of previous state to each position ----
    decay_from_start = jnp.exp(jnp.cumsum(dA, axis=2))   # [B,nc,Q,H]
    Ch = jnp.repeat(Cc, rep, axis=3) if G != H else Cc   # groups -> heads
    y_off = jnp.einsum("bnqhs,bnhds,bnqh->bnqhd",
                       Ch.astype(jnp.float32), h_prev,
                       decay_from_start.astype(jnp.float32))
    y = (y_diag + y_off).reshape(B_, S, H, hd)
    return cast_bf16(y[:, :S0]), hT


def ssm_block(p, prefix, x, cfg, cache: Optional[SSMCache] = None,
              return_state: bool = False):
    """Full mamba2 block: in_proj → conv → SSD → gated norm → out_proj.
    With `return_state` (cache=None): returns (out, (h_final, conv_tail))
    so the caller can prime a decode cache."""
    ssm = cfg.ssm
    B, S, d = x.shape
    d_in = ssm.expand * cfg.d_model
    H = d_in // ssm.head_dim
    hd, N, G = ssm.head_dim, ssm.state, ssm.n_groups
    conv_dim = d_in + 2 * G * N

    zxbcdt = dense(x, p[f"{prefix}/in_proj"])
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p[f"{prefix}/dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p[f"{prefix}/A_log"].astype(jnp.float32))        # [H]

    tail = cache.conv if cache is not None else None
    xbc, new_tail = _causal_conv(xbc, p[f"{prefix}/conv_w"],
                                 p[f"{prefix}/conv_b"], tail)
    xh, Bm, Cm = jnp.split(xbc, [d_in, d_in + G * N], axis=-1)
    xh = xh.reshape(B, S, H, hd)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)

    if cache is None:
        y, hT = ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk)
        new_cache = (hT, new_tail) if return_state else None
    else:
        # O(1) decode recurrence (S == 1)
        dA = jnp.exp(dt[:, 0] * A[None, :])                        # [B,H]
        dBx = jnp.einsum("bgs,bh,bhd->bhds",
                         Bm[:, 0].astype(jnp.float32),
                         dt[:, 0], xh[:, 0].astype(jnp.float32))
        h = cache.h * dA[..., None, None] + dBx
        rep = H // G
        Cr = jnp.repeat(Cm[:, 0], rep, axis=1) if G != H else Cm[:, 0]
        y = jnp.einsum("bhs,bhds->bhd", Cr.astype(jnp.float32), h)
        y = cast_bf16(y)[:, None]                                  # [B,1,H,hd]
        hT = h
        new_cache = SSMCache(hT, new_tail, cache.length + S)

    y = y + xh * p[f"{prefix}/D"].astype(jnp.bfloat16)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(jnp.bfloat16),
                 p[f"{prefix}/out_norm"], cfg.norm_eps)
    out = dense(y, p[f"{prefix}/out_proj"])
    return out, new_cache
