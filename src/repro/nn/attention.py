"""Attention: blocked (flash-style) softmax attention with GQA/MQA,
causal/bidirectional/sliding-window masking, KV-cache decode, and MLA
(multi-head latent attention, deepseek-v2) with absorbed decode.

The blocked kernel is pure jnp (lax.scan over query & KV chunks with an
online softmax), so peak memory is O(q_chunk × kv_chunk) per head rather
than O(S²) — this is what makes the 32k-prefill dry-run cells fit HBM.
A Pallas fused version is a recorded §Perf candidate; the XLA version is
the portable baseline.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.nn.layers import cast_bf16
from repro.nn.scanctl import scan_layers

NEG_INF = -1e30


def _mask(q_pos, kv_pos, kv_valid, *, causal: bool, window: int,
          kv_len=None):
    """[..., Sq, Sk] boolean validity mask from position vectors."""
    m = jnp.broadcast_to(kv_valid[None, :],
                         (q_pos.shape[-1], kv_pos.shape[-1]))
    if causal:
        m = m & (q_pos[:, None] >= kv_pos[None, :])
    if window > 0:
        m = m & (q_pos[:, None] - kv_pos[None, :] < window)
    if kv_len is not None:                       # decode: valid prefix only
        m = m & (kv_pos[None, :] < kv_len)
    return m


def blocked_attention(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                      window: int = 0, kv_len=None,
                      q_chunk: int = 1024, kv_chunk: int = 1024):
    """q [B,Sq,H,dk], k [B,Sk,KV,dk], v [B,Sk,KV,dv] (H % KV == 0).
    Returns [B,Sq,H,dv] (dv may differ from dk — MLA latent values).

    Memory: O(B · q_chunk · H · kv_chunk) per scan step (online softmax).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = H // KV
    scale = 1.0 / np.sqrt(hd)

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    # pad ragged tails; padded KV is masked out, padded Q rows are sliced
    pq, pk = (-Sq) % qc, (-Sk) % kc
    kv_valid = jnp.arange(Sk + pk) < Sk
    if pq:
        q = jnp.concatenate(
            [q, jnp.zeros((B, pq, H, hd), q.dtype)], axis=1)
        q_pos = jnp.concatenate([q_pos, jnp.zeros((pq,), q_pos.dtype)])
    if pk:
        k = jnp.concatenate(
            [k, jnp.zeros((B, pk, KV, hd), k.dtype)], axis=1)
        v = jnp.concatenate(
            [v, jnp.zeros((B, pk, KV, dv), v.dtype)], axis=1)
        kv_pos = jnp.concatenate([kv_pos, jnp.zeros((pk,), kv_pos.dtype)])
    Sqp, Skp = Sq + pq, Sk + pk
    nq, nk = Sqp // qc, Skp // kc

    qr = q.reshape(B, nq, qc, KV, rep, hd)
    kr = k.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, kc, KV, dv).transpose(1, 0, 2, 3, 4)
    qpr = q_pos.reshape(nq, qc)
    kpr = kv_pos.reshape(nk, kc)
    kvr = kv_valid.reshape(nk, kc)

    def q_step(_, qi):
        qb, qp = qi                                  # [B,qc,KV,rep,hd], [qc]

        def kv_step(carry, ki):
            acc, m, l = carry
            kb, vb, kp, kval = ki
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            valid = _mask(qp, kp, kval, causal=causal, window=window,
                          kv_len=kv_len)
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(jnp.bfloat16), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KV, rep, qc, dv), jnp.float32)
        m0 = jnp.full((B, KV, rep, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, qc), jnp.float32)
        (acc, m, l), _ = scan_layers(kv_step, (acc0, m0, l0),
                                     (kr, vr, kpr, kvr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,KV,rep,qc,dv]
        return None, cast_bf16(out.transpose(0, 3, 1, 2, 4))

    _, outs = scan_layers(q_step, None,
                          (qr.transpose(1, 0, 2, 3, 4, 5), qpr))
    # outs [nq, B, qc, KV, rep, dv] -> [B, Sq(+pad), H, dv]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sqp, H, dv)
    return out[:, :Sq]


# --------------------------------------------------------------------------
# GQA block (projections + rope + blocked attention)
# --------------------------------------------------------------------------

def gqa_project_qkv(p, prefix, x, cfg):
    from repro.nn.layers import dense, rms_norm
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    bias = (lambda n: p.get(f"{prefix}/{n}_b")) if cfg.qkv_bias else (lambda n: None)
    q = dense(x, p[f"{prefix}/wq"], bias("wq")).reshape(B, S, H, hd)
    k = dense(x, p[f"{prefix}/wk"], bias("wk")).reshape(B, S, KV, hd)
    v = dense(x, p[f"{prefix}/wv"], bias("wv")).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p[f"{prefix}/q_norm"], cfg.norm_eps)
        k = rms_norm(k, p[f"{prefix}/k_norm"], cfg.norm_eps)
    return q, k, v


class KVCache(NamedTuple):
    k: jax.Array          # [B, Smax, KV, hd]
    v: jax.Array
    length: jax.Array     # scalar i32 — tokens currently in the cache


def gqa_attention(p, prefix, x, cfg, positions, *, window: int = 0,
                  causal: bool = True, cache: Optional[KVCache] = None,
                  return_kv: bool = False, q_chunk=1024, kv_chunk=1024):
    """Full GQA block.

    cache=None: full-sequence attention (train / prefill).  With
    `return_kv`, also returns the rope'd (k, v) so the caller can prime a
    decode cache.  cache!=None: decode step(s); keys written at
    `cache.length` (ring-buffered iff window>0; decode is S==1 there).
    """
    from repro.nn.layers import dense
    B, S, _ = x.shape
    q, k, v = gqa_project_qkv(p, prefix, x, cfg)
    q = jax.vmap(lambda qq, pp: _rope_heads(qq, pp, cfg.rope_theta),
                 in_axes=(0, None))(q, positions)
    k = jax.vmap(lambda kk, pp: _rope_heads(kk, pp, cfg.rope_theta),
                 in_axes=(0, None))(k, positions)

    if cache is None:
        out = blocked_attention(q, k, v, positions, positions, causal=causal,
                                window=window, q_chunk=q_chunk,
                                kv_chunk=kv_chunk)
        aux = (k, v) if return_kv else None
    else:
        Smax = cache.k.shape[1]
        slot = cache.length % Smax if window > 0 else cache.length
        ck = lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
        if window > 0:
            # ring buffer: absolute position of physical slot s
            base = cache.length - (cache.length % Smax)
            phys = jnp.arange(Smax)
            kv_pos = jnp.where(phys <= slot, base + phys, base - Smax + phys)
        else:
            kv_pos = jnp.arange(Smax)
        q_pos = cache.length + jnp.arange(S, dtype=jnp.int32)
        out = blocked_attention(q, ck, cv, q_pos, kv_pos, causal=True,
                                window=window, kv_len=cache.length + S,
                                q_chunk=q_chunk, kv_chunk=kv_chunk)
        aux = KVCache(ck, cv, cache.length + S)
    out = dense(out.reshape(B, S, -1), p[f"{prefix}/wo"])
    return out, aux


def _rope_heads(x, positions, theta):
    """x [S, H, hd], positions [S] — angles broadcast over the head axis."""
    from repro.nn.layers import apply_rope
    return apply_rope(x, positions, theta)


# --------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v2), absorbed decode
# --------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array       # [B, Smax, kv_lora]   compressed KV
    k_rope: jax.Array     # [B, Smax, rope_dim]  shared rope key
    length: jax.Array


def mla_attention(p, prefix, x, cfg, positions, *,
                  cache: Optional[MLACache] = None, return_kv: bool = False,
                  q_chunk=1024, kv_chunk=1024):
    """Prefill/train: expand compressed KV and run blocked attention
    (with `return_kv`, also return (c_kv, k_rope) to prime a decode
    cache).  Decode: absorbed form — queries projected into the latent
    space, the cache stays [kv_lora + rope_dim] per position (the
    MLA memory win)."""
    from repro.nn.layers import dense, rms_norm, apply_rope
    mla = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd, vd, kvl = mla.nope_dim, mla.rope_dim, mla.v_dim, mla.kv_lora

    # --- queries (with LoRA) ---
    cq = dense(x, p[f"{prefix}/w_dq"])
    cq = rms_norm(cq, p[f"{prefix}/q_norm"], cfg.norm_eps)
    q = dense(cq, p[f"{prefix}/w_uq"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = jax.vmap(lambda qq, pp: _rope_heads(qq, pp, cfg.rope_theta),
                      in_axes=(0, None))(q_rope, positions)

    # --- compressed KV ---
    ckv = dense(x, p[f"{prefix}/w_dkv"])                    # [B,S,kvl]
    ckv = rms_norm(ckv, p[f"{prefix}/kv_norm"], cfg.norm_eps)
    krope = dense(x, p[f"{prefix}/w_kr"])                   # [B,S,rd]
    krope = jax.vmap(lambda kk, pp: apply_rope(kk, pp, cfg.rope_theta),
                     in_axes=(0, None))(krope, positions)

    w_uk = p[f"{prefix}/w_uk"].reshape(kvl, H, nd)
    w_uv = p[f"{prefix}/w_uv"].reshape(kvl, H, vd)

    if cache is None:
        # prefill/train: expand K latent -> per-head keys; rope part shared
        k_nope = jnp.einsum("bsc,chd->bshd", cast_bf16(ckv), cast_bf16(w_uk),
                            preferred_element_type=jnp.float32)
        k_nope = cast_bf16(k_nope)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, rd))],
            axis=-1)
        v_full = cast_bf16(jnp.einsum("bsc,chd->bshd", cast_bf16(ckv),
                                      cast_bf16(w_uv),
                                      preferred_element_type=jnp.float32))
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = blocked_attention(q_full, k_full, v_full, positions, positions,
                                causal=True, q_chunk=q_chunk,
                                kv_chunk=kv_chunk)
        new_cache = (ckv, krope) if return_kv else None
    else:
        # absorbed decode: q' = q_nope @ W_uk  ->  latent-space scores
        Smax = cache.c_kv.shape[1]
        cc = lax.dynamic_update_slice(cache.c_kv, ckv, (0, cache.length, 0))
        cr = lax.dynamic_update_slice(cache.k_rope, krope,
                                      (0, cache.length, 0))
        q_lat = jnp.einsum("bshd,chd->bshc", cast_bf16(q_nope),
                           cast_bf16(w_uk),
                           preferred_element_type=jnp.float32)
        q_lat = cast_bf16(q_lat)                            # [B,S,H,kvl]
        # treat (c_kv ++ k_rope) as a single-KV-head key of dim kvl+rd
        k_cat = jnp.concatenate([cc, cr], axis=-1)[:, :, None, :]
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)
        # §Perf P2c: align q with the latent-sharded cache so the scores
        # contraction partial-sums over latent shards (all-reduce of the
        # small [B,H,1,S] scores) instead of all-gathering the whole
        # cache every layer — decode was collective-bound 400:1 without it
        from repro.nn.layers import constrain
        q_cat = constrain(q_cat, None, None, None, "model")
        # scale correction: blocked_attention scales by 1/sqrt(kvl+rd);
        # MLA wants 1/sqrt(nd+rd)
        fix = np.sqrt(kvl + rd) / np.sqrt(nd + rd)
        ctx = blocked_attention(q_cat * fix, k_cat,
                                cc[:, :, None, :],      # latent values
                                cache.length + jnp.arange(S, dtype=jnp.int32),
                                jnp.arange(Smax), causal=True,
                                kv_len=cache.length + S,
                                q_chunk=q_chunk, kv_chunk=kv_chunk)
        out = jnp.einsum("bshc,chd->bshd", cast_bf16(ctx), cast_bf16(w_uv),
                         preferred_element_type=jnp.float32)
        out = cast_bf16(out)
        new_cache = MLACache(cc, cr, cache.length + S)

    out = dense(out.reshape(B, S, H * vd), p[f"{prefix}/wo"])
    return out, new_cache
