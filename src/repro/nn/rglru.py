"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = σ(W_a x_t + b_a)          recurrence gate
    i_t = σ(W_x x_t + b_x)          input gate
    a_t = a^(c·r_t)                 a = σ(Λ), c = 8
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Prefill/train uses `lax.associative_scan` (log-depth, TPU-friendly —
h_t = a_t h_{t-1} + b_t composes associatively), decode is the O(1) step;
bounded state is why this arch runs `long_500k`.

The block wraps the LRU in the Griffin recurrent-block structure:
gated branch (linear → GeLU) ⊗ (linear → causal conv → RG-LRU) → linear.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn.layers import cast_bf16, dense
from repro.nn.ssm import _causal_conv


class RGLRUCache(NamedTuple):
    h: jax.Array          # [B, W]  LRU hidden state (f32)
    conv: jax.Array       # [B, conv-1, W] conv tail
    length: jax.Array


def _rglru_scan(x, r, i, a_param, c: float):
    """x/r/i [B,S,W] (f32). Returns h [B,S,W] and final state."""
    log_a = c * r * jax.nn.log_sigmoid(a_param)[None, None, :]   # [B,S,W]
    a = jnp.exp(log_a)
    gated = i * x
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_s, h = lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_step(x, r, i, a_param, c: float, h_prev):
    log_a = c * r * jax.nn.log_sigmoid(a_param)[None, :]
    a = jnp.exp(log_a)
    h = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a),
                                          1e-12)) * (i * x)
    return h, h


def recurrent_block(p, prefix, x, cfg, cache: Optional[RGLRUCache] = None,
                    return_state: bool = False):
    """Griffin recurrent mixing block.  With `return_state` (cache=None):
    returns (out, (h_last, conv_tail)) to prime a decode cache."""
    rg = cfg.rglru
    W = rg.lru_width or cfg.d_model
    B, S, _ = x.shape

    gate = jax.nn.gelu(dense(x, p[f"{prefix}/w_gate"]).astype(jnp.float32))
    xr = dense(x, p[f"{prefix}/w_in"])                    # [B,S,W]
    tail = cache.conv if cache is not None else None
    xr, new_tail = _causal_conv(xr, p[f"{prefix}/conv_w"],
                                p[f"{prefix}/conv_b"], tail)

    xf = xr.astype(jnp.float32)

    def block_diag(w, b):
        """Griffin block-diagonal gate: [H, W/H, W/H] blocks."""
        H = w.shape[0]
        xh = xf.reshape(*xf.shape[:-1], H, W // H)
        y = jnp.einsum("...hk,hkj->...hj", xh, w.astype(jnp.float32))
        return jax.nn.sigmoid(y.reshape(*xf.shape) + b.astype(jnp.float32))

    r = block_diag(p[f"{prefix}/w_a"], p[f"{prefix}/b_a"])
    i = block_diag(p[f"{prefix}/w_x"], p[f"{prefix}/b_x"])

    if cache is None:
        h, h_last = _rglru_scan(
            xf, r, i, p[f"{prefix}/a_param"].astype(jnp.float32),
            rg.c_exponent)
        new_cache = (h_last, new_tail) if return_state else None
    else:
        hs, h_last = rglru_step(xf[:, 0], r[:, 0], i[:, 0],
                                p[f"{prefix}/a_param"].astype(jnp.float32),
                                rg.c_exponent, cache.h)
        h = hs[:, None]
        new_cache = RGLRUCache(h_last, new_tail, cache.length + S)

    out = dense(cast_bf16(h) * cast_bf16(gate), p[f"{prefix}/w_out"])
    return out, new_cache
