"""NN building blocks + the parameter-spec system.

Params are flat dicts ``path -> jnp array``; every param is declared once
as a `ParamSpec` (shape, logical sharding axes, initializer).  The same
specs drive: real initialization (smoke tests / examples), abstract
initialization (`jax.eval_shape` for the dry-run — no allocation), the
sharding rules (`distributed/sharding.py` maps logical axes → mesh axes),
and the parameter-count roofline terms.

Compute dtype policy: params are stored f32 (optimizer master), cast to
bf16 at use; matmuls accumulate f32 via ``preferred_element_type``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis names, len == ndim
    init: str = "normal"                # normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Specs = Dict[str, ParamSpec]


def init_params(specs: Specs, key: jax.Array, dtype=jnp.float32):
    """Materialize params (used by smoke tests/examples; the dry-run uses
    eval_shape over this same function)."""
    keys = jax.random.split(key, max(len(specs), 1))
    out = {}
    for (path, spec), k in zip(sorted(specs.items()), keys):
        if spec.init == "zeros":
            out[path] = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            out[path] = jnp.ones(spec.shape, dtype)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale / np.sqrt(max(fan_in, 1))
            if spec.init == "embed":
                std = spec.scale * 0.02
            out[path] = (jax.random.normal(k, spec.shape, dtype) * std)
    return out


def abstract_params(specs: Specs, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins (dry-run path: zero allocation)."""
    return {p: jax.ShapeDtypeStruct(s.shape, dtype)
            for p, s in specs.items()}


def logical_axes(specs: Specs):
    return {p: s.axes for p, s in specs.items()}


# --------------------------------------------------------------------------
# primitive layers (pure functions; weights passed in, bf16 compute)
# --------------------------------------------------------------------------

def cast_bf16(x):
    return x.astype(jnp.bfloat16)


def dense(x, w, bias=None):
    """x [..., in] @ w [in, out] in bf16, f32 accumulation."""
    y = jnp.einsum("...i,io->...o", cast_bf16(x), cast_bf16(w),
                   preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return cast_bf16(y)


def rms_norm(x, gamma, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return cast_bf16(xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32))


def swiglu(x, w_gate, w_up, w_down):
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g.astype(jnp.float32)).astype(jnp.bfloat16) * u,
                 w_down)


def embed_lookup(table, tokens):
    return cast_bf16(jnp.take(table, tokens, axis=0))


def constrain(x, *axes):
    """with_sharding_constraint by mesh-axis name; silently skipped when
    the named axes aren't in the ambient mesh (smoke tests, 1-device)."""
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    U = jax.sharding.PartitionSpec.UNCONSTRAINED
    entries = []
    for a in axes:
        if a is None:
            entries.append(U)
        elif isinstance(a, tuple):
            present = tuple(n for n in a if n in mesh.axis_names)
            entries.append(present if present else U)
        else:
            entries.append(a if a in mesh.axis_names else U)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*entries))


def unembed(x, table):
    """Logits in f32 (stable CE), forced vocab-sharded over `model` so the
    [B, S, V] tensor (and its grad) never materializes replicated."""
    y = jnp.einsum("...d,vd->...v", cast_bf16(x), cast_bf16(table),
                   preferred_element_type=jnp.float32)
    return constrain(y, *((None,) * (y.ndim - 1)), "model")


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x [S, ..., hd] with positions [S]; head axes (if any) sit between S
    and hd and broadcast. Paired-halves rotation convention."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    angles = positions[:, None].astype(jnp.float32) * freqs      # [S, hd/2]
    # insert broadcast axes for any head dims between S and hd
    while angles.ndim < x.ndim:
        angles = angles[:, None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return cast_bf16(out)
