"""Whole-model assembly: param specs, train/prefill/decode forwards, caches.

Families (configs/base.py): dense | moe | vlm | hybrid | audio | ssm.
Layer stacks are grouped into homogeneous *groups*; groups with count > 1
are `lax.scan`-ned over stacked params (HLO size O(1) in depth), size-1
groups are unrolled (e.g. deepseek's leading dense layer).  Caches mirror
the group structure with a leading layer axis.

Modality frontends are stubs per the assignment: `input_specs` (launch/
dryrun.py) provides precomputed patch/frame embeddings; a learned
projection makes them non-trivial without pretending to be a ViT/w2v-BERT.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.nn.scanctl import (scan_layers, unroll_scans,  # noqa: F401
                              remat_policy)
from repro.nn import scanctl

from repro.configs.base import ArchConfig
from repro.nn.layers import (ParamSpec, Specs, dense, embed_lookup, rms_norm,
                             unembed)
from repro.nn import transformer as T
from repro.nn.attention import KVCache, MLACache
from repro.nn.ssm import SSMCache
from repro.nn.rglru import RGLRUCache

# --------------------------------------------------------------------------
# group structure
# --------------------------------------------------------------------------


def decoder_groups(cfg: ArchConfig) -> List[Tuple[str, int, str]]:
    """[(kind, count, prefix)] for the decoder stack."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        return [("ssm", L, "blocks")]
    if cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        n_per = L // len(pat)
        groups = [("period", n_per, "periods")]
        for i in range(L % len(pat)):
            groups.append((f"tail_{pat[i]}", 1, f"tail{i}"))
        return groups
    if cfg.moe is not None:
        groups = []
        if cfg.moe.first_dense:
            groups.append(("dense", cfg.moe.first_dense, "dense0"))
        groups.append(("moe", L - cfg.moe.first_dense, "blocks"))
        return groups
    return [("dense", L, "blocks")]


# --------------------------------------------------------------------------
# param specs
# --------------------------------------------------------------------------

def _block_specs(cfg: ArchConfig, kind: str) -> Specs:
    d = cfg.d_model
    s: Specs = {}
    if kind in ("dense", "moe"):
        s["norm1"] = T.norm_spec(d)
        s["norm2"] = T.norm_spec(d)
        T.add(s, "attn", T.mla_specs(cfg) if cfg.mla else T.gqa_specs(cfg))
        if kind == "dense":
            ff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense)
                  else cfg.d_ff)
            T.add(s, "ffn", T.ffn_specs(d, ff))
        else:
            T.add(s, "moe", T.moe_specs(cfg))
    elif kind == "ssm":
        s["norm1"] = T.norm_spec(d)
        T.add(s, "ssm", T.ssm_specs(cfg))
    elif kind == "rec" or kind.startswith("tail_rec"):
        s["norm1"] = T.norm_spec(d)
        T.add(s, "rec", T.rglru_specs(cfg))
        s["norm2"] = T.norm_spec(d)
        T.add(s, "ffn", T.ffn_specs(d, cfg.d_ff))
    elif kind == "attn" or kind.startswith("tail_attn"):
        s["norm1"] = T.norm_spec(d)
        T.add(s, "attn", T.gqa_specs(cfg))
        s["norm2"] = T.norm_spec(d)
        T.add(s, "ffn", T.ffn_specs(d, cfg.d_ff))
    elif kind == "period":
        pat = cfg.rglru.pattern
        for i, sub_kind in enumerate(pat):
            sk = "rec" if sub_kind == "rec" else "attn"
            inner = _block_specs(cfg, sk)
            T.add(s, f"sub{i}_{sub_kind}", inner)
    elif kind == "enc":
        s["norm1"] = T.norm_spec(d)
        T.add(s, "attn", T.gqa_specs(cfg))
        s["norm2"] = T.norm_spec(d)
        T.add(s, "ffn", T.ffn_specs(d, cfg.d_ff))
    elif kind == "dec":
        s["norm1"] = T.norm_spec(d)
        T.add(s, "attn", T.gqa_specs(cfg))
        s["norm_x"] = T.norm_spec(d)
        T.add(s, "xattn", T.xattn_specs(cfg))
        s["norm2"] = T.norm_spec(d)
        T.add(s, "ffn", T.ffn_specs(d, cfg.d_ff))
    else:
        raise ValueError(kind)
    return s


def _stack(specs: Specs, n: int) -> Specs:
    return {k: ParamSpec((n,) + v.shape, ("layers",) + v.axes, v.init,
                         v.scale) for k, v in specs.items()}


def param_specs(cfg: ArchConfig) -> Specs:
    d, V = cfg.d_model, cfg.vocab
    s: Specs = {
        "embed/tok": ParamSpec((V, d), ("vocab", "embed"), init="embed"),
        "final_norm": T.norm_spec(d),
    }
    if not cfg.tie_embeddings:
        s["unembed/w"] = ParamSpec((V, d), ("vocab", "embed"), init="embed")

    if cfg.encdec is not None:
        s["frontend/proj"] = ParamSpec((d, d), ("embed", None))
        s["enc_final_norm"] = T.norm_spec(d)
        for k, v in _stack(_block_specs(cfg, "enc"),
                           cfg.encdec.enc_layers).items():
            s[f"enc_blocks/{k}"] = v
        for k, v in _stack(_block_specs(cfg, "dec"),
                           cfg.encdec.dec_layers).items():
            s[f"dec_blocks/{k}"] = v
        return s

    if cfg.frontend == "vit_stub":
        s["frontend/proj"] = ParamSpec((d, d), ("embed", None))

    for kind, count, prefix in decoder_groups(cfg):
        bs = _block_specs(cfg, kind)
        if count > 1:
            bs = _stack(bs, count)
        for k, v in bs.items():
            s[f"{prefix}/{k}"] = v
    return s


def active_param_fraction(cfg: ArchConfig, path: str) -> float:
    """Per-token activation fraction (MoE routed experts only)."""
    if cfg.moe is not None and "/moe/w_" in path:
        return cfg.moe.top_k / cfg.moe.n_experts
    return 1.0


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def _kv_cache(cfg, B, smax, n=None, dtype=jnp.bfloat16):
    KV, hd = cfg.n_kv_heads, cfg.hd
    lead = (n,) if n else ()
    z = lambda *sh: jnp.zeros(lead + sh, dtype)  # noqa: E731
    return KVCache(z(B, smax, KV, hd), z(B, smax, KV, hd),
                   jnp.zeros(lead, jnp.int32) if n else jnp.asarray(0, jnp.int32))


def _mla_cache(cfg, B, smax, n=None, dtype=jnp.bfloat16):
    mla = cfg.mla
    lead = (n,) if n else ()
    z = lambda *sh: jnp.zeros(lead + sh, dtype)  # noqa: E731
    return MLACache(z(B, smax, mla.kv_lora), z(B, smax, mla.rope_dim),
                    jnp.zeros(lead, jnp.int32) if n else jnp.asarray(0, jnp.int32))


def _ssm_cache(cfg, B, n=None):
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    H = d_in // ssm.head_dim
    conv_dim = d_in + 2 * ssm.n_groups * ssm.state
    lead = (n,) if n else ()
    return SSMCache(
        jnp.zeros(lead + (B, H, ssm.head_dim, ssm.state), jnp.float32),
        jnp.zeros(lead + (B, ssm.conv - 1, conv_dim), jnp.bfloat16),
        jnp.zeros(lead, jnp.int32) if n else jnp.asarray(0, jnp.int32))


def _rglru_cache(cfg, B, n=None):
    W = cfg.rglru.lru_width or cfg.d_model
    lead = (n,) if n else ()
    return RGLRUCache(
        jnp.zeros(lead + (B, W), jnp.float32),
        jnp.zeros(lead + (B, cfg.rglru.conv - 1, W), jnp.bfloat16),
        jnp.zeros(lead, jnp.int32) if n else jnp.asarray(0, jnp.int32))


def init_cache(cfg: ArchConfig, B: int, smax: int):
    """Zero caches for decoding up to `smax` tokens (window archs use a
    ring buffer of the window size — bounded state)."""
    if cfg.encdec is not None:
        KV, hd = cfg.n_kv_heads, cfg.hd
        nL = cfg.encdec.dec_layers
        enc_len = cfg.frontend_tokens
        return {
            "self": _kv_cache(cfg, B, smax, nL),
            "cross_k": jnp.zeros((nL, B, enc_len, KV, hd), jnp.bfloat16),
            "cross_v": jnp.zeros((nL, B, enc_len, KV, hd), jnp.bfloat16),
        }
    caches = {}
    for kind, count, prefix in decoder_groups(cfg):
        n = count if count > 1 else None
        if kind in ("dense", "moe"):
            c = (_mla_cache(cfg, B, smax, n) if cfg.mla
                 else _kv_cache(cfg, B, smax, n))
        elif kind == "ssm":
            c = _ssm_cache(cfg, B, n)
        elif kind == "period":
            c = {}
            for i, sk in enumerate(cfg.rglru.pattern):
                if sk == "rec":
                    c[f"sub{i}"] = _rglru_cache(cfg, B, n)
                else:
                    w = min(cfg.rglru.window, smax)
                    c[f"sub{i}"] = _kv_cache(cfg, B, w, n)
        elif kind.startswith("tail_rec"):
            c = _rglru_cache(cfg, B, None)
        elif kind.startswith("tail_attn"):
            c = _kv_cache(cfg, B, min(cfg.rglru.window, smax), None)
        else:
            raise ValueError(kind)
        caches[prefix] = c
    return caches


# --------------------------------------------------------------------------
# block forward dispatch (single layer)
# --------------------------------------------------------------------------

def _run_block(kind: str, p, x, cfg, positions, cache, chunks,
               prime: bool = False):
    """Returns (x, new_cache_or_primed_state, aux)."""
    aux = {}
    if kind in ("dense", "moe"):
        x, cache = T.run_attn(p, x, cfg, positions, cache=cache,
                              prime=prime, chunks=chunks)
        if kind == "dense":
            x = T.run_ffn(p, x, cfg)
        else:
            x, aux = T.run_moe(p, x, cfg)
    elif kind == "ssm":
        x, cache = T.run_ssm(p, x, cfg, cache=cache, prime=prime)
    elif kind == "rec" or kind.startswith("tail_rec"):
        x, cache = T.run_rglru(p, x, cfg, cache=cache, prime=prime)
        x = T.run_ffn(p, x, cfg)
    elif kind == "attn" or kind.startswith("tail_attn"):
        x, cache = T.run_attn(p, x, cfg, positions,
                              window=cfg.rglru.window, cache=cache,
                              prime=prime, chunks=chunks)
        x = T.run_ffn(p, x, cfg)
    elif kind == "period":
        new_c = {}
        for i, sk in enumerate(cfg.rglru.pattern):
            sp = T.sub(p, f"sub{i}_{sk}")
            ci = cache[f"sub{i}"] if cache is not None else None
            x, nc, _ = _run_block("rec" if sk == "rec" else "attn",
                                  sp, x, cfg, positions, ci, chunks, prime)
            new_c[f"sub{i}"] = nc
        cache = new_c if (cache is not None or prime) else None
    else:
        raise ValueError(kind)
    return x, cache, aux


def _merge_aux(acc: Dict, aux: Dict):
    for k, v in aux.items():
        acc[k] = acc.get(k, 0.0) + v
    return acc


def _scan_group(kind, params, prefix, x, cfg, positions, caches, chunks,
                remat: bool):
    """Scan one stacked group with its stacked cache.
    Returns (x, new_caches, aux)."""
    stacked = T.sub(params, prefix)
    cache = caches.get(prefix) if caches is not None else None

    def body(carry, layer):
        xc = carry
        lp, lc = layer
        xo, nc, aux = _run_block(kind, lp, xc, cfg, positions, lc, chunks)
        return xo, (nc, aux)

    body_fn = scanctl.checkpoint(body) if remat else body
    x, (new_cache, auxs) = scan_layers(body_fn, x, (stacked, cache))
    aux = {k: v.sum() for k, v in auxs.items()}
    return x, new_cache, aux


# --------------------------------------------------------------------------
# public forwards
# --------------------------------------------------------------------------

def _embed_inputs(params, cfg, batch):
    """tokens (+ stub modality inputs) -> (x [B,S,d], positions [S],
    n_prefix) where n_prefix = frontend tokens prepended before text."""
    from repro.nn.layers import constrain
    tokens = batch["tokens"]
    x = embed_lookup(params["embed/tok"], tokens)
    n_prefix = 0
    if cfg.frontend == "vit_stub":
        pe = dense(batch["patch_embeds"], params["frontend/proj"])
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix = pe.shape[1]
    # anchor the activation sharding: batch over (pod, data) — the embed
    # gather otherwise propagates the table's sharding, replicating batch
    x = constrain(x, ("pod", "data"), None, None)
    S = x.shape[1]
    return x, jnp.arange(S, dtype=jnp.int32), n_prefix


def forward_train(params, cfg: ArchConfig, batch, *, remat: bool = True,
                  chunks=(1024, 1024)):
    """Teacher-forced logits [B, S, V] (+ aux losses)."""
    if cfg.encdec is not None:
        return _forward_encdec_train(params, cfg, batch, remat=remat,
                                     chunks=chunks)
    x, positions, n_prefix = _embed_inputs(params, cfg, batch)
    aux: Dict = {}
    for kind, count, prefix in decoder_groups(cfg):
        if count > 1:
            x, _, a = _scan_group_nocache(kind, params, prefix, x, cfg,
                                          positions, chunks, remat)
        else:
            x, _, a = _run_block(kind, T.sub(params, prefix), x, cfg,
                                 positions, None, chunks)
        _merge_aux(aux, a)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed/w", params["embed/tok"]))
    if n_prefix:
        logits = logits[:, n_prefix:]
    return logits, aux


def _scan_group_nocache(kind, params, prefix, x, cfg, positions, chunks,
                        remat):
    stacked = T.sub(params, prefix)

    def body(xc, lp):
        xo, _, aux = _run_block(kind, lp, xc, cfg, positions, None, chunks)
        return xo, aux

    body_fn = scanctl.checkpoint(body) if remat else body
    x, auxs = scan_layers(body_fn, x, stacked)
    return x, None, {k: v.sum() for k, v in auxs.items()}


def _forward_encdec_train(params, cfg, batch, *, remat, chunks):
    from repro.nn.layers import constrain
    frames = batch["frames"]
    enc = dense(frames.astype(jnp.bfloat16), params["frontend/proj"])
    enc = constrain(enc, ("pod", "data"), None, None)
    S_enc = enc.shape[1]
    pos_e = jnp.arange(S_enc, dtype=jnp.int32)

    def enc_body(xc, lp):
        h = rms_norm(xc, lp["norm1"], cfg.norm_eps)
        from repro.nn.attention import gqa_attention
        o, _ = gqa_attention(lp, "attn", h, cfg, pos_e, causal=False,
                             q_chunk=chunks[0], kv_chunk=chunks[1])
        xc = xc + o
        return T.run_ffn(lp, xc, cfg), None

    enc_body_fn = scanctl.checkpoint(enc_body) if remat else enc_body
    enc, _ = scan_layers(enc_body_fn, enc, T.sub(params, "enc_blocks"))
    enc = rms_norm(enc, params["enc_final_norm"], cfg.norm_eps)

    x = embed_lookup(params["embed/tok"], batch["tokens"])
    x = constrain(x, ("pod", "data"), None, None)
    pos_d = jnp.arange(x.shape[1], dtype=jnp.int32)

    def dec_body(xc, lp):
        xc, _ = T.run_attn(lp, xc, cfg, pos_d, chunks=chunks)
        xc = T.run_cross_attn(lp, xc, T.cross_kv(lp, enc, cfg), cfg, chunks)
        return T.run_ffn(lp, xc, cfg), None

    dec_body_fn = scanctl.checkpoint(dec_body) if remat else dec_body
    x, _ = scan_layers(dec_body_fn, x, T.sub(params, "dec_blocks"))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed/w", params["embed/tok"]))
    return logits, {}


def _pad_prefix(arr, smax: int):
    """[..., B, S, ...rest] KV written into a zeroed [B, smax, ...] cache
    (seq axis is axis -3 for kv / -2 for latent tensors)."""
    def one(a):                                  # a [B, S, ...]
        S = a.shape[1]
        pad = [(0, 0)] * a.ndim
        pad[1] = (0, smax - S)
        return jnp.pad(a, pad)
    return one(arr)


def _place_ring(arr, W: int):
    """[B, S, ...] → ring buffer [B, W, ...] holding the last min(S,W)
    entries at physical slots ((S-w+i) mod W) — matches the decode-side
    ring reconstruction in gqa_attention."""
    S = arr.shape[1]
    w = min(S, W)
    last = arr[:, S - w:]
    phys = (S - w + np.arange(w)) % W
    out = jnp.zeros(arr.shape[:1] + (W,) + arr.shape[2:], arr.dtype)
    return out.at[:, phys].set(last)


def _maybe_vmap(fn, arr, stacked: bool):
    return jax.vmap(fn)(arr) if stacked else fn(arr)


def _assemble_cache(kind, raw, cfg, smax: int, S: int, stacked: bool):
    """Primed per-layer states → decode cache structures."""
    n = None
    if stacked:
        n = jax.tree_util.tree_leaves(raw)[0].shape[0]
    lengths = (jnp.full((n,), S, jnp.int32) if stacked
               else jnp.asarray(S, jnp.int32))
    if kind in ("dense", "moe"):
        if cfg.mla is not None:
            ckv, krope = raw
            return MLACache(
                _maybe_vmap(lambda a: _pad_prefix(a, smax), ckv, stacked),
                _maybe_vmap(lambda a: _pad_prefix(a, smax), krope, stacked),
                lengths)
        k, v = raw
        return KVCache(
            _maybe_vmap(lambda a: _pad_prefix(a, smax), k, stacked),
            _maybe_vmap(lambda a: _pad_prefix(a, smax), v, stacked),
            lengths)
    if kind == "ssm":
        h, tail = raw
        return SSMCache(h, tail, lengths)
    if kind == "rec" or kind.startswith("tail_rec"):
        h, tail = raw
        return RGLRUCache(h, tail, lengths)
    if kind == "attn" or kind.startswith("tail_attn"):
        k, v = raw
        W = min(cfg.rglru.window, smax)
        return KVCache(
            _maybe_vmap(lambda a: _place_ring(a, W), k, stacked),
            _maybe_vmap(lambda a: _place_ring(a, W), v, stacked),
            lengths)
    if kind == "period":
        out = {}
        for i, sk in enumerate(cfg.rglru.pattern):
            out[f"sub{i}"] = _assemble_cache(
                "rec" if sk == "rec" else "attn", raw[f"sub{i}"], cfg,
                smax, S, stacked)
        return out
    raise ValueError(kind)


def forward_prefill(params, cfg: ArchConfig, batch, smax: int,
                    chunks=(1024, 1024)):
    """Process a prompt with full-sequence kernels, then *prime* decode
    caches from the returned per-layer states.  Returns (last-token
    logits, caches)."""
    if cfg.encdec is not None:
        return _prefill_encdec(params, cfg, batch, smax, chunks)
    x, positions, n_prefix = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    caches = {}
    for kind, count, prefix in decoder_groups(cfg):
        if count > 1:
            stacked = T.sub(params, prefix)

            def body(xc, lp):
                xo, st, _ = _run_block(kind, lp, xc, cfg, positions, None,
                                       chunks, prime=True)
                return xo, st

            x, raw = scan_layers(body, x, stacked)
            caches[prefix] = _assemble_cache(kind, raw, cfg, smax, S, True)
        else:
            x, raw, _ = _run_block(kind, T.sub(params, prefix), x, cfg,
                                   positions, None, chunks, prime=True)
            caches[prefix] = _assemble_cache(kind, raw, cfg, smax, S, False)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed/w", params["embed/tok"]))
    return logits[:, 0], caches


def _prefill_encdec(params, cfg, batch, smax, chunks):
    """Seamless: encoder pass + cross-KV priming + teacher-forced decoder
    prefill over the prompt tokens."""
    from repro.nn.layers import constrain
    caches = encode_and_prime(params, cfg, batch, smax, chunks)
    tokens = batch["tokens"]
    x = embed_lookup(params["embed/tok"], tokens)
    x = constrain(x, ("pod", "data"), None, None)
    S = x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)

    def body(xc, layer):
        lp, ck, cv = layer
        xc, kv = T.run_attn(lp, xc, cfg, pos, prime=True, chunks=chunks)
        xc = T.run_cross_attn(lp, xc, (ck, cv), cfg, chunks)
        xc = T.run_ffn(lp, xc, cfg)
        return xc, kv

    x, raw = scan_layers(body, x, (T.sub(params, "dec_blocks"),
                                   caches["cross_k"], caches["cross_v"]))
    caches["self"] = _assemble_cache("dense", raw, cfg, smax, S, True)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed/w", params["embed/tok"]))
    return logits[:, 0], caches


def forward_decode(params, cfg: ArchConfig, tokens, caches,
                   chunks=(1, 1024), batch=None):
    """One decode step: tokens [B, 1] → logits [B, V], updated caches."""
    if cfg.encdec is not None:
        return _decode_encdec(params, cfg, tokens, caches, chunks)
    from repro.nn.layers import constrain
    x = embed_lookup(params["embed/tok"], tokens)
    x = constrain(x, ("pod", "data"), None, None)
    # absolute position = current cache length (uniform across batch)
    length = _cache_length(cfg, caches)
    positions = length[None].astype(jnp.int32)
    new_caches = {}
    for kind, count, prefix in decoder_groups(cfg):
        if count > 1:
            x, nc, _ = _scan_group(kind, params, prefix, x, cfg, positions,
                                   caches, chunks, remat=False)
        else:
            x, nc, _ = _run_block(kind, T.sub(params, prefix), x, cfg,
                                  positions, caches.get(prefix), chunks)
        new_caches[prefix] = nc
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed/w", params["embed/tok"]))
    return logits[:, 0], new_caches


def _decode_encdec(params, cfg, tokens, caches, chunks):
    from repro.nn.layers import constrain
    x = embed_lookup(params["embed/tok"], tokens)
    x = constrain(x, ("pod", "data"), None, None)
    pos = caches["self"].length[0][None].astype(jnp.int32)

    def body(xc, layer):
        lp, sc, ck, cv = layer
        xc, nsc = T.run_attn(lp, xc, cfg, pos, cache=sc, chunks=chunks)
        xc = T.run_cross_attn(lp, xc, (ck, cv), cfg, chunks)
        xc = T.run_ffn(lp, xc, cfg)
        return xc, nsc

    x, nsc = scan_layers(body, x, (T.sub(params, "dec_blocks"),
                                   caches["self"], caches["cross_k"],
                                   caches["cross_v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed/w", params["embed/tok"]))
    return logits[:, 0], {**caches, "self": nsc}


def encode_and_prime(params, cfg, batch, smax, chunks=(1024, 1024)):
    """Seamless: run the encoder, prime cross-KV caches + empty self cache."""
    from repro.nn.layers import constrain
    frames = batch["frames"]
    enc = dense(frames.astype(jnp.bfloat16), params["frontend/proj"])
    enc = constrain(enc, ("pod", "data"), None, None)
    pos_e = jnp.arange(enc.shape[1], dtype=jnp.int32)
    from repro.nn.attention import gqa_attention

    def enc_body(xc, lp):
        h = rms_norm(xc, lp["norm1"], cfg.norm_eps)
        o, _ = gqa_attention(lp, "attn", h, cfg, pos_e, causal=False,
                             q_chunk=chunks[0], kv_chunk=chunks[1])
        return T.run_ffn(lp, xc + o, cfg), None

    enc, _ = scan_layers(enc_body, enc, T.sub(params, "enc_blocks"))
    enc = rms_norm(enc, params["enc_final_norm"], cfg.norm_eps)

    def kv_body(_, lp):
        return None, T.cross_kv(lp, enc, cfg)

    _, (ck, cv) = scan_layers(kv_body, None, T.sub(params, "dec_blocks"))
    cache = init_cache(cfg, frames.shape[0], smax)
    return {**cache, "cross_k": ck, "cross_v": cv}


def _cache_length(cfg, caches):
    leaf = caches[decoder_groups(cfg)[0][2]]
    if isinstance(leaf, dict):                # period group
        for v in leaf.values():
            leaf = v
            break
    length = leaf.length
    return length[0] if length.ndim else length
