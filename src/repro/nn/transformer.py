"""Block-level assembly: every layer family as (specs, forward) pairs.

Params are flat dicts keyed by "<prefix>/<name>"; spec builders and
forward functions are kept adjacent so shapes/axes stay in sync.  Blocks
are pre-norm residual; caches are NamedTuples from the layer modules.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.layers import ParamSpec, Specs, dense, rms_norm, swiglu
from repro.nn import attention as A
from repro.nn import moe as M
from repro.nn import ssm as SSM
from repro.nn import rglru as RG


def sub(params: Dict, prefix: str) -> Dict:
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def add(specs: Specs, prefix: str, more: Specs) -> None:
    for k, v in more.items():
        specs[f"{prefix}/{k}"] = v


# -- GQA attention ----------------------------------------------------------

def gqa_specs(cfg: ArchConfig) -> Specs:
    H, KV, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    s: Specs = {
        "wq": ParamSpec((d, H * hd), ("embed", "heads")),
        "wk": ParamSpec((d, KV * hd), ("embed", "kv")),
        "wv": ParamSpec((d, KV * hd), ("embed", "kv")),
        "wo": ParamSpec((H * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["wq_b"] = ParamSpec((H * hd,), ("heads",), init="zeros")
        s["wk_b"] = ParamSpec((KV * hd,), ("kv",), init="zeros")
        s["wv_b"] = ParamSpec((KV * hd,), ("kv",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        s["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return s


# -- MLA --------------------------------------------------------------------

def mla_specs(cfg: ArchConfig) -> Specs:
    mla = cfg.mla
    H, d = cfg.n_heads, cfg.d_model
    nd, rd, vd = mla.nope_dim, mla.rope_dim, mla.v_dim
    return {
        "w_dq": ParamSpec((d, mla.q_lora), ("embed", None)),
        "q_norm": ParamSpec((mla.q_lora,), (None,), init="ones"),
        "w_uq": ParamSpec((mla.q_lora, H * (nd + rd)), (None, "heads")),
        "w_dkv": ParamSpec((d, mla.kv_lora), ("embed", None)),
        "kv_norm": ParamSpec((mla.kv_lora,), (None,), init="ones"),
        "w_kr": ParamSpec((d, rd), ("embed", None)),
        "w_uk": ParamSpec((mla.kv_lora, H * nd), (None, "heads")),
        "w_uv": ParamSpec((mla.kv_lora, H * vd), (None, "heads")),
        "wo": ParamSpec((H * vd, d), ("heads", "embed")),
    }


# -- FFN (dense SwiGLU) -----------------------------------------------------

def ffn_specs(d: int, ff: int) -> Specs:
    return {
        "w_gate": ParamSpec((d, ff), ("embed", "mlp")),
        "w_up": ParamSpec((d, ff), ("embed", "mlp")),
        "w_down": ParamSpec((ff, d), ("mlp", "embed")),
    }


# -- MoE --------------------------------------------------------------------

def moe_specs(cfg: ArchConfig) -> Specs:
    moe = cfg.moe
    d, E, fe = cfg.d_model, moe.n_experts, moe.d_expert
    s: Specs = {
        "router": ParamSpec((d, E), ("embed", None)),
        "w_gate": ParamSpec((E, d, fe), ("expert", "embed", "mlp")),
        "w_up": ParamSpec((E, d, fe), ("expert", "embed", "mlp")),
        "w_down": ParamSpec((E, fe, d), ("expert", "mlp", "embed")),
    }
    if moe.n_shared > 0:
        fs = moe.n_shared * fe
        s["shared_gate"] = ParamSpec((d, fs), ("embed", "mlp"))
        s["shared_up"] = ParamSpec((d, fs), ("embed", "mlp"))
        s["shared_down"] = ParamSpec((fs, d), ("mlp", "embed"))
    return s


# -- SSM (mamba2) -----------------------------------------------------------

def ssm_specs(cfg: ArchConfig) -> Specs:
    ssm = cfg.ssm
    d = cfg.d_model
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    conv_dim = d_in + 2 * ssm.n_groups * ssm.state
    return {
        "in_proj": ParamSpec((d, d_in + conv_dim + H), ("embed", "mlp")),
        "conv_w": ParamSpec((ssm.conv, conv_dim), (None, "mlp")),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "dt_bias": ParamSpec((H,), (None,), init="zeros"),
        "A_log": ParamSpec((H,), (None,), init="zeros"),
        "D": ParamSpec((H,), (None,), init="ones"),
        "out_norm": ParamSpec((d_in,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("mlp", "embed")),
    }


# -- RG-LRU recurrent block --------------------------------------------------

def rglru_specs(cfg: ArchConfig) -> Specs:
    rg = cfg.rglru
    d = cfg.d_model
    W = rg.lru_width or d
    H = cfg.n_heads                      # griffin: block-diagonal gates
    return {
        "w_gate": ParamSpec((d, W), ("embed", "mlp")),
        "w_in": ParamSpec((d, W), ("embed", "mlp")),
        "conv_w": ParamSpec((rg.conv, W), (None, "mlp")),
        "conv_b": ParamSpec((W,), ("mlp",), init="zeros"),
        "w_a": ParamSpec((H, W // H, W // H), ("heads", None, None)),
        "b_a": ParamSpec((W,), (None,), init="zeros"),
        "w_x": ParamSpec((H, W // H, W // H), ("heads", None, None)),
        "b_x": ParamSpec((W,), (None,), init="zeros"),
        "a_param": ParamSpec((W,), (None,), init="ones"),
        "w_out": ParamSpec((W, d), ("mlp", "embed")),
    }


# -- norms -------------------------------------------------------------------

def norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), init="ones")


# ==========================================================================
# forward blocks (pre-norm residual)
# ==========================================================================

def run_attn(p, x, cfg, positions, *, window=0, causal=True, cache=None,
             prime=False, chunks=(1024, 1024)):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if cfg.mla is not None:
        o, cache = A.mla_attention(p, "attn", h, cfg, positions, cache=cache,
                                   return_kv=prime,
                                   q_chunk=chunks[0], kv_chunk=chunks[1])
    else:
        o, cache = A.gqa_attention(p, "attn", h, cfg, positions,
                                   window=window, causal=causal, cache=cache,
                                   return_kv=prime,
                                   q_chunk=chunks[0], kv_chunk=chunks[1])
    return x + o, cache


def run_ffn(p, x, cfg):
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return x + swiglu(h, p["ffn/w_gate"], p["ffn/w_up"], p["ffn/w_down"])


def run_moe(p, x, cfg):
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    o, aux = M.moe_ffn(p, "moe", h, cfg)
    return x + o, aux


def run_ssm(p, x, cfg, cache=None, prime=False):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    o, cache = SSM.ssm_block(p, "ssm", h, cfg, cache=cache,
                             return_state=prime)
    return x + o, cache


def run_rglru(p, x, cfg, cache=None, prime=False):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    o, cache = RG.recurrent_block(p, "rec", h, cfg, cache=cache,
                                  return_state=prime)
    return x + o, cache


def run_cross_attn(p, x, enc_kv, cfg, chunks=(1024, 1024)):
    """Decoder cross-attention; enc_kv = (k, v) [B, S_enc, KV, hd]."""
    h = rms_norm(x, p["norm_x"], cfg.norm_eps)
    B, S, _ = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = dense(h, p["xattn/wq"]).reshape(B, S, H, hd)
    k, v = enc_kv
    S_enc = k.shape[1]
    o = A.blocked_attention(
        q, k, v,
        jnp.zeros((S,), jnp.int32), jnp.zeros((S_enc,), jnp.int32),
        causal=False, q_chunk=min(chunks[0], S), kv_chunk=min(chunks[1], S_enc))
    return x + dense(o.reshape(B, S, H * hd), p["xattn/wo"])


def cross_kv(p, enc_out, cfg):
    B, S_enc, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = dense(enc_out, p["xattn/wk"]).reshape(B, S_enc, KV, hd)
    v = dense(enc_out, p["xattn/wv"]).reshape(B, S_enc, KV, hd)
    return k, v


def xattn_specs(cfg: ArchConfig) -> Specs:
    H, KV, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    return {
        "wq": ParamSpec((d, H * hd), ("embed", "heads")),
        "wk": ParamSpec((d, KV * hd), ("embed", "kv")),
        "wv": ParamSpec((d, KV * hd), ("embed", "kv")),
        "wo": ParamSpec((H * hd, d), ("heads", "embed")),
    }
