"""Deterministic synthetic data pipeline.

Design goals of a production loader kept, scaled to this container:
  * **deterministic & step-addressable**: `batch(step)` is a pure function
    of (seed, step) — this is what makes checkpoint-restart exactly
    reproducible and lets any host recompute any shard after an elastic
    re-mesh (no data state to checkpoint beyond the step counter);
  * **shard-aware**: `batch(step, shard, n_shards)` returns only that
    shard's rows — per-host feeding on a real cluster;
  * **learnable structure**: tokens follow a per-sequence affine
    recurrence t_{i+1} = (a·t_i + c) mod V with (a, c) drawn from a small
    pool, so a model demonstrably learns (loss drops well below uniform).

Modality stubs: patch/frame embeddings are seeded Gaussians (the
assignment specifies precomputed-embedding frontends).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLM:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    n_rules: int = 8          # size of the (a, c) pool

    def _rules(self):
        rng = np.random.default_rng(self.seed)
        a = rng.integers(1, self.cfg.vocab - 1, size=self.n_rules)
        c = rng.integers(0, self.cfg.vocab - 1, size=self.n_rules)
        return a, c

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> Dict:
        assert self.global_batch % n_shards == 0
        b = self.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        a_pool, c_pool = self._rules()
        rule = rng.integers(0, self.n_rules, size=b)
        a = a_pool[rule][:, None]
        c = c_pool[rule][:, None]
        V = self.cfg.vocab
        toks = np.empty((b, self.seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, V, size=b)
        for i in range(self.seq_len):
            toks[:, i + 1] = (a[:, 0] * toks[:, i] + c[:, 0]) % V
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if self.cfg.frontend == "vit_stub":
            batch["patch_embeds"] = rng.standard_normal(
                (b, self.cfg.frontend_tokens, self.cfg.d_model)
            ).astype(np.float32)
            # image prefix carries no next-token target
        if self.cfg.encdec is not None:
            batch["frames"] = rng.standard_normal(
                (b, self.cfg.frontend_tokens, self.cfg.d_model)
            ).astype(np.float32)
        return batch


def input_shapes(cfg: ArchConfig, shape: ShapeConfig,
                 per_device_batch: Optional[int] = None) -> Dict:
    """Abstract input shapes for `input_specs()` (dry-run)."""
    import jax
    import jax.numpy as jnp
    B = per_device_batch or shape.global_batch
    if shape.kind == "train":
        text = shape.seq_len
        if cfg.frontend == "vit_stub":
            text = shape.seq_len - cfg.frontend_tokens
        d = {"tokens": jax.ShapeDtypeStruct((B, text), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, text), jnp.int32)}
    elif shape.kind == "prefill":
        text = shape.seq_len
        if cfg.frontend == "vit_stub":
            text = shape.seq_len - cfg.frontend_tokens
        if cfg.encdec is not None:
            text = shape.seq_len - cfg.frontend_tokens
        d = {"tokens": jax.ShapeDtypeStruct((B, text), jnp.int32)}
    else:                                     # decode
        d = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.frontend == "vit_stub" and shape.kind != "decode":
        d["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encdec is not None and shape.kind != "decode":
        d["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return d
