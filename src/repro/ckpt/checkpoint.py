"""Checkpointing: atomic, async-capable, resumable, with retention.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, plus <dir>/LATEST
written via atomic rename only after the payload is fully durable — a
crash mid-save can never corrupt the restore point (the FT test kills a
run mid-training and resumes bit-exact).

`save(..., background=True)` snapshots to host memory synchronously (so
training can mutate buffers immediately) and writes on a worker thread —
the usual async-checkpoint pattern.  On a real multi-host cluster each
host would write its addressable shards; here the process owns all
shards, and the manifest records the intended (logical-axis) shardings so
a restore onto a *different* mesh can re-put each array (elastic
restart, ft/elastic.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import numpy as np

_FLAT_SEP = "||"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_FLAT_SEP}"))
    else:
        out[prefix[:-len(_FLAT_SEP)]] = tree
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict = {}
    for k, v in flat.items():
        parts = k.split(_FLAT_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, params, opt_state, extra: Optional[Dict] = None,
             background: bool = False):
        flat = _flatten({"params": params, "opt": opt_state})
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        meta = {"step": int(step), "time": time.time(),
                "extra": extra or {}}
        if background:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: Dict, meta: Dict):
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        latest_tmp = os.path.join(self.dir, ".LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.rename(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def steps(self):
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            s = int(f.read().strip())
        return s if s in self.steps() else (self.steps() or [None])[-1]

    def restore(self, step: Optional[int] = None
                ) -> Optional[Tuple[int, Dict, Dict]]:
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        self.wait()
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(flat)
        return step, tree["params"], tree["opt"]
