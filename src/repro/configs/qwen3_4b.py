"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936 — qk_norm. [hf:Qwen/Qwen3-4B family; hf]"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-4B config.json; hf-verified",
)

SMOKE = ArchConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16, qk_norm=True,
    source="reduced config, same family",
)
