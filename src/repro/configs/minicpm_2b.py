"""minicpm-2b [dense] — 40L d_model=2304 36H (MHA kv=36) d_ff=5760
vocab=122753 — WSD learning-rate schedule (train/optimizer.py),
llama-like arch, tied embeddings. [arXiv:2404.06395; hf]"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, head_dim=64,
    tie_embeddings=True, rope_theta=10_000.0,
    source="arXiv:2404.06395 + hf:openbmb/MiniCPM-2B; hf-verified",
)

SMOKE = ArchConfig(
    name="minicpm-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16, tie_embeddings=True,
    source="reduced config, same family",
)
