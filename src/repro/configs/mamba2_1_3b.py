"""mamba2-1.3b [ssm] — 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

d_inner = 2·d_model = 4096, 64 heads × head_dim 64, n_groups=1.
Runs long_500k: O(1) recurrent decode state.
"""

from repro.configs.base import ArchConfig, SSMConfig

FULL = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    ssm=SSMConfig(state=128, head_dim=64, expand=2, conv=4, n_groups=1,
                  chunk=256),
    source="arXiv:2405.21060 + hf:state-spaces/mamba2-1.3b; unverified",
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=256,
    ssm=SSMConfig(state=16, head_dim=16, expand=2, conv=4, n_groups=1,
                  chunk=8),
    source="reduced config, same family",
)
