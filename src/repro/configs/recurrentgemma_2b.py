"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1)
d_ff=7680 vocab=256000 — RG-LRU + local attention, pattern
(rec, rec, attn), window 2048. [arXiv:2402.19427; hf]

26 layers = 8 full (rec,rec,attn) periods + 2 trailing rec layers.
Runs long_500k: bounded state (LRU hidden + 2048-token attention ring).
"""

from repro.configs.base import ArchConfig, RGLRUConfig

FULL = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    tie_embeddings=True,          # gemma family ties embeddings
    rope_theta=10_000.0,
    rglru=RGLRUConfig(pattern=("rec", "rec", "attn"), window=2048,
                      lru_width=2560, conv=4),
    source="arXiv:2402.19427 (griffin 2b table) + hf config; hf-verified",
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=256, head_dim=16,
    rglru=RGLRUConfig(pattern=("rec", "rec", "attn"), window=16,
                      lru_width=64, conv=4),
    source="reduced config, same family (1 period + 1 rec tail)",
)
