"""Architecture registry: ``--arch <id>`` resolution."""

from repro.configs import (dbrx_132b, deepseek_v2_236b, llama3_8b,
                           mamba2_1_3b, minicpm_2b, pixtral_12b, qwen2_5_3b,
                           qwen3_4b, recurrentgemma_2b,
                           seamless_m4t_large_v2)
from repro.configs.base import (ALL_SHAPES, ArchConfig, ShapeConfig,
                                applicable_shapes, skip_reason)

_MODULES = {
    "deepseek-v2-236b": deepseek_v2_236b,
    "dbrx-132b": dbrx_132b,
    "pixtral-12b": pixtral_12b,
    "qwen3-4b": qwen3_4b,
    "minicpm-2b": minicpm_2b,
    "qwen2.5-3b": qwen2_5_3b,
    "llama3-8b": llama3_8b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "mamba2-1.3b": mamba2_1_3b,
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].FULL


def get_smoke(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].SMOKE


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
