"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MoE 160e top-6 (+2 shared), MLA kv_lora=512.
[arXiv:2405.04434; hf]"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

FULL = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400, head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  first_dense=1, d_ff_dense=12288),
    mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64, nope_dim=128,
                  v_dim=128),
    source="arXiv:2405.04434 (table 1 + HF config); hf-verified",
)

SMOKE = ArchConfig(
    name="deepseek-v2-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab=256, head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                  first_dense=1, d_ff_dense=128),
    mla=MLAConfig(kv_lora=32, q_lora=48, rope_dim=8, nope_dim=16, v_dim=16),
    source="reduced config, same family",
)
