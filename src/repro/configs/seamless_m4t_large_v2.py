"""seamless-m4t-large-v2 [audio] — enc-dec 24L+24L d_model=1024 16H
(MHA kv=16) d_ff=8192 vocab=256206 — multimodal; the w2v-BERT audio
frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2308.11596; hf]"""

from repro.configs.base import ArchConfig, EncDecConfig

FULL = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    rope_theta=10_000.0,
    encdec=EncDecConfig(enc_layers=24, dec_layers=24),
    frontend="audio_stub", frontend_tokens=1024,
    source="arXiv:2308.11596 + hf:facebook/seamless-m4t-v2-large; hf",
)

SMOKE = ArchConfig(
    name="seamless-smoke", family="audio",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    encdec=EncDecConfig(enc_layers=2, dec_layers=2),
    frontend="audio_stub", frontend_tokens=8,
    source="reduced config, same family",
)
