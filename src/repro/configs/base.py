"""Architecture + shape configuration system.

One `ArchConfig` per assigned architecture (exact numbers from the public
sources cited in each config file) plus a `smoke()` reduction of the same
family for CPU tests.  `ShapeConfig` carries the assigned input shapes;
`applicable_shapes` encodes the skip rules (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN inner dim
    n_shared: int = 0             # shared (always-on) experts
    router_dtype: str = "float32"
    capacity_factor: float = 1.25
    first_dense: int = 0          # leading dense layers (deepseek style)
    d_ff_dense: int = 0           # FFN width of those dense layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:                  # mamba2 / SSD
    state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv: int = 4
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:                # recurrentgemma / griffin
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    window: int = 2048
    lru_width: int = 0            # 0 → d_model
    conv: int = 4
    c_exponent: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int
    dec_layers: int


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 → d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[str] = None    # "vit_stub" | "audio_stub"
    frontend_tokens: int = 0          # stub frontend sequence length
    source: str = ""                  # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def attn_free(self) -> bool:
        return self.ssm is not None

    @property
    def sub_quadratic(self) -> bool:
        """Can decode with O(1)/bounded per-token state at 500k context."""
        return self.ssm is not None or self.rglru is not None

    def n_params(self) -> int:
        """Exact parameter count from the spec tables (used for the
        MODEL_FLOPS = 6·N·D roofline term)."""
        from repro.nn.model import param_specs
        return sum(int(__import__("numpy").prod(s.shape))
                   for s in param_specs(self).values())

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: routed top-k + shared only)."""
        from repro.nn.model import param_specs, active_param_fraction
        total = 0
        for path, s in param_specs(self).items():
            n = int(__import__("numpy").prod(s.shape))
            total += int(n * active_param_fraction(self, path))
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ArchConfig) -> Tuple[ShapeConfig, ...]:
    """Skip rules (recorded in DESIGN.md §5): long_500k only for
    sub-quadratic archs; decode applies to every assigned arch (all have
    decoders)."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        shapes.append(LONG_500K)
    return tuple(shapes)


def skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 500k dense KV/attention is what this "
                "shape excludes (DESIGN.md §5)")
    return None
