"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab. [arXiv:2407.21783; unverified]"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128,
    rope_theta=500_000.0,
    source="arXiv:2407.21783 table 3; unverified",
)

SMOKE = ArchConfig(
    name="llama3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    source="reduced config, same family",
)
