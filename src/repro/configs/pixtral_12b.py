"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT frontend (STUB: input_specs provides patch
embeddings) + mistral-nemo decoder.
[hf:mistralai/Pixtral-12B-2409; unverified]"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1_000_000.0,
    frontend="vit_stub", frontend_tokens=1024,
    source="hf:mistralai/Pixtral-12B-2409 config.json; unverified",
)

SMOKE = ArchConfig(
    name="pixtral-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    frontend="vit_stub", frontend_tokens=8,
    source="reduced config, same family",
)
