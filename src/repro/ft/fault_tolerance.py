"""Fault tolerance & elasticity.

What a 1000+-node deployment of this framework does (and what of it is
implemented + tested here on host devices):

1. **Checkpoint/restart** (implemented, tested): deterministic data
   (`data/pipeline.py` is step-addressable) + atomic checkpoints
   (`ckpt/checkpoint.py`) + this module's `TrainSupervisor` give bit-exact
   resume after a kill at any step — the FT integration test kills a run
   mid-training and verifies the resumed run matches an uninterrupted one.

2. **Failure detection** (implemented, simulated): on a real cluster each
   host runs `Heartbeat` against its peers (here: an injectable clock +
   `FailureInjector` simulate silent host loss).  Missed beats ⇒ the
   supervisor declares the step epoch failed and triggers an elastic
   restart rather than hanging on a dead collective.

3. **Elastic re-mesh** (implemented, tested on host devices): restore the
   latest checkpoint onto a *smaller* mesh (`elastic_remesh`), re-shard
   every array via device_put with the new sharding, scale per-device
   batch so the global batch is preserved when divisible (else documented
   nearest-divisor fallback).

4. **Straggler mitigation** (implemented for the solver, designed for
   training): the solver engine rebalances EPS subproblem queues across
   lanes (`rebalance_lanes`); training-side mitigation = synchronous-step
   timeout + slow-host ejection through the same elastic path (no backup
   workers needed because steps are deterministic).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# heartbeat / failure detection (simulation-grade, injectable clock)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Heartbeat:
    """Tracks per-host liveness from beat timestamps."""
    hosts: List[str]
    timeout_s: float = 30.0
    clock: Callable[[], float] = time.time

    def __post_init__(self):
        now = self.clock()
        self.last_beat: Dict[str, float] = {h: now for h in self.hosts}

    def beat(self, host: str):
        self.last_beat[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last_beat.items()
                if now - t > self.timeout_s]

    def all_alive(self) -> bool:
        return not self.dead_hosts()


class FailureInjector:
    """Deterministic fault schedule for tests: {step: [host, ...]}."""

    def __init__(self, schedule: Dict[int, List[str]]):
        self.schedule = schedule
        self.failed: set = set()

    def advance(self, step: int, hb: Heartbeat):
        for h in self.schedule.get(step, []):
            self.failed.add(h)
        # failed hosts stop beating; everyone else beats
        for h in hb.hosts:
            if h not in self.failed:
                hb.beat(h)


# --------------------------------------------------------------------------
# elastic re-mesh
# --------------------------------------------------------------------------

def elastic_remesh(tree, new_mesh, shardings_fn):
    """Re-place a pytree onto a new (smaller/larger) mesh.

    `shardings_fn(new_mesh) -> shardings pytree` recomputes the logical →
    physical mapping for the surviving topology; device_put moves the
    bytes.  Works because shardings are derived from *logical* axes, not
    device ids (distributed/sharding.py)."""
    shardings = shardings_fn(new_mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def scaled_batch(global_batch: int, n_data_shards: int) -> int:
    """Per-shard batch after elastic rescale; exact when divisible, else
    the largest divisor-preserving value (recorded by the supervisor)."""
    if global_batch % n_data_shards == 0:
        return global_batch // n_data_shards
    return max(1, global_batch // n_data_shards)


# --------------------------------------------------------------------------
# training supervisor: crash-safe step loop
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TrainSupervisor:
    """Drives (restore → step* → checkpoint)* with failure handling.

    The step function must be deterministic in (params, opt, step) — the
    data pipeline being step-addressable makes resumed runs bit-exact.
    """
    checkpointer: "object"
    ckpt_every: int = 50
    heartbeat: Optional[Heartbeat] = None
    injector: Optional[FailureInjector] = None

    def run(self, params, opt_state, step_fn, n_steps: int,
            start_step: int = 0, on_failure: Optional[Callable] = None):
        step = start_step
        restored = self.checkpointer.restore()
        if restored is not None:
            step, p_np, o_np = restored
            params = jax.tree.map(lambda t, n: jnp.asarray(n).astype(t.dtype),
                                  params, p_np)
            opt_state = jax.tree.map(
                lambda t, n: jnp.asarray(n).astype(t.dtype), opt_state, o_np)
        metrics_log = []
        while step < n_steps:
            if self.injector is not None and self.heartbeat is not None:
                self.injector.advance(step, self.heartbeat)
                if not self.heartbeat.all_alive():
                    dead = self.heartbeat.dead_hosts()
                    if on_failure is not None:
                        return on_failure(dead, step, metrics_log)
                    raise RuntimeError(f"hosts lost at step {step}: {dead}")
            params, opt_state, metrics = step_fn(params, opt_state, step)
            step += 1
            metrics_log.append({k: float(v) for k, v in metrics.items()})
            if step % self.ckpt_every == 0 or step == n_steps:
                self.checkpointer.save(step, params, opt_state,
                                       background=True)
        self.checkpointer.wait()
        return params, opt_state, metrics_log


# --------------------------------------------------------------------------
# solver-side straggler mitigation (lane rebalance — beyond-paper)
# --------------------------------------------------------------------------

def rebalance_lanes(next_sub: np.ndarray, done: np.ndarray, n_subs: int,
                    n_lanes: int):
    """Host-side EPS queue rebalance: move unconsumed subproblem cursors
    from overloaded lanes to exhausted ones.  The paper's EPS assignment
    is static; this is the straggler-mitigation extension measured in
    §Perf (solver)."""
    remaining = np.maximum(0, (n_subs - next_sub + n_lanes - 1) // n_lanes)
    order = np.argsort(-remaining)
    idle = [i for i in order if done[i] or remaining[i] == 0]
    busy = [i for i in order if remaining[i] > 1]
    moved = 0
    for i in idle:
        if not busy:
            break
        donor = busy.pop(0)
        # steal the donor's last queued subproblem index
        last = next_sub[donor] + (remaining[donor] - 1) * n_lanes
        next_sub[i] = last
        done[i] = False
        remaining[donor] -= 1
        moved += 1
        if remaining[donor] > 1:
            busy.append(donor)
    return next_sub, done, moved
