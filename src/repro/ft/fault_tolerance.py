"""Fault tolerance & elasticity.

What a 1000+-node deployment of this framework does (and what of it is
implemented + tested here on host devices):

1. **Checkpoint/restart** (implemented, tested): deterministic data
   (`data/pipeline.py` is step-addressable) + atomic checkpoints
   (`ckpt/checkpoint.py`) + this module's `TrainSupervisor` give bit-exact
   resume after a kill at any step — the FT integration test kills a run
   mid-training and verifies the resumed run matches an uninterrupted one.

2. **Failure detection** (implemented, simulated): on a real cluster each
   host runs `Heartbeat` against its peers (here: an injectable clock +
   `FailureInjector` simulate silent host loss).  Missed beats ⇒ the
   supervisor declares the step epoch failed and triggers an elastic
   restart rather than hanging on a dead collective.

3. **Elastic re-mesh** (implemented, tested on host devices): restore the
   latest checkpoint onto a *smaller* mesh (`elastic_remesh`), re-shard
   every array via device_put with the new sharding, scale per-device
   batch so the global batch is preserved when divisible (else documented
   nearest-divisor fallback).

4. **Straggler mitigation** (implemented for the solver, designed for
   training): the solver engine rebalances EPS subproblem queues across
   lanes (`rebalance_lanes`); training-side mitigation = synchronous-step
   timeout + slow-host ejection through the same elastic path (no backup
   workers needed because steps are deterministic).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# heartbeat / failure detection (simulation-grade, injectable clock)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Heartbeat:
    """Tracks per-host liveness from beat timestamps."""
    hosts: List[str]
    timeout_s: float = 30.0
    clock: Callable[[], float] = time.time

    def __post_init__(self):
        now = self.clock()
        self.last_beat: Dict[str, float] = {h: now for h in self.hosts}

    def beat(self, host: str):
        self.last_beat[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last_beat.items()
                if now - t > self.timeout_s]

    def all_alive(self) -> bool:
        return not self.dead_hosts()


class FailureInjector:
    """Deterministic fault schedule for tests: {step: [host, ...]}."""

    def __init__(self, schedule: Dict[int, List[str]]):
        self.schedule = schedule
        self.failed: set = set()

    def advance(self, step: int, hb: Heartbeat):
        for h in self.schedule.get(step, []):
            self.failed.add(h)
        # failed hosts stop beating; everyone else beats
        for h in hb.hosts:
            if h not in self.failed:
                hb.beat(h)


# --------------------------------------------------------------------------
# elastic re-mesh
# --------------------------------------------------------------------------

def elastic_remesh(tree, new_mesh, shardings_fn):
    """Re-place a pytree onto a new (smaller/larger) mesh.

    `shardings_fn(new_mesh) -> shardings pytree` recomputes the logical →
    physical mapping for the surviving topology; device_put moves the
    bytes.  Works because shardings are derived from *logical* axes, not
    device ids (distributed/sharding.py)."""
    shardings = shardings_fn(new_mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def scaled_batch(global_batch: int, n_data_shards: int) -> int:
    """Per-shard batch after elastic rescale; exact when divisible, else
    the largest divisor-preserving value (recorded by the supervisor)."""
    if global_batch % n_data_shards == 0:
        return global_batch // n_data_shards
    return max(1, global_batch // n_data_shards)


# --------------------------------------------------------------------------
# training supervisor: crash-safe step loop
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TrainSupervisor:
    """Drives (restore → step* → checkpoint)* with failure handling.

    The step function must be deterministic in (params, opt, step) — the
    data pipeline being step-addressable makes resumed runs bit-exact.
    """
    checkpointer: "object"
    ckpt_every: int = 50
    heartbeat: Optional[Heartbeat] = None
    injector: Optional[FailureInjector] = None

    def run(self, params, opt_state, step_fn, n_steps: int,
            start_step: int = 0, on_failure: Optional[Callable] = None):
        step = start_step
        restored = self.checkpointer.restore()
        if restored is not None:
            step, p_np, o_np = restored
            params = jax.tree.map(lambda t, n: jnp.asarray(n).astype(t.dtype),
                                  params, p_np)
            opt_state = jax.tree.map(
                lambda t, n: jnp.asarray(n).astype(t.dtype), opt_state, o_np)
        metrics_log = []
        while step < n_steps:
            if self.injector is not None and self.heartbeat is not None:
                self.injector.advance(step, self.heartbeat)
                if not self.heartbeat.all_alive():
                    dead = self.heartbeat.dead_hosts()
                    if on_failure is not None:
                        return on_failure(dead, step, metrics_log)
                    raise RuntimeError(f"hosts lost at step {step}: {dead}")
            params, opt_state, metrics = step_fn(params, opt_state, step)
            step += 1
            metrics_log.append({k: float(v) for k, v in metrics.items()})
            if step % self.ckpt_every == 0 or step == n_steps:
                self.checkpointer.save(step, params, opt_state,
                                       background=True)
        self.checkpointer.wait()
        return params, opt_state, metrics_log


# --------------------------------------------------------------------------
# solver-side elastic remesh (distributed EPS engine, DESIGN.md §14)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceLoss:
    """Deterministic fault schedule for the distributed EPS solver
    (core/dist_solve.py): after ``at_chunk`` completed host chunks,
    shard ``shard`` is declared dead.  The loss is *detected* by the same
    Heartbeat/FailureInjector pair the training supervisor uses (hosts
    are named ``shard<d>``), and *recovered* by `solver_shard_loss` —
    the solver analogue of `elastic_remesh`."""
    at_chunk: int
    shard: int


class LogicalClock:
    """Chunk-counter clock for the solver heartbeat: the solve loop
    advances ``t`` once per host chunk, so a shard that misses one beat
    is declared dead at the *next chunk boundary* — the solver analogue
    of the training supervisor's wall-clock timeout, without making
    fault detection latency depend on real time in tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def solver_heartbeat(n_shards: int, loss: Optional[DeviceLoss]):
    """(Heartbeat, FailureInjector) pair watching the solve's shards.
    With ``loss=None`` the injector schedule is empty — every shard
    beats forever.  The heartbeat runs on a `LogicalClock` (exposed as
    ``hb.clock``) that the solve loop ticks once per chunk."""
    hosts = [f"shard{d}" for d in range(n_shards)]
    schedule = ({loss.at_chunk: [f"shard{loss.shard}"]}
                if loss is not None else {})
    hb = Heartbeat(hosts=hosts, timeout_s=0.5, clock=LogicalClock())
    return hb, FailureInjector(schedule)


def solver_shard_loss(snapshot: dict, lost: int) -> dict:
    """Recover a distributed solve from the loss of shard ``lost``,
    given the last chunk-boundary ``snapshot`` (host-side numpy views,
    leading axis = shard):

    * ``state``    — pytree of lane state, each leaf ``[D, L, ...]``
    * ``owned``    — per-shard lists of undispatched pool ids
    * ``inflight`` — per-shard ``(root_lb, root_ub)`` rows of lanes that
      are mid-DFS (loaded a subproblem, not yet done)

    Returns the survivor view: the lost shard's lane state is dropped
    (its rows are unrecoverable device memory), while everything the
    host can reconstruct from the checkpoint is requeued — its
    undispatched pool slice verbatim, plus the *root* stores of its
    in-flight subproblems (re-exploring part of a subtree is sound: DFS
    over a pool partition finds the same optimum, it just repeats
    nodes).  The incumbent is NOT taken from the lost shard's device
    state — callers must fold in the host-side incumbent checkpoint
    streamed at every chunk boundary (api.solve_iter's anytime
    contract), which is exactly what survives a crash on a real mesh.
    """
    D = len(snapshot["owned"])
    keep = [d for d in range(D) if d != lost]
    state = jax.tree.map(lambda x: np.asarray(x)[keep], snapshot["state"])
    owned = [list(snapshot["owned"][d]) for d in keep]
    requeue_ids = sorted(snapshot["owned"][lost])
    lost_lb, lost_ub = snapshot["inflight"][lost]
    return dict(state=state, owned=owned, requeue_ids=requeue_ids,
                requeue_roots=(np.asarray(lost_lb), np.asarray(lost_ub)),
                survivors=keep)


# --------------------------------------------------------------------------
# solver-side straggler mitigation (lane rebalance — beyond-paper)
# --------------------------------------------------------------------------

def rebalance_lanes(next_sub: np.ndarray, done: np.ndarray, n_subs: int,
                    n_lanes: int):
    """Host-side EPS queue rebalance: move unconsumed subproblem cursors
    from overloaded lanes to exhausted ones.  The paper's EPS assignment
    is static; this is the straggler-mitigation extension measured in
    §Perf (solver)."""
    remaining = np.maximum(0, (n_subs - next_sub + n_lanes - 1) // n_lanes)
    order = np.argsort(-remaining)
    idle = [i for i in order if done[i] or remaining[i] == 0]
    busy = [i for i in order if remaining[i] > 1]
    moved = 0
    for i in idle:
        if not busy:
            break
        donor = busy.pop(0)
        # steal the donor's last queued subproblem index
        last = next_sub[donor] + (remaining[donor] - 1) * n_lanes
        next_sub[i] = last
        done[i] = False
        remaining[donor] -= 1
        moved += 1
        if remaining[donor] > 1:
            busy.append(donor)
    return next_sub, done, moved
