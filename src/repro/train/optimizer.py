"""AdamW with global-norm clipping and cosine / WSD schedules.

Built from scratch (no optax in this environment).  WSD (warmup — stable
— decay) is the MiniCPM schedule (arXiv:2404.06395): linear warmup,
long constant plateau, short exponential-ish decay tail.

Optimizer state is a flat dict mirror of params (f32 moments), so it
shards with the same logical axes as the parameters (ZeRO-style: moments
inherit the param sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # "cosine" | "wsd" | "const"
    wsd_decay_frac: float = 0.1       # last 10% of steps decay (MiniCPM)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moe_balance_weight: float = 0.01


def learning_rate(step, cfg: OptConfig):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        decay_start = 1.0 - cfg.wsd_decay_frac
        in_decay = t > decay_start
        d = (t - decay_start) / jnp.maximum(cfg.wsd_decay_frac, 1e-9)
        frac = jnp.where(in_decay,
                         cfg.min_lr_frac ** jnp.clip(d, 0, 1), 1.0)
    elif cfg.schedule == "const":
        frac = jnp.ones(())
    else:
        raise ValueError(cfg.schedule)
    return cfg.peak_lr * warm * frac


def init_opt_state(params) -> Dict:
    zeros = {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()}
    return {"mu": zeros,
            "nu": {k: jnp.zeros(v.shape, jnp.float32)
                   for k, v in params.items()},
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _decay_mask(path: str) -> bool:
    """No weight decay on norms/biases/scalars (standard)."""
    leaf = path.rsplit("/", 1)[-1]
    return not (("norm" in leaf) or leaf.endswith("_b")
                or leaf in ("b_a", "b_x", "a_param", "A_log", "D",
                            "dt_bias", "conv_b"))


def apply_updates(params: Dict, grads: Dict, state: Dict, cfg: OptConfig
                  ) -> Tuple[Dict, Dict, Dict]:
    """One AdamW step. Returns (params', state', metrics)."""
    step = state["step"] + 1
    lr = learning_rate(step, cfg)

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_p, new_mu, new_nu = {}, {}, {}
    for k, p in params.items():
        g = grads[k].astype(jnp.float32) * scale
        mu = b1 * state["mu"][k] + (1 - b1) * g
        nu = b2 * state["nu"][k] + (1 - b2) * g * g
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if cfg.weight_decay > 0 and _decay_mask(k):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p[k] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        new_mu[k] = mu
        new_nu[k] = nu

    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
