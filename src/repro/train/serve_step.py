"""Serving steps: prefill (prompt → caches) and decode (one token/step,
greedy or temperature sampling).  These are the functions the dry-run
lowers for the `prefill_*` / `decode_*` / `long_*` shapes.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import model as MD


def prefill_step(params, cfg: ArchConfig, batch: Dict, smax: int,
                 chunks=(1024, 1024)):
    """Returns (first generated token [B], caches)."""
    logits, caches = MD.forward_prefill(params, cfg, batch, smax,
                                        chunks=chunks)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches


def decode_step(params, cfg: ArchConfig, tokens, caches,
                temperature: float = 0.0, rng: Optional[jax.Array] = None,
                chunks=(1, 1024)):
    """tokens [B, 1] → (next token [B], caches', logits [B, V])."""
    logits, caches = MD.forward_decode(params, cfg, tokens, caches,
                                       chunks=chunks)
    if temperature > 0 and rng is not None:
        nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
    else:
        nxt = jnp.argmax(logits, axis=-1)
    return nxt.astype(jnp.int32), caches, logits


def generate(params, cfg: ArchConfig, batch: Dict, steps: int, smax: int,
             temperature: float = 0.0, seed: int = 0,
             chunks=(1024, 1024)):
    """Greedy/sampled generation loop (host-side; serving example)."""
    tok, caches = prefill_step(params, cfg, batch, smax, chunks=chunks)
    out = [tok]
    rng = jax.random.PRNGKey(seed)
    for i in range(steps - 1):
        rng, sub = jax.random.split(rng)
        tok, caches, _ = decode_step(params, cfg, tok[:, None], caches,
                                     temperature=temperature, rng=sub,
                                     chunks=(1, chunks[1]))
        out.append(tok)
    return jnp.stack(out, axis=1)                 # [B, steps]
