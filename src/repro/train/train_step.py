"""Loss + train step (pure functions; pjit-able with shardings applied by
the launcher / dry-run).

Loss: next-token cross-entropy over `labels` (-1 = ignore), computed in
f32 with logsumexp; MoE balance aux added with a configurable weight.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import model as MD
from repro.train.optimizer import OptConfig, apply_updates


def cross_entropy(logits, labels):
    """logits [B,S,V] f32, labels [B,S] int (-1 = ignore)."""
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def loss_fn(params, cfg: ArchConfig, batch: Dict, opt_cfg: OptConfig,
            remat: bool = True, chunks=(1024, 1024)):
    logits, aux = MD.forward_train(params, cfg, batch, remat=remat,
                                   chunks=chunks)
    loss = cross_entropy(logits, batch["labels"])
    total = loss
    if "moe_balance" in aux:
        total = total + opt_cfg.moe_balance_weight * aux["moe_balance"]
    metrics = {"loss": loss, **{k: v for k, v in aux.items()}}
    return total, metrics


def train_step(params, opt_state, batch, cfg: ArchConfig,
               opt_cfg: OptConfig, remat: bool = True,
               chunks=(1024, 1024), microbatches: int = 1):
    """One optimizer step. Grad reductions across data shards happen
    implicitly through pjit output shardings.

    ``microbatches > 1``: gradient accumulation via `lax.scan` — activation
    (and MoE dispatch) memory divides by the microbatch count at the cost
    of one params-sized f32 accumulator (§Perf hillclimb C3)."""
    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b, opt_cfg, remat, chunks),
        has_aux=True)

    if microbatches <= 1:
        (total, metrics), grads = grad_fn(params, batch)
    else:
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def acc_step(carry, mbatch):
            gacc, tacc = carry
            (t, m), g = grad_fn(params, mbatch)
            gacc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), gacc, g)
            return (gacc, tacc + t), m

        gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, total), ms = jax.lax.scan(acc_step, (gacc0, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        total = total / microbatches
        metrics = jax.tree.map(lambda v: v.mean(), ms)

    params, opt_state, opt_metrics = apply_updates(params, grads, opt_state,
                                                   opt_cfg)
    return params, opt_state, {**metrics, **opt_metrics, "total": total}
