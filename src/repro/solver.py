"""``repro.solver`` — the public solver surface (DESIGN.md §11).

One import serves the whole serving story::

    from repro import solver

    cfg = solver.SolveConfig.preset("prove", backend="pallas")
    sess = solver.Solver(cfg)
    res = sess.solve(cm)                 # cold: compiles the chunk runner
    res2 = sess.solve(cm2)               # warm: same shapes, no compile
    results = sess.solve_many(cms)       # N instances, ONE device dispatch
    for ev in sess.solve_iter(cm):       # anytime incumbent stream
        print(ev.superstep, ev.best_objective)

Module-level `solve` / `solve_many` / `solve_iter` use a process-wide
default session, so casual callers still amortize compilation.  The
legacy ``repro.core.engine.solve`` is a deprecation shim over this
module.
"""

from repro.core.api import (  # noqa: F401
    OPTIMAL, SAT, UNSAT, UNKNOWN,
    PRESETS, SolveConfig, Solver,
    SolveResult, Progress, Improvement,
    default_solver, derive_result, shape_signature,
    solve, solve_iter, solve_many,
)

__all__ = [
    "OPTIMAL", "SAT", "UNSAT", "UNKNOWN",
    "PRESETS", "SolveConfig", "Solver",
    "SolveResult", "Progress", "Improvement",
    "default_solver", "derive_result", "shape_signature",
    "solve", "solve_iter", "solve_many",
]
