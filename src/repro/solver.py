"""``repro.solver`` — the public solver surface (DESIGN.md §11).

One import serves the whole serving story::

    from repro import solver

    cfg = solver.SolveConfig.preset("prove", backend="pallas")
    sess = solver.Solver(cfg)
    res = sess.solve(cm)                 # cold: compiles the chunk runner
    res2 = sess.solve(cm2)               # warm: same shapes, no compile
    results = sess.solve_many(cms)       # N instances, ONE device dispatch
    for ev in sess.solve_iter(cm):       # anytime incumbent stream
        print(ev.superstep, ev.best_objective)

Module-level `solve` / `solve_many` / `solve_iter` use a process-wide
default session, so casual callers still amortize compilation.  The
legacy ``repro.core.engine.solve`` is a deprecation shim over this
module.

`LaneBatch` (via `Solver.lane_batch`) is the continuous-batching
primitive underneath `solve_many`: a fixed-width compiled batch whose
slots independent requests join (`splice`) and leave (`retire`) at chunk
boundaries without recompiling.  The request-queue scheduler built on it
lives in `repro.serve` (DESIGN.md §15).
"""

from repro.core.api import (  # noqa: F401
    OPTIMAL, SAT, UNSAT, UNKNOWN,
    PRESETS, SolveConfig, Solver,
    SolveResult, Progress, Improvement,
    BatchSnapshot, LaneBatch,
    default_solver, derive_result, shape_signature,
    solve, solve_iter, solve_many,
)

__all__ = [
    "OPTIMAL", "SAT", "UNSAT", "UNKNOWN",
    "PRESETS", "SolveConfig", "Solver",
    "SolveResult", "Progress", "Improvement",
    "BatchSnapshot", "LaneBatch",
    "default_solver", "derive_result", "shape_signature",
    "solve", "solve_iter", "solve_many",
]
