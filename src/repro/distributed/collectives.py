"""Collective helpers: compressed gradient all-reduce + overlap notes.

`int8_psum_mean` implements the standard 1-byte gradient compression for
cross-pod data parallelism: per-tensor symmetric int8 quantization with a
psum-max shared scale, integer all-reduce, dequantize.  4× less ICI
traffic than f32 (2× vs bf16) on the pod axis, with bounded error
(≤ scale/2 per element before averaging; the test asserts it).

Intended placement (multi-pod training): within-pod reductions stay
exact (pjit-inserted, high-bandwidth ICI); only the *pod* axis — the
slow DCN/optical hop on a real 2-pod system — uses compression:

    grads = pod_sync_grads(grads, axis="pod", compress=True)

Overlap: XLA's latency-hiding scheduler already interleaves the
per-layer FSDP all-gathers with compute inside the scan (visible in the
dry-run HLO as async-start/done pairs on TPU); nothing manual needed for
the baseline.  The explicit shard_map region here is for the pod hop
that pjit would otherwise fold into one big synchronous reduce.
"""

from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax


def all_min(x: jax.Array, axis_name) -> jax.Array:
    """Min-reduce across a mesh axis (or tuple of axes) — the anytime
    B&B bound share of DESIGN.md §9/§14.  A thin named wrapper over
    ``lax.pmin`` so the solver's cross-shard traffic is auditable in one
    place (and countable by the distributed bench)."""
    return lax.pmin(x, axis_name)


def all_any(flag: jax.Array, axis_name) -> jax.Array:
    """Boolean OR across a mesh axis (pmax on the int embedding)."""
    return lax.pmax(flag.astype(jnp.int32), axis_name) == 1


def all_every(flag: jax.Array, axis_name) -> jax.Array:
    """Boolean AND across a mesh axis (pmin on the int embedding)."""
    return lax.pmin(flag.astype(jnp.int32), axis_name) == 1


def solver_bound_sync(best, done, any_sol, axis_name):
    """One bound-sharing round for the distributed EPS engine
    (DESIGN.md §14): the global incumbent bound is the min over shards,
    the pool is globally exhausted only when EVERY shard is done, and a
    solution exists anywhere iff SOME shard has one.  Runs once per
    superstep inside the sharded chunk body (`api._chunk_body`), so all
    lanes on all devices prune against the best objective found
    anywhere — TURBO's global-memory best-bound cell, stretched over the
    mesh."""
    return (all_min(best, axis_name), all_every(done, axis_name),
            all_any(any_sol, axis_name))


def int8_psum_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over `axis_name` with int8-compressed payload.

    Scale is the psum-max of |x| so every participant quantizes into the
    same grid (required for exact integer summation semantics).
    """
    absmax = lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    # sum in int32 (n ≤ 2^24 participants fits easily)
    s = lax.psum(q.astype(jnp.int32), axis_name)
    n = lax.psum(jnp.ones((), jnp.int32), axis_name)
    return (s.astype(jnp.float32) * scale) / n.astype(jnp.float32)


def psum_mean(x: jax.Array, axis_name: str) -> jax.Array:
    n = lax.psum(jnp.ones((), x.dtype), axis_name)
    return lax.psum(x, axis_name) / n


def pod_sync_grads(grads: Dict, mesh, axis: str = "pod",
                   compress: bool = True, specs=None) -> Dict:
    """Average a grad pytree across the `axis` mesh dimension.

    `specs` (pytree of PartitionSpec, default fully-replicated) describes
    how each leaf is laid out over the *other* mesh axes; only the pod
    replica dimension is reduced.  With `compress`, payloads cross the
    pod link as int8.
    """
    if axis not in mesh.shape:
        return grads
    op = int8_psum_mean if compress else psum_mean
    P_ = jax.sharding.PartitionSpec

    def sync_leaf(g, spec):
        from repro.compat import shard_map
        fn = shard_map(
            partial(op, axis_name=axis),
            mesh=mesh, in_specs=spec, out_specs=spec,
            check_vma=False)
        return fn(g).astype(g.dtype)

    if specs is None:
        specs = jax.tree.map(lambda _: P_(), grads)
    return jax.tree.map(sync_leaf, grads, specs)
