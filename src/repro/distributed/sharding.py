"""Logical-axis sharding rules → NamedShardings.

Every parameter/cache/activation dimension carries a *logical* axis name
(ParamSpec.axes, cache_axes below); a rule table maps logical names to
mesh axes per execution mode.  `spec_for` drops any mapping that does not
divide the concrete dimension (e.g. batch=1 in long_500k), so one rule
table covers all 40 cells.

train (ZeRO-3 + TP):            serve (TP + EP, no ZeRO gather latency):
  batch  → (pod, data)            batch  → (pod, data) when divisible
  embed  → data   (FSDP shard)    embed  → —       (params replicated
  vocab  → model                  vocab  → model    across data; big-MoE
  heads/kv/mlp → model            heads/kv/mlp → model  experts → data)
  expert → —  (d/ff already       expert → data
           sharded both ways)
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Tuple[str, ...]]

TRAIN_RULES: Rules = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "embed": ("data",),          # ZeRO-3/FSDP shard of every weight matrix
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": (),
    "layers": (),
    "seq": (),
    "kv_heads": (),
    "head_dim": (),
}

SERVE_RULES: Rules = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "embed": (),                 # replicated: no per-layer gather at decode
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("data",),         # big-MoE weights: expert-parallel rows
    "layers": (),
    "seq": (),
    "kv_heads": ("model",),
    "head_dim": (),
}


# distributed EPS solver (core/dist_solve.py, DESIGN.md §14): the lane
# batch and the subproblem pool both shard over the 1-D `solve` axis;
# everything else (model tables, scalar bounds/flags) is replicated.
SOLVE_RULES: Rules = {
    "lanes": ("solve",),
    "pool": ("solve",),
}


def rules_for(mode: str) -> Rules:
    return TRAIN_RULES if mode == "train" else SERVE_RULES


def dist_solve_specs(state, n_pool: int, mesh: Mesh,
                     rules: Optional[Rules] = None):
    """PartitionSpecs for one distributed-solve chunk call
    (DESIGN.md §14): ``(pool_spec, carry_spec)`` where the carry is
    ``(lane_state, gbest, gdone, it, pool_heads)``.

    Derived through the same logical-axis rule table as the NN side
    (`spec_for` drops any non-dividing assignment), so a pool or lane
    count that does not divide the mesh degrades to replication instead
    of an invalid sharding — callers pad first (`eps.pad_pool`) to keep
    the shards real.
    """
    rules = rules or SOLVE_RULES

    def lane_leaf(x):
        axes = ("lanes",) + (None,) * (x.ndim - 1)
        return spec_for(tuple(x.shape), axes, rules, mesh)

    state_spec = jax.tree.map(lane_leaf, state)
    pool_spec = spec_for((n_pool, 1), ("pool", None), rules, mesh)
    n_dev = int(np.prod([mesh.shape[a] for a in rules["lanes"]
                         if a in mesh.shape]))
    heads_spec = spec_for((n_dev,), ("lanes",), rules, mesh)
    carry_spec = (state_spec, P(), P(), P(), heads_spec)
    return pool_spec, carry_spec


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             rules: Rules, mesh: Mesh) -> P:
    """PartitionSpec with divisibility-checked axis assignment."""
    entries = []
    used = set()
    for dim, ax in zip(shape, axes):
        mesh_axes = tuple(a for a in rules.get(ax or "", ())
                          if a in mesh.shape and a not in used)
        size = int(np.prod([mesh.shape[a] for a in mesh_axes])) \
            if mesh_axes else 1
        if mesh_axes and dim % size == 0 and dim > 0:
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
            used.update(mesh_axes)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shardings_for_specs(param_specs, rules: Rules, mesh: Mesh):
    """{path: NamedSharding} for a ParamSpec dict."""
    return {k: NamedSharding(mesh, spec_for(s.shape, s.axes, rules, mesh))
            for k, s in param_specs.items()}


def sharding_for_tree(shapes_tree, axes_tree, rules: Rules, mesh: Mesh):
    """NamedShardings for an arbitrary (shapes, axes) pytree pair."""
    return jax.tree.map(
        lambda sh, ax: NamedSharding(mesh, spec_for(sh, ax, rules, mesh)),
        shapes_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, (int, str, type(None))) for i in x))


def batch_sharding(batch_tree, rules: Rules, mesh: Mesh):
    """Inputs: dim 0 = batch, rest unsharded."""
    def one(x):
        shape = x.shape
        axes = ("batch",) + (None,) * (len(shape) - 1)
        return NamedSharding(mesh, spec_for(shape, axes, rules, mesh))
    return jax.tree.map(one, batch_tree)


# -- cache shardings (field-name keyed; mirrors nn/model.py structures) -----

CACHE_RULES_SERVE: Rules = {
    **SERVE_RULES,
    # latent/feature dims shard over model (MLA latent attention and
    # head_dim contractions partial-sum + all-reduce under SPMD); when
    # kv_heads doesn't divide the model axis, head_dim picks it up.
    "embed_cache": ("model",),
    "head_dim": ("model",),
    "state": (),                 # ssm state dim
    "heads": ("model",),
}

# per cache field, axes WITHOUT the optional leading "layers" (added by
# rank).  `spec_for` drops any non-dividing assignment, so odd shapes
# degrade to replication, never to an invalid sharding.
_CACHE_FIELD_AXES = {
    "k": ("batch", "seq", "kv_heads", "head_dim"),
    "v": ("batch", "seq", "kv_heads", "head_dim"),
    "cross_k": ("batch", "seq", "kv_heads", "head_dim"),
    "cross_v": ("batch", "seq", "kv_heads", "head_dim"),
    "c_kv": ("batch", "seq", "embed_cache"),
    "k_rope": ("batch", "seq", "embed_cache"),
    "conv": ("batch", "seq", "mlp"),
    "length": (),
}
_H_SSM = ("batch", "heads", "head_dim", "state")   # mamba2 state
_H_LRU = ("batch", "mlp")                          # rg-lru hidden


def cache_shardings(cfg, caches, mesh, rules=None):
    rules = rules or CACHE_RULES_SERVE

    def one(path, x):
        name = None
        for p in reversed(path):
            n = getattr(p, "name", getattr(p, "key", None))
            if isinstance(n, str) and (n in _CACHE_FIELD_AXES or n == "h"):
                name = n
                break
        shape = tuple(x.shape)
        if name == "h":
            ax = _H_SSM if len(shape) >= 4 else _H_LRU
        elif name is not None:
            ax = _CACHE_FIELD_AXES[name]
        else:
            ax = ()
        if len(shape) == len(ax) + 1:
            ax = ("layers",) + ax
        ax = ax[:len(shape)] if len(ax) >= len(shape) else \
            ax + (None,) * (len(shape) - len(ax))
        return NamedSharding(mesh, spec_for(shape, ax, rules, mesh))

    return jax.tree_util.tree_map_with_path(one, caches)
