"""Constraint-based distributed planning — PCCP inside the LM framework.

The paper's contribution (a deterministic parallel constraint solver) is
used here as the framework's *planning engine*:

1. `plan_partition` — layer → pipeline-stage assignment: contiguous
   partition of L layers into P stages minimizing the bottleneck stage
   cost under a per-stage memory cap.  Modelled with monotone stage
   indices g_i (g_i ≤ g_{i+1} ≤ g_i + 1) and reified membership booleans
   b_{ik} ⇔ (g_i = k) — all lowered to the same reified-linear propagators
   as RCPSP.

2. `schedule_microbatches` — pipeline round scheduling IS an RCPSP: tasks
   are (microbatch, stage) pairs, precedence (m,s) ≪ (m,s+1), each stage
   is a unit-capacity renewable resource.  The solver's min-makespan
   schedule reproduces 1F1B-style interleaving without hand-coding it.

Both run on the exact engine validated against the paper (core/engine.py),
so planning inherits its determinism guarantees (Thm 6): every host
computes the same plan from the same inputs — no coordinator needed.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import solver
from repro.core import search as S
from repro.core.model import Model
from repro.core.models import rcpsp


def plan_partition(layer_costs: Sequence[int], layer_mems: Sequence[int],
                   n_stages: int, mem_cap: int,
                   timeout_s: float = 60.0) -> Tuple[List[int], int]:
    """Contiguous layer→stage assignment minimizing the max stage cost.

    Returns (stage_of_layer, bottleneck_cost). Raises if infeasible.
    """
    L, P = len(layer_costs), n_stages
    m = Model("partition")
    g = [m.int_var(0, P - 1, f"g{i}") for i in range(L)]
    m.add(g[0] <= 0)                       # first layer in stage 0
    m.add(g[L - 1] >= P - 1)               # last layer in the last stage
    for i in range(L - 1):
        m.add(g[i] <= g[i + 1])            # monotone
        m.add(g[i + 1] <= g[i] + 1)        # contiguous, no empty stages
    T = m.int_var(max(layer_costs), int(sum(layer_costs)), "T")
    b = [[None] * P for _ in range(L)]
    for i in range(L):
        for k in range(P):
            bik = m.bool_var(f"b{i}_{k}")
            b[i][k] = bik
            m.iff_and(bik, [g[i] <= k, -g[i] <= -k])    # b ⇔ (g_i == k)
    for k in range(P):
        m.add(sum(int(layer_costs[i]) * b[i][k] for i in range(L)) <= T)
        m.add(sum(int(layer_mems[i]) * b[i][k] for i in range(L))
              <= int(mem_cap))
    m.minimize(T)
    m.branch_on(g + [T])
    res = solver.solve(m.compile(), config=solver.SolveConfig(
        n_lanes=16, eps_target=64, var_strategy=S.INPUT_ORDER,
        max_depth=1024, timeout_s=timeout_s))
    if res.solution is None:
        raise ValueError(f"no feasible partition ({res.status}): "
                         f"mem_cap={mem_cap} too tight?")
    stages = [int(res.solution[v.idx]) for v in g]
    return stages, int(res.objective)


def schedule_microbatches(stage_costs: Sequence[int], n_microbatches: int,
                          timeout_s: float = 60.0):
    """Pipeline round schedule as RCPSP. Returns (start[m][s], makespan).

    Tasks: (m, s) with duration stage_costs[s]; precedence (m,s)≪(m,s+1);
    resource: one unit-capacity resource per stage.
    """
    Sn = len(stage_costs)
    M = n_microbatches
    n = M * Sn
    tid = lambda mb, st: mb * Sn + st            # noqa: E731
    dur = np.array([stage_costs[t % Sn] for t in range(n)], dtype=np.int64)
    prec = [(tid(mb, st), tid(mb, st + 1))
            for mb in range(M) for st in range(Sn - 1)]
    usage = np.zeros((Sn, n), dtype=np.int64)
    for t in range(n):
        usage[t % Sn, t] = 1
    cap = np.ones(Sn, dtype=np.int64)
    inst = rcpsp.RCPSP(durations=dur, precedences=prec, usage=usage,
                       capacity=cap, name=f"pipe-{Sn}x{M}")
    model, handles = rcpsp.build_model(inst)
    res = solver.solve(model.compile(), config=solver.SolveConfig(
        n_lanes=16, eps_target=64, var_strategy=S.MIN_LB,
        max_depth=2048, timeout_s=timeout_s))
    if res.solution is None:
        raise RuntimeError(f"scheduler failed: {res.status}")
    starts = [[int(res.solution[handles["s"][tid(mb, st)].idx])
               for st in range(Sn)] for mb in range(M)]
    return starts, int(res.objective), res


def plan_steal(owned: Sequence[Sequence[int]], n_shards: int
               ) -> Tuple[List[List[int]], int]:
    """Work-stealing plan for the distributed EPS engine (DESIGN.md §14):
    repartition the undispatched subproblem ids over ``n_shards`` so
    shard loads are balanced to within one entry, moving as few entries
    as possible (a shard keeps its own ids up to its quota before any
    surplus migrates to deficit shards).

    Deterministic in its inputs — like the rest of this module, every
    host computes the same plan from the same cursor snapshot, so no
    coordinator is needed.  Returns ``(assignment, n_moved)`` where
    ``assignment[d]`` is the id list shard ``d`` owns after the steal.

    ``n_shards`` may differ from ``len(owned)``: the elastic-remesh path
    (ft/fault_tolerance.py) replans a lost shard's slice over the
    surviving shard count with the same function.
    """
    total = sum(len(o) for o in owned)
    base, extra = divmod(total, n_shards)
    quota = [base + (1 if d < extra else 0) for d in range(n_shards)]
    assignment: List[List[int]] = [[] for _ in range(n_shards)]
    surplus: List[int] = []
    for d in range(n_shards):
        own = sorted(owned[d]) if d < len(owned) else []
        assignment[d] = own[:quota[d]]
        surplus.extend(own[quota[d]:])
    # a shrinking remesh folds the dropped shards' ids into the surplus
    surplus.extend(x for o in owned[n_shards:] for x in sorted(o))
    surplus.sort()
    moved = len(surplus)
    for d in range(n_shards):
        need = quota[d] - len(assignment[d])
        if need > 0:
            assignment[d].extend(surplus[:need])
            del surplus[:need]
    assert not surplus, "plan_steal: quota bookkeeping broke"
    return assignment, moved


def pipeline_efficiency(stage_costs: Sequence[int], makespan: int,
                        n_microbatches: int) -> float:
    """Schedule quality vs the pipeline lower bound
    Σcosts + (M−1)·max — 1.0 means a perfectly packed pipeline."""
    ideal = sum(stage_costs) + (n_microbatches - 1) * max(stage_costs)
    return ideal / makespan if makespan else 0.0
