"""JAX API compatibility shim (single import point for drifted APIs).

The repo targets the *installed* JAX (currently 0.4.37 in this container)
while staying forward-compatible with the 0.5+/0.6+ API renames that the
code was originally written against.  Everything that drifted lives here,
and the rest of the codebase imports these names instead of reaching into
``jax.sharding`` / ``jax.experimental`` directly:

=====================  =========================  =========================
name here              modern JAX (≥ 0.6)         legacy JAX (0.4.x)
=====================  =========================  =========================
``AxisType``           ``jax.sharding.AxisType``  local enum stand-in
``make_mesh``          ``jax.make_mesh(...,       ``jax.make_mesh`` without
                       axis_types=...)``          ``axis_types``
``get_abstract_mesh``  ``jax.sharding.            ``thread_resources.env.
                       get_abstract_mesh()``      physical_mesh`` (set by
                                                  the ``with mesh:`` ctx)
``use_mesh``           ``jax.sharding.use_mesh``  the `Mesh` object itself
                       / ``jax.set_mesh``         (Mesh is a context mgr)
``shard_map``          ``jax.shard_map(...,       ``jax.experimental.
                       check_vma=...)``           shard_map.shard_map(...,
                                                  check_rep=...)``
``cost_analysis``      dict-valued                one-element list of dicts
=====================  =========================  =========================

Minimum supported JAX: **0.4.37** (see README §Requirements).  All shims
are resolved once at import; the fallbacks use only APIs present in every
version in the supported range.
"""

from __future__ import annotations

import enum
import inspect

import jax

MIN_JAX = "0.4.37"


# -- AxisType ---------------------------------------------------------------

if hasattr(jax.sharding, "AxisType"):           # modern JAX
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` on legacy JAX.

        Legacy meshes have no user-facing axis-type concept (everything
        behaves like ``Auto``), so these values are accepted and
        discarded by `make_mesh`.
        """
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` that tolerates the ``axis_types`` kwarg drift.

    On modern JAX the axis types are forwarded; on legacy JAX (where all
    mesh axes are implicitly auto-sharded) they are dropped.
    """
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and _MAKE_MESH_AXIS_TYPES:
        kw["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


# -- ambient mesh -----------------------------------------------------------

def get_abstract_mesh():
    """The ambient mesh set by `use_mesh` (or None when there is none).

    Modern JAX tracks an abstract mesh; legacy JAX tracks the physical
    mesh of the active ``with mesh:`` context.  Callers only rely on the
    returned object having ``.axis_names`` (possibly empty).
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh_lib
    env_mesh = _mesh_lib.thread_resources.env.physical_mesh
    return None if env_mesh.empty else env_mesh


def use_mesh(mesh):
    """Context manager making `mesh` ambient (for bare-PartitionSpec
    ``with_sharding_constraint`` and friends) across JAX versions."""
    fn = getattr(jax.sharding, "use_mesh", None)
    if fn is not None:
        return fn(mesh)
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh        # legacy: Mesh is itself the context manager


# -- compiled-artifact introspection ----------------------------------------

def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as one flat dict across JAX versions
    (legacy JAX returns a one-element list of per-program dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


# -- shard_map --------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    """``jax.shard_map`` across the experimental→public move.

    The replication-check kwarg was renamed ``check_rep`` → ``check_vma``;
    pass the modern name here and it is translated when running on legacy
    JAX.
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return native(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _legacy
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   **kw)
