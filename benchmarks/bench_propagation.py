"""Propagation-throughput microbenchmark (the paper's core claim:
propagation parallelizes).

Measures fixpoint throughput (propagator-executions/sec) of the batched
engine as the lane count grows — the CPU-visible analogue of filling GPU
SMs with blocks.  Near-flat time per sweep as lanes grow ⇒ the work
vectorizes, which is what TURBO exploits on real parallel hardware.
Compares gather sweep / scatter oracle / Pallas (interpret) kernels.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models import rcpsp
from repro.kernels import ops


def bench(cm, lbs, ubs, impl: str, iters: int = 5, **kw) -> float:
    f = lambda: ops.batched_fixpoint(cm, lbs, ubs, impl=impl, **kw)  # noqa
    jax.block_until_ready(f())                       # compile
    t0 = time.time()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-tasks", type=int, default=10)
    ap.add_argument("--lanes", type=int, nargs="+",
                    default=[1, 8, 32, 128])
    ap.add_argument("--skip-pallas", action="store_true")
    args = ap.parse_args(argv)

    inst = rcpsp.generate(args.n_tasks, n_resources=4, seed=0)
    m, _ = rcpsp.build_model(inst)
    cm = m.compile()
    rng = np.random.default_rng(0)

    rows = ["impl,lanes,ms_per_fixpoint,ms_per_lane,props_per_sec"]
    for L in args.lanes:
        lb0 = np.tile(np.asarray(cm.lb0), (L, 1))
        ub0 = np.tile(np.asarray(cm.ub0), (L, 1))
        # randomize one tell per lane so lanes aren't identical
        for i in range(L):
            v = int(rng.integers(1, cm.n_vars))
            if lb0[i, v] < ub0[i, v]:
                lb0[i, v] += 1
        lbs, ubs = jnp.asarray(lb0), jnp.asarray(ub0)
        impls = ["gather", "scatter"] + \
            ([] if args.skip_pallas else ["pallas"])
        for impl in impls:
            kw = dict(lane_tile=min(8, L)) if impl == "pallas" else {}
            dt = bench(cm, lbs, ubs, impl, **kw)
            # sweeps-to-fixpoint is data dependent; report prop-executions
            # assuming the measured fixpoint ran to convergence once
            pps = cm.n_props * L / dt
            rows.append(f"{impl},{L},{dt * 1e3:.2f},"
                        f"{dt * 1e3 / L:.3f},{pps:.3g}")
    print("\n".join(rows))
    return rows


if __name__ == "__main__":
    main()
