"""Propagation-throughput microbenchmark (the paper's core claim:
propagation parallelizes).

Measures lane-batched fixpoint throughput (propagator-executions/sec) of
every registered propagation backend (`core/backend.py`) as the lane
count and instance size grow — the CPU-visible analogue of filling GPU
SMs with blocks.  Near-flat time per sweep as lanes grow ⇒ the work
vectorizes, which is what TURBO exploits on real parallel hardware.

  PYTHONPATH=src python -m benchmarks.bench_propagation \
      --sizes 8 12 --lanes 1 8 32 [--backends gather scatter pallas] \
      [--json BENCH_propagation.json]

CSV columns: backend,n_tasks,lanes,ms_per_fixpoint,ms_per_lane,
sweeps_exec,props_per_sec.  `sweeps_exec` is the backend-reported number
of sweeps physically executed (pallas runs whole lane *tiles* in
lockstep, so it exceeds the per-lane counts of the XLA backends on the
same input).  `props_per_sec` is therefore computed from a
backend-independent work measure — the gather backend's per-lane useful
sweep count on the identical stores — so rates are comparable across
backends: same numerator, each backend's own wall clock.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import available_backends, get_backend
from repro.core.models import rcpsp


def bench(cm, lbs, ubs, backend_name: str, iters: int = 5, **backend_kw):
    """Return (seconds_per_fixpoint, total_sweeps) for one backend."""
    backend = get_backend(backend_name, **backend_kw)
    f = lambda: backend.fixpoint_batch(cm, lbs, ubs)  # noqa: E731
    out = f()
    jax.block_until_ready(out)                       # compile
    sweeps = int(np.asarray(out[2]).sum())
    t0 = time.time()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(out)
    return (time.time() - t0) / iters, sweeps


def perturbed_stores(cm, n_lanes: int, rng: np.random.Generator):
    """n_lanes copies of the root store, one random tell each so lanes
    aren't identical (fixpoints then differ per lane)."""
    lb0 = np.tile(np.asarray(cm.lb0), (n_lanes, 1))
    ub0 = np.tile(np.asarray(cm.ub0), (n_lanes, 1))
    for i in range(n_lanes):
        v = int(rng.integers(1, cm.n_vars))
        if lb0[i, v] < ub0[i, v]:
            lb0[i, v] += 1
    return jnp.asarray(lb0), jnp.asarray(ub0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[8, 12],
                    help="RCPSP task counts (>=2 sizes for the trajectory)")
    ap.add_argument("--lanes", type=int, nargs="+", default=[1, 8, 32])
    ap.add_argument("--backends", nargs="+", default=None,
                    help=f"subset of {available_backends()}")
    ap.add_argument("--skip-pallas", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON (perf trajectory file)")
    args = ap.parse_args(argv)

    backends = list(args.backends or available_backends())
    if args.skip_pallas and "pallas" in backends:
        backends.remove("pallas")

    rng = np.random.default_rng(0)
    header = ("backend,n_tasks,lanes,ms_per_fixpoint,ms_per_lane,"
              "sweeps_exec,props_per_sec")
    rows = [header]
    records = []
    for n_tasks in args.sizes:
        inst = rcpsp.generate(n_tasks, n_resources=4, seed=0)
        m, _ = rcpsp.build_model(inst)
        cm = m.compile()
        for L in args.lanes:
            lbs, ubs = perturbed_stores(cm, L, rng)
            # backend-independent work measure: useful per-lane sweeps of
            # the canonical gather fixpoint on these exact stores
            useful = int(np.asarray(
                get_backend("gather").fixpoint_batch(cm, lbs, ubs)[2]).sum())
            for name in backends:
                kw = dict(lane_tile=min(8, L)) if name == "pallas" else {}
                dt, sweeps = bench(cm, lbs, ubs, name, **kw)
                pps = cm.n_props * useful / dt
                rows.append(f"{name},{n_tasks},{L},{dt * 1e3:.2f},"
                            f"{dt * 1e3 / L:.3f},{sweeps},{pps:.3g}")
                records.append(dict(backend=name, n_tasks=n_tasks, lanes=L,
                                    ms_per_fixpoint=dt * 1e3,
                                    ms_per_lane=dt * 1e3 / L,
                                    sweeps_exec=sweeps,
                                    sweeps_useful=useful,
                                    props_per_sec=pps))
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"bench": "propagation", "rows": records}, fh,
                      indent=2)
    return rows


if __name__ == "__main__":
    main()
