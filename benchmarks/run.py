"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One harness per paper table/figure:
  * Table 1 analogue  — bench_solver (batched engine vs sequential CPU)
  * propagation claim — bench_propagation (throughput vs lane count)
plus the planner micro-benchmark (framework-integration feature).

Roofline (§Roofline of EXPERIMENTS.md) is the separate heavyweight
harness: ``python -m benchmarks.roofline --all`` (needs the 512-device
dry-run env; see benchmarks/roofline.py).
Prints ``name,us_per_call,derived`` CSV per the repo convention.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    fast = "--fast" in sys.argv
    print("# === propagation throughput (paper: parallel propagation) ===")
    from benchmarks import bench_propagation
    t0 = time.time()
    bench_propagation.main(["--lanes", "1", "8", "32"] +
                           (["--skip-pallas"] if fast else []))
    print(f"# bench_propagation,{(time.time()-t0)*1e6:.0f},wall_us")

    print("\n# === Table 1 analogue (solver suites + model zoo) ===")
    from benchmarks import bench_solver
    t0 = time.time()
    bench_solver.main(["--timeout", "20", "--zoo", "--zoo-size", "small"]
                      if fast else ["--zoo"])
    print(f"# bench_solver,{(time.time()-t0)*1e6:.0f},wall_us")

    print("\n# === session API serving throughput (DESIGN.md §11) ===")
    t0 = time.time()
    bench_solver.main(["--throughput"])
    print(f"# bench_solver_throughput,{(time.time()-t0)*1e6:.0f},wall_us")

    print("\n# === planner (pipeline scheduling as RCPSP) ===")
    from repro.distributed import planner
    t0 = time.time()
    starts, mk, res = planner.schedule_microbatches([3, 3, 3, 3], 4,
                                                    timeout_s=60)
    dt = (time.time() - t0) * 1e6
    print("name,us_per_call,derived")
    print(f"schedule_microbatches_4x4,{dt:.0f},makespan={mk}"
          f";status={res.status}")


if __name__ == "__main__":
    main()
