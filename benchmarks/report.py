"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
reports produced by launch/dryrun.py and benchmarks/roofline.py."""

from __future__ import annotations

import argparse
import json


def dryrun_table(path: str) -> str:
    with open(path) as f:
        recs = json.load(f)
    out = ["| arch | shape | mesh | compile s | args GB/dev | temp GB/dev "
           "| HLO flops/dev* | coll GB/dev* | collective ops |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP — {r['reason'].split('(')[0].strip()} | | | | | |")
            continue
        pd = r["per_device"]
        ops = ", ".join(f"{k.split('-')[-1]}:{v}"
                        for k, v in r["hlo_ops"].items() if v) or "—"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.1f} | {pd['argument_bytes']/1e9:.2f} | "
            f"{pd['temp_bytes']/1e9:.2f} | {pd['flops']:.3g} | "
            f"{r['collectives']['total']/1e9:.3f} | {ops} |")
    out.append("")
    out.append("*scanned-HLO numbers: scan bodies counted once by XLA "
               "cost analysis — see §Roofline for depth-corrected terms.")
    return "\n".join(out)


def roofline_table(path: str) -> str:
    with open(path) as f:
        recs = json.load(f)
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f}ms | "
            f"{r['memory_s']*1e3:.2f}ms | {r['collective_s']*1e3:.2f}ms | "
            f"{r['bottleneck']} | {r['useful_ratio']} | "
            f"{r['roofline_frac']} |")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="dryrun_report.json")
    ap.add_argument("--roofline", default="roofline_report.json")
    ap.add_argument("--which", default="both")
    a = ap.parse_args()
    if a.which in ("both", "dryrun"):
        print(dryrun_table(a.dryrun))
    if a.which in ("both", "roofline"):
        print(roofline_table(a.roofline))
