"""Table-1 analogue: TURBO-style batched engine vs sequential CPU solver.

The paper compares TURBO (GPU, 3072 cores) against parallel GECODE (6
cores) on Patterson + PSPLIB j30.  This container has one CPU and no
PSPLIB files, so (DESIGN.md §8): instances come from the seeded generator
in the same families, GECODE's role is played by our event-driven
sequential solver (same model, same branching), and the batched engine
runs with `--lanes` vectorized lanes.  Columns mirror Table 1:
feas / opt / nodes-per-sec / time.  The GPU-side claim that survives CPU
emulation is *throughput scaling with lanes* (bench_propagation.py) and
*identical objectives* (determinism, Thm 6); wall-clock superiority needs
the real accelerator.
"""

from __future__ import annotations

import argparse
import time
from typing import List

from repro.core import baseline, engine
from repro.core import search as S
from repro.core.backend import available_backends
from repro.core.models import rcpsp


def suite(kind: str, full: bool):
    if kind == "patterson-like":
        sizes = [14, 18, 22] if full else [6, 8, 10]
        return [rcpsp.generate(n, n_resources=3, seed=s, edge_prob=0.25)
                for n in sizes for s in range(4 if full else 3)]
    if kind == "j30-like":
        sizes = [30] if full else [12]
        return [rcpsp.generate(n, n_resources=4, seed=s, edge_prob=0.2)
                for n in sizes for s in range(4 if full else 3)]
    raise ValueError(kind)


def run_suite(name: str, instances: List[rcpsp.RCPSP], timeout_s: float,
              lanes: int, subs: int, rows: List[str],
              backend: str = "gather"):
    opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=1024,
                           backend=backend)
    # §Perf P0/H1: the optimized profile caps sweeps per superstep
    # (bounded chaotic iteration; identical optima, 1.7–2.5× faster)
    opts_fast = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=1024,
                                max_fixpoint_iters=4, backend=backend)
    agg = {}
    for solver_name in ("sequential", "turbo-jax", "turbo-jax-opt"):
        feas = opt = nodes = 0
        wall = 0.0
        objs = []
        for inst in instances:
            m, _ = rcpsp.build_model(inst)
            cm = m.compile()
            if solver_name == "sequential":
                res = baseline.SequentialSolver(cm, opts).solve(
                    timeout_s=timeout_s)
            elif solver_name == "turbo-jax":
                res = engine.solve(cm, n_lanes=lanes, n_subproblems=subs,
                                   opts=opts, timeout_s=timeout_s)
            else:
                res = engine.solve(cm, n_lanes=lanes, n_subproblems=subs,
                                   opts=opts_fast, timeout_s=timeout_s)
            feas += res.solution is not None
            opt += res.status == engine.OPTIMAL
            nodes += res.n_nodes
            wall += res.wall_s
            objs.append((res.objective, res.status))
        agg[solver_name] = objs
        rows.append(f"{name},{solver_name},{len(instances)},{feas},{opt},"
                    f"{nodes / max(wall, 1e-9):.0f},{wall:.1f}")
    # determinism cross-check: identical objectives wherever BOTH proved
    # optimality (timed-out incumbents legitimately differ)
    def _mism(x, y):
        return sum(1 for (a, sa), (b, sb) in zip(x, y)
                   if sa == engine.OPTIMAL and sb == engine.OPTIMAL
                   and a != b)
    mism = _mism(agg["sequential"], agg["turbo-jax"]) +         _mism(agg["turbo-jax"], agg["turbo-jax-opt"])
    rows.append(f"{name},objective-mismatches,{len(instances)},{mism},,,")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="bigger instances (minutes-scale, paper-like)")
    ap.add_argument("--timeout", type=float, default=None)
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--subs", type=int, default=128)
    ap.add_argument("--backend", default="gather",
                    choices=available_backends(),
                    help="propagation backend for the batched engine")
    args = ap.parse_args(argv)
    timeout = args.timeout or (300 if args.full else 30)

    rows = ["suite,solver,instances,feasible,optimal,nodes_per_sec,time_s"]
    for kind in ("patterson-like", "j30-like"):
        run_suite(kind, suite(kind, args.full), timeout, args.lanes,
                  args.subs, rows, backend=args.backend)
    print("\n".join(rows))
    return rows


if __name__ == "__main__":
    main()
