"""Table-1 analogue: TURBO-style batched engine vs sequential CPU solver.

The paper compares TURBO (GPU, 3072 cores) against parallel GECODE (6
cores) on Patterson + PSPLIB j30.  This container has one CPU and no
PSPLIB files, so (DESIGN.md §8): instances come from the seeded generator
in the same families, GECODE's role is played by our event-driven
sequential solver (same model, same branching), and the batched engine
runs with `--lanes` vectorized lanes.  Columns mirror Table 1:
feas / opt / nodes-per-sec / time.  The GPU-side claim that survives CPU
emulation is *throughput scaling with lanes* (bench_propagation.py) and
*identical objectives* (determinism, Thm 6); wall-clock superiority needs
the real accelerator.

``--zoo`` adds a per-model section over the whole model zoo (DESIGN.md
§10: rcpsp, nqueens, coloring, knapsack, jobshop) through the
EPS-decomposed engine; ``--zoo-smoke --json BENCH_propagation_smoke.json``
is the `make check` tier — small instances, records merged into the bench
JSON as its `solver` section.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

from repro.core import baseline, engine
from repro.core import models as zoo
from repro.core import search as S
from repro.core.backend import available_backends
from repro.core.models import rcpsp


def suite(kind: str, full: bool):
    if kind == "patterson-like":
        sizes = [14, 18, 22] if full else [6, 8, 10]
        return [rcpsp.generate(n, n_resources=3, seed=s, edge_prob=0.25)
                for n in sizes for s in range(4 if full else 3)]
    if kind == "j30-like":
        sizes = [30] if full else [12]
        return [rcpsp.generate(n, n_resources=4, seed=s, edge_prob=0.2)
                for n in sizes for s in range(4 if full else 3)]
    raise ValueError(kind)


def run_suite(name: str, instances: List[rcpsp.RCPSP], timeout_s: float,
              lanes: int, subs: int, rows: List[str],
              backend: str = "gather"):
    opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=1024,
                           backend=backend)
    # §Perf P0/H1: the optimized profile caps sweeps per superstep
    # (bounded chaotic iteration; identical optima, 1.7–2.5× faster)
    opts_fast = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=1024,
                                max_fixpoint_iters=4, backend=backend)
    agg = {}
    for solver_name in ("sequential", "turbo-jax", "turbo-jax-opt"):
        feas = opt = nodes = 0
        wall = 0.0
        objs = []
        for inst in instances:
            m, _ = rcpsp.build_model(inst)
            cm = m.compile()
            if solver_name == "sequential":
                res = baseline.SequentialSolver(cm, opts).solve(
                    timeout_s=timeout_s)
            elif solver_name == "turbo-jax":
                res = engine.solve(cm, n_lanes=lanes, n_subproblems=subs,
                                   opts=opts, timeout_s=timeout_s)
            else:
                res = engine.solve(cm, n_lanes=lanes, n_subproblems=subs,
                                   opts=opts_fast, timeout_s=timeout_s)
            feas += res.solution is not None
            opt += res.status == engine.OPTIMAL
            nodes += res.n_nodes
            wall += res.wall_s
            objs.append((res.objective, res.status))
        agg[solver_name] = objs
        rows.append(f"{name},{solver_name},{len(instances)},{feas},{opt},"
                    f"{nodes / max(wall, 1e-9):.0f},{wall:.1f}")
    # determinism cross-check: identical objectives wherever BOTH proved
    # optimality (timed-out incumbents legitimately differ)
    def _mism(x, y):
        return sum(1 for (a, sa), (b, sb) in zip(x, y)
                   if sa == engine.OPTIMAL and sb == engine.OPTIMAL
                   and a != b)
    mism = _mism(agg["sequential"], agg["turbo-jax"]) +         _mism(agg["turbo-jax"], agg["turbo-jax-opt"])
    rows.append(f"{name},objective-mismatches,{len(instances)},{mism},,,")
    return rows


def run_zoo(timeout_s: float, lanes: int, eps_target: int, rows: List[str],
            backend: str = "gather", smoke: bool = False, seed: int = 0):
    """Per-model solver numbers across the whole zoo (DESIGN.md §10):
    nodes/s and time-to-optimum through the EPS-decomposed engine.
    Returns the JSON-able records for the BENCH `solver` section."""
    opts = S.SearchOptions(var_strategy=S.MIN_LB, max_depth=512,
                           backend=backend)
    records = []
    for name in sorted(zoo.ZOO):
        mod = zoo.ZOO[name]
        inst = (zoo.small_instance(name, seed=seed) if smoke
                else zoo.bench_instance(name, seed=seed))
        m, h = mod.build_model(inst)
        cm = m.compile()
        res = engine.solve(cm, n_lanes=lanes, eps_target=eps_target,
                           opts=opts, timeout_s=timeout_s)
        # True/False = checked; None = nothing to check (timeout/UNSAT)
        checked = zoo.ground_check(mod, inst, h, res)
        rows.append(f"zoo,{name},{backend},{res.status},{res.objective},"
                    f"{res.nodes_per_sec:.0f},{res.wall_s:.2f},{checked}")
        # time to the *proven* optimum: wall clock until B&B returned
        # OPTIMAL, jit compile included (the honest CPU-emulation figure —
        # incumbent timestamps would need engine support)
        records.append(dict(
            model=name, instance=inst.name, backend=backend,
            status=res.status, objective=res.objective,
            n_nodes=res.n_nodes, nodes_per_sec=res.nodes_per_sec,
            n_supersteps=res.n_supersteps,
            time_to_proven_optimum_s=(
                res.wall_s if res.status == engine.OPTIMAL else None),
            wall_s=res.wall_s, ground_check=checked))
    return records


def write_solver_json(path: str, records) -> None:
    """Merge the zoo records into `path` as its `solver` section,
    preserving whatever the propagation smoke already wrote there."""
    doc = {}
    if os.path.exists(path):
        with open(path) as fh:
            doc = json.load(fh)
    doc["solver"] = records
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="bigger instances (minutes-scale, paper-like)")
    ap.add_argument("--timeout", type=float, default=None)
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--subs", type=int, default=128)
    ap.add_argument("--backend", default="gather",
                    choices=available_backends(),
                    help="propagation backend for the batched engine")
    ap.add_argument("--zoo", action="store_true",
                    help="also run the model-zoo section (all 5 models)")
    ap.add_argument("--zoo-size", choices=("small", "bench"), default=None,
                    help="zoo instance tier (default: bench for --zoo, "
                         "small for --zoo-smoke)")
    ap.add_argument("--zoo-smoke", action="store_true",
                    help="ONLY the zoo on small instances (the make-check "
                         "tier); implies --zoo, skips the RCPSP tables")
    ap.add_argument("--eps-target", type=int, default=64,
                    help="EPS pool size for the zoo runs (DESIGN.md §9)")
    ap.add_argument("--json", default=None,
                    help="merge the zoo records into this JSON file as its "
                         "`solver` section (e.g. BENCH_propagation_smoke"
                         ".json)")
    args = ap.parse_args(argv)
    if args.json and not (args.zoo or args.zoo_smoke):
        ap.error("--json records the zoo section; pass --zoo or --zoo-smoke")
    timeout = args.timeout or (300 if args.full else 30)

    rows = []
    if not args.zoo_smoke:
        rows.append(
            "suite,solver,instances,feasible,optimal,nodes_per_sec,time_s")
        for kind in ("patterson-like", "j30-like"):
            run_suite(kind, suite(kind, args.full), timeout, args.lanes,
                      args.subs, rows, backend=args.backend)
    records = None
    if args.zoo or args.zoo_smoke:
        rows.append("zoo,model,backend,status,objective,nodes_per_sec,"
                    "time_s,ground_check")
        smoke = (args.zoo_size == "small" if args.zoo_size
                 else args.zoo_smoke)
        records = run_zoo(timeout, args.lanes, args.eps_target, rows,
                          backend=args.backend, smoke=smoke)
    print("\n".join(rows))
    if args.json and records is not None:
        write_solver_json(args.json, records)
    return rows


if __name__ == "__main__":
    main()
