"""Table-1 analogue: TURBO-style batched engine vs sequential CPU solver.

The paper compares TURBO (GPU, 3072 cores) against parallel GECODE (6
cores) on Patterson + PSPLIB j30.  This container has one CPU and no
PSPLIB files, so (DESIGN.md §8): instances come from the seeded generator
in the same families, GECODE's role is played by our event-driven
sequential solver (same model, same branching), and the batched engine
runs with `--lanes` vectorized lanes.  Columns mirror Table 1:
feas / opt / nodes-per-sec / time.  The GPU-side claim that survives CPU
emulation is *throughput scaling with lanes* (bench_propagation.py) and
*identical objectives* (determinism, Thm 6); wall-clock superiority needs
the real accelerator.

All solving goes through the session API (`repro.solver`, DESIGN.md
§11); the `prove` / `fast` presets replace the old hand-rolled
SearchOptions recipes.

``--zoo`` adds a per-model section over the whole model zoo (DESIGN.md
§10: rcpsp, nqueens, coloring, knapsack, jobshop) through the
EPS-decomposed engine; ``--zoo-smoke --json BENCH_propagation_smoke.json``
is the `make check` tier — small instances, records merged into the bench
JSON as its `solver` section.  Since §12 each record also carries the
typed propagator-table size (`n_props`, per-kind split, and
`n_props_decomposed` — the pre-§12 ReifLinLe blowup the native lowering
replaced), so the table-size win is tracked per PR alongside nodes/s.

``--throughput`` is the serving-story benchmark (DESIGN.md §11): one
`Solver` session over 4 same-shape knapsack instances — cold-vs-warm
solve (compile amortization) and `solve_many` batched dispatch
(instances/s) vs sequential warm solves; records land in the `api`
section of the bench JSON.

``--superstep-bench`` is the resident-megakernel metric (DESIGN.md §13):
per backend, one warm solve driven at ``chunk=1`` host granularity,
recording ms_per_superstep / supersteps_per_sec / dispatches_per_solve
into the `superstep` section — the unfused backends pay one host
dispatch per superstep, ``pallas_resident`` one per K supersteps.

``--serve-bench`` is the solver-as-a-service metric (DESIGN.md §15): a
seeded open-loop Poisson load (≥50 requests over ≥2 shape buckets with
mixed deadlines) through the continuous-batching `SolverScheduler`,
hard-failing unless every completed result is bit-identical to a
sequential `Solver.solve` reference, slots actually batch (>1 request
co-resident) and each bucket compiled at most once; p50/p99
TTFI/latency, queue depth, occupancy and instances/s land in the
`serving` section.

``--scale-smoke`` is the sparse-bank scale metric (DESIGN.md §16):
analytic dense-vs-sparse peak bank-tile bytes for nqueens N ∈ {32, 128,
256, 512} (hard-failing unless the sparse O(M²) tile is strictly smaller
than the dense O(N³) tile at N ≥ 128), forced dense/sparse
objective-parity solves on smoke instances (hard-failing on any
status/objective mismatch), and bounded large-tier throughput probes
(props/s at the root fixpoint, nodes/s over a supersteps-capped solve);
records land in the `scale` section.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

from repro import solver
from repro.core import baseline
from repro.core import models as zoo
from repro.core.backend import available_backends
from repro.core.models import knapsack, rcpsp


def suite(kind: str, full: bool):
    if kind == "patterson-like":
        sizes = [14, 18, 22] if full else [6, 8, 10]
        return [rcpsp.generate(n, n_resources=3, seed=s, edge_prob=0.25)
                for n in sizes for s in range(4 if full else 3)]
    if kind == "j30-like":
        sizes = [30] if full else [12]
        return [rcpsp.generate(n, n_resources=4, seed=s, edge_prob=0.2)
                for n in sizes for s in range(4 if full else 3)]
    raise ValueError(kind)


def run_suite(name: str, instances: List[rcpsp.RCPSP], timeout_s: float,
              lanes: int, subs: int, rows: List[str],
              backend: str = "gather"):
    cfg = solver.SolveConfig.preset(
        "prove", n_lanes=lanes, eps_target=subs, timeout_s=timeout_s,
        backend=backend)
    # §Perf P0/H1: the `fast` preset caps sweeps per superstep (bounded
    # chaotic iteration; identical optima, 1.7–2.5× faster)
    cfg_fast = solver.SolveConfig.preset(
        "fast", n_lanes=lanes, eps_target=subs, timeout_s=timeout_s,
        backend=backend)
    sess = solver.Solver(cfg)
    agg = {}
    for solver_name in ("sequential", "turbo-jax", "turbo-jax-opt"):
        feas = opt = nodes = 0
        wall = 0.0
        objs = []
        for inst in instances:
            m, _ = rcpsp.build_model(inst)
            cm = m.compile()
            if solver_name == "sequential":
                res = baseline.SequentialSolver(cm, cfg.search_options()) \
                    .solve(timeout_s=timeout_s)
            elif solver_name == "turbo-jax":
                res = sess.solve(cm)
            else:
                res = sess.solve(cm, config=cfg_fast)
            feas += res.solution is not None
            opt += res.status == solver.OPTIMAL
            nodes += res.n_nodes
            wall += res.wall_s
            objs.append((res.objective, res.status))
        agg[solver_name] = objs
        rows.append(f"{name},{solver_name},{len(instances)},{feas},{opt},"
                    f"{nodes / max(wall, 1e-9):.0f},{wall:.1f}")
    # determinism cross-check: identical objectives wherever BOTH proved
    # optimality (timed-out incumbents legitimately differ)
    def _mism(x, y):
        return sum(1 for (a, sa), (b, sb) in zip(x, y)
                   if sa == solver.OPTIMAL and sb == solver.OPTIMAL
                   and a != b)
    mism = _mism(agg["sequential"], agg["turbo-jax"]) + \
        _mism(agg["turbo-jax"], agg["turbo-jax-opt"])
    rows.append(f"{name},objective-mismatches,{len(instances)},{mism},,,")
    return rows


def run_zoo(timeout_s: float, lanes: int, eps_target: int, rows: List[str],
            backend="gather", smoke: bool = False, seed: int = 0):
    """Per-model solver numbers across the whole zoo (DESIGN.md §10):
    nodes/s and time-to-optimum through the EPS-decomposed engine.
    `backend` may be a name or a sequence of names (the smoke tier
    records every registered backend, pallas_resident included, so the
    `solver` section tracks objective parity per backend per PR).
    Returns the JSON-able records for the BENCH `solver` section."""
    backends = ((backend,) if isinstance(backend, str) else tuple(backend))
    records = []
    objectives = {}                       # model -> {backend: objective}
    for be in backends:
        cfg = solver.SolveConfig.preset(
            "prove", n_lanes=lanes, eps_target=eps_target,
            timeout_s=timeout_s, backend=be, max_depth=512)
        sess = solver.Solver(cfg)
        for name in sorted(zoo.ZOO):
            mod = zoo.ZOO[name]
            inst = (zoo.small_instance(name, seed=seed) if smoke
                    else zoo.bench_instance(name, seed=seed))
            m, h = mod.build_model(inst)
            cm = m.compile()
            # typed-table size vs the pre-§12 ReifLinLe decomposition
            # (models without a native lowering — knapsack — compile
            # identically)
            import inspect
            if "decompose" in inspect.signature(mod.build_model).parameters:
                cmd = mod.build_model(inst, decompose=True)[0].compile()
                decomposed_props = cmd.total_props
            else:
                decomposed_props = cm.total_props
            res = sess.solve(cm)
            # True/False = checked; None = nothing to check (timeout/UNSAT)
            checked = zoo.ground_check(mod, inst, h, res)
            rows.append(f"zoo,{name},{be},{res.status},{res.objective},"
                        f"{res.nodes_per_sec:.0f},{res.wall_s:.2f},"
                        f"{checked},P={cm.total_props}/{decomposed_props}")
            objectives.setdefault(name, {})[be] = (res.status,
                                                   res.objective)
            # time to the *proven* optimum: wall clock until B&B returned
            # OPTIMAL, jit compile included (the honest CPU-emulation
            # figure); the improvements trace also gives time-to-incumbent
            records.append(dict(
                model=name, instance=inst.name, backend=be,
                status=res.status, objective=res.objective,
                n_props=cm.total_props,
                n_props_by_kind=dict(lin=cm.n_props, alldiff=cm.n_alldiff,
                                     cumulative=cm.n_cumulative),
                n_props_decomposed=decomposed_props,
                n_vars=cm.n_vars,
                n_nodes=res.n_nodes, nodes_per_sec=res.nodes_per_sec,
                n_supersteps=res.n_supersteps,
                time_to_proven_optimum_s=(
                    res.wall_s if res.status == solver.OPTIMAL else None),
                time_to_first_incumbent_s=(
                    res.improvements[0].wall_s if res.improvements
                    else None),
                wall_s=res.wall_s, ground_check=checked))
    # cross-backend determinism: proven optima must agree bit-for-bit
    for name, per_be in objectives.items():
        proven = {o for s, o in per_be.values() if s == solver.OPTIMAL}
        if len(proven) > 1:
            raise SystemExit(f"zoo objective mismatch on {name}: {per_be}")
    return records


def run_superstep_bench(rows: List[str], backends, lanes: int = 8,
                        eps_target: int = 16, timeout_s: float = 300.0,
                        supersteps_per_launch: int = 16):
    """Superstep-orchestration overhead per backend (the ISSUE-6 metric):
    drive each solve at the finest host granularity — ``chunk=1`` so
    every unfused runner call is exactly ONE superstep (one host
    dispatch of the 4-phase `lanes_step`), while ``pallas_resident``
    returns per K-superstep megakernel launch — and count the host
    dispatches to completion via the `solve_iter` event stream (one
    event per runner call, by the Progress granularity contract).

    Records ms_per_superstep / supersteps_per_sec / dispatches_per_solve
    (warm timings; the cold solve is run first to compile) for the BENCH
    `superstep` section.
    """
    inst = zoo.small_instance("rcpsp", seed=0)
    m, _ = zoo.ZOO["rcpsp"].build_model(inst)
    cm = m.compile()
    records = []
    for backend in backends:
        kw = dict(supersteps_per_launch=supersteps_per_launch) \
            if backend == "pallas_resident" else {}
        cfg = solver.SolveConfig.preset(
            "prove", n_lanes=lanes, eps_target=eps_target, chunk=1,
            timeout_s=timeout_s, backend=backend, max_depth=512, **kw)
        sess = solver.Solver(cfg)
        res = sess.solve(cm)                       # cold: compile
        wall = float("inf")
        for _ in range(5):                         # warm: best of 5 drains
            dispatches = 0
            for ev in sess.solve_iter(cm):
                dispatches += 1
                if ev.final:
                    res = ev.result
            # the Progress timing contract (api.Progress): wall_s is the
            # event stream's own elapsed-since-solve-start clock — the
            # single timing source shared with the serving metrics, so
            # this bench never re-times what solve_iter already stamped
            wall = min(wall, res.wall_s)
        n_steps = max(res.n_supersteps, 1)
        rec = dict(
            backend=backend, model=inst.name,
            supersteps_per_launch=(supersteps_per_launch
                                   if backend == "pallas_resident" else 1),
            n_supersteps=res.n_supersteps,
            dispatches_per_solve=dispatches,
            ms_per_superstep=round(1e3 * wall / n_steps, 3),
            supersteps_per_sec=round(n_steps / max(wall, 1e-9), 1),
            status=res.status, objective=res.objective,
            wall_s=round(wall, 4))
        records.append(rec)
        rows.append(
            f"superstep,{backend},K={rec['supersteps_per_launch']},"
            f"steps={rec['n_supersteps']},"
            f"dispatches={rec['dispatches_per_solve']},"
            f"{rec['ms_per_superstep']}ms/step,"
            f"{rec['supersteps_per_sec']}steps/s,{res.status}")
    return records


def run_throughput(lanes: int, eps_target: int, rows: List[str],
                   backends=("gather",), n_instances: int = 4,
                   seed0: int = 0, timeout_s: float = 120.0):
    """The serving benchmark (DESIGN.md §11): cold vs warm session solve
    and `solve_many` batched throughput on same-shape knapsack
    instances, per backend.  Returns records for the BENCH `api`
    section."""
    instances = [knapsack.generate(n=6, seed=seed0 + s)
                 for s in range(n_instances)]
    cms = []
    for inst in instances:
        m, _ = knapsack.build_model(inst)
        cms.append(m.compile())

    records = []
    for backend in backends:
        cfg = solver.SolveConfig.preset(
            "prove", n_lanes=lanes, eps_target=eps_target,
            timeout_s=timeout_s, backend=backend)
        sess = solver.Solver(cfg)

        t0 = time.time()
        cold_res = sess.solve(cms[0])
        cold_s = time.time() - t0
        assert sess.stats["last_solve_cold"], "first solve must compile"

        t0 = time.time()
        warm_res = sess.solve(cms[0])
        warm_s = time.time() - t0
        assert not sess.stats["last_solve_cold"], "second solve recompiled!"
        assert warm_res.objective == cold_res.objective

        # sequential warm throughput: every instance through the session
        t0 = time.time()
        seq = [sess.solve(cm) for cm in cms]
        seq_s = time.time() - t0

        # batched: ONE device dispatch for all instances (cold for the
        # batched runner, so also record a warm repeat)
        t0 = time.time()
        many = sess.solve_many(cms)
        many_cold_s = time.time() - t0
        t0 = time.time()
        many = sess.solve_many(cms)
        many_s = time.time() - t0

        parity = all(a.status == b.status and a.objective == b.objective
                     for a, b in zip(many, seq))
        stats = sess.session_stats()
        rec = dict(
            backend=backend, n_instances=len(cms),
            model="knapsack-n6",
            cold_solve_s=round(cold_s, 4), warm_solve_s=round(warm_s, 4),
            cold_warm_speedup=round(cold_s / max(warm_s, 1e-9), 1),
            compile_s=round(stats["compile_s"], 4),
            n_compiles=stats["n_compiles"],
            runner_builds=stats["runner_builds"],
            runner_hits=stats["runner_hits"],
            solve_many_cold_s=round(many_cold_s, 4),
            solve_many_warm_s=round(many_s, 4),
            instances_per_sec_batched=round(len(cms) / max(many_s, 1e-9), 1),
            instances_per_sec_sequential=round(
                len(cms) / max(seq_s, 1e-9), 1),
            batched_vs_sequential=round(seq_s / max(many_s, 1e-9), 2),
            parity_ok=parity,
            objectives=[r.objective for r in many],
        )
        records.append(rec)
        rows.append(
            f"api,{backend},cold={cold_s:.2f}s,warm={warm_s:.3f}s,"
            f"x{rec['cold_warm_speedup']},batched="
            f"{rec['instances_per_sec_batched']}/s,sequential="
            f"{rec['instances_per_sec_sequential']}/s,parity={parity}")
        if not parity:
            raise SystemExit(
                f"solve_many parity FAILED on {backend}: "
                f"{[(r.status, r.objective) for r in many]} vs "
                f"{[(r.status, r.objective) for r in seq]}")
    return records


def run_dist_bench(rows: List[str], timeout_s: float = 120.0,
                   lanes: int = 4, eps_target: int = 16,
                   meshes=(1, 2, 4, 8)):
    """Distributed-EPS benchmark (core/dist_solve.py, DESIGN.md §14):
    per mesh size, warm-solve wall time (speedup vs mesh=1), steal
    events, bound-all-reduce count, and status/objective parity with the
    single-shard solve.  Returns records for the BENCH `distributed`
    section.  Mesh sizes beyond `jax.device_count()` are skipped — the
    make-check invocation fakes 8 host devices via XLA_FLAGS."""
    import jax

    from repro.core import dist_solve
    from repro.core import models as zoo

    m, _ = zoo.ZOO["coloring"].build_model(
        zoo.small_instance("coloring", seed=0))
    cm = m.compile()
    n_dev = jax.device_count()
    records = []
    ref = None
    warm1 = None
    for D in [d for d in meshes if d <= n_dev]:
        cfg = solver.SolveConfig.preset(
            "prove", n_lanes=lanes, eps_target=eps_target,
            timeout_s=timeout_s, mesh_shards=D)
        sess = solver.Solver(cfg)
        res, _ = dist_solve.solve_dist(cm, cfg, session=sess)   # cold
        t0 = time.time()
        res, tr = dist_solve.solve_dist(cm, cfg, session=sess)  # warm
        warm_s = time.time() - t0
        if D == 1:
            ref, warm1 = res, warm_s
        parity = (res.status == ref.status
                  and res.objective == ref.objective)
        rec = dict(
            mesh=D, model="coloring-small", status=res.status,
            objective=res.objective, warm_solve_s=round(warm_s, 4),
            speedup_vs_mesh1=round(warm1 / max(warm_s, 1e-9), 2),
            n_chunks=tr.n_chunks, n_bound_allreduce=tr.n_bound_syncs,
            n_steals=tr.n_steals, n_remeshes=len(tr.remesh_events),
            parity_ok=parity)
        records.append(rec)
        rows.append(
            f"distributed,mesh={D},{res.status},obj={res.objective},"
            f"warm={warm_s:.3f}s,x{rec['speedup_vs_mesh1']},"
            f"steals={tr.n_steals},allreduce={tr.n_bound_syncs},"
            f"parity={parity}")
        if not parity:
            raise SystemExit(
                f"dist parity FAILED at mesh={D}: "
                f"{(res.status, res.objective)} vs "
                f"{(ref.status, ref.objective)}")
    if n_dev < max(meshes):
        rows.append(f"distributed,NOTE,only {n_dev} device(s) visible; "
                    f"run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=8 for the "
                    f"full sweep")
    return records


def run_serve_bench(rows: List[str], *, n_requests: int = 50,
                    rate_rps: float = 100.0, seed: int = 0,
                    max_batch: int = 4, backend: str = "gather",
                    max_wall_s: float = 600.0):
    """Solver-as-a-service under seeded open-loop load (DESIGN.md §15).

    Drives the continuous-batching `SolverScheduler` with a fixed-seed
    Poisson trace over the default zoo mix (two seed-stable shape
    buckets, mixed deadlines) and HARD-FAILS (SystemExit) unless:

    * parity — every completed request's (status, objective) is
      bit-identical to a sequential warm `Solver.solve` of the same
      instance;
    * batching — more than one request was co-resident in a lane batch
      at some quantum (the continuous-batching win actually happened);
    * compile discipline — every bucket cold-compiled at most once
      (late same-shape requests joined warm).

    Returns one record for the BENCH `serving` section: the
    `MetricsRecorder` summary (p50/p99 TTFI / time-to-optimal / latency,
    queue depth, occupancy, instances/s) plus per-bucket counters.
    """
    from repro.serve.loadgen import (poisson_trace, run_open_loop,
                                     sequential_reference)
    from repro.serve.scheduler import SolverScheduler

    cfg = solver.SolveConfig.preset(
        "prove", backend=backend, n_lanes=8, eps_target=16, chunk=16,
        max_depth=256)
    trace = poisson_trace(n_requests, rate_rps, seed=seed)
    sched = SolverScheduler(cfg, max_batch=max_batch)
    handles = run_open_loop(sched, trace, max_wall_s=max_wall_s)
    summary = sched.recorder.summary()
    buckets = sched.buckets()

    ref = sequential_reference(trace, cfg)
    n_checked = n_bad = 0
    for _, h in handles:
        res = h.result()
        if not res.complete:        # deadline evictions have no oracle
            continue
        n_checked += 1
        if (res.status, res.objective) != ref[h.request.request_id]:
            n_bad += 1
            print(f"serve-bench PARITY MISMATCH {h.request.request_id}: "
                  f"served={(res.status, res.objective)} "
                  f"sequential={ref[h.request.request_id]}")
    max_live = summary["batch_live_slots"].get("max", 0.0)
    bad_compiles = {k: v["n_compiles"] for k, v in buckets.items()
                    if v["n_compiles"] > 1}
    if n_bad:
        raise SystemExit(f"serve-bench: {n_bad}/{n_checked} parity "
                         f"mismatches vs sequential Solver.solve")
    if len(buckets) < 2:
        raise SystemExit(f"serve-bench: expected >= 2 shape buckets, "
                         f"got {list(buckets)}")
    if not max_live > 1:
        raise SystemExit(f"serve-bench: no continuous batching happened "
                         f"(max live slots {max_live} <= 1)")
    if bad_compiles:
        raise SystemExit(f"serve-bench: buckets recompiled after their "
                         f"cold compile: {bad_compiles}")

    rec = dict(n_requests=n_requests, rate_rps=rate_rps, seed=seed,
               max_batch=max_batch, backend=backend,
               parity_checked=n_checked, parity_ok=True,
               summary=summary, buckets=buckets)
    rows.append(
        f"serving,{backend},req={n_requests},rate={rate_rps}/s,"
        f"buckets={len(buckets)},"
        f"ttfi_p50={summary['ttfi_s'].get('p50')}s,"
        f"ttfi_p99={summary['ttfi_s'].get('p99')}s,"
        f"lat_p50={summary['latency_s'].get('p50')}s,"
        f"lat_p99={summary['latency_s'].get('p99')}s,"
        f"occ_max={summary['batch_occupancy'].get('max')},"
        f"live_max={max_live},"
        f"inst/s={summary['instances_per_sec']},parity=OK")
    return [rec]


def run_scale_smoke(rows: List[str], timeout_s: float = 120.0,
                    seed: int = 0):
    """Scale-tier records (DESIGN.md §16) for the bench `scale` section.

    Three bounded sub-parts (the make-check tier):

    * ``bank_bytes`` — analytic per-lane tile scratch, dense O(N³) vs
      sparse O(M²), for nqueens N ∈ {32, 128, 256, 512} via the same
      estimators `compile.py`'s crossover and `kernels.vmem_budget`
      use; **hard-fails** unless sparse < dense at N ≥ 128;
    * ``parity`` — full proven solves of smoke-tier alldiff/cumulative
      models under both *forced* layouts; **hard-fails** on any
      status/objective mismatch (the dense/sparse determinism gate);
    * ``large`` — the industrial-size tier compiled onto the auto
      (sparse) layouts: root-fixpoint props/s and a supersteps-capped
      solve's nodes/s per model (throughput probes, not proofs — the
      proven-optimum run is the `large`-marked test).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import fixpoint as F
    from repro.core.compile import (alldiff_dense_tile_bytes,
                                    alldiff_sparse_tile_bytes,
                                    cumulative_dense_tile_bytes,
                                    cumulative_sparse_tile_bytes)
    from repro.core.models import nqueens as nq_mod

    records = []

    # ---- (a) peak bank-tile bytes, dense vs sparse ----------------------
    for n in (32, 128, 256, 512):
        m, _ = nq_mod.build_model(nq_mod.generate(n, seed=seed))
        cm = m.compile()                        # auto crossover layout
        it = cm.jdtype.itemsize
        dense_b = alldiff_dense_tile_bytes(cm.n_alldiff, cm.ad_width, it)
        sparse_b = alldiff_sparse_tile_bytes(cm.ad_packed, it)
        rows.append(f"scale,bank_bytes,nqueens-{n},layout={cm.ad_layout},"
                    f"dense={dense_b},sparse={sparse_b},"
                    f"ratio={dense_b / max(sparse_b, 1):.1f}x")
        records.append(dict(
            kind="bank_bytes", model=f"nqueens-{n}", layout=cm.ad_layout,
            ad_packed=cm.ad_packed, dense_tile_bytes=dense_b,
            sparse_tile_bytes=sparse_b))
        if n >= 128 and not sparse_b < dense_b:
            raise SystemExit(
                f"scale: sparse AllDifferent tile not smaller than the "
                f"dense O(N³) tile at N={n}: {sparse_b} >= {dense_b}")

    # ---- (b) dense vs sparse objective parity (hard gate) ---------------
    for name in ("nqueens", "rcpsp"):
        mod = zoo.ZOO[name]
        inst = zoo.small_instance(name, seed=seed)
        m, h = mod.build_model(inst)
        out = {}
        for layout in ("dense", "sparse"):
            cm = m.compile(bank_layout=layout)
            cfg = solver.SolveConfig.preset(
                "prove", n_lanes=8, eps_target=16, timeout_s=timeout_s)
            res = solver.Solver(cfg).solve(cm)
            out[layout] = (res.status, res.objective)
            checked = zoo.ground_check(mod, inst, h, res)
            rows.append(f"scale,parity,{name},{layout},{res.status},"
                        f"{res.objective},{checked}")
            records.append(dict(
                kind="parity", model=name, instance=inst.name,
                layout=layout, status=res.status, objective=res.objective,
                ground_check=checked))
        if out["dense"] != out["sparse"]:
            raise SystemExit(
                f"scale: dense/sparse status/objective mismatch on "
                f"{name}: {out}")

    # ---- (c) large-tier throughput probes (auto = sparse layouts) -------
    for name in ("nqueens", "rcpsp", "jobshop"):
        inst = zoo.large_instance(name, seed=seed)
        m, _ = zoo.ZOO[name].build_model(inst)
        cm = m.compile()
        it = cm.jdtype.itemsize
        bank_bytes = dict(
            alldiff=(alldiff_sparse_tile_bytes(cm.ad_packed, it)
                     if cm.ad_layout == "sparse"
                     else alldiff_dense_tile_bytes(cm.n_alldiff,
                                                   cm.ad_width, it)),
            cumulative=(cumulative_sparse_tile_bytes(cm.cu_packed, it)
                        if cm.cu_layout == "sparse"
                        else cumulative_dense_tile_bytes(
                            cm.n_cumulative, cm.cu_width, cm.horizon, it)))
        L = 4
        lb = jnp.broadcast_to(cm.lb0[None], (L, cm.n_vars))
        ub = jnp.broadcast_to(cm.ub0[None], (L, cm.n_vars))
        F.fixpoint_batch(cm, lb, ub, max_iters=2)[0].block_until_ready()
        t0 = time.time()
        sweeps = int(np.asarray(
            F.fixpoint_batch(cm, lb, ub, max_iters=8)[2]).sum())
        wall = max(time.time() - t0, 1e-9)
        props_per_sec = cm.total_props * sweeps / wall
        cfg = solver.SolveConfig.preset(
            "prove", n_lanes=4, eps_target=4, timeout_s=timeout_s,
            max_supersteps=6)
        res = solver.Solver(cfg).solve(cm)
        rows.append(
            f"scale,large,{inst.name},ad={cm.ad_layout},cu={cm.cu_layout},"
            f"props/s={props_per_sec:.0f},nodes/s={res.nodes_per_sec:.0f},"
            f"peak_bank_bytes={max(bank_bytes.values())}")
        records.append(dict(
            kind="large", model=name, instance=inst.name,
            n_vars=cm.n_vars, n_props=cm.total_props,
            ad_layout=cm.ad_layout, cu_layout=cm.cu_layout,
            ad_packed=cm.ad_packed, cu_packed=cm.cu_packed,
            peak_bank_tile_bytes=bank_bytes,
            root_fixpoint_sweeps=sweeps,
            props_per_sec=props_per_sec,
            capped_solve_status=res.status,
            nodes_per_sec=res.nodes_per_sec,
            n_nodes=res.n_nodes, wall_s=res.wall_s))
    return records


def run_ct_smoke(rows: List[str], timeout_s: float = 120.0, seed: int = 0):
    """Compact-Table smoke records (DESIGN.md §17) for the bench
    `compact_table` section.

    Per extensional zoo model (crossword, configuration):

    * root-fixpoint **props/s** with the bitset store carried (the
      native CT path) plus the per-bank statics — currtable words
      (`ct_words`), bitset words per variable (`n_words`);
    * a proven solve on EVERY backend; **hard-fails** on any
      status/objective mismatch or ground-check failure (the §17
      determinism gate);
    * the same instance under ``decompose=True`` (the reified
      disjunction oracle) — **hard-fails** on status/objective
      mismatch vs native; the wall-clock ratio is the
      `native_speedup` headline.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import bitset as B
    from repro.core import fixpoint as F

    records = []
    for name in ("crossword", "configuration"):
        mod = zoo.ZOO[name]
        inst = zoo.small_instance(name, seed=seed)
        mn, h = mod.build_model(inst)
        md, _ = mod.build_model(inst, decompose=True)
        cmn, cmd = mn.compile(), md.compile()

        # ---- root-fixpoint propagation throughput (bitset carried) ----
        L = 8
        lb = jnp.broadcast_to(cmn.lb0[None], (L, cmn.n_vars))
        ub = jnp.broadcast_to(cmn.ub0[None], (L, cmn.n_vars))
        dom = B.from_bounds(lb, ub, jnp.asarray(cmn.dom_off), cmn.n_words,
                            track=jnp.asarray(cmn.dom_track))
        F.fixpoint_batch(cmn, lb, ub, dom, max_iters=2)[0] \
            .block_until_ready()
        t0 = time.time()
        sweeps = int(np.asarray(
            F.fixpoint_batch(cmn, lb, ub, dom, max_iters=8)[3]).sum())
        wall = max(time.time() - t0, 1e-9)
        props_per_sec = cmn.total_props * sweeps / wall

        # ---- every backend proves the same optimum (hard gate) --------
        native = {}
        for be in available_backends():
            cfg = solver.SolveConfig.preset(
                "prove", backend=be, n_lanes=8, eps_target=16,
                timeout_s=timeout_s)
            res = solver.Solver(cfg).solve(cmn)
            checked = zoo.ground_check(mod, inst, h, res)
            native[be] = dict(status=res.status, objective=res.objective,
                              wall_s=res.wall_s, ground_check=checked)
            rows.append(f"compact_table,{name},{be},{res.status},"
                        f"{res.objective},{res.wall_s:.3f},{checked}")
            if res.status != solver.OPTIMAL or checked is not True:
                raise SystemExit(
                    f"compact_table: {name} on {be} not proven+checked: "
                    f"{res.status} gc={checked}")
        if len({(r['status'], r['objective'])
                for r in native.values()}) != 1:
            raise SystemExit(
                f"compact_table: backend status/objective mismatch on "
                f"{name}: {native}")

        # ---- native CT vs the reified-disjunction oracle --------------
        cfg = solver.SolveConfig.preset(
            "prove", n_lanes=8, eps_target=16, timeout_s=timeout_s)
        rd = solver.Solver(cfg).solve(cmd)
        ref = native["gather"]
        if (rd.status, rd.objective) != (ref["status"], ref["objective"]):
            raise SystemExit(
                f"compact_table: native vs decomposed mismatch on {name}: "
                f"native={ref['status']}/{ref['objective']} "
                f"decomposed={rd.status}/{rd.objective}")
        speedup = rd.wall_s / max(ref["wall_s"], 1e-9)
        rows.append(
            f"compact_table,{name},tables={cmn.n_table},"
            f"arity={cmn.ct_arity},currtable_words={cmn.ct_words},"
            f"bitset_words={cmn.n_words},props/s={props_per_sec:.0f},"
            f"native_speedup={speedup:.1f}x")
        records.append(dict(
            model=name, instance=inst.name,
            n_table=cmn.n_table, ct_arity=cmn.ct_arity,
            currtable_words=cmn.ct_words, bitset_words=cmn.n_words,
            props_native=cmn.total_props, props_decomposed=cmd.total_props,
            root_fixpoint_sweeps=sweeps, props_per_sec=props_per_sec,
            native=native,
            decomposed=dict(status=rd.status, objective=rd.objective,
                            wall_s=rd.wall_s),
            native_speedup=speedup))
    return records


def merge_json(path: str, section: str, records) -> None:
    """Merge `records` into `path` under `section`, preserving whatever
    the propagation smoke already wrote there."""
    doc = {}
    if os.path.exists(path):
        with open(path) as fh:
            doc = json.load(fh)
    doc[section] = records
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)


def write_solver_json(path: str, records) -> None:
    merge_json(path, "solver", records)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="bigger instances (minutes-scale, paper-like)")
    ap.add_argument("--timeout", type=float, default=None)
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--subs", type=int, default=128)
    ap.add_argument("--backend", default="gather",
                    choices=available_backends(),
                    help="propagation backend for the batched engine")
    ap.add_argument("--zoo", action="store_true",
                    help="also run the model-zoo section (all 5 models)")
    ap.add_argument("--zoo-size", choices=("small", "bench"), default=None,
                    help="zoo instance tier (default: bench for --zoo, "
                         "small for --zoo-smoke)")
    ap.add_argument("--zoo-smoke", action="store_true",
                    help="ONLY the zoo on small instances (the make-check "
                         "tier); implies --zoo, skips the RCPSP tables")
    ap.add_argument("--throughput", action="store_true",
                    help="ONLY the session-API serving benchmark: cold/warm "
                         "compile amortization + solve_many instances/s on "
                         "4 knapsack instances, all backends (the make-"
                         "check api tier)")
    ap.add_argument("--superstep-bench", action="store_true",
                    help="ONLY the superstep-orchestration benchmark "
                         "(DESIGN.md §13): ms_per_superstep / "
                         "supersteps_per_sec / dispatches_per_solve per "
                         "backend at chunk=1 host granularity; records go "
                         "to the bench JSON `superstep` section")
    ap.add_argument("--supersteps-per-launch", type=int, default=16,
                    help="K for pallas_resident in --superstep-bench")
    ap.add_argument("--serve-bench", action="store_true",
                    help="ONLY the solver-as-a-service benchmark "
                         "(DESIGN.md §15): fixed-seed open-loop Poisson "
                         "load through the continuous-batching "
                         "scheduler; hard-fails on parity vs sequential "
                         "Solver.solve, on no-batching, and on per-"
                         "bucket recompiles; records go to the bench "
                         "JSON `serving` section")
    ap.add_argument("--serve-requests", type=int, default=50,
                    help="trace length for --serve-bench")
    ap.add_argument("--serve-rate", type=float, default=100.0,
                    help="arrival rate (req/s) for --serve-bench")
    ap.add_argument("--dist-bench", action="store_true",
                    help="ONLY the distributed-EPS benchmark (DESIGN.md "
                         "§14): warm solve wall per mesh size with "
                         "speedup vs mesh=1, steal events and bound-all-"
                         "reduce counts; records go to the bench JSON "
                         "`distributed` section (run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--scale-smoke", action="store_true",
                    help="ONLY the scale-tier benchmark (DESIGN.md §16): "
                         "dense-vs-sparse peak bank-tile bytes for "
                         "nqueens N∈{32..512} (hard-fails unless sparse "
                         "< dense at N ≥ 128), forced dense/sparse "
                         "objective-parity solves (hard-fails on "
                         "mismatch), and large-tier props/s + nodes/s "
                         "probes; records go to the bench JSON `scale` "
                         "section")
    ap.add_argument("--ct-smoke", action="store_true",
                    help="ONLY the Compact-Table benchmark (DESIGN.md "
                         "§17): bitset-carried root-fixpoint props/s + "
                         "currtable/bitset word statics on the "
                         "extensional zoo models, every backend proven "
                         "and ground-checked (hard-fails on any "
                         "status/objective mismatch), native vs "
                         "decompose=True oracle speedup; records go to "
                         "the bench JSON `compact_table` section")
    ap.add_argument("--eps-target", type=int, default=64,
                    help="EPS pool size for the zoo runs (DESIGN.md §9)")
    ap.add_argument("--json", default=None,
                    help="merge the zoo records into this JSON file as its "
                         "`solver` section (and `--throughput` records as "
                         "its `api` section), e.g. "
                         "BENCH_propagation_smoke.json")
    args = ap.parse_args(argv)
    if args.json and not (args.zoo or args.zoo_smoke or args.throughput
                          or args.superstep_bench or args.dist_bench
                          or args.serve_bench or args.scale_smoke
                          or args.ct_smoke):
        ap.error("--json records the zoo/api/superstep/distributed/"
                 "serving/scale/compact_table sections; pass --zoo, "
                 "--zoo-smoke, --throughput, --superstep-bench, "
                 "--dist-bench, --serve-bench, --scale-smoke or "
                 "--ct-smoke")
    timeout = args.timeout or (300 if args.full else 30)

    rows = []
    if args.ct_smoke:
        rows.append("compact_table,model,backend,status,objective,time_s,"
                    "ground_check (+ per-model statics/speedup line)")
        records = run_ct_smoke(rows, timeout_s=timeout if args.timeout
                               else 120.0)
        print("\n".join(rows))
        if args.json:
            merge_json(args.json, "compact_table", records)
        return rows
    if args.scale_smoke:
        rows.append("scale,kind,model,per-kind columns "
                    "(bank_bytes|parity|large)")
        records = run_scale_smoke(rows, timeout_s=timeout if args.timeout
                                  else 120.0)
        print("\n".join(rows))
        if args.json:
            merge_json(args.json, "scale", records)
        return rows
    if args.serve_bench:
        rows.append("serving,backend,requests,rate,buckets,ttfi_p50,"
                    "ttfi_p99,lat_p50,lat_p99,occ_max,live_max,inst_s,"
                    "parity")
        records = run_serve_bench(rows, n_requests=args.serve_requests,
                                  rate_rps=args.serve_rate,
                                  backend=args.backend)
        print("\n".join(rows))
        if args.json:
            merge_json(args.json, "serving", records)
        return rows
    if args.dist_bench:
        rows.append("distributed,mesh,status,objective,warm,speedup,"
                    "steals,allreduce,parity")
        records = run_dist_bench(rows, timeout_s=timeout)
        print("\n".join(rows))
        if args.json:
            merge_json(args.json, "distributed", records)
        return rows
    if args.superstep_bench:
        rows.append("superstep,backend,K,steps,dispatches,ms_per_step,"
                    "steps_per_sec,status")
        records = run_superstep_bench(
            rows, backends=available_backends(), timeout_s=timeout,
            supersteps_per_launch=args.supersteps_per_launch)
        print("\n".join(rows))
        if args.json:
            merge_json(args.json, "superstep", records)
        return rows
    if args.throughput:
        rows.append("api,backend,cold,warm,speedup,batched,sequential,"
                    "parity")
        records = run_throughput(lanes=8, eps_target=16, rows=rows,
                                 backends=available_backends(),
                                 timeout_s=timeout)
        print("\n".join(rows))
        if args.json:
            merge_json(args.json, "api", records)
        return rows
    if not args.zoo_smoke:
        rows.append(
            "suite,solver,instances,feasible,optimal,nodes_per_sec,time_s")
        for kind in ("patterson-like", "j30-like"):
            run_suite(kind, suite(kind, args.full), timeout, args.lanes,
                      args.subs, rows, backend=args.backend)
    records = None
    if args.zoo or args.zoo_smoke:
        rows.append("zoo,model,backend,status,objective,nodes_per_sec,"
                    "time_s,ground_check,props_native/decomposed")
        smoke = (args.zoo_size == "small" if args.zoo_size
                 else args.zoo_smoke)
        # the smoke tier sweeps EVERY backend (objective-parity gate);
        # full --zoo runs stay single-backend (they're minutes-scale)
        be = available_backends() if args.zoo_smoke else args.backend
        records = run_zoo(timeout, args.lanes, args.eps_target, rows,
                          backend=be, smoke=smoke)
    print("\n".join(rows))
    if args.json and records is not None:
        write_solver_json(args.json, records)
    return rows


if __name__ == "__main__":
    main()
