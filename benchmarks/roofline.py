"""Roofline analysis (deliverable g).

Per (arch × shape × mesh): the three roofline terms derived from compiled
HLO on the production mesh —

    compute    = HLO_FLOPs_per_chip / 197e12  (bf16 peak, TPU v5e)
    memory     = HLO_bytes_per_chip / 819e9   (HBM bandwidth)
    collective = collective_bytes_per_chip / 50e9 (ICI per link)

Methodology note (verified empirically, see DESIGN.md §8): XLA's
`cost_analysis()` counts a while/scan body ONCE regardless of trip count,
so naive numbers undercount by ~n_layers.  This harness therefore lowers
two reduced-depth UNROLLED variants of every cell (`unroll_scans()`
replaces every scan — layer stacks, attention chunk loops, SSD chunk
recurrence — with an exact python unroll), and linearly extrapolates
per-unit cost to full depth:

    X_total = X(k_a) + (units_full − k_a) · (X(k_b) − X(k_a)) / (k_b − k_a)

Collective bytes come from the same unrolled HLO text (the scanned text
has the identical undercount).  The full-depth *scanned* compile remains
the memory/fits proof (launch/dryrun.py); the two artifacts are reported
side by side in EXPERIMENTS.md.

MODEL_FLOPS = 6·N·D (train) or 2·N·D (prefill/decode) with N = active
params, D = tokens — the "useful compute" yardstick; the ratio
MODEL_FLOPS/HLO_FLOPS exposes remat/attention/routing overheads.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12      # bf16 / chip, TPU v5e
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link


def reduced_cfg(arch: str, k: int):
    """Config with k repeating units (structure-preserving)."""
    from repro import configs
    from repro.configs.base import EncDecConfig
    cfg = configs.get(arch)
    if cfg.encdec is not None:
        return dataclasses.replace(
            cfg, n_layers=2 * k, encdec=EncDecConfig(k, k))
    if cfg.family == "hybrid":
        pat = len(cfg.rglru.pattern)
        return dataclasses.replace(cfg,
                                   n_layers=pat * k + cfg.n_layers % pat)
    if cfg.moe is not None and cfg.moe.first_dense:
        return dataclasses.replace(cfg, n_layers=k + cfg.moe.first_dense)
    return dataclasses.replace(cfg, n_layers=k)


def unit_counts(arch: str) -> Tuple[int, Tuple[int, int]]:
    """(units_full, (k_a, k_b)) for the extrapolation."""
    from repro import configs
    cfg = configs.get(arch)
    if cfg.encdec is not None:
        return cfg.encdec.enc_layers, (1, 2)
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.rglru.pattern), (1, 2)
    if cfg.moe is not None and cfg.moe.first_dense:
        return cfg.n_layers - cfg.moe.first_dense, (1, 3)
    return cfg.n_layers, (1, 3)


def _cost_lowering(arch: str, shape_name: str, k: int, mesh) -> Dict:
    """Compile a reduced-depth unrolled variant; return per-device costs."""
    import jax
    from repro.launch import dryrun as DR
    from repro import configs
    from repro.nn.scanctl import unroll_scans

    shape = configs.get_shape(shape_name)
    cfg = reduced_cfg(arch, k)
    # big chunks: fewer unrolled attention bodies, identical FLOPs
    ch = min(4096, shape.seq_len)
    if cfg.ssm is not None:
        pass  # ssd chunk scan unrolls exactly; keep production chunk size
    fn, args, outs, donate = DR.build_cell(arch, shape_name, mesh,
                                           chunks=(ch, ch), cfg=cfg)
    from repro.compat import use_mesh
    with unroll_scans():
        with use_mesh(mesh):
            lowered = jax.jit(fn, out_shardings=outs,
                              donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    txt = compiled.as_text()
    coll = DR.collective_bytes(txt)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total"]),
        "coll_breakdown": {c: coll[c] for c in DR._COLLECTIVES},
    }


def model_flops_per_chip(arch: str, shape_name: str, n_chips: int) -> float:
    from repro import configs
    cfg = configs.get(arch)
    shape = configs.get_shape(shape_name)
    n_active = cfg.n_active_params()
    # exclude the embedding *lookup* table (no matmul), keep unembed
    embed_tables = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_eff = n_active - embed_tables + cfg.vocab * cfg.d_model
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        per_tok = 6 * n_eff
    elif shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        per_tok = 2 * n_eff
    else:
        D = shape.global_batch
        per_tok = 2 * n_eff
    return per_tok * D / n_chips


def roofline_cell(arch: str, shape_name: str, multi_pod: bool = False
                  ) -> Optional[Dict]:
    import jax
    from repro import configs
    from repro.configs.base import skip_reason
    from repro.launch.mesh import make_production_mesh

    cfg = configs.get(arch)
    shape = configs.get_shape(shape_name)
    reason = skip_reason(cfg, shape)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": "SKIP", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    full, (ka, kb) = unit_counts(arch)
    t0 = time.time()
    a = _cost_lowering(arch, shape_name, ka, mesh)
    b = _cost_lowering(arch, shape_name, kb, mesh)

    def extrap(key):
        # per-unit delta can be slightly negative when the base (embed/
        # unembed) collectives dominate and layout noise shifts between
        # the two lowerings — clamp: totals can't shrink with depth.
        per = max((b[key] - a[key]) / (kb - ka), 0.0)
        return max(a[key] + (full - ka) * per, a[key], b[key])

    flops = extrap("flops")
    byts = extrap("bytes")
    coll = extrap("coll_bytes")
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_chip(arch, shape_name, n_chips)
    step = max(terms.values())
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "status": "OK", "n_chips": n_chips,
        "per_chip_flops": flops, "per_chip_bytes": byts,
        "per_chip_coll_bytes": coll,
        "coll_breakdown_at_kb": b["coll_breakdown"],
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_per_chip": mf,
        "useful_ratio": round(mf / flops, 4) if flops else None,
        "roofline_frac": round((mf / PEAK_FLOPS) / step, 4) if step else None,
        "analysis_s": round(time.time() - t0, 1),
    }


def solver_roofline(lanes: int = 32, supersteps_per_launch: int = 16
                    ) -> Dict:
    """Superstep roofline for the constraint solver (DESIGN.md §13).

    Two terms bound a superstep of the resident search megakernel on the
    zoo smoke tier:

      memory   = per-launch VMEM traffic / HBM_BW — the state the kernel
                 streams in/out of HBM once per K supersteps (tables +
                 lane state + subproblem pool), amortized over K;
      dispatch = host launch overhead / K — measured per-dispatch cost
                 from the unfused path (`bench_solver --superstep-bench`
                 ms_per_superstep is dominated by it on CPU interpret).

    The unfused path pays BOTH terms every superstep (traffic and a
    dispatch per phase); the resident kernel pays traffic once per
    launch and keeps supersteps in VMEM, so its modeled
    ms_per_superstep(K) = t_kernel + overhead/K — the K-amortization
    curve this function tabulates.
    """
    from repro.core import models as zoo
    from repro.kernels.fixpoint_kernel import vmem_budget

    inst = zoo.small_instance("rcpsp", seed=0)
    cm = zoo.ZOO["rcpsp"].build_model(inst)[0].compile()
    K = supersteps_per_launch
    bud = vmem_budget(cm, lanes, resident=True, max_depth=512,
                      pool_size=64)
    traffic = bud["total"]                    # bytes in+out per launch
    t_mem_launch = traffic / HBM_BW
    # per-dispatch host overhead: order-10µs on a real accelerator
    # (launch latency); the measured CPU-interpret figure lives in
    # BENCH_propagation_smoke.json's `superstep` section
    overhead_s = 10e-6
    curve = {k: round(1e3 * (t_mem_launch / k + overhead_s / k
                             + t_mem_launch), 6)
             for k in (1, 4, 16, 64)}
    rec = {
        "model": inst.name, "lanes": lanes, "K": K,
        "vmem_bytes": {k: int(v) for k, v in bud.items()},
        "launch_traffic_bytes": int(traffic),
        "memory_s_per_launch": round(t_mem_launch, 9),
        "dispatch_overhead_s": overhead_s,
        "modeled_ms_per_superstep_by_K": curve,
        "bottleneck": ("dispatch" if overhead_s > t_mem_launch
                       else "memory"),
    }
    print(f"solver roofline: {inst.name} lanes={lanes} "
          f"VMEM={bud['total']/2**20:.2f}MiB "
          f"traffic={traffic/2**10:.1f}KiB/launch "
          f"bottleneck={rec['bottleneck']}")
    for k, ms in curve.items():
        print(f"  K={k:>3}: modeled {ms:.6f} ms/superstep")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--solver", action="store_true",
                    help="ONLY the solver superstep roofline (DESIGN.md "
                         "§13): VMEM footprint, per-launch HBM traffic "
                         "and the K-amortization curve for the resident "
                         "megakernel")
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--supersteps-per-launch", type=int, default=16)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.solver:
        rec = solver_roofline(
            lanes=args.lanes,
            supersteps_per_launch=args.supersteps_per_launch)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(rec, f, indent=1)
            print("wrote", args.out)
        return [rec]

    from repro import configs
    cells = []
    if args.all:
        cells = [(a, s.name) for a in configs.ARCH_IDS
                 for s in configs.ALL_SHAPES]
    else:
        archs = [args.arch] if args.arch else list(configs.ARCH_IDS)
        shapes = [args.shape] if args.shape else \
            [s.name for s in configs.ALL_SHAPES]
        cells = [(a, s) for a in archs for s in shapes]

    out = []
    for arch, shp in cells:
        rec = roofline_cell(arch, shp, multi_pod=args.multi_pod)
        out.append(rec)
        if rec["status"] == "SKIP":
            print(f"SKIP {arch} × {shp}: {rec['reason']}")
        else:
            print(f"OK {arch} × {shp}: comp={rec['compute_s']*1e3:.2f}ms "
                  f"mem={rec['memory_s']*1e3:.2f}ms "
                  f"coll={rec['collective_s']*1e3:.2f}ms "
                  f"bottleneck={rec['bottleneck']} "
                  f"useful={rec['useful_ratio']} "
                  f"roofline={rec['roofline_frac']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print("wrote", args.out)
    return out


if __name__ == "__main__":
    import os
    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=512"
    main()
